"""L2 model correctness: partial/full factorization against the oracle,
plus the identity-padding property the Rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def spd(seed, n):
    return ref.random_spd(jax.random.PRNGKey(seed), n)


def assert_close(a, b, atol=3e-5, rtol=3e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


@pytest.mark.parametrize("n,k,tile", [(32, 16, 16), (64, 32, 16), (64, 32, 32), (128, 64, 32)])
def test_partial_factor_matches_ref(n, k, tile):
    a = spd(n + k, n)
    got = model.partial_factor(a, k, tile=tile)
    want = ref.ref_partial_factor(a, k)
    for g, w in zip(got, want):
        assert_close(g, w)


@pytest.mark.parametrize("n,panel", [(32, 16), (64, 16), (64, 32), (128, 32)])
def test_full_factor_matches_ref(n, panel):
    a = spd(n, n)
    assert_close(model.full_factor(a, panel=panel, tile=panel), ref.ref_cholesky(a), atol=1e-4, rtol=1e-4)


def test_full_factor_residual():
    a = spd(77, 96)
    l = model.full_factor(a, panel=32, tile=32)
    assert_close(l @ l.T, a, atol=1e-4, rtol=1e-4)


def test_partial_then_full_composes():
    """Eliminating k then factoring the Schur complement equals the full
    factor — the multifrontal invariant the Rust pipeline depends on."""
    n, k = 64, 32
    a = spd(5, n)
    l11, l21, s = model.partial_factor(a, k, tile=16)
    l22 = model.full_factor(s, panel=16, tile=16)
    l = np.zeros((n, n), np.float32)
    l[:k, :k] = l11
    l[k:, :k] = l21
    l[k:, k:] = l22
    assert_close(l, ref.ref_cholesky(a), atol=1e-4, rtol=1e-4)


def test_identity_padding_is_exact():
    """Pad a front with decoupled identity rows/cols inside the eliminated
    block and at the tail: the embedded results must be bit-compatible
    with the unpadded ones (this is DESIGN.md S12, what lets Rust bucket
    arbitrary fronts into the fixed artifact menu)."""
    n, k = 48, 16
    pad_n, pad_k = 64, 32
    a = spd(31, n)
    # build padded front
    ap = np.eye(pad_n, dtype=np.float32)
    # eliminated block occupies [0,k) real + [k,pad_k) identity
    ap[:k, :k] = np.asarray(a[:k, :k])
    rest = n - k  # real trailing size
    ap[pad_k : pad_k + rest, :k] = np.asarray(a[k:, :k])
    ap[:k, pad_k : pad_k + rest] = np.asarray(a[:k, k:])
    ap[pad_k : pad_k + rest, pad_k : pad_k + rest] = np.asarray(a[k:, k:])
    l11p, l21p, sp = model.partial_factor(jnp.asarray(ap), pad_k, tile=16)
    l11, l21, s = ref.ref_partial_factor(a, k)
    assert_close(l11p[:k, :k], l11)
    assert_close(l21p[:rest, :k], l21)
    assert_close(sp[:rest, :rest], s)
    # padding lanes stay exactly identity / zero
    np.testing.assert_allclose(np.asarray(sp[rest:, rest:]), np.eye(pad_n - pad_k - rest), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nk=st.sampled_from([(32, 16), (48, 16), (64, 32)]))
def test_hyp_partial(seed, nk):
    n, k = nk
    a = spd(seed, n)
    got = model.partial_factor(a, k, tile=16)
    want = ref.ref_partial_factor(a, k)
    for g, w in zip(got, want):
        assert_close(g, w, atol=1e-4, rtol=1e-4)


def test_front_flops_monotone():
    assert model.front_flops(64, 32) < model.front_flops(128, 32)
    assert model.front_flops(64, 32) < model.front_flops(64, 64)
    # full elimination equals n^3/3
    assert model.front_flops(96, 96) == pytest.approx(96**3 / 3.0)
