"""Kernel-vs-oracle correctness: the CORE numeric signal for L1.

Every Pallas kernel is compared against the pure-jnp oracle in
``compile.kernels.ref`` over deterministic seeds and hypothesis-driven
shape/tile sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import potrf, trsm, schur_update
from compile.kernels import ref

ATOL = 2e-5
RTOL = 2e-5


def spd(seed, n):
    return ref.random_spd(jax.random.PRNGKey(seed), n)


def assert_close(a, b, atol=ATOL, rtol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------- potrf


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128])
def test_potrf_matches_ref(n):
    a = spd(n, n)
    assert_close(potrf(a), ref.ref_potrf(a))


def test_potrf_identity():
    eye = jnp.eye(16, dtype=jnp.float32)
    assert_close(potrf(eye), eye)


def test_potrf_diagonal():
    d = jnp.diag(jnp.arange(1.0, 9.0, dtype=jnp.float32))
    assert_close(potrf(d), jnp.sqrt(d))


def test_potrf_is_lower_triangular():
    l = np.asarray(potrf(spd(7, 32)))
    assert np.allclose(np.triu(l, 1), 0.0)


def test_potrf_reconstructs_input():
    a = spd(11, 48)
    l = potrf(a)
    assert_close(l @ l.T, a, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------- trsm


@pytest.mark.parametrize("m,k,tile", [(8, 8, 8), (32, 16, 16), (64, 32, 32), (96, 32, 32), (64, 32, 16)])
def test_trsm_matches_ref(m, k, tile):
    a = spd(m * 1000 + k, m + k)
    l11 = ref.ref_potrf(a[:k, :k])
    a21 = a[k:, :k][:m]
    assert_close(trsm(a21, l11, tile=tile), ref.ref_trsm(a21, l11))


def test_trsm_identity_factor():
    # L11 = I  =>  L21 = A21
    a21 = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    eye = jnp.eye(16, dtype=jnp.float32)
    assert_close(trsm(a21, eye, tile=16), a21)


def test_trsm_solves_system():
    # (L21 @ L11^T) must reconstruct A21
    a = spd(5, 64)
    l11 = ref.ref_potrf(a[:32, :32])
    a21 = a[32:, :32]
    l21 = trsm(a21, l11, tile=16)
    assert_close(l21 @ l11.T, a21, atol=1e-4, rtol=1e-4)


def test_trsm_nondivisible_rows_falls_back():
    # m=40 with tile=16 -> falls back to a divisor tile (8)
    a = spd(9, 56)
    l11 = ref.ref_potrf(a[:16, :16])
    a21 = a[16:, :16]
    assert_close(trsm(a21, l11, tile=16), ref.ref_trsm(a21, l11))


# ---------------------------------------------------------------- schur


@pytest.mark.parametrize("m,k,tile", [(16, 16, 8), (32, 32, 16), (64, 32, 32), (64, 64, 16), (128, 64, 32)])
def test_schur_matches_ref(m, k, tile):
    key = jax.random.PRNGKey(m * 7 + k)
    a22 = ref.random_spd(key, m)
    l21 = jax.random.normal(jax.random.PRNGKey(m + k + 1), (m, k), dtype=jnp.float32)
    assert_close(schur_update(a22, l21, tile=tile), ref.ref_schur(a22, l21))


def test_schur_zero_panel_is_identity_update():
    a22 = spd(2, 32)
    z = jnp.zeros((32, 16), jnp.float32)
    assert_close(schur_update(a22, z, tile=16), a22)


def test_schur_rank_one():
    a22 = jnp.zeros((16, 16), jnp.float32)
    v = jnp.arange(16.0, dtype=jnp.float32).reshape(16, 1)
    # tile falls back to divisor of k=1
    assert_close(schur_update(a22, v, tile=16), -v @ v.T)


def test_schur_accumulates_over_k_blocks():
    # k spanning multiple tiles exercises the revisit/accumulate path
    m, k, tile = 32, 64, 16
    a22 = spd(21, m)
    l21 = jax.random.normal(jax.random.PRNGKey(22), (m, k), dtype=jnp.float32)
    assert_close(schur_update(a22, l21, tile=tile), ref.ref_schur(a22, l21))


def test_schur_symmetry_preserved():
    a22 = spd(13, 32)
    l21 = jax.random.normal(jax.random.PRNGKey(14), (32, 32), dtype=jnp.float32)
    s = np.asarray(schur_update(a22, l21, tile=16))
    np.testing.assert_allclose(s, s.T, atol=1e-4)


# --------------------------------------------------- hypothesis sweeps


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.sampled_from([8, 16, 24, 32, 48, 64]),
)
def test_hyp_potrf(seed, n):
    a = spd(seed, n)
    assert_close(potrf(a), ref.ref_potrf(a))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([16, 32, 48, 64]),
    k=st.sampled_from([8, 16, 32]),
    tile=st.sampled_from([8, 16, 32]),
)
def test_hyp_trsm(seed, m, k, tile):
    a = spd(seed, m + k)
    l11 = ref.ref_potrf(a[:k, :k])
    a21 = a[k:, :k][:m]
    assert_close(trsm(a21, l11, tile=tile), ref.ref_trsm(a21, l11))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([8, 16, 32, 64]),
    tile=st.sampled_from([8, 16, 32]),
)
def test_hyp_schur(seed, m, k, tile):
    key = jax.random.PRNGKey(seed)
    a22 = ref.random_spd(key, m)
    l21 = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k), dtype=jnp.float32)
    assert_close(schur_update(a22, l21, tile=tile), ref.ref_schur(a22, l21))
