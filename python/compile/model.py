"""L2: the JAX compute graph for one malleable task of the paper's tree.

A task of the assembly tree is the *partial factorization of a dense
frontal matrix* (paper §3): eliminate the leading ``k`` fully-summed
columns of an ``n x n`` symmetric front and produce

  * the panel factor ``(L11, L21)`` — rows of the final sparse factor, and
  * the Schur complement ``S = A22 - L21 L21^T`` — the contribution block
    that is extend-added into the parent front.

The functions here orchestrate the L1 Pallas kernels and are the units
that ``aot.py`` lowers to HLO text for the Rust runtime.  Shapes are
static per variant; the Rust coordinator pads real fronts into the
nearest variant (identity padding inside the eliminated block and at the
trailing end is exact for Cholesky — see DESIGN.md S12).
"""

import jax.numpy as jnp

from .kernels import potrf, trsm, schur_update
from .kernels.cholesky import DEFAULT_TILE


def partial_factor(front, k, *, tile=DEFAULT_TILE, interpret=True):
    """Eliminate the leading ``k`` columns of the ``n x n`` ``front``.

    Returns ``(L11, L21, S)``.  Requires ``0 < k < n``.
    """
    n = front.shape[0]
    assert 0 < k < n, (k, n)
    a11 = front[:k, :k]
    a21 = front[k:, :k]
    a22 = front[k:, k:]
    l11 = potrf(a11, interpret=interpret)
    l21 = trsm(a21, l11, tile=tile, interpret=interpret)
    s = schur_update(a22, l21, tile=tile, interpret=interpret)
    return l11, l21, s


def full_factor(front, *, panel=DEFAULT_TILE, tile=DEFAULT_TILE, interpret=True):
    """Blocked dense Cholesky of the whole front (root tasks, ``k == n``).

    A static Python loop over panel steps — each step is a
    ``partial_factor`` with shrinking static shapes, so the lowered HLO
    is one straight-line module (no dynamic shapes on the request path).
    Returns the lower factor ``L`` as a single (n, n) array.
    """
    n = front.shape[0]
    l_full = jnp.zeros((n, n), front.dtype)
    trailing = front
    off = 0
    while n - off > panel:
        k = panel
        l11, l21, s = partial_factor(
            trailing, k, tile=tile, interpret=interpret
        )
        col = jnp.concatenate([l11, l21], axis=0)
        l_full = l_full.at[off:, off : off + k].set(col)
        trailing = s
        off += k
    # last pivot block
    l11 = potrf(trailing, interpret=interpret)
    l_full = l_full.at[off:, off:].set(l11)
    # Panels left of the diagonal already carry exact zeros above it;
    # enforce the triangle once for bitwise stability.
    return jnp.tril(l_full)


def front_flops(n, k):
    """Flop count of a partial factorization (used by the scheduler's
    task lengths and by the kernel-DAG simulator's cost model).

    potrf: k^3/3, trsm: (n-k) k^2, schur: (n-k)^2 k.
    """
    m = n - k
    return k**3 / 3.0 + m * k**2 + m * m * k
