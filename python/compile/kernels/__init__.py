"""L1 Pallas kernels for frontal-matrix partial factorization.

``potrf`` / ``trsm`` (cholesky.py) and ``schur_update`` (schur.py) are the
compute hot-spot of the paper's malleable tasks; ``ref`` holds the
pure-jnp oracle they are tested against.
"""

from .cholesky import potrf, trsm, DEFAULT_TILE
from .schur import schur_update, vmem_footprint_bytes, mxu_utilization_estimate

__all__ = [
    "potrf",
    "trsm",
    "schur_update",
    "DEFAULT_TILE",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
]
