"""Pure-jnp reference oracle for the frontal-factorization kernels.

This is the correctness anchor for the whole numeric stack: the Pallas
kernels (cholesky.py / schur.py) and the L2 model (model.py) are tested
against these functions, and the Rust side re-validates end-to-end by
checking ``A = L L^T`` residuals after a multifrontal run.

Everything here is straight-line jax.numpy — no Pallas, no tricks — so a
bug can only live on one side of the comparison.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def ref_potrf(a):
    """Cholesky factor (lower) of a symmetric positive-definite block."""
    return jnp.linalg.cholesky(a)


def ref_trsm(a21, l11):
    """Solve ``X @ L11^T = A21`` for X (the sub-diagonal panel L21)."""
    # X L11^T = A21  <=>  L11 X^T = A21^T
    return jsl.solve_triangular(l11, a21.T, lower=True).T


def ref_schur(a22, l21):
    """Schur complement update ``A22 - L21 @ L21^T``."""
    return a22 - l21 @ l21.T


def ref_partial_factor(front, k):
    """Partial Cholesky factorization eliminating the leading ``k`` columns.

    Returns ``(L11, L21, S)`` where ``L11`` is the k-by-k lower Cholesky
    factor of the pivot block, ``L21`` the (n-k)-by-k panel, and ``S`` the
    trailing (n-k)-by-(n-k) Schur complement.
    """
    a11 = front[:k, :k]
    a21 = front[k:, :k]
    a22 = front[k:, k:]
    l11 = ref_potrf(a11)
    l21 = ref_trsm(a21, l11)
    s = ref_schur(a22, l21)
    return l11, l21, s


def ref_cholesky(a):
    """Full dense Cholesky (lower) — oracle for the K == N variants."""
    return jnp.linalg.cholesky(a)


def random_spd(key, n, dtype=jnp.float32):
    """A well-conditioned random SPD matrix (for tests)."""
    import jax

    m = jax.random.normal(key, (n, n), dtype=jnp.float32)
    a = m @ m.T / n + 2.0 * jnp.eye(n, dtype=jnp.float32)
    return a.astype(dtype)
