"""L1 Pallas kernels: blocked Cholesky panel factorization.

The paper's tasks are *partial factorizations of dense frontal matrices*
(Section 3, Figure 1).  On the paper's 40-core CPU these were tiled BLAS
kernels scheduled by StarPU; the TPU re-thinking (DESIGN.md
§Hardware-Adaptation) expresses the same tile graph as Pallas kernels:

* ``potrf``  — Cholesky of the pivot block (VPU-bound, one grid cell);
* ``trsm``   — triangular panel solve, grid over row blocks of the panel
  (each block is an independent VMEM-resident solve);
* ``schur``  (in schur.py) — the MXU hot-spot, a tiled
  ``C -= L @ L^T`` matmul.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the kernels to
plain HLO that any backend runs.  The BlockSpecs are nevertheless written
exactly as a real TPU deployment would tile them (``TILE`` aligned to the
128x128 MXU when the operand is large enough).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edge for the panel solve.  On a real TPU this is 128 (MXU edge);
# the artifacts in this repo are built with whatever divides the variant
# sizes (DEFAULT_TILE or smaller), which keeps interpret-mode runtimes
# reasonable while preserving the HBM<->VMEM schedule structure.
DEFAULT_TILE = 128


def _pick_tile(n, tile):
    """Largest tile <= ``tile`` that divides ``n`` (fall back to n)."""
    t = min(tile, n)
    while n % t != 0:
        t -= 1
    return t


def chol_jnp(a):
    """Pure-jnp left-looking Cholesky (no LAPACK custom-calls).

    AOT constraint: ``jnp.linalg.cholesky`` lowers to a
    ``lapack_*potrf`` custom-call with the TYPED_FFI API on CPU, which
    the runtime's xla_extension 0.5.1 rejects ("Unknown custom-call API
    version"). A `fori_loop` over columns lowers to a plain HLO While —
    portable everywhere. One matvec per column: O(n³) total, identical
    arithmetic to the textbook algorithm.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        # s = Σ_{t<j} L[:,t]·L[j,t] — columns ≥ j are still zero in l.
        s = l @ l[j]
        d = jnp.sqrt(a[j, j] - s[j])
        col = (a[:, j] - s) / d
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(d)
        return l.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def solve_triangular_jnp(l, b):
    """Pure-jnp forward substitution for ``X @ L^T = B``
    (i.e. ``X = B L^{-T}``), column by column — same custom-call-free
    rationale as :func:`chol_jnp`. ``l``: (k, k) lower, ``b``: (m, k).
    """
    k = l.shape[0]

    def body(j, x):
        # x columns >= j are still zero: x @ l[j] sums t < j terms.
        s = x @ l[j]
        col = (b[:, j] - s) / l[j, j]
        return x.at[:, j].set(col)

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def _potrf_kernel(a_ref, o_ref):
    """Single-block Cholesky.

    The pivot block lives entirely in VMEM; the factorization is
    expressed with jax ops which interpret-mode Pallas traces into the
    surrounding HLO module.
    """
    o_ref[...] = chol_jnp(a_ref[...])


def potrf(a, *, interpret=True):
    """Cholesky factor (lower) of the SPD pivot block ``a`` (k x k).

    One grid cell: the pivot block of a front is small relative to the
    trailing submatrix (it is the O(k^3) part of an O(n^2 k) task) and is
    kept VMEM-resident.
    """
    k = a.shape[0]
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((k, k), a.dtype),
        interpret=interpret,
    )(a)


def _trsm_kernel(l11_ref, a_ref, o_ref):
    """One row-block of the panel solve ``X @ L11^T = A21``."""
    l11 = l11_ref[...]
    a = a_ref[...]
    # forward substitution on the VPU (custom-call-free)
    o_ref[...] = solve_triangular_jnp(l11, a)


def trsm(a21, l11, *, tile=DEFAULT_TILE, interpret=True):
    """Panel solve ``L21 = A21 @ L11^{-T}`` tiled over row blocks.

    Grid = row blocks of the (m x k) panel; every block re-reads the
    (k x k) factor ``L11`` (broadcast BlockSpec) and solves its own tile —
    the exact analogue of the per-tile TRSM tasks in the paper's Figure 1
    kernel DAG.
    """
    m, k = a21.shape
    t = _pick_tile(m, tile)
    grid = (m // t,)
    return pl.pallas_call(
        _trsm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((t, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), a21.dtype),
        interpret=interpret,
    )(l11, a21)


@functools.lru_cache(maxsize=None)
def _cached_tile(n, tile):
    return _pick_tile(n, tile)
