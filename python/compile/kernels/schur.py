"""L1 Pallas kernel: tiled Schur-complement update (the MXU hot-spot).

``S = A22 - L21 @ L21^T`` carries >= 90% of the flops of a partial
factorization for realistic front shapes; this is the kernel the paper's
speedup measurements (Figures 2-6) are dominated by, and the one a TPU
port must land on the MXU.

Mapping (DESIGN.md §Hardware-Adaptation): grid = (i, j, k) over TILE-sized
output tiles and the contraction dimension; the accumulator tile stays in
VMEM across the k-steps (output BlockSpec ignores k, Pallas keeps the
block resident), operand tiles stream HBM->VMEM per step — the double
buffering a real Mosaic lowering would insert is implicit in the
BlockSpec schedule.  ``preferred_element_type=float32`` keeps the MXU
accumulating in f32 even for bf16 operands.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cholesky import DEFAULT_TILE, _pick_tile


def _schur_kernel(a22_ref, l_ref, lt_ref, o_ref, *, nk):
    """Grid (i, j, k): o[i,j] = a22[i,j] - sum_k l[i,k] @ l[j,k]^T."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = a22_ref[...]

    part = jnp.dot(
        l_ref[...], lt_ref[...].T, preferred_element_type=jnp.float32
    )
    o_ref[...] = o_ref[...] - part.astype(o_ref.dtype)


def schur_update(a22, l21, *, tile=DEFAULT_TILE, interpret=True):
    """Tiled ``A22 - L21 @ L21^T`` with f32 accumulation.

    ``a22``: (m, m) trailing submatrix, ``l21``: (m, k) panel factor.
    """
    m, kdim = a22.shape[0], l21.shape[1]
    tm = _pick_tile(m, tile)
    tk = _pick_tile(kdim, tile)
    grid = (m // tm, m // tm, kdim // tk)
    return pl.pallas_call(
        lambda a, l, lt, o: _schur_kernel(a, l, lt, o, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tm), lambda i, j, k: (i, j)),
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tm, tk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), a22.dtype),
        interpret=interpret,
    )(a22, l21, l21)


def vmem_footprint_bytes(m, k, tile=DEFAULT_TILE, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (for §Perf).

    Three operand tiles + one accumulator tile resident at a time.
    """
    tm = _pick_tile(m, tile)
    tk = _pick_tile(k, tile)
    return dtype_bytes * (tm * tm + 2 * tm * tk + tm * tm)


def mxu_utilization_estimate(m, k, tile=DEFAULT_TILE):
    """Fraction of MXU-shaped work per grid step (for §Perf).

    A 128x128 MXU is fully fed when both tile edges are multiples of 128;
    smaller tiles pad and waste the systolic array proportionally.
    """
    tm = _pick_tile(m, tile)
    tk = _pick_tile(k, tile)
    eff_m = tm / (128 * -(-tm // 128))
    eff_k = tk / (128 * -(-tk // 128))
    return eff_m * eff_k
