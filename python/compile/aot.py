"""AOT compile path: lower the L2 model to HLO **text** artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client.  Text — NOT ``lowered.compile().serialize()`` and NOT the
serialized ``HloModuleProto`` — is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Variants: the multifrontal coordinator pads every real front into one of
a fixed menu of static shapes ``(N, K)`` (front order, eliminated
columns).  Identity padding is exact for Cholesky, so the menu trades a
bounded flop overhead (< 2x in the worst case, measured in
EXPERIMENTS.md) for a finite set of compiled executables — the same
trade vLLM-style servers make with bucketed sequence lengths.

Usage:  python -m compile.aot --out-dir ../artifacts [--tile 32]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (N, K) menu. K == N//2 covers interior supernodes (eliminate half,
# pass half up); K == N covers roots / fully-summed fronts.  Tile size
# divides every N and K.
PARTIAL_VARIANTS = [(32, 16), (64, 32), (128, 64), (256, 128)]
FULL_VARIANTS = [32, 64, 128, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_partial(n, k, tile):
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    fn = lambda f: model.partial_factor(f, k, tile=tile)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_full(n, tile):
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    fn = lambda f: (model.full_factor(f, panel=tile, tile=tile),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--tile",
        type=int,
        default=32,
        help="Pallas tile edge baked into the artifacts (128 on real TPU;"
        " 32 keeps interpret-mode CPU artifacts fast)",
    )
    ap.add_argument("--out", default=None, help="compat: single-file mode")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for n, k in PARTIAL_VARIANTS:
        name = f"partial_n{n}_k{k}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_partial(n, k, args.tile)
        with open(path, "w") as f:
            f.write(text)
        # outputs: L11 (k,k), L21 (n-k,k), S (n-k,n-k)
        manifest.append(
            f"{name} kind=partial n={n} k={k} tile={args.tile} outputs=3"
        )
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)
    for n in FULL_VARIANTS:
        name = f"full_n{n}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_full(n, args.tile)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} kind=full n={n} k={n} tile={args.tile} outputs=1")
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# malltree AOT artifact manifest: name key=value...\n")
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} variants", file=sys.stderr)


if __name__ == "__main__":
    main()
