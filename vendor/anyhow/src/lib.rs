//! Offline, dependency-free subset of the `anyhow` crate API.
//!
//! The build container has no crates.io access, so this vendored shim
//! provides exactly the surface the repository uses:
//!
//! * [`Error`] — a message + source chain, `Display`/`Debug`, and a
//!   blanket `From<E: std::error::Error + Send + Sync + 'static>`;
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (both std errors and `anyhow::Error`) and on `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Semantics follow the real crate closely enough that swapping the
//! path dependency for crates.io `anyhow = "1"` requires no code
//! changes. Deliberately not implemented: downcasting, backtraces.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an optional chain of sources.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M>(message: M) -> Error
    where
        M: Display + Send + Sync + 'static,
    {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C>(self, context: C) -> Error
    where
        C: Display + Send + Sync + 'static,
    {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message (no chain).
    pub fn to_msg(&self) -> &str {
        &self.msg
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        // `{:#}` renders the whole chain, like the real crate.
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(mut cur) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            loop {
                write!(f, "\n    {}", cur.msg)?;
                match cur.source.as_deref() {
                    Some(next) => cur = next,
                    None => break,
                }
            }
        }
        Ok(())
    }
}

// Mirrors the real crate: every std error converts into `Error`. The
// impl cannot overlap `From<Error> for Error` because `Error` does not
// implement `std::error::Error` (and, being a local type under a
// foreign trait, never can downstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain: Vec<String> = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err = Error { msg: e.to_string(), source: None };
        // rebuild the source chain innermost-first
        for msg in chain.into_iter().rev() {
            let inner = Error { msg, source: None };
            attach_innermost(&mut err, inner);
        }
        err
    }
}

fn attach_innermost(err: &mut Error, inner: Error) {
    let mut cur = err;
    loop {
        if cur.source.is_none() {
            cur.source = Some(Box::new(inner));
            return;
        }
        cur = cur.source.as_mut().unwrap();
    }
}

mod ext {
    use super::*;

    /// Internal adapter so [`Context`] works uniformly for std errors
    /// and for `anyhow::Error` itself (the real crate uses the same
    /// non-overlapping-impl trick).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> Error;
    }

    impl<E> IntoAnyhow for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with a new message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with a lazily evaluated message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: ext::IntoAnyhow,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| ext::IntoAnyhow::into_anyhow(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| ext::IntoAnyhow::into_anyhow(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_err() -> Result<i32> {
        let n: i32 = "banana".parse().context("parsing banana")?;
        Ok(n)
    }

    #[test]
    fn context_on_std_error() {
        let e = parse_err().unwrap_err();
        assert_eq!(e.to_string(), "parsing banana");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<i32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let r: Result<i32> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert!(format!("{e:?}").contains("inner"));
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
