//! Quickstart: build a small malleable task tree, compute the optimal
//! Prasanna–Musicus schedule, compare against the α-unaware baselines,
//! and show the §7 `Agreg` transformation (paper Figure 15 flavor).
//!
//! Run: `cargo run --release --example quickstart`

use malltree::model::{dot, SpGraph, TaskTree};
use malltree::sched::{
    agreg, divisible::divisible_makespan_tree, pm::PmSolution, proportional_makespan,
    PmSchedule, Profile,
};

fn main() -> anyhow::Result<()> {
    // The paper's running shape: a root with two subtrees, one bushy.
    //           T0 (root, L=2)
    //          /            \
    //       T1 (L=3)       T2 (L=8)
    //      /   |   \
    //   T3(4) T4(5) T5(0.2)
    let tree = TaskTree::from_parents(
        &[0, 0, 0, 1, 1, 1],
        &[2.0, 3.0, 8.0, 4.0, 5.0, 0.2],
    )?;
    let alpha = 0.9; // the value the paper measures on real kernels
    let p = 4.0;
    let profile = Profile::constant(p);

    println!("tree ({} tasks, total work {}):", tree.len(), tree.total_work());
    println!("{}", dot::tree_to_dot(&tree));

    // --- the optimal (PM) schedule -------------------------------------
    let pm = PmSchedule::for_tree(&tree, alpha, &profile);
    println!("PM equivalent length L_G = {:.4}", pm.solution.total_len);
    println!("PM makespan on p={p}: {:.4}", pm.schedule.makespan);
    println!("task spans (constant ratios, Theorem 6):");
    for s in &pm.schedule.spans {
        println!(
            "  T{}: [{:.3}, {:.3})  ratio {:.3} ({:.2} processors)",
            s.task,
            s.start,
            s.finish,
            s.ratio,
            s.ratio * p
        );
    }
    // validity per the paper's three conditions
    pm.schedule.validate(&tree, alpha, &profile, 1e-9)?;
    println!("schedule valid: resource, completion, precedence all hold\n");

    // --- baselines -------------------------------------------------------
    let g = SpGraph::from_tree(&tree);
    let prop = proportional_makespan(&g, alpha, p);
    let div = divisible_makespan_tree(&tree, alpha, p);
    println!("baseline makespans (α-unaware):");
    println!("  Proportional (Pothen–Sun): {prop:.4}  (+{:.1}%)",
        100.0 * (prop - pm.schedule.makespan) / pm.schedule.makespan);
    println!("  Divisible (sequential):    {div:.4}  (+{:.1}%)\n",
        100.0 * (div - pm.schedule.makespan) / pm.schedule.makespan);

    // --- Agreg (§7): no task below one processor ------------------------
    let sol = PmSolution::solve(&g, alpha);
    println!(
        "smallest PM share before Agreg: {:.3} processors (task T5 is tiny)",
        sol.min_task_share(&g, p)
    );
    let (rewritten, stats) = agreg(&g, alpha, p);
    let sol2 = PmSolution::solve(&rewritten, alpha);
    println!(
        "after Agreg ({} iteration(s), {} branch(es) serialized): min share {:.3}",
        stats.iterations,
        stats.moved,
        sol2.min_task_share(&rewritten, p)
    );
    println!(
        "makespan cost of the rewrite: {:.4} -> {:.4}",
        sol.makespan_const(p),
        sol2.makespan_const(p)
    );
    println!("\nrewritten SP graph:\n{}", dot::sp_to_dot(&rewritten.normalized()));
    Ok(())
}
