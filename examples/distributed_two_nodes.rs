//! Distributed-memory scheduling demo (paper §6): two multicore nodes,
//! tasks may not span nodes.
//!
//! * Theorem 7's Partition gadget: watch the scheduling problem decide
//!   PARTITION instances;
//! * Algorithm 11 on an assembly tree (homogeneous nodes): measured
//!   ratio vs the `(4/3)^α` guarantee;
//! * Algorithm 12 on independent tasks (heterogeneous nodes): λ sweep
//!   vs the exhaustive optimum.
//!
//! Run: `cargo run --release --example distributed_two_nodes`

use malltree::dist::{
    het_schedule, homog_approx, independent_optimal, partition_reduction,
};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let alpha = 0.9;

    println!("== Theorem 7: scheduling decides PARTITION ==");
    for (a, desc) in [
        (vec![3u64, 1, 2, 2], "perfect partition exists ({3,1} / {2,2})"),
        (vec![3u64, 1, 1], "no perfect partition"),
    ] {
        let (lens, p, t) = partition_reduction(&a, alpha);
        let (_, opt) = independent_optimal(&lens, alpha, p, p);
        println!(
            "  a={a:?} ({desc}): optimal two-node makespan {opt:.6} vs deadline {t} -> {}",
            if opt <= t + 1e-9 { "YES instance" } else { "NO instance" }
        );
    }

    println!("\n== Algorithm 11: trees on two homogeneous nodes ==");
    for k in [16usize, 24, 32] {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4)?;
        for p in [4.0, 8.0, 20.0] {
            let s = homog_approx(&at.tree, alpha, p);
            let guarantee = (4.0f64 / 3.0).powf(alpha);
            println!(
                "  grid {k:>2}x{k:<2} p={p:>4}: makespan {:.4e}, / lower-bound = {:.4} (guarantee {:.4}, {} phases)",
                s.makespan,
                s.makespan / s.lower_bound,
                guarantee,
                s.phases
            );
        }
    }

    println!("\n== Algorithm 12: independent tasks on (p, q) nodes ==");
    let mut rng = Rng::new(42);
    let lens: Vec<f64> = (0..12).map(|_| rng.log_uniform(1.0, 100.0)).collect();
    let (p, q) = (12.0, 4.0);
    let (_, opt) = independent_optimal(&lens, alpha, p, q);
    println!("  12 tasks, p={p}, q={q}: exhaustive optimum {opt:.4}");
    for lambda in [2.0, 1.5, 1.2, 1.05, 1.01] {
        let s = het_schedule(&lens, alpha, p, q, lambda);
        println!(
            "  λ={lambda:<5}: makespan {:.4}  ratio {:.4}  (|on p-node| = {})",
            s.makespan,
            s.makespan / opt,
            s.on_p.len()
        );
        anyhow::ensure!(s.makespan <= lambda * opt * (1.0 + 1e-9), "λ-guarantee violated");
    }
    println!("\nOK: all guarantees hold");
    Ok(())
}
