//! Regenerate every table and figure of the paper in one run
//! (reduced sweeps — the full-size versions live in `cargo bench`,
//! one bench target per artifact; see DESIGN.md §5 for the index).
//!
//! Run: `cargo run --release --example paper_figures`

use malltree::cli::run;

fn main() -> anyhow::Result<()> {
    run(vec!["figures".to_string()])
}
