//! End-to-end driver (the EXPERIMENTS.md E14 run): a *real* sparse
//! Cholesky factorization through the full three-layer stack.
//!
//! 1. Generate a 2D grid Laplacian (a real PDE matrix), order it with
//!    nested dissection, run symbolic analysis → assembly tree of
//!    malleable tasks (Layer 3 substrates).
//! 2. Compute the optimal PM schedule and the baselines (the paper's
//!    contribution).
//! 3. Execute the schedule: every supernode's partial frontal
//!    factorization runs through the AOT-compiled Pallas kernels on the
//!    PJRT CPU client (Layers 1+2), streamed as one accelerator queue;
//!    a pure-Rust parallel run cross-checks the numbers.
//! 4. Verify `‖PAPᵀ − LLᵀ‖_F / ‖A‖_F` and report makespans + wall time.
//!
//! Run: `make artifacts && cargo run --release --example factorize_grid [-- k=24 pjrt=1]`

use std::sync::Arc;

use malltree::exec::{execute_parallel, execute_serial};
use malltree::frontal::{multifrontal, PjrtBackend, RustBackend};
use malltree::model::SpGraph;
use malltree::runtime::Runtime;
use malltree::sched::{
    divisible::divisible_makespan_tree, proportional_makespan, PmSchedule, Profile,
};
use malltree::sparse::{gen, order, symbolic};

fn arg(name: &str, default: usize) -> usize {
    std::env::args()
        .find_map(|a| a.strip_prefix(&format!("{name}=")).map(|v| v.parse().ok()))
        .flatten()
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let k = arg("k", 24);
    let use_pjrt = arg("pjrt", 1) != 0;
    let workers = arg("workers", 4);
    let alpha = 0.9;
    let p = 8.0;

    println!("== analysis ==");
    let a = gen::grid_laplacian_2d(k);
    let perm = order::nested_dissection_2d(k);
    let at = symbolic::analyze(&a, &perm, 4)?;
    let ap = a.permute_sym(&at.symbolic.perm)?;
    let widest = at
        .symbolic
        .supernodes
        .iter()
        .map(|s| s.front_order())
        .max()
        .unwrap();
    println!(
        "grid {k}x{k}: n={}, nnz={}, {} supernodes, widest front {widest}, {:.3e} flops",
        a.n,
        a.nnz(),
        at.tree.len(),
        at.tree.total_work()
    );

    println!("\n== scheduling (alpha={alpha}, p={p}) ==");
    let profile = Profile::constant(p);
    let pm = PmSchedule::for_tree(&at.tree, alpha, &profile);
    pm.schedule.validate(&at.tree, alpha, &profile, 1e-9)?;
    let g = SpGraph::from_tree(&at.tree);
    let prop = proportional_makespan(&g, alpha, p);
    let div = divisible_makespan_tree(&at.tree, alpha, p);
    println!("PM makespan           : {:.4e} (optimal, Theorem 6)", pm.schedule.makespan);
    println!(
        "Proportional makespan : {:.4e} (+{:.2}%)",
        prop,
        100.0 * (prop - pm.schedule.makespan) / pm.schedule.makespan
    );
    println!(
        "Divisible makespan    : {:.4e} (+{:.2}%)",
        div,
        100.0 * (div - pm.schedule.makespan) / pm.schedule.makespan
    );

    println!("\n== numeric execution ==");
    // Reference: parallel pure-Rust work crew.
    let (fact_rust, report_rust) =
        execute_parallel(&at, &ap, &pm.schedule, &RustBackend::default(), workers)?;
    println!("rust  | {}", report_rust.render());
    let r_rust = multifrontal::residual(&at, &ap, &fact_rust);
    println!("rust  | residual = {r_rust:.3e}");
    anyhow::ensure!(r_rust < 1e-10, "rust backend residual too large");

    if use_pjrt {
        // The TPU-shaped path: AOT HLO artifacts on the PJRT CPU client.
        let rt = Arc::new(Runtime::cpu(std::path::Path::new("artifacts"))?);
        println!("pjrt  | platform {}", rt.platform());
        let n_compiled = rt.warm_up()?;
        println!("pjrt  | compiled {n_compiled} kernel variants");
        let backend = PjrtBackend::new(rt);
        anyhow::ensure!(
            widest <= backend.max_front(),
            "widest front {widest} exceeds artifact menu {}; increase aot.py variants",
            backend.max_front()
        );
        let (fact_pjrt, report_pjrt) = execute_serial(&at, &ap, &pm.schedule, &backend)?;
        println!("pjrt  | {}", report_pjrt.render());
        let r_pjrt = multifrontal::residual(&at, &ap, &fact_pjrt);
        println!("pjrt  | residual = {r_pjrt:.3e}");
        anyhow::ensure!(r_pjrt < 1e-3, "pjrt backend residual too large (f32 path)");

        // cross-check the two backends against each other
        let mut max_dev = 0.0f64;
        for (pa, pb) in fact_rust.panels.iter().zip(&fact_pjrt.panels) {
            for (x, y) in pa.iter().zip(pb) {
                max_dev = max_dev.max((x - y).abs() / x.abs().max(1.0));
            }
        }
        println!("pjrt  | max relative deviation vs rust backend = {max_dev:.3e}");
        anyhow::ensure!(max_dev < 1e-3, "backends disagree");
    }

    println!("\nOK: end-to-end factorization verified");
    Ok(())
}
