//! Step processor profiles (paper §4-§5): the PM schedule stays optimal
//! when the number of available processors varies over time — the
//! equivalent-task makespan is computed through θ(t) = ∫ p(x)^α dx.
//!
//! This example schedules the same assembly tree under several
//! profiles and verifies Theorem 6's invariants numerically.
//!
//! Run: `cargo run --release --example processor_profiles`

use malltree::sched::{PmSchedule, Profile};
use malltree::sparse::{gen, order, symbolic};

fn main() -> anyhow::Result<()> {
    let alpha = 0.9;
    let a = gen::grid_laplacian_2d(20);
    let perm = order::nested_dissection_2d(20);
    let at = symbolic::analyze(&a, &perm, 4)?;
    println!(
        "tree: {} tasks, total flops {:.3e}",
        at.tree.len(),
        at.tree.total_work()
    );

    let profiles: Vec<(&str, Profile)> = vec![
        ("constant 40", Profile::constant(40.0)),
        ("constant 10", Profile::constant(10.0)),
        (
            "ramp up 10→20→40",
            Profile::steps(&[(2e3, 10.0), (2e3, 20.0), (1.0, 40.0)])?,
        ),
        (
            "night dip 40→8→40",
            Profile::steps(&[(2e3, 40.0), (4e3, 8.0), (1.0, 40.0)])?,
        ),
    ];

    for (name, profile) in &profiles {
        let pm = PmSchedule::for_tree(&at.tree, alpha, profile);
        pm.schedule.validate(&at.tree, alpha, profile, 1e-6)?;
        // Theorem 6: the whole tree behaves as one task of length L_G
        let equiv_completion = profile.completion(alpha, pm.solution.total_len);
        println!(
            "{name:>20}: makespan {:.4e} (equivalent-task completion {:.4e}, ratio {:.6})",
            pm.schedule.makespan,
            equiv_completion,
            pm.schedule.makespan / equiv_completion
        );
        anyhow::ensure!(
            (pm.schedule.makespan - equiv_completion).abs() < 1e-6 * equiv_completion,
            "Theorem 6 violated"
        );
    }
    println!("\nOK: PM optimality verified under every profile");
    Ok(())
}
