//! Figure 14 reproduction: same protocol as Figure 13 with
//! p(t) = 100. Paper: "results on average in a 25% (resp. 10%)
//! increase in the relative distance with Proportional (resp.
//! Divisible)" compared to p = 40.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::model::SpGraph;
use malltree::sched::relative_distances_graph;
use malltree::metrics::{BoxplotRow, Table};
use malltree::workload::{dataset, DatasetSpec};

fn main() {
    header("fig14", "PM vs Divisible/Proportional, p(t) = 100 (boxplot rows)");
    let trees = env_usize("TREES", 600);
    let max_nodes = env_usize("MAXNODES", 50_000);
    let spec = DatasetSpec {
        random_trees: trees,
        min_nodes: 2_000,
        max_nodes,
        include_analysis_trees: true,
        seed: 0xDA7A,
    };
    let corpus = dataset(&spec);
    let graphs: Vec<SpGraph> = corpus.iter().map(|(_, t)| SpGraph::from_tree(t)).collect();
    println!("corpus: {} trees, p = 100", corpus.len());

    let mut table = Table::new(&[
        "alpha", "strategy", "d10", "q25", "median", "q75", "d90", "mean",
    ]);
    // also track means at both p for the paper's cross-figure claim
    let mut mean40 = Vec::new();
    let mut mean100 = Vec::new();
    let (_, secs) = timed(|| {
        for alpha in [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0] {
            let mut div100 = Vec::new();
            let mut prop100 = Vec::new();
            let mut div40 = Vec::new();
            let mut prop40 = Vec::new();
            for g in &graphs {
                let (d, pr) = relative_distances_graph(g, alpha, 100.0);
                div100.push(d);
                prop100.push(pr);
                let (d, pr) = relative_distances_graph(g, alpha, 40.0);
                div40.push(d);
                prop40.push(pr);
            }
            for (name, data) in [("Divisible", &div100), ("Proportional", &prop100)] {
                let r = BoxplotRow::from_data(data);
                table.row(&[
                    format!("{alpha:.2}"),
                    name.to_string(),
                    format!("{:.2}", r.d10),
                    format!("{:.2}", r.q25),
                    format!("{:.2}", r.median),
                    format!("{:.2}", r.q75),
                    format!("{:.2}", r.d90),
                    format!("{:.2}", r.mean),
                ]);
            }
            if alpha < 1.0 {
                mean40.push((BoxplotRow::from_data(&div40).mean, BoxplotRow::from_data(&prop40).mean));
                mean100.push((BoxplotRow::from_data(&div100).mean, BoxplotRow::from_data(&prop100).mean));
            }
        }
    });
    print!("{}", table.render());
    let inc = |a: f64, b: f64| 100.0 * (b - a) / a.max(1e-9);
    let div_inc: f64 = mean40
        .iter()
        .zip(&mean100)
        .map(|((d40, _), (d100, _))| inc(*d40, *d100))
        .sum::<f64>()
        / mean40.len() as f64;
    let prop_inc: f64 = mean40
        .iter()
        .zip(&mean100)
        .map(|((_, p40), (_, p100))| inc(*p40, *p100))
        .sum::<f64>()
        / mean40.len() as f64;
    println!(
        "relative-distance increase p=40 → p=100: Divisible {div_inc:+.1}%, Proportional {prop_inc:+.1}%"
    );
    println!("(paper: ≈ +10% Divisible, ≈ +25% Proportional)");
    println!("sweep wall time: {secs:.1}s");
}
