//! §Fault harness: fault-tolerant elastic scheduling (DESIGN.md §13).
//!
//! Two measurements land in `BENCH_fault.json`:
//!
//! * **DES failure replay** — for each tree family × platform size ×
//!   α × crash lateness, a node crash at `frac · M_ff` (a fraction of
//!   the fault-free makespan) is replayed under the three recovery
//!   policies. The per-crash lookahead makes `Best` never worse than
//!   the restart-from-scratch baseline *by construction* — asserted
//!   hard on every cell (`best <= restart`). The recovery overhead of
//!   `Best` over the fault-free makespan is reported per cell; note it
//!   can be slightly **negative**: a mid-run share re-solve over the
//!   remaining forest is not bound by the static schedule's
//!   equal-finish structure once shares fall below the one-core
//!   speedup kink.
//! * **self-healing executor** — a real malleable factorization with
//!   injected transient failures (`FaultPlan`) and elastic crew
//!   events; the crew retries, re-rounds teams, and must still produce
//!   a factorization whose residual passes (asserted), with the retry
//!   count and lost flops reported.
//!
//! CI runs a reduced-size smoke (`MALLTREE_BENCH_DIV`) and archives
//! the JSON artifact.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::dist::{map_tree, MappingStrategy};
use malltree::exec::{execute_malleable, execute_malleable_faulty, FaultPlan};
use malltree::frontal::{multifrontal, RustBackend};
use malltree::metrics::Table;
use malltree::model::{FaultEvent, FaultKind, FaultTrace, Platform, TaskTree};
use malltree::sched::{PmSchedule, Profile};
use malltree::sim::{replay_faults_distributed, Policy, RecoveryPolicy};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;
use malltree::workload::generator::{random_tree, TreeClass};

struct Cell {
    key: String,
    mff: f64,
    best: f64,
    remap: f64,
    restart: f64,
    overhead_pct: f64,
    gain_vs_restart_pct: f64,
    lost_work: f64,
    remapped: usize,
    restarted: bool,
}

fn main() {
    header("fault_sim", "fault replay + self-healing executor (§Fault)");
    let scale = env_usize("SCALE", 1).max(1);
    let div = env_usize("DIV", 1).max(1);
    let grid2d = (24 * scale / div).max(8);
    let grid3d = (8 * scale / div).max(4);
    let rand_n = (3_000 * scale / div).max(200);
    let lambda = 1.1;

    let mut rng = Rng::new(0xFA17);
    let mut families: Vec<(String, TaskTree)> = Vec::new();
    {
        let a = gen::grid_laplacian_2d(grid2d);
        let perm = order::nested_dissection_2d(grid2d);
        let at = symbolic::analyze(&a, &perm, 4).expect("grid2d analysis");
        families.push((format!("grid2d_{grid2d}"), at.tree));
    }
    {
        let a = gen::grid_laplacian_3d(grid3d);
        let perm = order::nested_dissection_3d(grid3d);
        let at = symbolic::analyze(&a, &perm, 4).expect("grid3d analysis");
        families.push((format!("grid3d_{grid3d}"), at.tree));
    }
    for class in [TreeClass::Uniform, TreeClass::Deep] {
        let t = random_tree(class, rand_n, &mut rng);
        families.push((format!("rand_{class:?}"), t));
    }

    let mut table = Table::new(&[
        "family", "nodes", "alpha", "crash@", "overhead", "best vs restart", "remapped",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    let (_, replay_secs) = timed(|| {
        for (name, tree) in &families {
            for nodes in [2usize, 4] {
                let platform = Platform::Homogeneous { nodes, p: 8.0 };
                for alpha in [0.7, 0.9, 1.0] {
                    let mapping = map_tree(tree, &platform, alpha, MappingStrategy::Pm, lambda);
                    let run = |trace: &FaultTrace, rec: RecoveryPolicy| {
                        replay_faults_distributed(
                            tree, alpha, &platform, &mapping.node_of, Policy::Pm, trace, rec,
                        )
                        .expect("fault replay")
                    };
                    let mff = run(&FaultTrace::empty(), RecoveryPolicy::Best).makespan;
                    for frac in [0.25, 0.5, 0.75] {
                        // crash the last node: under the PM mapping it
                        // hosts mapped subtrees but never the root chain
                        // (map_tree pins that to the fastest = first)
                        let trace = FaultTrace::new(vec![FaultEvent {
                            time: frac * mff,
                            kind: FaultKind::Crash { node: nodes - 1 },
                        }]);
                        let best = run(&trace, RecoveryPolicy::Best);
                        let remap = run(&trace, RecoveryPolicy::RemapOnly);
                        let restart = run(&trace, RecoveryPolicy::RestartOnly);
                        // the headline robustness guarantee: lookahead
                        // recovery never loses to restart-from-scratch
                        assert!(
                            best.makespan <= restart.makespan * (1.0 + 1e-9),
                            "{name} nodes={nodes} α={alpha} crash@{frac}: Best \
                             {} worse than restart {}",
                            best.makespan,
                            restart.makespan
                        );
                        assert!(
                            (best.fault_free_makespan - mff).abs() <= 1e-9 * mff,
                            "{name}: fault-free reference drifted"
                        );
                        let overhead_pct = 100.0 * best.recovery_overhead() / mff;
                        let gain_vs_restart_pct =
                            100.0 * (restart.makespan - best.makespan) / restart.makespan;
                        table.row(&[
                            name.clone(),
                            format!("{nodes}"),
                            format!("{alpha:.2}"),
                            format!("{frac:.2}"),
                            format!("{overhead_pct:+.2}%"),
                            format!("{gain_vs_restart_pct:+.2}%"),
                            format!(
                                "{}{}",
                                best.remapped_subtrees,
                                if best.restarted { " (restart)" } else { "" }
                            ),
                        ]);
                        cells.push(Cell {
                            key: format!("{name}_n{nodes}_a{alpha:.2}_f{frac:.2}"),
                            mff,
                            best: best.makespan,
                            remap: remap.makespan,
                            restart: restart.makespan,
                            overhead_pct,
                            gain_vs_restart_pct,
                            lost_work: best.lost_work,
                            remapped: best.remapped_subtrees,
                            restarted: best.restarted,
                        });
                    }
                }
            }
        }
    });
    print!("{}", table.render());
    println!("replayed {} cells in {replay_secs:.2}s", cells.len());

    // self-healing executor: injected transient faults + elastic crew
    // on a real factorization; clean run first for the overhead ratio
    let exec_grid = (16 * scale / div).max(6);
    let a = gen::grid_laplacian_2d(exec_grid);
    let perm = order::nested_dissection_2d(exec_grid);
    let at = symbolic::analyze(&a, &perm, 4).expect("exec analysis");
    let ap = a.permute_sym(&at.symbolic.perm).expect("permute");
    let pm = PmSchedule::for_tree(&at.tree, 0.9, &Profile::constant(8.0));
    let workers = 4;
    let (clean, clean_secs) = timed(|| {
        execute_malleable(&at, &ap, &pm.schedule, &RustBackend::default(), workers).expect("clean run")
    });
    let mut plan = FaultPlan::new();
    plan.backoff_ms = 0;
    plan.parse_inject("every:7:1", at.tree.len()).expect("inject spec");
    plan.parse_elastic("-2@4,+2@16").expect("elastic spec");
    let expected_retries: usize = plan.injected_failures(at.tree.len()).iter().sum();
    let (healed, healed_secs) = timed(|| {
        execute_malleable_faulty(&at, &ap, &pm.schedule, &RustBackend::default(), workers, &plan)
            .expect("self-healing run")
    });
    let (fact, report) = healed;
    assert_eq!(report.retries, expected_retries, "every injected fault retries once");
    assert!(report.lost_flops > 0.0, "retried fronts must report lost work");
    let residual = multifrontal::residual(&at, &ap, &fact);
    assert!(
        residual < 1e-10,
        "self-healed factorization lost accuracy: residual {residual:.3e}"
    );
    let slowdown = healed_secs / clean_secs.max(1e-12);
    println!(
        "executor grid2d_{exec_grid}: {} fronts, {} retries, lost {:.3e} flops, \
         recovery {:.3}s, wall {healed_secs:.3}s vs clean {clean_secs:.3}s ({slowdown:.2}x)",
        at.tree.len(),
        report.retries,
        report.lost_flops,
        report.recovery_seconds
    );
    drop(clean);

    let mut json = String::from("{\n");
    json.push_str(&format!("  \"scale\": {scale},\n  \"div\": {div},\n"));
    json.push_str(&format!(
        "  \"executor\": {{\"grid\": {exec_grid}, \"tasks\": {}, \"retries\": {}, \
         \"lost_flops\": {:.6e}, \"recovery_seconds\": {:.6}, \"wall_seconds\": {:.6}, \
         \"clean_wall_seconds\": {:.6}, \"residual\": {:.6e}}},\n",
        at.tree.len(),
        report.retries,
        report.lost_flops,
        report.recovery_seconds,
        healed_secs,
        clean_secs,
        residual
    ));
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  \"{}\": {{\"fault_free\": {:.6e}, \"best\": {:.6e}, \"remap\": {:.6e}, \
             \"restart\": {:.6e}, \"overhead_pct\": {:.4}, \"gain_vs_restart_pct\": {:.4}, \
             \"lost_work\": {:.6e}, \"remapped_subtrees\": {}, \"restarted\": {}}}{}\n",
            c.key,
            c.mff,
            c.best,
            c.remap,
            c.restart,
            c.overhead_pct,
            c.gain_vs_restart_pct,
            c.lost_work,
            c.remapped,
            c.restarted,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    let out = bench_util::bench_output_path("BENCH_fault.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
