//! Algorithm 11 quality (paper §6.1, Theorem 8): measured ratio of the
//! homogeneous two-node approximation to (a) the exhaustive optimum on
//! independent tasks and (b) the shared-memory lower bound on trees —
//! checked against the `(4/3)^α` guarantee. Also exercises the
//! Theorem 7 Partition gadget (NP-hardness witness).

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::dist::{homog_approx, independent_optimal, partition_reduction};
use malltree::metrics::{BoxplotRow, Table};
use malltree::model::TaskTree;
use malltree::util::rng::Rng;
use malltree::workload::{dataset, DatasetSpec};

fn main() {
    header("approx_quality", "Algorithm 11 (two homogeneous nodes) ratios");
    let cases = env_usize("CASES", 300);
    let mut rng = Rng::new(0xA11);

    // (a) independent tasks vs exact optimum
    let mut ratios = Vec::with_capacity(cases);
    let mut worst: f64 = 0.0;
    let (_, secs_a) = timed(|| {
        for _ in 0..cases {
            let n = rng.range(3, 14);
            let alpha = rng.range_f64(0.5, 1.0);
            let p = rng.range_f64(1.0, 32.0);
            let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.5, 100.0)).collect();
            let mut parents = vec![0usize];
            parents.extend(std::iter::repeat(0).take(n));
            let mut all = vec![0.0];
            all.extend_from_slice(&lens);
            let tree = TaskTree::from_parents(&parents, &all).unwrap();
            let s = homog_approx(&tree, alpha, p);
            let (_, opt) = independent_optimal(&lens, alpha, p, p);
            let ratio = s.makespan / opt;
            worst = worst.max(ratio / (4.0f64 / 3.0).powf(alpha));
            ratios.push(ratio);
        }
    });
    let r = BoxplotRow::from_data(&ratios);
    println!("independent tasks vs exhaustive optimum ({cases} cases, {secs_a:.1}s):");
    println!("  ratio quantiles: {}", r.render());
    println!("  worst ratio / (4/3)^α bound: {worst:.4} (must be <= 1)");
    assert!(worst <= 1.0 + 1e-6, "approximation guarantee violated");

    // (b) assembly trees vs the shared-memory lower bound
    let spec = DatasetSpec {
        random_trees: env_usize("TREES", 60),
        min_nodes: 2_000,
        max_nodes: 10_000,
        include_analysis_trees: true,
        seed: 0xA12,
    };
    let corpus = dataset(&spec);
    let mut table = Table::new(&["p", "alpha", "median ratio to LB", "d90"]);
    let (_, secs_b) = timed(|| {
        for p in [4.0, 20.0, 50.0] {
            for alpha in [0.7, 0.9] {
                let rs: Vec<f64> = corpus
                    .iter()
                    .map(|(_, t)| {
                        let s = homog_approx(t, alpha, p);
                        s.makespan / s.lower_bound
                    })
                    .collect();
                let row = BoxplotRow::from_data(&rs);
                table.row(&[
                    format!("{p}"),
                    format!("{alpha}"),
                    format!("{:.4}", row.median),
                    format!("{:.4}", row.d90),
                ]);
            }
        }
    });
    println!("\nassembly trees vs shared-memory lower bound ({} trees, {secs_b:.1}s):", corpus.len());
    print!("{}", table.render());

    // (c) Theorem 7 gadget: random YES/NO Partition instances decided
    let mut correct = 0;
    let total = 200;
    for case in 0..total {
        let n = rng.range(4, 12);
        let (instance, is_yes) = if case % 2 == 0 {
            // YES: build two halves with equal sums
            let half: Vec<u64> = (0..n / 2).map(|_| rng.range(1, 50) as u64).collect();
            let mut a = half.clone();
            // mirror with a couple of splits to disguise
            a.extend(half.iter().copied());
            (a, true)
        } else {
            // force odd total sum -> definite NO
            let mut a: Vec<u64> = (0..n).map(|_| rng.range(1, 50) as u64).collect();
            let s: u64 = a.iter().sum();
            if s % 2 == 0 {
                a[0] += 1;
            }
            (a, false)
        };
        let alpha = 0.8;
        let (lens, p, t) = partition_reduction(&instance, alpha);
        let (_, opt) = independent_optimal(&lens, alpha, p, p);
        let decided_yes = opt <= t + 1e-9;
        if decided_yes == is_yes {
            correct += 1;
        }
    }
    println!("\nTheorem 7 gadget: {correct}/{total} Partition instances decided correctly");
    assert_eq!(correct, total, "reduction must decide Partition exactly");
}
