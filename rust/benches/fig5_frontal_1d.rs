//! Figure 5 reproduction: qr_mumps frontal-matrix factorization kernel
//! with **1D partitioning** (block-columns of width 32). The paper
//! fits α on p ≤ 10 and reports noticeably lower values than 2D
//! (Table 2: 0.78–0.89) — panel factorization serializes the column.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("fig5", "qr_mumps frontal kernel, 1D partitioning");
    let machine = MachineModel::default();
    let p_max = env_usize("PMAX", 40);
    let sizes: [(usize, usize); 3] = [(5000, 1000), (10000, 2500), (20000, 5000)];

    let mut table = Table::new(&["front (MxN)", "p=1", "p=5", "p=10", "p=40", "alpha(p<=10)", "alpha(p<=4)"]);
    let (_, secs) = timed(|| {
        for &(m, n) in &sizes {
            let dag = KernelDag::frontal(m, n, 32, true);
            let curve = timing_curve(&dag, p_max, &machine);
            let (alpha, _) = fit_alpha(&curve, 10.0).expect("alpha fit");
            let (alpha4, _) = fit_alpha(&curve, 4.0).expect("alpha fit");
            let pick = |p: usize| -> String {
                curve
                    .iter()
                    .find(|&&(cp, _)| cp as usize == p)
                    .map(|&(_, t)| format!("{t:.3e}"))
                    .unwrap_or_else(|| "-".into())
            };
            table.row(&[
                format!("{m}x{n}"),
                pick(1),
                pick(5),
                pick(10),
                pick(p_max.min(40)),
                format!("{alpha:.3}"),
                format!("{alpha4:.3}"),
            ]);
        }
    });
    print!("{}", table.render());
    println!("(paper Table 2 1D column: 0.78 / 0.88 / 0.89 — smallest front worst,");
    println!(" paper notes p<=4 regression gives 0.87 for the 5000x1000 front)");
    println!("bench wall time: {secs:.2}s");
}
