//! Algorithm 12 quality and scaling (paper §6.2, Theorem 18 /
//! Corollary 19): achieved ratio vs the requested λ across random
//! instances, and runtime growth as λ → 1 (the FPTAS trade-off).

mod bench_util;

use bench_util::{env_usize, header, median_time, timed};
use malltree::dist::{het_schedule, independent_optimal, subset_sum_exact, subset_sum_fptas};
use malltree::metrics::{BoxplotRow, Table};
use malltree::util::rng::Rng;

fn main() {
    header("fptas_quality", "Algorithm 12 (two heterogeneous nodes) + subset-sum FPTAS");
    let cases = env_usize("CASES", 200);
    let mut rng = Rng::new(0xF7A);

    // (a) λ-guarantee across random instances
    let mut table = Table::new(&["lambda", "median ratio", "d90 ratio", "worst/λ"]);
    let (_, secs) = timed(|| {
        for lambda in [2.0, 1.5, 1.25, 1.1, 1.05, 1.01] {
            let mut ratios = Vec::with_capacity(cases);
            let mut worst: f64 = 0.0;
            for _ in 0..cases {
                let n = rng.range(3, 14);
                let alpha = rng.range_f64(0.5, 1.0);
                let p = rng.range_f64(1.0, 24.0);
                let q = rng.range_f64(1.0, 24.0);
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.5, 100.0)).collect();
                let s = het_schedule(&lens, alpha, p, q, lambda);
                let (_, opt) = independent_optimal(&lens, alpha, p, q);
                let ratio = s.makespan / opt;
                worst = worst.max(ratio / lambda);
                ratios.push(ratio);
            }
            let r = BoxplotRow::from_data(&ratios);
            table.row(&[
                format!("{lambda}"),
                format!("{:.4}", r.median),
                format!("{:.4}", r.d90),
                format!("{:.4}", worst),
            ]);
            assert!(worst <= 1.0 + 1e-6, "λ-guarantee violated at λ={lambda}");
        }
    });
    print!("{}", table.render());
    println!("guarantee check: worst/λ <= 1 everywhere ({cases} cases per λ, {secs:.1}s)\n");

    // (b) subset-sum FPTAS runtime scaling vs ε (Corollary 19's knob)
    let n = 60;
    let xs: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 1000.0)).collect();
    let target = xs.iter().sum::<f64>() * 0.45;
    let (_, exact_opt) = subset_sum_exact(&xs, target);
    let mut table = Table::new(&["eps", "time (ms)", "achieved / OPT"]);
    for eps in [0.5, 0.2, 0.1, 0.05, 0.02, 0.01] {
        let t = median_time(3, || {
            let _ = subset_sum_fptas(&xs, target, eps);
        });
        let (_, got) = subset_sum_fptas(&xs, target, eps);
        table.row(&[
            format!("{eps}"),
            format!("{:.3}", t * 1e3),
            format!("{:.6}", got / exact_opt),
        ]);
        assert!(got >= (1.0 - eps) * exact_opt - 1e-9);
    }
    print!("{}", table.render());
    println!("(runtime grows ~1/ε as the trimming list lengthens; ratio >= 1-ε always)");
}
