//! Figure 2 reproduction: timings of the QR kernel for M = 1024,
//! N ∈ {5000, …, 40000}, p = 1..40, plus the p^α model curve fitted on
//! p ≤ 10 (exactly the paper's regression protocol).
//!
//! Paper shape to match: log-log-straight timing lines for small p,
//! flattening for small matrices at large p; α close to 1.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("fig2", "QR kernel timings, M=1024 (tiled-DAG simulator)");
    let b = 256;
    let m_rows = 1024usize;
    let p_max = env_usize("PMAX", 40);
    let machine = MachineModel::default();
    let sizes = [5000usize, 10000, 15000, 20000, 25000, 30000, 35000, 40000];

    let mut table = Table::new(&["N", "p=1", "p=2", "p=5", "p=10", "p=20", "p=40", "alpha", "r2"]);
    let (rows, secs) = timed(|| {
        sizes
            .iter()
            .map(|&n| {
                let dag = KernelDag::qr(m_rows.div_ceil(b), n.div_ceil(b), b);
                let curve = timing_curve(&dag, p_max, &machine);
                let (alpha, fit) = fit_alpha(&curve, 10.0).expect("alpha fit");
                (n, curve, alpha, fit.r2)
            })
            .collect::<Vec<_>>()
    });
    let pick = |curve: &[(f64, f64)], p: usize| -> String {
        curve
            .iter()
            .find(|&&(cp, _)| cp as usize == p)
            .map(|&(_, t)| format!("{t:.3e}"))
            .unwrap_or_else(|| "-".into())
    };
    for (n, curve, alpha, r2) in &rows {
        table.row(&[
            format!("{n}"),
            pick(curve, 1),
            pick(curve, 2),
            pick(curve, 5),
            pick(curve, 10),
            pick(curve, 20),
            pick(curve, p_max.min(40)),
            format!("{alpha:.3}"),
            format!("{r2:.4}"),
        ]);
    }
    print!("{}", table.render());
    println!("(model check: curves straight in log-log for p<=10; flattening for small N)");
    println!("bench wall time: {secs:.2}s");
}
