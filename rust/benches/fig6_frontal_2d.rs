//! Figure 6 reproduction: qr_mumps frontal-matrix factorization kernel
//! with **2D partitioning** (square 256-tiles). More parallelism than
//! 1D: the paper fits α on p ≤ 20 and reports 0.93–0.95.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("fig6", "qr_mumps frontal kernel, 2D partitioning");
    let machine = MachineModel::default();
    let p_max = env_usize("PMAX", 40);
    let sizes: [(usize, usize); 3] = [(5000, 1000), (10000, 2500), (20000, 5000)];

    let mut table = Table::new(&["front (MxN)", "p=1", "p=10", "p=20", "p=40", "alpha(p<=20)"]);
    let (_, secs) = timed(|| {
        for &(m, n) in &sizes {
            let dag = KernelDag::frontal(m, n, 256, false);
            let curve = timing_curve(&dag, p_max, &machine);
            let (alpha, _) = fit_alpha(&curve, 20.0).expect("alpha fit");
            let pick = |p: usize| -> String {
                curve
                    .iter()
                    .find(|&&(cp, _)| cp as usize == p)
                    .map(|&(_, t)| format!("{t:.3e}"))
                    .unwrap_or_else(|| "-".into())
            };
            table.row(&[
                format!("{m}x{n}"),
                pick(1),
                pick(10),
                pick(20),
                pick(p_max.min(40)),
                format!("{alpha:.3}"),
            ]);
        }
    });
    print!("{}", table.render());
    println!("(paper Table 2 2D column: 0.93 / 0.95 / 0.94)");
    println!("bench wall time: {secs:.2}s");
}
