//! §Online harness: multi-tenant service under load (DESIGN.md §14).
//!
//! A load sweep lands in `BENCH_online.json`: for every α ∈ {0.7, 0.9,
//! 1.0} and offered load λ/capacity ∈ {0.5, 0.9, 1.2, 2.0} (capacity
//! calibrated as `p / mean(L)` from a probe stream — with shares capped
//! at one core per running job the service completes at most `p` units
//! of work per unit time), the same Poisson job stream is replayed
//! twice:
//!
//! * **admitted** — bounded queue + deadline-driven admission control
//!   (`deadline_ratio · T_iso` implied deadlines, Reject backpressure);
//! * **baseline** — no admission control: unbounded queue, no
//!   deadlines, everything is accepted and eventually completes.
//!
//! The headline robustness guarantee is asserted hard whenever the
//! sweep contains both the 0.9 and 2.0 load cells: at 2× capacity the
//! admitted service sheds load, its p99 sojourn stays (a) under the
//! structural bound `deadline_ratio · max T_iso` and (b) within a
//! constant factor of its own λ = 0.9 p99, while the baseline's p99
//! diverges past the admitted one.
//!
//! CI runs a reduced smoke (`MALLTREE_BENCH_DIV=20`,
//! `MALLTREE_BENCH_LOADS=0.9,2.0`) and archives the JSON artifact.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::Table;
use malltree::online::{job_stream, OverloadPolicy, ServiceConfig, StreamSpec};
use malltree::sim::simulate_online;
use malltree::workload::generator::ArrivalProcess;

/// Admitted p99 at λ = 2.0 must stay within this factor of the λ = 0.9
/// cell. The structural deadline bound alone gives
/// `ratio · max T_iso / p99(0.9)` and p99(0.9) is at least about one
/// isolated runtime, so this is generous but not vacuous.
const P99_BLOWUP_LIMIT: f64 = 25.0;

fn loads_from_env() -> Vec<f64> {
    match std::env::var("MALLTREE_BENCH_LOADS") {
        Ok(s) => {
            let loads: Vec<f64> = s
                .split(',')
                .filter(|t| !t.trim().is_empty())
                .map(|t| {
                    let x: f64 = t.trim().parse().unwrap_or_else(|_| {
                        panic!("MALLTREE_BENCH_LOADS: bad load factor {t:?}")
                    });
                    assert!(x.is_finite() && x > 0.0, "load factor must be > 0 (got {x})");
                    x
                })
                .collect();
            assert!(!loads.is_empty(), "MALLTREE_BENCH_LOADS is empty");
            loads
        }
        Err(_) => vec![0.5, 0.9, 1.2, 2.0],
    }
}

struct Cell {
    key: String,
    alpha: f64,
    load: f64,
    rate: f64,
    adm_completed: usize,
    adm_shed: usize,
    adm_timed_out: usize,
    adm_p50: f64,
    adm_p99: f64,
    adm_slo: f64,
    adm_throughput: f64,
    adm_max_queue: usize,
    base_p99: f64,
    base_max_queue: usize,
    bound: f64,
}

fn main() {
    header("online_sim", "online service load sweep: admission vs baseline (§Online)");
    let scale = env_usize("SCALE", 1).max(1);
    let div = env_usize("DIV", 1).max(1);
    let jobs_per_cell = (600 * scale / div).max(160);
    let loads = loads_from_env();
    let p = 8usize;
    let queue_cap = 8usize;
    let deadline_ratio = 8.0;

    let mut table = Table::new(&[
        "alpha", "load", "completed", "shed", "timeout", "adm p50", "adm p99", "slo",
        "base p99", "base queue",
    ]);
    let mut cells: Vec<Cell> = Vec::new();
    let (_, sweep_secs) = timed(|| {
        for alpha in [0.7, 0.9, 1.0] {
            let spec = StreamSpec {
                jobs: jobs_per_cell,
                tenants: 4,
                min_nodes: 10,
                max_nodes: 40,
                seed: 0x0A11 + (alpha * 100.0) as u64,
            };
            // calibrate capacity from a probe stream: with per-job
            // shares in [1, p] the machine retires at most p units of
            // work per unit time, so it sustains p / mean(L) jobs/sec
            let probe = job_stream(ArrivalProcess::Poisson { rate: 1.0 }, &spec);
            let mean_work: f64 =
                probe.iter().map(|j| j.tree.total_work()).sum::<f64>() / probe.len() as f64;
            let capacity = p as f64 / mean_work;
            let max_t_iso = probe
                .iter()
                .map(|j| j.tree.total_work())
                .fold(0.0f64, f64::max)
                / (p as f64).powf(alpha);
            for &load in &loads {
                let rate = load * capacity;
                let jobs = job_stream(ArrivalProcess::Poisson { rate }, &spec);
                let adm = simulate_online(
                    &jobs,
                    ServiceConfig {
                        alpha,
                        p,
                        queue_cap,
                        deadline_ratio,
                        overload: OverloadPolicy::Reject,
                        ..ServiceConfig::default()
                    },
                )
                .expect("admitted replay");
                let base = simulate_online(
                    &jobs,
                    ServiceConfig {
                        alpha,
                        p,
                        queue_cap: usize::MAX,
                        deadline_ratio: f64::INFINITY,
                        overload: OverloadPolicy::Reject,
                        ..ServiceConfig::default()
                    },
                )
                .expect("baseline replay");
                assert!(adm.conserved(), "α={alpha} load={load}: admitted run not conserved");
                assert!(base.conserved(), "α={alpha} load={load}: baseline run not conserved");
                assert_eq!(
                    base.shed + base.timed_out,
                    0,
                    "the no-admission baseline accepts and completes everything"
                );
                // structural bound: every completed admitted job made
                // its implied deadline `arrival + ratio · T_iso`
                let bound = deadline_ratio * max_t_iso;
                assert!(
                    adm.p99_sojourn <= bound * (1.0 + 1e-9),
                    "α={alpha} load={load}: admitted p99 {} exceeds deadline bound {bound}",
                    adm.p99_sojourn
                );
                table.row(&[
                    format!("{alpha:.2}"),
                    format!("{load:.2}"),
                    format!("{}/{}", adm.completed, jobs.len()),
                    format!("{}", adm.shed),
                    format!("{}", adm.timed_out),
                    format!("{:.2}", adm.p50_sojourn),
                    format!("{:.2}", adm.p99_sojourn),
                    format!("{:.3}", adm.slo_attainment),
                    format!("{:.2}", base.p99_sojourn),
                    format!("{}", base.max_queue),
                ]);
                cells.push(Cell {
                    key: format!("a{alpha:.2}_l{load:.2}"),
                    alpha,
                    load,
                    rate,
                    adm_completed: adm.completed,
                    adm_shed: adm.shed,
                    adm_timed_out: adm.timed_out,
                    adm_p50: adm.p50_sojourn,
                    adm_p99: adm.p99_sojourn,
                    adm_slo: adm.slo_attainment,
                    adm_throughput: adm.throughput,
                    adm_max_queue: adm.max_queue,
                    base_p99: base.p99_sojourn,
                    base_max_queue: base.max_queue,
                    bound,
                });
            }
        }
    });
    print!("{}", table.render());
    println!("swept {} cells in {sweep_secs:.2}s", cells.len());

    // headline guarantee, per α, whenever the sweep has both cells:
    // overload sheds, the admitted tail stays within a constant factor
    // of the near-capacity tail, and the baseline tail diverges
    for alpha in [0.7, 0.9, 1.0] {
        let cell = |load: f64| {
            cells.iter().find(|c| c.alpha == alpha && (c.load - load).abs() < 1e-12)
        };
        let (Some(near), Some(over)) = (cell(0.9), cell(2.0)) else { continue };
        assert!(
            over.adm_shed > 0,
            "α={alpha}: 2× overload must shed ({} shed)",
            over.adm_shed
        );
        assert!(over.adm_completed > 0, "α={alpha}: overload cell completed nothing");
        assert!(
            near.adm_p99 > 0.0 && over.adm_p99 <= P99_BLOWUP_LIMIT * near.adm_p99,
            "α={alpha}: admitted p99 blew up under overload: {} at λ=2.0 vs {} at λ=0.9",
            over.adm_p99,
            near.adm_p99
        );
        assert!(
            over.base_p99 > over.adm_p99,
            "α={alpha}: baseline p99 {} should diverge past admitted p99 {}",
            over.base_p99,
            over.adm_p99
        );
        println!(
            "α={alpha}: admitted p99 {:.2} → {:.2} ({:.1}x) under 2x load; baseline {:.2}",
            near.adm_p99,
            over.adm_p99,
            over.adm_p99 / near.adm_p99,
            over.base_p99
        );
    }

    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"scale\": {scale},\n  \"div\": {div},\n  \"jobs_per_cell\": {jobs_per_cell},\n  \
         \"p\": {p},\n  \"queue_cap\": {queue_cap},\n  \"deadline_ratio\": {deadline_ratio},\n"
    ));
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "  \"{}\": {{\"alpha\": {}, \"load\": {}, \"rate\": {:.6e}, \
             \"completed\": {}, \"shed\": {}, \"timed_out\": {}, \
             \"p50_sojourn\": {:.6e}, \"p99_sojourn\": {:.6e}, \"slo\": {:.6}, \
             \"throughput\": {:.6e}, \"max_queue\": {}, \"deadline_bound\": {:.6e}, \
             \"baseline_p99\": {:.6e}, \"baseline_max_queue\": {}}}{}\n",
            c.key,
            c.alpha,
            c.load,
            c.rate,
            c.adm_completed,
            c.adm_shed,
            c.adm_timed_out,
            c.adm_p50,
            c.adm_p99,
            c.adm_slo,
            c.adm_throughput,
            c.adm_max_queue,
            c.bound,
            c.base_p99,
            c.base_max_queue,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    let out = bench_util::bench_output_path("BENCH_online.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
