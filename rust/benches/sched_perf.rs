//! §Perf harness for the L3 coordinator hot paths: PM solve
//! throughput (one-shot and workspace-reused), Agreg rewriting
//! (incremental vs full-resolve), batch scheduling, DES event rate,
//! and symbolic analysis — the numbers tracked in EXPERIMENTS.md §Perf
//! and persisted machine-readably to `BENCH_sched.json` at the repo
//! root (one object per operation: median seconds + throughput).
//!
//! Targets (DESIGN.md §8): PM solve >= 2 Mnodes/s on the 1M-task tree;
//! incremental Agreg >= 3x the full-resolve baseline on the 100k-task
//! stress case; DES >= 1M events/s.
//!
//! Scaling knobs: `MALLTREE_BENCH_SCALE` multiplies sizes,
//! `MALLTREE_BENCH_DIV` divides them (CI smoke uses DIV=20).

mod bench_util;

use bench_util::{env_usize, header, median_time};
use malltree::metrics::Table;
use malltree::model::SpGraph;
use malltree::sched::batch::{effective_threads, schedule_batch, BatchConfig};
use malltree::sched::{agreg, agreg_full_resolve, pm::PmSolution, SchedWorkspace};
use malltree::sim::des::{simulate, simulate_with_workspace, Policy};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;
use malltree::workload::{generator::random_tree, TreeClass};

/// One emitted measurement: label → (size, median seconds, throughput
/// in the unit named by `unit`).
struct Row {
    key: &'static str,
    size: usize,
    median_s: f64,
    throughput: f64,
    unit: &'static str,
}

fn main() {
    header("sched_perf", "coordinator hot-path throughput (§Perf)");
    let scale = env_usize("SCALE", 1).max(1);
    let div = env_usize("DIV", 1).max(1);
    let sz = |n: usize| (n * scale / div).max(1_000);

    let mut table = Table::new(&["operation", "size", "median time", "throughput"]);
    let mut rows: Vec<Row> = Vec::new();

    // PM solve on large trees: one-shot and workspace-reused. Keys are
    // fixed per loop row (not derived from the scaled size) so the JSON
    // never emits duplicates under extreme SCALE/DIV settings.
    for &(base_n, key, ws_key) in &[
        (100_000usize, "pm_solve_100k", "pm_solve_workspace_100k"),
        (1_000_000, "pm_solve_1m", "pm_solve_workspace_1m"),
    ] {
        let n = sz(base_n);
        let mut rng = Rng::new(7);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let g = SpGraph::from_tree(&tree);
        let t = median_time(5, || {
            let s = PmSolution::solve(&g, 0.9);
            std::hint::black_box(s.total_len);
        });
        table.row(&[
            "PM solve".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key,
            size: n,
            median_s: t,
            throughput: n as f64 / t / 1e6,
            unit: "Mnodes_per_s",
        });

        let mut ws = SchedWorkspace::new();
        ws.solve(&g, 0.9); // warm the buffers: steady state is alloc-free
        let t = median_time(5, || {
            let s = ws.solve(&g, 0.9);
            std::hint::black_box(s.total_len);
        });
        table.row(&[
            "PM solve (workspace)".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key: ws_key,
            size: n,
            median_s: t,
            throughput: n as f64 / t / 1e6,
            unit: "Mnodes_per_s",
        });
    }

    // tree -> SP conversion
    {
        let n = sz(1_000_000);
        let mut rng = Rng::new(8);
        let tree = random_tree(TreeClass::Recent, n, &mut rng);
        let t = median_time(5, || {
            let g = SpGraph::from_tree(&tree);
            std::hint::black_box(g.nodes.len());
        });
        table.row(&[
            "tree→SP".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key: "tree_to_sp",
            size: n,
            median_s: t,
            throughput: n as f64 / t / 1e6,
            unit: "Mnodes_per_s",
        });
    }

    // Agreg to fixpoint on a stress tree (small p triggers rewrites):
    // incremental engine vs the full-resolve baseline
    {
        let n = sz(100_000);
        let mut rng = Rng::new(9);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let g = SpGraph::from_tree(&tree);
        let (_, stats) = agreg(&g, 0.9, 8.0);
        let t_inc = median_time(3, || {
            let (out, stats) = agreg(&g, 0.9, 8.0);
            std::hint::black_box((out.nodes.len(), stats.iterations));
        });
        let t_full = median_time(3, || {
            let (out, stats) = agreg_full_resolve(&g, 0.9, 8.0);
            std::hint::black_box((out.nodes.len(), stats.iterations));
        });
        table.row(&[
            format!("Agreg incremental ({} iters)", stats.iterations),
            format!("{n} tasks"),
            format!("{:.1} ms", t_inc * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t_inc / 1e6),
        ]);
        table.row(&[
            "Agreg full-resolve".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t_full * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t_full / 1e6),
        ]);
        table.row(&[
            "Agreg speedup".into(),
            format!("{n} tasks"),
            "-".into(),
            format!("{:.2}x", t_full / t_inc),
        ]);
        rows.push(Row {
            key: "agreg_incremental_100k",
            size: n,
            median_s: t_inc,
            throughput: n as f64 / t_inc / 1e6,
            unit: "Mnodes_per_s",
        });
        rows.push(Row {
            key: "agreg_full_resolve_100k",
            size: n,
            median_s: t_full,
            throughput: n as f64 / t_full / 1e6,
            unit: "Mnodes_per_s",
        });
        rows.push(Row {
            key: "agreg_speedup",
            size: n,
            median_s: 0.0,
            throughput: t_full / t_inc,
            unit: "x_vs_full_resolve",
        });
    }

    // batch scheduling throughput (multi-tenant front-end)
    {
        let n_trees = (64 * scale / div).max(8);
        // scale grows the tree *count*; per-tree size caps at 20k so the
        // batch row measures many-tenant throughput, not one giant tree
        let per_tree = sz(20_000).min(20_000);
        let mut rng = Rng::new(11);
        let classes = [
            TreeClass::Uniform,
            TreeClass::Recent,
            TreeClass::Deep,
            TreeClass::Binary,
        ];
        let trees: Vec<_> = (0..n_trees)
            .map(|i| random_tree(classes[i % classes.len()], per_tree, &mut rng))
            .collect();
        let total_tasks: usize = trees.iter().map(|t| t.len()).sum();
        let workers = effective_threads(0);
        let cfg = BatchConfig { alpha: 0.9, p: 40.0, threads: 0, agreg: true };
        let t = median_time(3, || {
            let r = schedule_batch(&trees, &cfg);
            std::hint::black_box(r.len());
        });
        table.row(&[
            format!("batch ({workers} threads)"),
            format!("{n_trees} trees / {total_tasks} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mtasks/s", total_tasks as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key: "batch_schedule",
            size: total_tasks,
            median_s: t,
            throughput: total_tasks as f64 / t / 1e6,
            unit: "Mtasks_per_s",
        });
    }

    // DES simulation event rate (plus the PM-policy workspace path)
    {
        let n = sz(200_000);
        let mut rng = Rng::new(10);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let events = simulate(&tree, 0.9, 40.0, Policy::Proportional).events;
        let t = median_time(3, || {
            let r = simulate(&tree, 0.9, 40.0, Policy::Proportional);
            std::hint::black_box(r.makespan);
        });
        table.row(&[
            "DES (Proportional)".into(),
            format!("{events} events"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mevents/s", events as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key: "des_proportional",
            size: events,
            median_s: t,
            throughput: events as f64 / t / 1e6,
            unit: "Mevents_per_s",
        });

        let mut ws = SchedWorkspace::new();
        let pm_events = simulate_with_workspace(&tree, 0.9, 40.0, Policy::Pm, &mut ws).events;
        let t = median_time(3, || {
            let r = simulate_with_workspace(&tree, 0.9, 40.0, Policy::Pm, &mut ws);
            std::hint::black_box(r.makespan);
        });
        table.row(&[
            "DES (PM, workspace)".into(),
            format!("{pm_events} events"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mevents/s", pm_events as f64 / t / 1e6),
        ]);
        rows.push(Row {
            key: "des_pm_workspace",
            size: pm_events,
            median_s: t,
            throughput: pm_events as f64 / t / 1e6,
            unit: "Mevents_per_s",
        });
    }

    // symbolic analysis of a grid problem
    {
        let k = 64;
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let t = median_time(3, || {
            let at = symbolic::analyze(&a, &perm, 4).unwrap();
            std::hint::black_box(at.tree.len());
        });
        table.row(&[
            "symbolic analyze".into(),
            format!("grid {k}x{k} (n={})", k * k),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} kcols/s", (k * k) as f64 / t / 1e3),
        ]);
        rows.push(Row {
            key: "symbolic_analyze",
            size: k * k,
            median_s: t,
            throughput: (k * k) as f64 / t / 1e3,
            unit: "kcols_per_s",
        });
    }

    print!("{}", table.render());

    // Machine-readable perf trajectory (BENCH_sched.json at repo root).
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"scale\": {scale},\n  \"div\": {div},\n"));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "  \"{}\": {{\"size\": {}, \"median_s\": {:.6}, \"{}\": {:.4}}}{}\n",
            r.key,
            r.size,
            r.median_s,
            r.unit,
            r.throughput,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    // repo-root path via CARGO_MANIFEST_DIR, not the bench CWD
    let out = bench_util::bench_output_path("BENCH_sched.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
}
