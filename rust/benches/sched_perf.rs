//! §Perf harness for the L3 coordinator hot paths: PM solve
//! throughput, Agreg rewriting, DES event rate, and symbolic analysis —
//! the numbers tracked in EXPERIMENTS.md §Perf.
//!
//! Targets (DESIGN.md §8): PM solve >= 1M nodes/s; DES >= 1M events/s.

mod bench_util;

use bench_util::{env_usize, header, median_time};
use malltree::metrics::Table;
use malltree::model::SpGraph;
use malltree::sched::{agreg, pm::PmSolution};
use malltree::sim::des::{simulate, Policy};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;
use malltree::workload::{generator::random_tree, TreeClass};

fn main() {
    header("sched_perf", "coordinator hot-path throughput (§Perf)");
    let scale = env_usize("SCALE", 1);

    let mut table = Table::new(&["operation", "size", "median time", "throughput"]);

    // PM solve on a large tree
    for &n in &[100_000usize, 1_000_000] {
        let n = n * scale;
        let mut rng = Rng::new(7);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let g = SpGraph::from_tree(&tree);
        let t = median_time(5, || {
            let s = PmSolution::solve(&g, 0.9);
            std::hint::black_box(s.total_len);
        });
        table.row(&[
            "PM solve".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
    }

    // tree -> SP conversion
    {
        let n = 1_000_000 * scale;
        let mut rng = Rng::new(8);
        let tree = random_tree(TreeClass::Recent, n, &mut rng);
        let t = median_time(5, || {
            let g = SpGraph::from_tree(&tree);
            std::hint::black_box(g.nodes.len());
        });
        table.row(&[
            "tree→SP".into(),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
    }

    // Agreg to fixpoint on a stress tree (small p triggers rewrites)
    {
        let n = 100_000 * scale;
        let mut rng = Rng::new(9);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let g = SpGraph::from_tree(&tree);
        let t = median_time(3, || {
            let (out, stats) = agreg(&g, 0.9, 8.0);
            std::hint::black_box((out.nodes.len(), stats.iterations));
        });
        let (_, stats) = agreg(&g, 0.9, 8.0);
        table.row(&[
            format!("Agreg ({} iters)", stats.iterations),
            format!("{n} tasks"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mnodes/s", n as f64 / t / 1e6),
        ]);
    }

    // DES simulation event rate
    {
        let n = 200_000 * scale;
        let mut rng = Rng::new(10);
        let tree = random_tree(TreeClass::Uniform, n, &mut rng);
        let events = simulate(&tree, 0.9, 40.0, Policy::Proportional).events;
        let t = median_time(3, || {
            let r = simulate(&tree, 0.9, 40.0, Policy::Proportional);
            std::hint::black_box(r.makespan);
        });
        table.row(&[
            "DES (Proportional)".into(),
            format!("{events} events"),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} Mevents/s", events as f64 / t / 1e6),
        ]);
    }

    // symbolic analysis of a grid problem
    {
        let k = 64;
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let t = median_time(3, || {
            let at = symbolic::analyze(&a, &perm, 4).unwrap();
            std::hint::black_box(at.tree.len());
        });
        table.row(&[
            "symbolic analyze".into(),
            format!("grid {k}x{k} (n={})", k * k),
            format!("{:.1} ms", t * 1e3),
            format!("{:.2} kcols/s", (k * k) as f64 / t / 1e3),
        ]);
    }

    print!("{}", table.render());
}
