//! Table 1 reproduction: measured α for the dense QR (M = 1024 and
//! M = 4096) and Cholesky kernels across matrix sizes, regression on
//! p ≤ 10 — the exact protocol of paper §3.
//!
//! Shape to match: all α close to 1, increasing with N; the M = 4096 QR
//! column above the M = 1024 one.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("table1", "alpha for dense kernels (paper Table 1)");
    let b = 256;
    let machine = MachineModel::default();
    let p_max = env_usize("PMAX", 12); // only p <= 10 enters the fit
    let n_cap = env_usize("NCAP", 40000);
    let sizes: Vec<usize> = [5000usize, 10000, 15000, 20000, 25000, 30000, 35000, 40000]
        .into_iter()
        .filter(|&n| n <= n_cap)
        .collect();

    let alpha_of = |dag: &KernelDag| -> f64 {
        let curve = timing_curve(dag, p_max, &machine);
        fit_alpha(&curve, 10.0).expect("alpha fit").0
    };

    let mut table = Table::new(&["N", "QR M=1024", "QR M=4096", "Cholesky"]);
    let (_, secs) = timed(|| {
        for &n in &sizes {
            let qr_small = alpha_of(&KernelDag::qr(1024usize.div_ceil(b), n.div_ceil(b), b));
            let qr_large = alpha_of(&KernelDag::qr(4096usize.div_ceil(b), n.div_ceil(b), b));
            let chol = alpha_of(&KernelDag::cholesky(n.div_ceil(b), b));
            table.row(&[
                format!("{n}"),
                format!("{qr_small:.3}"),
                format!("{qr_large:.3}"),
                format!("{chol:.3}"),
            ]);
        }
    });
    print!("{}", table.render());
    println!("(paper: QR M=1024 0.95→1.00, QR M=4096 0.988→0.999, Cholesky 0.94→0.98)");
    println!("bench wall time: {secs:.2}s");
}
