//! §Obs harness (EXPERIMENTS.md E22): the observability layer pays its
//! way. Four sections, all hard-asserted, land in **`BENCH_obs.json`**:
//!
//! * **overhead** — the same malleable factorization with the Null
//!   sink vs the Buffer sink; recording the full span timeline must
//!   cost < 3% wall time (with a 10 ms additive allowance so sub-50ms
//!   CI runs don't flake on scheduler jitter).
//! * **α recovery, model spans** — the shared DES traced at several
//!   processor counts under a known α; the spans are noiseless, so
//!   [`malltree::obs::calibrate`] must recover α to 1e-3 (and a
//!   fortiori the ±0.05 acceptance band).
//! * **α recovery, noisy wall spans** — a synthetic wall-clock trace
//!   with 5% lognormal duration noise; the fit must land within ±0.05
//!   of the planted exponent and recover the planted unit cost.
//! * **drift** — a real traced execution calibrated against itself:
//!   per-width drift rows under the assumed vs the fitted α, plus a
//!   Chrome-JSON round-trip of the executor log.
//!
//! `MALLTREE_BENCH_GRID` scales the overhead problem,
//! `MALLTREE_BENCH_REPS` the median-of-k timing.

mod bench_util;

use bench_util::{bench_output_path, env_usize, header};
use malltree::exec::execute_malleable_traced;
use malltree::frontal::RustBackend;
use malltree::metrics::Table;
use malltree::model::TaskTree;
use malltree::obs::{
    self, chrome_trace, parse_chrome_trace, Span, SpanKind, TimeUnit, TraceLog, TraceSink,
};
use malltree::sched::{PmSchedule, Profile};
use malltree::sim::{simulate_traced, Policy};
use malltree::sparse::{gen, order, symbolic, AssemblyTree, CscMatrix};
use malltree::util::rng::Rng;

const ASSUMED_ALPHA: f64 = 0.9;
const OVERHEAD_LIMIT_PCT: f64 = 3.0;
/// Additive jitter allowance for the overhead assert (seconds).
const OVERHEAD_SLACK_S: f64 = 0.010;

fn analyze_2d(k: usize) -> (AssemblyTree, CscMatrix) {
    let a = gen::grid_laplacian_2d(k);
    let perm = order::nested_dissection_2d(k);
    let at = symbolic::analyze(&a, &perm, 4).unwrap();
    let ap = a.permute_sym(&at.symbolic.perm).unwrap();
    (at, ap)
}

/// Median-of-k wall time of one traced factorization with `sink`.
fn run_median(
    k: usize,
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &malltree::sched::Schedule,
    backend: &RustBackend,
    workers: usize,
    sink: TraceSink,
) -> f64 {
    let mut times: Vec<f64> = (0..k.max(1) + 1)
        .map(|_| {
            let t0 = std::time::Instant::now();
            let (_, r) = execute_malleable_traced(at, ap, schedule, backend, workers, sink)
                .expect("factorization");
            assert_eq!(r.trace.is_some(), sink.enabled(), "sink controls trace presence");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.remove(0); // warmup
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn random_tree(rng: &mut Rng, n: usize) -> TaskTree {
    let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
    let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 100.0)).collect();
    TaskTree::from_parents(&parents, &lens).unwrap()
}

fn main() {
    header("obs_trace", "span tracing: overhead, alpha recovery, model drift (§Obs)");
    let grid = env_usize("GRID", 40);
    let reps = env_usize("REPS", 7);
    let workers = 4usize;
    let mut json: Vec<String> = Vec::new();

    // -- overhead: Null sink vs Buffer sink on the same problem ------
    let (at, ap) = analyze_2d(grid);
    let backend = RustBackend::default();
    let pm = PmSchedule::for_tree(&at.tree, ASSUMED_ALPHA, &Profile::constant(workers as f64));
    let t_plain = run_median(reps, &at, &ap, &pm.schedule, &backend, workers, TraceSink::Null);
    let t_traced = run_median(reps, &at, &ap, &pm.schedule, &backend, workers, TraceSink::Buffer);
    let overhead_pct = (t_traced / t_plain - 1.0) * 100.0;
    println!(
        "overhead: grid2d {grid}, {} fronts, {workers} workers: \
         null {t_plain:.4}s, buffer {t_traced:.4}s ({overhead_pct:+.2}%)",
        at.tree.len()
    );
    assert!(
        overhead_pct < OVERHEAD_LIMIT_PCT || t_traced - t_plain < OVERHEAD_SLACK_S,
        "span recording costs {overhead_pct:.2}% (> {OVERHEAD_LIMIT_PCT}%) \
         and {:.4}s (> {OVERHEAD_SLACK_S}s jitter allowance)",
        t_traced - t_plain
    );
    json.push(format!("  \"grid\": {grid}, \"reps\": {reps}, \"workers\": {workers}"));
    json.push(format!(
        "  \"t_plain_s\": {t_plain:.6e}, \"t_traced_s\": {t_traced:.6e}, \
         \"overhead_pct\": {overhead_pct:.4}"
    ));

    // -- alpha recovery from noiseless model spans -------------------
    let alpha_true = 0.85;
    let mut rng = Rng::new(0x0B5E);
    let trees: Vec<TaskTree> = (0..4).map(|_| random_tree(&mut rng, 400)).collect();
    let mut model_logs: Vec<TraceLog> = Vec::new();
    for tree in &trees {
        for p in [4.0, 8.0, 16.0, 32.0] {
            for pol in [Policy::Pm, Policy::Proportional] {
                let (_, log) = simulate_traced(tree, alpha_true, p, pol);
                log.validate().expect("DES trace invariants");
                model_logs.push(log);
            }
        }
    }
    let refs: Vec<&TraceLog> = model_logs.iter().collect();
    let cal_model = obs::calibrate(&refs, None).expect("model-span calibration");
    println!(
        "alpha from DES spans: fitted {:.5} vs planted {alpha_true} \
         (r² {:.6}, {} samples)",
        cal_model.alpha, cal_model.fit.r2, cal_model.samples
    );
    assert!(
        (cal_model.alpha - alpha_true).abs() < 1e-3,
        "noiseless model spans must recover alpha near-exactly, got {}",
        cal_model.alpha
    );
    assert!((cal_model.alpha - alpha_true).abs() < 0.05, "acceptance band");
    json.push(format!(
        "  \"alpha_true_model\": {alpha_true}, \"alpha_fit_model\": {:.6}, \
         \"model_r2\": {:.6}, \"model_samples\": {}",
        cal_model.alpha, cal_model.fit.r2, cal_model.samples
    ));

    // -- alpha recovery from noisy wall spans ------------------------
    let unit_cost_ns = 2.5; // planted ns per flop at one processor
    let mut log = TraceLog::new("synthetic", TimeUnit::WallNs, 8);
    let mut cursor = 0.0f64;
    for team in [1.0f64, 2.0, 4.0, 8.0] {
        for i in 0..300u32 {
            let flops = rng.log_uniform(1e6, 1e9);
            let noise = (0.05 * rng.normal()).exp();
            let dur = unit_cost_ns * flops / team.powf(alpha_true) * noise;
            log.push(Span {
                kind: SpanKind::Factor,
                task: i,
                worker: rng.below(8) as u32,
                team,
                flops,
                start: cursor,
                end: cursor + dur,
            });
            cursor += dur;
        }
    }
    log.validate().expect("synthetic trace invariants");
    let cal_noisy = obs::calibrate(&[&log], None).expect("noisy calibration");
    println!(
        "alpha from noisy wall spans: fitted {:.4} vs planted {alpha_true}, \
         unit cost {:.3} ns/flop vs planted {unit_cost_ns}",
        cal_noisy.alpha, cal_noisy.unit_cost
    );
    assert!(
        (cal_noisy.alpha - alpha_true).abs() < 0.05,
        "5% lognormal noise must not push the fit out of the ±0.05 band, got {}",
        cal_noisy.alpha
    );
    assert!(
        (cal_noisy.unit_cost - unit_cost_ns).abs() / unit_cost_ns < 0.10,
        "unit cost off by >10%: {}",
        cal_noisy.unit_cost
    );
    json.push(format!(
        "  \"alpha_true_noisy\": {alpha_true}, \"alpha_fit_noisy\": {:.6}, \
         \"unit_cost_planted\": {unit_cost_ns}, \"unit_cost_fitted\": {:.6}",
        cal_noisy.alpha, cal_noisy.unit_cost
    ));

    // -- drift: the executor calibrated against its own telemetry ----
    let widths: Vec<usize> =
        at.symbolic.supernodes.iter().map(|s| s.front_order()).collect();
    let mut exec_logs: Vec<TraceLog> = Vec::new();
    for w in [2usize, workers] {
        let pmw = PmSchedule::for_tree(&at.tree, ASSUMED_ALPHA, &Profile::constant(w as f64));
        let (_, rep) =
            execute_malleable_traced(&at, &ap, &pmw.schedule, &backend, w, TraceSink::Buffer)
                .expect("traced run");
        exec_logs.push(rep.trace.expect("buffer sink records"));
    }
    // Chrome-JSON round-trip is bit-exact on the real executor log
    let back = parse_chrome_trace(&chrome_trace(&exec_logs[1]).unwrap()).unwrap();
    assert_eq!(back, exec_logs[1], "chrome export must round-trip");
    let exec_refs: Vec<&TraceLog> = exec_logs.iter().collect();
    let cal_exec = obs::calibrate(&exec_refs, Some(&widths)).expect("exec calibration");
    let m_assumed = PmSchedule::for_tree(&at.tree, ASSUMED_ALPHA, &Profile::constant(workers as f64))
        .schedule
        .makespan;
    // a noisy host can fit an exponent outside the model's (0, 1]
    // domain; the schedule re-solve needs a legal α
    let fitted_for_solve = cal_exec.alpha.clamp(0.05, 1.0);
    let m_fitted = PmSchedule::for_tree(&at.tree, fitted_for_solve, &Profile::constant(workers as f64))
        .schedule
        .makespan;
    let drift = obs::drift_report(
        &exec_logs[1],
        &widths,
        &cal_exec,
        ASSUMED_ALPHA,
        m_assumed,
        m_fitted,
    );
    assert!(!drift.rows.is_empty(), "drift report must bucket at least one front");
    let mut table = Table::new(&["front width", "fronts", "err% (assumed)", "err% (fitted)"]);
    for r in &drift.rows {
        let hi = if r.hi == usize::MAX { "inf".to_string() } else { r.hi.to_string() };
        table.row(&[
            format!("({}, {hi}]", r.lo),
            format!("{}", r.fronts),
            format!("{:.1}", r.err_assumed_pct),
            format!("{:.1}", r.err_fitted_pct),
        ]);
    }
    print!("{}", table.render());
    println!(
        "exec fit: alpha {:.3} (r² {:.4}, {} samples); makespan err \
         {:.1}% assumed / {:.1}% fitted",
        cal_exec.alpha,
        cal_exec.fit.r2,
        cal_exec.samples,
        drift.makespan_err_assumed_pct,
        drift.makespan_err_fitted_pct
    );
    json.push(format!(
        "  \"alpha_fit_exec\": {:.6}, \"exec_r2\": {:.6}, \"exec_samples\": {}, \
         \"drift_assumed_pct\": {:.4}, \"drift_fitted_pct\": {:.4}, \
         \"makespan_err_assumed_pct\": {:.4}, \"makespan_err_fitted_pct\": {:.4}",
        cal_exec.alpha,
        cal_exec.fit.r2,
        cal_exec.samples,
        drift.overall_assumed_pct,
        drift.overall_fitted_pct,
        drift.makespan_err_assumed_pct,
        drift.makespan_err_fitted_pct
    ));

    let out = bench_output_path("BENCH_obs.json");
    let body = format!("{{\n{}\n}}\n", json.join(",\n"));
    match std::fs::write(&out, &body) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
