//! Figure 4 reproduction: Cholesky kernel timings for square matrices
//! N ∈ {5000, …, 40000}, p = 1..40 under the tiled-DAG simulator.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("fig4", "Cholesky kernel timings (tiled-DAG simulator)");
    let b = 256;
    let p_max = env_usize("PMAX", 40);
    // N=40000 gives a 157-tile DAG (~650k kernels); trim via env for CI.
    let n_cap = env_usize("NCAP", 40000);
    let machine = MachineModel::default();
    let sizes: Vec<usize> = [5000usize, 10000, 15000, 20000, 25000, 30000, 35000, 40000]
        .into_iter()
        .filter(|&n| n <= n_cap)
        .collect();

    let mut table = Table::new(&["N", "kernels", "p=1", "p=10", "p=40", "speedup@40", "alpha", "r2"]);
    let (_, secs) = timed(|| {
        for &n in &sizes {
            let dag = KernelDag::cholesky(n.div_ceil(b), b);
            let curve = timing_curve(&dag, p_max, &machine);
            let (alpha, fit) = fit_alpha(&curve, 10.0).expect("alpha fit");
            let t1 = curve[0].1;
            let tmax = curve.last().unwrap().1;
            let pick = |p: usize| -> String {
                curve
                    .iter()
                    .find(|&&(cp, _)| cp as usize == p)
                    .map(|&(_, t)| format!("{t:.3e}"))
                    .unwrap_or_else(|| "-".into())
            };
            table.row(&[
                format!("{n}"),
                format!("{}", dag.len()),
                pick(1),
                pick(10),
                pick(p_max.min(40)),
                format!("{:.1}", t1 / tmax),
                format!("{alpha:.3}"),
                format!("{:.4}", fit.r2),
            ]);
        }
    });
    print!("{}", table.render());
    println!("(paper Table 1 Cholesky column: alpha 0.94-1.00, rising with N)");
    println!("bench wall time: {secs:.2}s");
}
