//! Figure 3 reproduction: QR kernel timings for M = 4096 — the larger
//! panel height gives more intra-step parallelism, so α is closer to 1
//! than Figure 2's (Table 1: 0.988–0.999 vs 0.95–1.00).

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::{fit_alpha, Table};
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("fig3", "QR kernel timings, M=4096 (tiled-DAG simulator)");
    let b = 256;
    let m_rows = 4096usize;
    let p_max = env_usize("PMAX", 40);
    let machine = MachineModel::default();
    let sizes = [5000usize, 10000, 15000, 20000, 25000, 30000, 35000, 40000];

    let mut table = Table::new(&["N", "p=1", "p=5", "p=10", "p=20", "p=40", "alpha", "r2"]);
    let (_, secs) = timed(|| {
        for &n in &sizes {
            let dag = KernelDag::qr(m_rows.div_ceil(b), n.div_ceil(b), b);
            let curve = timing_curve(&dag, p_max, &machine);
            let (alpha, fit) = fit_alpha(&curve, 10.0).expect("alpha fit");
            let pick = |p: usize| -> String {
                curve
                    .iter()
                    .find(|&&(cp, _)| cp as usize == p)
                    .map(|&(_, t)| format!("{t:.3e}"))
                    .unwrap_or_else(|| "-".into())
            };
            table.row(&[
                format!("{n}"),
                pick(1),
                pick(5),
                pick(10),
                pick(20),
                pick(p_max.min(40)),
                format!("{alpha:.3}"),
                format!("{fit:.4}", fit = fit.r2),
            ]);
        }
    });
    print!("{}", table.render());
    println!("(paper Table 1 M=4096 column: alpha 0.988-0.999, rising with N)");
    println!("bench wall time: {secs:.2}s");
}
