//! Figure 13 reproduction: relative makespan distance (%) of the
//! Divisible and Proportional strategies to the optimal PM schedule,
//! over the assembly-tree corpus, p(t) = 40, α ∈ [0.5, 1.0].
//!
//! Shape to match (paper §7):
//!   * Divisible: median grows ~8 points per 0.05 drop of α; ≈16% at
//!     α = 0.9;
//!   * Proportional: much closer to PM; median ≈3% at α = 0.9;
//!   * both shrink to 0 at α = 1.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::model::SpGraph;
use malltree::sched::relative_distances_graph;
use malltree::metrics::{BoxplotRow, Table};
use malltree::workload::{dataset, DatasetSpec};

fn run(p: f64, trees: usize, max_nodes: usize) {
    let spec = DatasetSpec {
        random_trees: trees,
        min_nodes: 2_000,
        max_nodes,
        include_analysis_trees: true,
        seed: 0xDA7A,
    };
    let (corpus, gen_secs) = timed(|| dataset(&spec));
    // §Perf: convert each tree to its pseudo-tree once, not per alpha
    let graphs: Vec<SpGraph> = corpus.iter().map(|(_, t)| SpGraph::from_tree(t)).collect();
    println!("corpus: {} trees (generated in {gen_secs:.1}s), p = {p}", corpus.len());

    let mut table = Table::new(&[
        "alpha", "strategy", "d10", "q25", "median", "q75", "d90", "mean",
    ]);
    let (_, secs) = timed(|| {
        for alpha in [0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 1.0] {
            let mut div = Vec::with_capacity(corpus.len());
            let mut prop = Vec::with_capacity(corpus.len());
            for g in &graphs {
                let (d, pr) = relative_distances_graph(g, alpha, p);
                div.push(d);
                prop.push(pr);
            }
            for (name, data) in [("Divisible", &div), ("Proportional", &prop)] {
                let r = BoxplotRow::from_data(data);
                table.row(&[
                    format!("{alpha:.2}"),
                    name.to_string(),
                    format!("{:.2}", r.d10),
                    format!("{:.2}", r.q25),
                    format!("{:.2}", r.median),
                    format!("{:.2}", r.q75),
                    format!("{:.2}", r.d90),
                    format!("{:.2}", r.mean),
                ]);
            }
        }
    });
    print!("{}", table.render());
    println!("sweep wall time: {secs:.1}s");
}

fn main() {
    header("fig13", "PM vs Divisible/Proportional, p(t) = 40 (boxplot rows)");
    let trees = env_usize("TREES", 600);
    let max_nodes = env_usize("MAXNODES", 50_000);
    run(40.0, trees, max_nodes);
}
