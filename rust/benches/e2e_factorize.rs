//! End-to-end factorization bench (EXPERIMENTS.md E14/E15): the
//! complete pipeline — analysis → PM schedule → numeric multifrontal
//! execution — timed for the parallel Rust backend (worker sweep), the
//! naive-kernel baseline, the PJRT accelerator-queue backend when
//! artifacts are present, and (E15) the **malleable** executor against
//! the task-parallel one on a 3D problem whose root front dominates
//! the flops. Writes the machine-readable **`BENCH_e2e.json`** at the
//! repo root (per worker count: Mflop/s, assembly fraction, peak front
//! bytes, parallel efficiency; plus `malleable_speedup_8w` and
//! per-width team occupancy), the numeric-pipeline counterpart of
//! `BENCH_sched.json`.
//!
//! The E21 section (EXPERIMENTS.md §Kernels) additionally runs the
//! `.mtx` corpus in `examples/matrices/` — scalar vs dispatched-SIMD
//! kernel throughput at one worker plus the malleable 8-worker speedup,
//! residual-gated in epsilon mode — and a block-size × amalgamation
//! sweep on the 3D problem; JSON gains `corpus_<stem>` rows,
//! `block_sweep`, and `kernel_isa`. On SIMD hardware the widest-front
//! corpus cell hard-asserts `mflops_simd >= mflops_scalar`.
//!
//! Flags: `--malleable` (default on) / `--no-malleable` toggle the E15
//! section; `MALLTREE_BENCH_GRID` scales the 2D sweep,
//! `MALLTREE_BENCH_GRID3D` the malleable comparison and block sweep.

mod bench_util;

use bench_util::{bench_output_path, env_usize, has_flag, header, timed};
use malltree::exec::{execute_malleable, execute_parallel, execute_serial, ExecReport};
use malltree::frontal::{
    dense, multifrontal, Factorization, FrontConfig, NaiveBackend, PjrtBackend, RustBackend,
    SimdMode,
};
use malltree::metrics::Table;
use malltree::sched::{PmSchedule, Profile, Schedule};
use malltree::sparse::{gen, mm, order, symbolic, AssemblyTree, CscMatrix};

struct Row {
    key: String,
    report: ExecReport,
    /// `wall₁ / (w · wall_w)`; `None` for rows outside the worker sweep.
    efficiency: Option<f64>,
    residual: f64,
}

fn analyze_2d(k: usize) -> (AssemblyTree, CscMatrix) {
    let a = gen::grid_laplacian_2d(k);
    let perm = order::nested_dissection_2d(k);
    let at = symbolic::analyze(&a, &perm, 4).unwrap();
    let ap = a.permute_sym(&at.symbolic.perm).unwrap();
    (at, ap)
}

fn analyze_3d(k: usize) -> (AssemblyTree, CscMatrix) {
    let a = gen::grid_laplacian_3d(k);
    let perm = order::nested_dissection_3d(k);
    let at = symbolic::analyze(&a, &perm, 8).unwrap();
    let ap = a.permute_sym(&at.symbolic.perm).unwrap();
    (at, ap)
}

fn assert_bitwise(reference: &Factorization, got: &Factorization, what: &str) {
    for (s, (a, b)) in reference.panels.iter().zip(&got.panels).enumerate() {
        assert_eq!(a.len(), b.len(), "{what}: snode {s} panel length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "{what}: snode {s} entry {i}: {x} vs {y}"
            );
        }
    }
}

/// The E15 malleable-vs-task-parallel comparison. Returns JSON lines
/// plus the 8-worker speedup.
fn malleable_section(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    json: &mut Vec<String>,
) -> f64 {
    let widest = at
        .symbolic
        .supernodes
        .iter()
        .map(|s| s.front_order())
        .max()
        .unwrap();
    let root_share = {
        let root_flops: f64 = at
            .symbolic
            .supernodes
            .iter()
            .filter(|s| s.front_order() * 2 > widest)
            .map(|s| s.flops())
            .sum();
        root_flops / at.tree.total_work()
    };
    println!(
        "malleable comparison: {} supernodes, widest front {widest}, \
         wide-front flop share {:.0}%",
        at.tree.len(),
        100.0 * root_share
    );

    // serial blocked reference: both executors must be bit-identical
    let (reference, _) = execute_serial(at, ap, schedule, &RustBackend::default()).unwrap();

    let mut table = Table::new(&[
        "executor", "workers", "wall (s)", "Mflop/s", "efficiency", "avg team", "max team",
    ]);
    let mut tp_wall = std::collections::BTreeMap::new();
    let mut ml_wall = std::collections::BTreeMap::new();
    let mut base_wall = None;
    for workers in [1usize, 2, 4, 8] {
        for malleable in [false, true] {
            let ((fact, report), _) = timed(|| {
                if malleable {
                    execute_malleable(at, ap, schedule, &RustBackend::default(), workers).unwrap()
                } else {
                    execute_parallel(at, ap, schedule, &RustBackend::default(), workers).unwrap()
                }
            });
            let label = if malleable { "malleable" } else { "task-parallel" };
            assert_bitwise(&reference, &fact, label);
            let base = *base_wall.get_or_insert(report.wall_seconds);
            let efficiency = base / (workers as f64 * report.wall_seconds.max(1e-12));
            table.row(&[
                label.into(),
                format!("{workers}"),
                format!("{:.3}", report.wall_seconds),
                format!("{:.1}", report.flop_rate() / 1e6),
                format!("{efficiency:.2}"),
                format!("{:.2}", report.avg_team()),
                format!("{}", report.max_team()),
            ]);
            if malleable {
                ml_wall.insert(workers, report.wall_seconds);
            } else {
                tp_wall.insert(workers, report.wall_seconds);
            }
            json.push(format!(
                "  \"e2e_{}_workers_{workers}\": {{\"wall_s\": {:.6}, \"mflops\": {:.2}, \
                 \"parallel_efficiency\": {efficiency:.4}, \"avg_team\": {:.4}, \
                 \"max_team\": {}}}",
                if malleable { "malleable" } else { "taskpar" },
                report.wall_seconds,
                report.flop_rate() / 1e6,
                report.avg_team(),
                report.max_team(),
            ));
            if malleable && workers == 8 {
                for occ in report.occupancy() {
                    println!(
                        "  occupancy ({}, {}]: {} fronts, avg team {:.2}, max team {}",
                        occ.lo,
                        if occ.hi == usize::MAX { "inf".into() } else { occ.hi.to_string() },
                        occ.fronts,
                        occ.avg_team,
                        occ.max_team
                    );
                }
            }
        }
    }
    print!("{}", table.render());
    let speedup = tp_wall[&8] / ml_wall[&8].max(1e-12);
    println!("malleable speedup at 8 workers: {speedup:.3}x");
    json.push(format!(
        "  \"malleable_widest_front\": {widest}, \"malleable_root_flop_share\": {root_share:.4}, \
         \"malleable_speedup_8w\": {speedup:.4}"
    ));
    speedup
}

/// E21 corpus rows: each `.mtx` under `examples/matrices/` through the
/// full pipeline (parse → RCM → analyze → PM schedule) with the scalar
/// blocked backend and the dispatched-SIMD one, best-of-3 timing.
/// Residuals are gated normwise (epsilon mode — SIMD reassociates the
/// inner loops, so bit-identity to the scalar path is not claimed).
fn corpus_section(json: &mut Vec<String>) {
    let scalar =
        RustBackend::with_config(FrontConfig { block: dense::BLOCK, simd: SimdMode::Off })
            .expect("scalar config");
    let simd = RustBackend::with_config(FrontConfig { block: dense::BLOCK, simd: SimdMode::Auto })
        .expect("auto config");
    println!("dispatched isa: {}", simd.isa().name());
    json.push(format!("  \"kernel_isa\": \"{}\"", simd.isa().tag()));

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/matrices");
    let mut paths: Vec<std::path::PathBuf> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "mtx"))
            .collect(),
        Err(e) => {
            println!("(corpus skipped: cannot read {}: {e})", dir.display());
            return;
        }
    };
    paths.sort();

    let mut table = Table::new(&[
        "matrix", "n", "widest", "Mflop/s scalar", "Mflop/s simd", "simd x", "malleable 8w x",
        "residual",
    ]);
    // (widest front, scalar Mflop/s, simd Mflop/s, stem) of the
    // widest-front cell — the hard-assert target
    let mut widest_cell: Option<(usize, f64, f64, String)> = None;
    for path in &paths {
        let stem = path.file_stem().unwrap().to_string_lossy().to_string();
        let a = mm::read_matrix_market(path).expect("corpus file parses");
        let perm = order::reverse_cuthill_mckee(&a);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let pm = PmSchedule::for_tree(&at.tree, 0.9, &Profile::constant(8.0));
        let widest =
            at.symbolic.supernodes.iter().map(|s| s.front_order()).max().unwrap();
        let flops = at.tree.total_work();

        let run = |backend: &RustBackend, workers: usize, malleable: bool| -> (f64, f64) {
            let mut best = f64::INFINITY;
            let mut resid = 0.0;
            for _ in 0..3 {
                let (fact, report) = if malleable {
                    execute_malleable(&at, &ap, &pm.schedule, backend, workers).unwrap()
                } else {
                    execute_parallel(&at, &ap, &pm.schedule, backend, workers).unwrap()
                };
                best = best.min(report.wall_seconds.max(1e-12));
                resid = multifrontal::residual(&at, &ap, &fact);
            }
            (flops / best / 1e6, resid)
        };
        let (mf_scalar, r_scalar) = run(&scalar, 1, false);
        let (mf_simd, r_simd) = run(&simd, 1, false);
        let (mf_ml, r_ml) = run(&simd, 8, true);
        for (r, what) in [(r_scalar, "scalar"), (r_simd, "simd"), (r_ml, "malleable")] {
            assert!(r < 1e-8, "{stem} {what}: residual {r:.3e} over epsilon gate");
        }
        let simd_x = mf_simd / mf_scalar.max(1e-12);
        let ml_x = mf_ml / mf_simd.max(1e-12);
        table.row(&[
            stem.clone(),
            format!("{}", a.n),
            format!("{widest}"),
            format!("{mf_scalar:.1}"),
            format!("{mf_simd:.1}"),
            format!("{simd_x:.2}"),
            format!("{ml_x:.2}"),
            format!("{r_simd:.1e}"),
        ]);
        json.push(format!(
            "  \"corpus_{stem}\": {{\"n\": {}, \"widest_front\": {widest}, \
             \"mflops_scalar\": {mf_scalar:.2}, \"mflops_simd\": {mf_simd:.2}, \
             \"simd_speedup\": {simd_x:.4}, \"malleable_speedup_8w\": {ml_x:.4}, \
             \"residual\": {r_simd:.3e}}}",
            a.n
        ));
        let wider = match &widest_cell {
            Some(c) => widest > c.0,
            None => true,
        };
        if wider {
            widest_cell = Some((widest, mf_scalar, mf_simd, stem));
        }
    }
    print!("{}", table.render());

    if let Some((widest, mf_scalar, mf_simd, stem)) = widest_cell {
        if simd.isa().is_simd() {
            // the tentpole's hard gate: on SIMD hardware the dispatched
            // microkernels must beat the scalar blocked path where the
            // fronts are widest
            assert!(
                mf_simd >= mf_scalar,
                "simd kernels slower than scalar on {stem} (widest front {widest}): \
                 {mf_simd:.1} < {mf_scalar:.1} Mflop/s"
            );
            println!("simd >= scalar on widest-front cell {stem}: ok");
        } else {
            println!("(simd-vs-scalar assert skipped: dispatched isa is scalar)");
        }
    }
}

/// E21 block-size × amalgamation sweep on the 3D problem: single-worker
/// throughput per `(block, amalg)` cell under the dispatched ISA.
fn block_sweep_section(k3: usize, json: &mut Vec<String>) {
    println!();
    header("e2e_factorize sweep", "block size x amalgamation on grid3d");
    let a = gen::grid_laplacian_3d(k3);
    let perm = order::nested_dissection_3d(k3);
    let mut table = Table::new(&["block", "amalg", "wall (s)", "Mflop/s"]);
    let mut cells: Vec<String> = Vec::new();
    let mut best: Option<(f64, usize, usize)> = None;
    for amalg in [4usize, 16] {
        let at = symbolic::analyze(&a, &perm, amalg).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let pm = PmSchedule::for_tree(&at.tree, 0.9, &Profile::constant(8.0));
        let flops = at.tree.total_work();
        for block in [32usize, 64, 128] {
            let backend = RustBackend::with_config(FrontConfig { block, simd: SimdMode::Auto })
                .expect("sweep config");
            let mut wall = f64::INFINITY;
            for _ in 0..2 {
                let (_, report) =
                    execute_parallel(&at, &ap, &pm.schedule, &backend, 1).unwrap();
                wall = wall.min(report.wall_seconds.max(1e-12));
            }
            let mflops = flops / wall / 1e6;
            table.row(&[
                format!("{block}"),
                format!("{amalg}"),
                format!("{wall:.3}"),
                format!("{mflops:.1}"),
            ]);
            cells.push(format!("\"b{block}_a{amalg}\": {mflops:.2}"));
            let better = match best {
                Some((m, _, _)) => mflops > m,
                None => true,
            };
            if better {
                best = Some((mflops, block, amalg));
            }
        }
    }
    print!("{}", table.render());
    let (bm, bb, ba) = best.expect("sweep ran at least one cell");
    println!("best cell: block {bb}, amalg {ba} ({bm:.1} Mflop/s)");
    json.push(format!(
        "  \"block_sweep\": {{{}, \"best_block\": {bb}, \"best_amalg\": {ba}, \
         \"best_mflops\": {bm:.2}}}",
        cells.join(", ")
    ));
}

fn main() {
    header("e2e_factorize", "grid Laplacian multifrontal factorization");
    let k = env_usize("GRID", 40);
    let k3 = env_usize("GRID3D", 14);
    let malleable_on = !has_flag("no-malleable") || has_flag("malleable");
    let alpha = 0.9;
    let p = 8.0;

    let ((at, ap), secs) = timed(|| analyze_2d(k));
    println!(
        "analysis: grid {k}x{k}, {} supernodes, {:.3e} flops ({secs:.2}s)",
        at.tree.len(),
        at.tree.total_work()
    );
    println!(
        "symbolic peak front memory: {:.1} MiB",
        malltree::frontal::arena::symbolic_peak_f64s(&at) as f64 * 8.0 / (1024.0 * 1024.0)
    );
    let (pm, secs) = timed(|| PmSchedule::for_tree(&at.tree, alpha, &Profile::constant(p)));
    println!("PM schedule: makespan {:.3e} ({secs:.3}s)", pm.schedule.makespan);

    let mut table = Table::new(&[
        "backend", "workers", "wall (s)", "Mflop/s", "assembly", "peak front", "efficiency",
        "residual",
    ]);
    let mut rows: Vec<Row> = Vec::new();
    let mut base_wall = None;
    for workers in [1usize, 2, 4, 8] {
        let ((fact, report), _) =
            timed(|| execute_parallel(&at, &ap, &pm.schedule, &RustBackend::default(), workers).unwrap());
        let r = multifrontal::residual(&at, &ap, &fact);
        assert!(r < 1e-10, "workers={workers}: residual {r}");
        let base = *base_wall.get_or_insert(report.wall_seconds);
        let efficiency = base / (workers as f64 * report.wall_seconds.max(1e-12));
        table.row(&[
            report.backend.clone(),
            format!("{workers}"),
            format!("{:.3}", report.wall_seconds),
            format!("{:.1}", report.flop_rate() / 1e6),
            format!("{:.1}%", 100.0 * report.assembly_fraction()),
            format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
            format!("{efficiency:.2}"),
            format!("{r:.1e}"),
        ]);
        rows.push(Row {
            key: format!("e2e_workers_{workers}"),
            report,
            efficiency: Some(efficiency),
            residual: r,
        });
    }

    // unblocked-kernel baseline at 1 worker: the blocked-vs-naive gap
    {
        let ((fact, report), _) =
            timed(|| execute_parallel(&at, &ap, &pm.schedule, &NaiveBackend, 1).unwrap());
        let r = multifrontal::residual(&at, &ap, &fact);
        table.row(&[
            report.backend.clone(),
            "1".into(),
            format!("{:.3}", report.wall_seconds),
            format!("{:.1}", report.flop_rate() / 1e6),
            format!("{:.1}%", 100.0 * report.assembly_fraction()),
            format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
            "-".into(),
            format!("{r:.1e}"),
        ]);
        rows.push(Row { key: "e2e_naive_workers_1".into(), report, efficiency: None, residual: r });
    }

    // PJRT path if artifacts are available
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        match malltree::runtime::Runtime::cpu(artifacts) {
            Ok(rt) => {
                let rt = std::sync::Arc::new(rt);
                rt.warm_up().expect("compile artifacts");
                let backend = PjrtBackend::new(rt);
                let widest = at
                    .symbolic
                    .supernodes
                    .iter()
                    .map(|s| s.front_order())
                    .max()
                    .unwrap();
                if widest <= backend.max_front() {
                    let ((fact, report), _) = timed(|| {
                        execute_serial(&at, &ap, &pm.schedule, &backend).unwrap()
                    });
                    let r = multifrontal::residual(&at, &ap, &fact);
                    table.row(&[
                        report.backend.clone(),
                        "1 (queue)".into(),
                        format!("{:.3}", report.wall_seconds),
                        format!("{:.1}", report.flop_rate() / 1e6),
                        format!("{:.1}%", 100.0 * report.assembly_fraction()),
                        format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
                        "-".into(),
                        format!("{r:.1e}"),
                    ]);
                } else {
                    println!("(pjrt skipped: widest front {widest} > artifact menu)");
                }
            }
            Err(e) => println!("(pjrt skipped: {e})"),
        }
    } else {
        println!("(pjrt skipped: run `make artifacts` first)");
    }
    print!("{}", table.render());

    // E15: malleable vs task-parallel on a root-dominated 3D problem
    let mut extra_json: Vec<String> = Vec::new();
    if malleable_on {
        println!();
        header(
            "e2e_factorize --malleable",
            "share-driven worker teams vs task parallelism",
        );
        let ((at3, ap3), secs) = timed(|| analyze_3d(k3));
        println!(
            "analysis: grid {k3}x{k3}x{k3}, {} supernodes, {:.3e} flops ({secs:.2}s)",
            at3.tree.len(),
            at3.tree.total_work()
        );
        let pm3 = PmSchedule::for_tree(&at3.tree, alpha, &Profile::constant(p));
        malleable_section(&at3, &ap3, &pm3.schedule, &mut extra_json);
    } else {
        println!("(malleable comparison skipped: --no-malleable)");
    }

    // E21: SIMD kernel corpus + block-size sweep (EXPERIMENTS.md §Kernels)
    println!();
    header("e2e_factorize corpus", "SIMD microkernels on the .mtx corpus");
    corpus_section(&mut extra_json);
    block_sweep_section(k3, &mut extra_json);

    // Machine-readable perf trajectory (BENCH_e2e.json at repo root).
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"grid\": {k},\n  \"grid3d\": {k3},\n  \"supernodes\": {},\n  \
         \"total_flops\": {:.6e},\n",
        at.tree.len(),
        at.tree.total_work()
    ));
    for row in rows.iter() {
        let efficiency = match row.efficiency {
            Some(e) => format!("{e:.4}"),
            None => "null".into(),
        };
        json.push_str(&format!(
            "  \"{}\": {{\"wall_s\": {:.6}, \"mflops\": {:.2}, \"assembly_fraction\": {:.4}, \
             \"peak_front_bytes\": {}, \"parallel_efficiency\": {efficiency}, \
             \"residual\": {:.3e}}},\n",
            row.key,
            row.report.wall_seconds,
            row.report.flop_rate() / 1e6,
            row.report.assembly_fraction(),
            row.report.peak_front_bytes,
            row.residual,
        ));
    }
    json.push_str(&extra_json.join(",\n"));
    if extra_json.is_empty() {
        // drop the dangling comma of the last worker row
        json.truncate(json.trim_end_matches(",\n").len());
    }
    json.push_str("\n}\n");
    let out = bench_output_path("BENCH_e2e.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
}
