//! End-to-end factorization bench (EXPERIMENTS.md E14): the complete
//! pipeline — analysis → PM schedule → numeric multifrontal execution —
//! timed for the parallel Rust backend (worker sweep), the naive-kernel
//! baseline, and the PJRT accelerator-queue backend when artifacts are
//! present. Writes the machine-readable **`BENCH_e2e.json`** at the
//! repo root (per worker count: Mflop/s, assembly fraction, peak front
//! bytes, parallel efficiency), the numeric-pipeline counterpart of
//! `BENCH_sched.json`.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::exec::{execute_parallel, execute_serial, ExecReport};
use malltree::frontal::{multifrontal, NaiveBackend, PjrtBackend, RustBackend};
use malltree::metrics::Table;
use malltree::sched::{PmSchedule, Profile};
use malltree::sparse::{gen, order, symbolic};

struct Row {
    key: String,
    report: ExecReport,
    /// `wall₁ / (w · wall_w)`; `None` for rows outside the worker sweep.
    efficiency: Option<f64>,
    residual: f64,
}

fn main() {
    header("e2e_factorize", "grid Laplacian multifrontal factorization");
    let k = env_usize("GRID", 40);
    let alpha = 0.9;
    let p = 8.0;

    let ((at, ap), secs) = timed(|| {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        (at, ap)
    });
    println!(
        "analysis: grid {k}x{k}, {} supernodes, {:.3e} flops ({secs:.2}s)",
        at.tree.len(),
        at.tree.total_work()
    );
    println!(
        "symbolic peak front memory: {:.1} MiB",
        malltree::frontal::arena::symbolic_peak_f64s(&at) as f64 * 8.0 / (1024.0 * 1024.0)
    );
    let (pm, secs) = timed(|| PmSchedule::for_tree(&at.tree, alpha, &Profile::constant(p)));
    println!("PM schedule: makespan {:.3e} ({secs:.3}s)", pm.schedule.makespan);

    let mut table = Table::new(&[
        "backend", "workers", "wall (s)", "Mflop/s", "assembly", "peak front", "efficiency",
        "residual",
    ]);
    let mut rows: Vec<Row> = Vec::new();
    let mut base_wall = None;
    for workers in [1usize, 2, 4, 8] {
        let ((fact, report), _) =
            timed(|| execute_parallel(&at, &ap, &pm.schedule, &RustBackend, workers).unwrap());
        let r = multifrontal::residual(&at, &ap, &fact);
        assert!(r < 1e-10, "workers={workers}: residual {r}");
        let base = *base_wall.get_or_insert(report.wall_seconds);
        let efficiency = base / (workers as f64 * report.wall_seconds.max(1e-12));
        table.row(&[
            report.backend.clone(),
            format!("{workers}"),
            format!("{:.3}", report.wall_seconds),
            format!("{:.1}", report.flop_rate() / 1e6),
            format!("{:.1}%", 100.0 * report.assembly_fraction()),
            format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
            format!("{efficiency:.2}"),
            format!("{r:.1e}"),
        ]);
        rows.push(Row {
            key: format!("e2e_workers_{workers}"),
            report,
            efficiency: Some(efficiency),
            residual: r,
        });
    }

    // unblocked-kernel baseline at 1 worker: the blocked-vs-naive gap
    {
        let ((fact, report), _) =
            timed(|| execute_parallel(&at, &ap, &pm.schedule, &NaiveBackend, 1).unwrap());
        let r = multifrontal::residual(&at, &ap, &fact);
        table.row(&[
            report.backend.clone(),
            "1".into(),
            format!("{:.3}", report.wall_seconds),
            format!("{:.1}", report.flop_rate() / 1e6),
            format!("{:.1}%", 100.0 * report.assembly_fraction()),
            format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
            "-".into(),
            format!("{r:.1e}"),
        ]);
        rows.push(Row { key: "e2e_naive_workers_1".into(), report, efficiency: None, residual: r });
    }

    // PJRT path if artifacts are available
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        match malltree::runtime::Runtime::cpu(artifacts) {
            Ok(rt) => {
                let rt = std::sync::Arc::new(rt);
                rt.warm_up().expect("compile artifacts");
                let backend = PjrtBackend::new(rt);
                let widest = at
                    .symbolic
                    .supernodes
                    .iter()
                    .map(|s| s.front_order())
                    .max()
                    .unwrap();
                if widest <= backend.max_front() {
                    let ((fact, report), _) = timed(|| {
                        execute_serial(&at, &ap, &pm.schedule, &backend).unwrap()
                    });
                    let r = multifrontal::residual(&at, &ap, &fact);
                    table.row(&[
                        report.backend.clone(),
                        "1 (queue)".into(),
                        format!("{:.3}", report.wall_seconds),
                        format!("{:.1}", report.flop_rate() / 1e6),
                        format!("{:.1}%", 100.0 * report.assembly_fraction()),
                        format!("{:.1} MiB", report.peak_front_bytes as f64 / (1024.0 * 1024.0)),
                        "-".into(),
                        format!("{r:.1e}"),
                    ]);
                } else {
                    println!("(pjrt skipped: widest front {widest} > artifact menu)");
                }
            }
            Err(e) => println!("(pjrt skipped: {e})"),
        }
    } else {
        println!("(pjrt skipped: run `make artifacts` first)");
    }
    print!("{}", table.render());

    // Machine-readable perf trajectory (BENCH_e2e.json at repo root).
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"grid\": {k},\n  \"supernodes\": {},\n  \"total_flops\": {:.6e},\n",
        at.tree.len(),
        at.tree.total_work()
    ));
    for (i, row) in rows.iter().enumerate() {
        let efficiency = match row.efficiency {
            Some(e) => format!("{e:.4}"),
            None => "null".into(),
        };
        json.push_str(&format!(
            "  \"{}\": {{\"wall_s\": {:.6}, \"mflops\": {:.2}, \"assembly_fraction\": {:.4}, \
             \"peak_front_bytes\": {}, \"parallel_efficiency\": {efficiency}, \
             \"residual\": {:.3e}}}{}\n",
            row.key,
            row.report.wall_seconds,
            row.report.flop_rate() / 1e6,
            row.report.assembly_fraction(),
            row.report.peak_front_bytes,
            row.residual,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    match std::fs::write("BENCH_e2e.json", &json) {
        Ok(()) => println!("\nwrote BENCH_e2e.json"),
        Err(e) => eprintln!("\ncould not write BENCH_e2e.json: {e}"),
    }
}
