//! End-to-end factorization bench (EXPERIMENTS.md E14): the complete
//! pipeline — analysis → PM schedule → numeric multifrontal execution —
//! timed for the parallel Rust backend (worker sweep) and the PJRT
//! accelerator-queue backend when artifacts are present.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::exec::{execute_parallel, execute_serial};
use malltree::frontal::{multifrontal, PjrtBackend, RustBackend};
use malltree::metrics::Table;
use malltree::sched::{PmSchedule, Profile};
use malltree::sparse::{gen, order, symbolic};

fn main() {
    header("e2e_factorize", "grid Laplacian multifrontal factorization");
    let k = env_usize("GRID", 40);
    let alpha = 0.9;
    let p = 8.0;

    let ((at, ap), secs) = timed(|| {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        (at, ap)
    });
    println!(
        "analysis: grid {k}x{k}, {} supernodes, {:.3e} flops ({secs:.2}s)",
        at.tree.len(),
        at.tree.total_work()
    );
    let (pm, secs) = timed(|| PmSchedule::for_tree(&at.tree, alpha, &Profile::constant(p)));
    println!("PM schedule: makespan {:.3e} ({secs:.3}s)", pm.schedule.makespan);

    let mut table = Table::new(&["backend", "workers", "wall (s)", "Gflop/s", "residual"]);
    for workers in [1usize, 2, 4, 8] {
        let ((fact, report), _) =
            timed(|| execute_parallel(&at, &ap, &pm.schedule, &RustBackend, workers).unwrap());
        let r = multifrontal::residual(&at, &ap, &fact);
        table.row(&[
            "rust-f64".into(),
            format!("{workers}"),
            format!("{:.3}", report.wall_seconds),
            format!("{:.3}", report.flop_rate() / 1e9),
            format!("{r:.1e}"),
        ]);
    }

    // PJRT path if artifacts are available
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        match malltree::runtime::Runtime::cpu(artifacts) {
            Ok(rt) => {
                let rt = std::sync::Arc::new(rt);
                rt.warm_up().expect("compile artifacts");
                let backend = PjrtBackend::new(rt);
                let widest = at
                    .symbolic
                    .supernodes
                    .iter()
                    .map(|s| s.front_order())
                    .max()
                    .unwrap();
                if widest <= backend.max_front() {
                    let ((fact, report), _) = timed(|| {
                        execute_serial(&at, &ap, &pm.schedule, &backend).unwrap()
                    });
                    let r = multifrontal::residual(&at, &ap, &fact);
                    table.row(&[
                        "pjrt-xla-f32".into(),
                        "1 (queue)".into(),
                        format!("{:.3}", report.wall_seconds),
                        format!("{:.3}", report.flop_rate() / 1e9),
                        format!("{r:.1e}"),
                    ]);
                } else {
                    println!("(pjrt skipped: widest front {widest} > artifact menu)");
                }
            }
            Err(e) => println!("(pjrt skipped: {e})"),
        }
    } else {
        println!("(pjrt skipped: run `make artifacts` first)");
    }
    print!("{}", table.render());
}
