//! §Mem harness: memory-aware scheduling quality (DESIGN.md §12).
//!
//! For each tree family × α ∈ {0.7, 0.9, 1.0}:
//!
//! * **Liu vs default order** — peak reduction (%) of Liu's optimal
//!   sequential postorder over the repo's default `topo_up` traversal
//!   (`liu_reduction_pct`; ≥ 0 by Liu's optimality, asserted);
//! * **makespan vs cap** — the memory-bounded PM schedule's makespan
//!   inflation (%) at caps interpolated between the Liu serial peak
//!   (minimum possible) and the unbounded plan's peak, each point
//!   DES-replayed to confirm the cap is respected
//!   (`pareto` rows: `cap_ratio` of the unbounded peak,
//!   `makespan_inflation_pct`, `replay_peak_ratio`).
//!
//! Families: real analysis trees (grid2d / grid3d under nested
//! dissection, exact symbolic weights), random trees with synthetic
//! weights, and a crafted adversarial family where the default order
//! is provably suboptimal (its reduction is asserted strictly
//! positive). Results land machine-readably in `BENCH_mem.json` at
//! the repo root; CI runs a reduced-size smoke (`MALLTREE_BENCH_DIV`).

mod bench_util;

use bench_util::{env_usize, header};
use malltree::mem::{bounded_schedule, liu_order, peak, subtree_peaks, MemWeights};
use malltree::metrics::Table;
use malltree::model::TaskTree;
use malltree::sched::Profile;
use malltree::sim::replay_memory;
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;
use malltree::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};

/// Root with `pairs` leaf-child pairs ordered adversarially for the
/// default traversal: a high-residual/low-peak leaf (front = cb = H)
/// listed *before* a high-peak/low-residual leaf (front = 4H, cb = 1).
/// The default order pays `H + 4H` per pair where Liu pays `~4H`.
fn adversarial(pairs: usize, h: f64) -> (TaskTree, MemWeights) {
    let n = 1 + 2 * pairs;
    let parents = vec![0usize; n];
    let lens: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
    let tree = TaskTree::from_parents(&parents, &lens).unwrap();
    let mut front = vec![h / 2.0];
    let mut cb = vec![0.0];
    for _ in 0..pairs {
        front.push(h); // B: low peak, heavy residual
        cb.push(h);
        front.push(4.0 * h); // A: heavy peak, light residual
        cb.push(1.0);
    }
    (tree, MemWeights { front, cb })
}

struct Cell {
    key: String,
    liu_reduction_pct: f64,
    unbounded_peak: f64,
    /// `(cap_ratio, makespan_inflation_pct, replay_peak_ratio)`
    pareto: Vec<(f64, f64, f64)>,
}

fn main() {
    header("mem_sched", "memory-aware scheduling: Liu order + cap Pareto (§Mem)");
    let scale = env_usize("SCALE", 1).max(1);
    let div = env_usize("DIV", 1).max(1);
    let grid2d = (32 * scale / div).max(10);
    let grid3d = (10 * scale / div).max(5);
    let rand_n = (4_000 * scale / div).max(200);
    let p = 8.0;
    let cap_fracs = [0.0, 0.35, 0.6, 0.85, 1.0];

    let mut rng = Rng::new(0x3E3);
    let mut families: Vec<(String, TaskTree, MemWeights)> = Vec::new();
    {
        let a = gen::grid_laplacian_2d(grid2d);
        let perm = order::nested_dissection_2d(grid2d);
        let at = symbolic::analyze(&a, &perm, 4).expect("grid2d analysis");
        let w = MemWeights::from_symbolic(&at);
        families.push((format!("grid2d_{grid2d}"), at.tree, w));
    }
    {
        let a = gen::grid_laplacian_3d(grid3d);
        let perm = order::nested_dissection_3d(grid3d);
        let at = symbolic::analyze(&a, &perm, 4).expect("grid3d analysis");
        let w = MemWeights::from_symbolic(&at);
        families.push((format!("grid3d_{grid3d}"), at.tree, w));
    }
    for class in [TreeClass::Uniform, TreeClass::Deep] {
        let t = random_tree(class, rand_n, &mut rng);
        let w = synthetic_mem_weights(&t, &mut rng);
        families.push((format!("rand_{class:?}"), t, w));
    }
    {
        let (t, w) = adversarial(8, 1000.0);
        families.push(("adversarial".to_string(), t, w));
    }

    let mut table = Table::new(&[
        "family", "alpha", "liu reduction", "unbounded peak", "cap 0.35", "cap 0.60", "cap 0.85",
    ]);
    let mut cells: Vec<Cell> = Vec::new();

    for (name, tree, w) in &families {
        w.validate(tree).expect("weights valid");
        let default_peak = peak(tree, w, &tree.topo_up());
        let liu_peak = peak(tree, w, &liu_order(tree, w));
        // the cap anchor uses the formula value: the serial-fallback
        // plan reproduces it bit-for-bit, so `cap >= anchor` is
        // feasible by construction (the evaluated `liu_peak` can
        // differ by float association)
        let liu_anchor = subtree_peaks(tree, w)[tree.root as usize];
        assert!(
            liu_peak <= default_peak * (1.0 + 1e-9),
            "{name}: Liu order lost to the default ({liu_peak} > {default_peak})"
        );
        let liu_reduction_pct = 100.0 * (default_peak - liu_peak) / default_peak.max(1e-300);
        for alpha in [0.7, 0.9, 1.0] {
            let profile = Profile::constant(p);
            let unbounded = bounded_schedule(tree, w, alpha, &profile, f64::INFINITY);
            let unbounded_peak = unbounded.planned_peak;
            let mut pareto = Vec::new();
            let mut row_cells = Vec::new();
            for &frac in &cap_fracs {
                let cap = liu_anchor + frac * (unbounded_peak - liu_anchor);
                let b = bounded_schedule(tree, w, alpha, &profile, cap);
                assert!(
                    b.feasible,
                    "{name} α={alpha}: cap {cap} >= liu peak must be feasible"
                );
                let replay = replay_memory(tree, w, &b.schedule, None);
                assert!(
                    replay.peak <= cap * (1.0 + 1e-9),
                    "{name} α={alpha}: replay peak {} over cap {cap}",
                    replay.peak
                );
                let inflation =
                    100.0 * (b.makespan - unbounded.makespan) / unbounded.makespan;
                assert!(
                    inflation >= -1e-6,
                    "{name} α={alpha}: bounded schedule beat the unbounded one"
                );
                pareto.push((cap / unbounded_peak, inflation, replay.peak / unbounded_peak));
                if (0.3..0.9).contains(&frac) {
                    row_cells.push(format!("{inflation:+.2}%"));
                }
            }
            table.row(&[
                name.clone(),
                format!("{alpha:.2}"),
                format!("{liu_reduction_pct:.2}%"),
                format!("{unbounded_peak:.3e}"),
                row_cells[0].clone(),
                row_cells[1].clone(),
                row_cells[2].clone(),
            ]);
            cells.push(Cell {
                key: format!("{name}_a{alpha:.2}"),
                liu_reduction_pct,
                unbounded_peak,
                pareto,
            });
        }
    }
    print!("{}", table.render());

    // the crafted family must show a strict Liu improvement
    let adv_reduction = cells
        .iter()
        .filter(|c| c.key.starts_with("adversarial"))
        .map(|c| c.liu_reduction_pct)
        .fold(f64::NEG_INFINITY, f64::max);
    println!("\nadversarial-family Liu reduction vs default order: {adv_reduction:.2}%");
    assert!(
        adv_reduction > 0.0,
        "Liu order should strictly beat the default on the adversarial family"
    );

    // Machine-readable artifact (BENCH_mem.json at the repo root).
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"scale\": {scale},\n  \"div\": {div},\n"));
    json.push_str(&format!(
        "  \"adversarial_liu_reduction_pct\": {adv_reduction:.4},\n"
    ));
    for (i, c) in cells.iter().enumerate() {
        let pareto: Vec<String> = c
            .pareto
            .iter()
            .map(|&(r, infl, pk)| {
                format!(
                    "{{\"cap_ratio\": {r:.6}, \"makespan_inflation_pct\": {infl:.4}, \
                     \"replay_peak_ratio\": {pk:.6}}}"
                )
            })
            .collect();
        json.push_str(&format!(
            "  \"{}\": {{\"liu_reduction_pct\": {:.4}, \"unbounded_peak\": {:.6e}, \
             \"pareto\": [{}]}}{}\n",
            c.key,
            c.liu_reduction_pct,
            c.unbounded_peak,
            pareto.join(", "),
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    let out = bench_util::bench_output_path("BENCH_mem.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
