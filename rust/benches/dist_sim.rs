//! §Dist harness: the paper-§6 simulation study on N-node platforms.
//!
//! For each tree family × node count × α, map the tree with the
//! speedup-aware strategy (power-length LPT candidates selected by
//! DES replay — Algorithm 11 generalized, with the baseline
//! partitions and the single-node schedule in the candidate set) and
//! with the speedup-unaware baselines as mapped (work-LPT "prop",
//! critical-path-LPT "cp"), replay everything through the cross-node
//! DES, and record machine-readably in `BENCH_dist.json` at the repo
//! root:
//!
//! * `approx_ratio` — DES makespan of the pm mapping over the pooled
//!   `L_G/(Np)^α` lower bound (≥ 1; closer to 1 is better);
//! * `gain_vs_prop_pct` / `gain_vs_cp_pct` — relative makespan gain of
//!   the pm mapping over each baseline mapping (the §6 analogue of the
//!   paper's "up to 16% for α = 0.9" shared-memory claim; ≥ 0 by the
//!   candidate sweep);
//! * `vs_single_node` — pm-mapped makespan over the best single-node
//!   PM makespan (≤ 1 by the Algorithm-11 fallback).
//!
//! `RootMix` is the explicitly root-dominated family
//! (`workload::generator::root_shape_mix`): a heavy root over
//! equal-work branches of deliberately mixed shapes (chains next to
//! bushy stars), where balancing power-lengths provably beats
//! balancing raw work for α < 1. A two-node heterogeneous cell
//! exercises the Algorithm-12 λ-trimmed split (> 20 sibling
//! subtrees).
//!
//! The `net_*` cells price the links (DESIGN.md §15): the
//! network-aware pipeline (`distribute_networked`) versus the
//! comm-blind pm mapping under the same priced DES, plus a link-fault
//! replay of the winning mapping. They add per-cell
//! `gain_comm_aware_vs_blind_pct`, `bytes_moved`, `transfer_stall`,
//! `retransmits`, `remaps` and `best_vs_wait_pct` columns, and
//! hard-assert the structural bounds: network-aware ≤ comm-blind,
//! network-aware ≤ single node, Best recovery ≤ WaitOnly.
//!
//! Scaling knobs: `MALLTREE_BENCH_SCALE` multiplies sizes,
//! `MALLTREE_BENCH_DIV` divides them (CI smoke uses DIV=20 and skips
//! the N=8 row).

mod bench_util;

use bench_util::{env_usize, header};
use malltree::dist::{distribute, distribute_networked, MappingStrategy};
use malltree::mem::MemWeights;
use malltree::metrics::Table;
use malltree::model::{FaultEvent, FaultKind, FaultTrace, Platform, TaskTree};
use malltree::net::{replay_link_faults, NetModel, NetRecovery, NetSimConfig};
use malltree::sim::Policy;
use malltree::util::rng::Rng;
use malltree::workload::generator::{random_tree, root_shape_mix};
use malltree::workload::TreeClass;

/// Root-dominated tree with many shape-diverse random branches for the
/// heterogeneous two-node cell: > 20 sibling subtrees force the
/// Algorithm-12 trimmed enumeration to decide the split.
fn random_root_mix(k: usize, sub_n: usize, rng: &mut Rng) -> TaskTree {
    let classes = [
        TreeClass::Deep,
        TreeClass::Uniform,
        TreeClass::Deep,
        TreeClass::Binary,
    ];
    let mut parents = vec![0usize];
    let mut lens = vec![0.0f64];
    for i in 0..k {
        let sub = random_tree(classes[i % classes.len()], sub_n, rng);
        let off = parents.len();
        for node in &sub.nodes {
            parents.push(match node.parent {
                Some(p) => off + p as usize,
                None => 0,
            });
            lens.push(node.len);
        }
    }
    // root-dominated: the root carries ~5% of the total work itself
    lens[0] = lens.iter().sum::<f64>() * 0.05;
    TaskTree::from_parents(&parents, &lens).unwrap()
}

struct Cell {
    key: String,
    approx_ratio: f64,
    gain_vs_prop_pct: f64,
    gain_vs_cp_pct: f64,
    vs_single_node: f64,
}

/// One §Net cell: the network-aware pipeline on priced links, plus the
/// link-fault replay of the winning mapping under both recovery
/// policies.
struct NetCell {
    key: String,
    makespan: f64,
    gain_comm_aware_vs_blind_pct: f64,
    vs_single_node: f64,
    bytes_moved: f64,
    transfer_stall: f64,
    retransmits: usize,
    remaps: usize,
    best_vs_wait_pct: f64,
}

fn main() {
    header("dist_sim", "N-node mapping quality vs baselines (§6, §Dist)");
    let scale = env_usize("SCALE", 1).max(1);
    let div = env_usize("DIV", 1).max(1);
    let n_sub = (1_500 * scale / div).max(150);
    let trees_per_cell = 4usize;
    let p = 8.0;
    let lambda = 1.1;
    let nodes_list: Vec<usize> = if div == 1 { vec![2, 4, 8] } else { vec![2, 4] };

    // family generators take (rng, nodes): the crafted RootMix family
    // scales its branch count with the platform so every node has one
    // chain-shaped and one bushy branch to balance
    type Gen = Box<dyn Fn(&mut Rng, usize) -> TaskTree>;
    let families: Vec<(&str, Gen)> = vec![
        (
            "Uniform",
            Box::new(move |rng: &mut Rng, _| random_tree(TreeClass::Uniform, 2 * n_sub, rng)),
        ),
        (
            "Deep",
            Box::new(move |rng: &mut Rng, _| random_tree(TreeClass::Deep, 2 * n_sub, rng)),
        ),
        (
            "Binary",
            Box::new(move |rng: &mut Rng, _| random_tree(TreeClass::Binary, 2 * n_sub, rng)),
        ),
        (
            "RootMix",
            Box::new(|rng: &mut Rng, nodes| {
                // chain length varies per draw (the chain:bushy power
                // ratio is leaves-driven, so every draw stays kink-free
                // and strictly pm-favorable at α < 1); the scale factor
                // alone would make all draws equivalent
                let chain_len = rng.range(2, 5);
                root_shape_mix(nodes, rng.log_uniform(1.0, 10.0), chain_len, 3)
            }),
        ),
    ];

    let mut table = Table::new(&[
        "family", "N", "alpha", "ratio to bound", "gain vs prop", "gain vs cp", "vs 1 node",
    ]);
    let mut cells: Vec<Cell> = Vec::new();

    for (fam_i, (fam, gen)) in families.iter().enumerate() {
        for &nodes in &nodes_list {
            let plat = Platform::Homogeneous { nodes, p };
            for alpha in [0.7, 0.9, 1.0] {
                let mut rng = Rng::new(0xD157 + fam_i as u64);
                let (mut ratio, mut g_prop, mut g_cp, mut v_single) = (0.0, 0.0, 0.0, 0.0);
                for _ in 0..trees_per_cell {
                    let tree = gen(&mut rng, nodes);
                    let pm = distribute(&tree, &plat, alpha, MappingStrategy::Pm, lambda)
                        .expect("pm distribute");
                    let prop =
                        distribute(&tree, &plat, alpha, MappingStrategy::Proportional, lambda)
                            .expect("prop distribute");
                    let cp =
                        distribute(&tree, &plat, alpha, MappingStrategy::CriticalPath, lambda)
                            .expect("cp distribute");
                    // hard invariants of the pipeline (acceptance
                    // criteria of the §6 reproduction)
                    assert!(
                        pm.makespan >= pm.lower_bound * (1.0 - 1e-9),
                        "{fam} N={nodes} α={alpha}: below the pooled bound"
                    );
                    assert!(
                        pm.makespan <= pm.single_node_makespan * (1.0 + 1e-9),
                        "{fam} N={nodes} α={alpha}: worse than one node"
                    );
                    assert!(
                        pm.makespan <= prop.makespan * (1.0 + 1e-9),
                        "{fam} N={nodes} α={alpha}: pm lost to prop"
                    );
                    ratio += pm.approx_ratio();
                    g_prop += pm.gain_over(prop.makespan);
                    g_cp += pm.gain_over(cp.makespan);
                    v_single += pm.makespan / pm.single_node_makespan;
                }
                let k = trees_per_cell as f64;
                let cell = Cell {
                    key: format!("N{nodes}_a{alpha:.2}_{fam}"),
                    approx_ratio: ratio / k,
                    gain_vs_prop_pct: g_prop / k,
                    gain_vs_cp_pct: g_cp / k,
                    vs_single_node: v_single / k,
                };
                table.row(&[
                    fam.to_string(),
                    format!("{nodes}"),
                    format!("{alpha:.2}"),
                    format!("{:.3}", cell.approx_ratio),
                    format!("{:+.2}%", cell.gain_vs_prop_pct),
                    format!("{:+.2}%", cell.gain_vs_cp_pct),
                    format!("{:.3}", cell.vs_single_node),
                ]);
                cells.push(cell);
            }
        }
    }

    // Two heterogeneous nodes with > 20 sibling subtrees: the
    // Algorithm-12 λ-trimmed enumeration decides the split.
    {
        let mut rng = Rng::new(0xBEEF);
        let tree = random_root_mix(26, (n_sub / 8).max(40), &mut rng);
        let plat = Platform::Heterogeneous { speeds: vec![12.0, 5.0] };
        let alpha = 0.9;
        let pm = distribute(&tree, &plat, alpha, MappingStrategy::Pm, lambda)
            .expect("het distribute");
        let prop = distribute(&tree, &plat, alpha, MappingStrategy::Proportional, lambda)
            .expect("het prop distribute");
        let cp = distribute(&tree, &plat, alpha, MappingStrategy::CriticalPath, lambda)
            .expect("het cp distribute");
        assert!(pm.makespan >= pm.lower_bound * (1.0 - 1e-9));
        assert!(pm.makespan <= pm.single_node_makespan * (1.0 + 1e-9));
        let cell = Cell {
            key: "het2_trimmed_a0.90_RandomRootMix".to_string(),
            approx_ratio: pm.approx_ratio(),
            gain_vs_prop_pct: pm.gain_over(prop.makespan),
            gain_vs_cp_pct: pm.gain_over(cp.makespan),
            vs_single_node: pm.makespan / pm.single_node_makespan,
        };
        table.row(&[
            "RandomRootMix (het 12,5)".to_string(),
            "2".to_string(),
            format!("{alpha:.2}"),
            format!("{:.3}", cell.approx_ratio),
            format!("{:+.2}%", cell.gain_vs_prop_pct),
            format!("{:+.2}%", cell.gain_vs_cp_pct),
            format!("{:.3}", cell.vs_single_node),
        ]);
        cells.push(cell);
    }

    print!("{}", table.render());

    // §Net cells (DESIGN.md §15): price the links, let the candidate
    // sweep see them, then stress the winner with a link-fault trace.
    // Hard invariants: the network-aware selection never loses to the
    // comm-blind pm mapping or the best single node under the same
    // priced DES, and Best recovery never loses to WaitOnly.
    let mut net_table = Table::new(&[
        "family", "N", "net", "makespan", "gain vs blind", "words moved", "xfer stall",
        "retx", "remaps", "best vs wait",
    ]);
    let mut net_cells: Vec<NetCell> = Vec::new();
    let cfg = NetSimConfig { timeout_factor: 2.0, ..NetSimConfig::default() };
    for (fam_i, (fam, gen)) in families.iter().enumerate().take(3) {
        for &nodes in &nodes_list {
            if nodes > 4 {
                continue; // the priced DES rows stay at the smoke sizes
            }
            let plat = Platform::Homogeneous { nodes, p };
            let alpha = 0.9;
            for (net_name, lat, bw) in [("lan", 0.02, 8.0), ("wan", 0.5, 0.5)] {
                let net = NetModel::uniform(nodes, lat, bw);
                let mut rng = Rng::new(0x4E7 + fam_i as u64);
                let (mut mk, mut gain, mut v_single, mut bytes, mut stall) =
                    (0.0, 0.0, 0.0, 0.0, 0.0);
                let (mut retx, mut remaps) = (0usize, 0usize);
                let mut best_vs_wait = 0.0;
                let cell_trees = 2usize;
                for _ in 0..cell_trees {
                    let tree = gen(&mut rng, nodes);
                    let weights = MemWeights::from_task_lens(&tree);
                    let nd = distribute_networked(&tree, &plat, alpha, lambda, &weights, &net, &cfg)
                        .expect("networked distribute");
                    assert!(
                        nd.sim.makespan <= nd.comm_blind_makespan * (1.0 + 1e-9),
                        "{fam} N={nodes} {net_name}: network-aware lost to comm-blind pm"
                    );
                    assert!(
                        nd.sim.makespan <= nd.single_node_makespan * (1.0 + 1e-9),
                        "{fam} N={nodes} {net_name}: network-aware lost to single node"
                    );
                    let mff = nd.sim.makespan;
                    let trace = FaultTrace::new(vec![
                        FaultEvent {
                            time: 0.25 * mff,
                            kind: FaultKind::LinkDegrade {
                                a: 0,
                                b: 1,
                                factor: 0.25,
                                duration: 0.2 * mff,
                            },
                        },
                        FaultEvent {
                            time: 0.55 * mff,
                            kind: FaultKind::LinkDown { a: 0, b: 1, duration: 0.15 * mff },
                        },
                    ]);
                    let replay = |rec: NetRecovery| {
                        let cfg = NetSimConfig { recovery: rec, ..cfg };
                        replay_link_faults(
                            &tree,
                            alpha,
                            &plat,
                            &nd.mapping.node_of,
                            Policy::Pm,
                            &weights,
                            &net,
                            &cfg,
                            &trace,
                        )
                        .expect("link-fault replay")
                    };
                    let best = replay(NetRecovery::Best);
                    let wait = replay(NetRecovery::WaitOnly);
                    assert!(
                        best.sim.makespan <= wait.sim.makespan * (1.0 + 1e-9),
                        "{fam} N={nodes} {net_name}: Best recovery lost to WaitOnly"
                    );
                    mk += nd.sim.makespan;
                    gain += nd.gain_comm_aware_vs_blind_pct();
                    v_single += nd.sim.makespan / nd.single_node_makespan;
                    bytes += best.sim.bytes_moved;
                    stall += best.sim.transfer_stall;
                    retx += best.sim.retransmits;
                    remaps += best.sim.remaps;
                    best_vs_wait += 100.0 * (best.sim.makespan - wait.sim.makespan)
                        / wait.sim.makespan;
                }
                let k = cell_trees as f64;
                let cell = NetCell {
                    key: format!("net_{net_name}_N{nodes}_a{alpha:.2}_{fam}"),
                    makespan: mk / k,
                    gain_comm_aware_vs_blind_pct: gain / k,
                    vs_single_node: v_single / k,
                    bytes_moved: bytes / k,
                    transfer_stall: stall / k,
                    retransmits: retx,
                    remaps,
                    best_vs_wait_pct: best_vs_wait / k,
                };
                net_table.row(&[
                    fam.to_string(),
                    format!("{nodes}"),
                    net_name.to_string(),
                    format!("{:.3e}", cell.makespan),
                    format!("{:+.2}%", cell.gain_comm_aware_vs_blind_pct),
                    format!("{:.3e}", cell.bytes_moved),
                    format!("{:.3e}", cell.transfer_stall),
                    format!("{}", cell.retransmits),
                    format!("{}", cell.remaps),
                    format!("{:+.2}%", cell.best_vs_wait_pct),
                ]);
                net_cells.push(cell);
            }
        }
    }
    println!("\nnetworked cells (faulty-link replay on the winning mapping):");
    print!("{}", net_table.render());

    // The §6 headline: the speedup-aware mapping must beat the
    // proportional baseline on the root-dominated family (the crafted
    // RootMix construction guarantees a strict win for α < 1).
    let best_rootmix_gain = cells
        .iter()
        .filter(|c| c.key.contains("_RootMix"))
        .map(|c| c.gain_vs_prop_pct)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nbest RootMix gain vs proportional mapping: {best_rootmix_gain:+.2}%"
    );
    assert!(
        best_rootmix_gain > 0.0,
        "pm mapping should beat proportional on the root-dominated family"
    );

    // Machine-readable artifact (BENCH_dist.json at the repo root).
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"scale\": {scale},\n  \"div\": {div},\n"));
    json.push_str(&format!(
        "  \"best_rootmix_gain_vs_prop_pct\": {best_rootmix_gain:.4},\n"
    ));
    for c in cells.iter() {
        json.push_str(&format!(
            "  \"{}\": {{\"approx_ratio\": {:.6}, \"gain_vs_prop_pct\": {:.4}, \
             \"gain_vs_cp_pct\": {:.4}, \"vs_single_node\": {:.6}}},\n",
            c.key, c.approx_ratio, c.gain_vs_prop_pct, c.gain_vs_cp_pct, c.vs_single_node,
        ));
    }
    for (i, c) in net_cells.iter().enumerate() {
        json.push_str(&format!(
            "  \"{}\": {{\"makespan\": {:.6e}, \"gain_comm_aware_vs_blind_pct\": {:.4}, \
             \"vs_single_node\": {:.6}, \"bytes_moved\": {:.6e}, \"transfer_stall\": {:.6e}, \
             \"retransmits\": {}, \"remaps\": {}, \"best_vs_wait_pct\": {:.4}}}{}\n",
            c.key,
            c.makespan,
            c.gain_comm_aware_vs_blind_pct,
            c.vs_single_node,
            c.bytes_moved,
            c.transfer_stall,
            c.retransmits,
            c.remaps,
            c.best_vs_wait_pct,
            if i + 1 == net_cells.len() { "" } else { "," }
        ));
    }
    json.push_str("}\n");
    let out = bench_util::bench_output_path("BENCH_dist.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
