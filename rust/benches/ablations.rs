//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. integer-rounded shares vs fractional PM (the cost of the
//!    largest-remainder discretization at several platform sizes);
//! 2. Agreg on/off (what the ≥1-processor constraint costs);
//! 3. bandwidth roofline on/off in the kernel-DAG simulator (what
//!    actually produces α < 1);
//! 4. amalgamation width sweep (task count vs front padding in the
//!    analysis phase).

mod bench_util;

use bench_util::{env_usize, header};
use malltree::metrics::{BoxplotRow, Table};
use malltree::metrics::fit_alpha;
use malltree::model::{SpGraph, SpNode};
use malltree::sched::{agreg, pm::PmSolution};

use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;
use malltree::workload::{generator::random_tree, TreeClass};

fn main() {
    header("ablations", "design-choice ablations");
    let trees = env_usize("TREES", 40);

    // 1. fractional vs integer-rounded PM shares -----------------------
    // Integer realization: every task's PM ratio is floored to whole
    // cores (>= 1 after Agreg), the schedule replayed by the static
    // DES engine. This is the cost a runtime pays if it cannot
    // time-share cores at all.
    println!("-- 1. integer share rounding cost (makespan increase %) --");
    let mut table = Table::new(&["p", "median %", "d90 %"]);
    let mut rng = Rng::new(0xAB1);
    for p in [8.0f64, 40.0, 100.0] {
        let mut deltas = Vec::new();
        for _ in 0..trees {
            let tree = random_tree(TreeClass::Uniform, 2_000, &mut rng);
            let g = SpGraph::from_tree(&tree);
            let (ag, _) = agreg(&g, 0.9, p);
            let sol = PmSolution::solve(&ag, 0.9);
            let frac = sol.makespan_const(p);
            // floor every leaf's share to whole cores (>= 1) and
            // re-evaluate the Agreg'd SP structure: Series sums,
            // Parallel maxes (feasible: floor <= share per branch set)
            let n = ag.nodes.len();
            let mut dur = vec![0f64; n];
            for &v in &ag.topo_up() {
                let vi = v as usize;
                dur[vi] = match &ag.nodes[vi] {
                    SpNode::Leaf { len, .. } => {
                        if *len <= 0.0 {
                            0.0
                        } else {
                            let int_share = (sol.ratio[vi] * p).floor().max(1.0);
                            len / int_share.powf(0.9)
                        }
                    }
                    SpNode::Series(c) => c.iter().map(|&x| dur[x as usize]).sum(),
                    SpNode::Parallel(c) => {
                        c.iter().map(|&x| dur[x as usize]).fold(0.0, f64::max)
                    }
                };
            }
            let int_ms = dur[ag.root as usize];
            deltas.push(100.0 * (int_ms - frac) / frac);
        }
        let r = BoxplotRow::from_data(&deltas);
        table.row(&[
            format!("{p}"),
            format!("{:.2}", r.median),
            format!("{:.2}", r.d90),
        ]);
    }
    print!("{}", table.render());

    // 2. Agreg on/off ---------------------------------------------------
    println!("\n-- 2. Agreg cost (constrained vs unconstrained PM, %) --");
    let mut table = Table::new(&["p", "median %", "d90 %", "branches moved (med)"]);
    for p in [4.0, 8.0, 40.0] {
        let mut deltas = Vec::new();
        let mut moved = Vec::new();
        let mut rng = Rng::new(0xAB2);
        for _ in 0..trees {
            let tree = random_tree(TreeClass::Uniform, 2_000, &mut rng);
            let g = SpGraph::from_tree(&tree);
            let before = PmSolution::solve(&g, 0.9).makespan_const(p);
            let (ag, stats) = agreg(&g, 0.9, p);
            let after = PmSolution::solve(&ag, 0.9).makespan_const(p);
            deltas.push(100.0 * (after - before) / before);
            moved.push(stats.moved as f64);
        }
        let r = BoxplotRow::from_data(&deltas);
        let m = BoxplotRow::from_data(&moved);
        table.row(&[
            format!("{p}"),
            format!("{:.3}", r.median),
            format!("{:.3}", r.d90),
            format!("{:.0}", m.median),
        ]);
    }
    print!("{}", table.render());

    // 3. bandwidth roofline on/off in the kernel simulator --------------
    println!("\n-- 3. kernel-DAG simulator: bandwidth roofline on/off --");
    let mut table = Table::new(&["kernel", "alpha (BW on)", "alpha (BW off)"]);
    let dags: Vec<(&str, KernelDag)> = vec![
        ("cholesky N=20000", KernelDag::cholesky(79, 256)),
        ("frontal1d 10000x2500", KernelDag::frontal(10_000, 2_500, 32, true)),
        ("frontal2d 10000x2500", KernelDag::frontal(10_000, 2_500, 256, false)),
    ];
    for (name, dag) in &dags {
        let on = MachineModel::default();
        let off = MachineModel { core_rate: 1.0, bandwidth: f64::INFINITY };
        let (a_on, _) = fit_alpha(&timing_curve(dag, 20, &on), 10.0).expect("alpha fit");
        let (a_off, _) = fit_alpha(&timing_curve(dag, 20, &off), 10.0).expect("alpha fit");
        table.row(&[
            name.to_string(),
            format!("{a_on:.3}"),
            format!("{a_off:.3}"),
        ]);
    }
    print!("{}", table.render());
    println!("(roofline off ⇒ α ≈ 1 until critical-path saturation: contention is what bends α)");

    // 4. amalgamation sweep ---------------------------------------------
    println!("\n-- 4. amalgamation width (grid 32x32) --");
    let mut table = Table::new(&["amalgamate", "tasks", "total flops", "widest front"]);
    let a = gen::grid_laplacian_2d(32);
    let perm = order::nested_dissection_2d(32);
    for w in [0usize, 2, 4, 8, 16] {
        let at = symbolic::analyze(&a, &perm, w).unwrap();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap();
        table.row(&[
            format!("{w}"),
            format!("{}", at.tree.len()),
            format!("{:.3e}", at.tree.total_work()),
            format!("{widest}"),
        ]);
    }
    print!("{}", table.render());
    println!("(relaxation saturates once every fusible column pair is merged;");
    println!(" width 0 = fundamental supernodes only)");
}
