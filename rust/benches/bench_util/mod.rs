//! Shared helpers for the harness-free bench binaries.

use std::time::Instant;

/// Time a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Median-of-k timing for micro-benchmarks (one warmup + k measured).
pub fn median_time(k: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Read an env-var override (`MALLTREE_BENCH_<NAME>`) for bench scaling.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(format!("MALLTREE_BENCH_{name}"))
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Print the standard bench header.
pub fn header(id: &str, what: &str) {
    println!("================================================================");
    println!("bench {id}: {what}");
    println!("================================================================");
}
