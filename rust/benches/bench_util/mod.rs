//! Shared helpers for the harness-free bench binaries.

// each bench binary compiles this module afresh and uses a different
// subset of the helpers — unused ones are fine
#![allow(dead_code)]

use std::time::Instant;

/// Time a closure; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Median-of-k timing for micro-benchmarks (one warmup + k measured).
pub fn median_time(k: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..k.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Read an env-var override (`MALLTREE_BENCH_<NAME>`) for bench scaling.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(format!("MALLTREE_BENCH_{name}"))
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Print the standard bench header.
pub fn header(id: &str, what: &str) {
    println!("================================================================");
    println!("bench {id}: {what}");
    println!("================================================================");
}

/// Repo-root path for a machine-readable bench artifact. Anchored at
/// `CARGO_MANIFEST_DIR` (compile-time), **not** the process CWD —
/// `cargo bench` offers no CWD guarantee, and CI asserts these files
/// exist at the repo root before archiving them.
pub fn bench_output_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name)
}

/// True when the bench binary was invoked with `--<name>` (args after
/// `cargo bench ... --` reach us verbatim; harness-style flags that
/// other runners inject are simply never matched).
pub fn has_flag(name: &str) -> bool {
    let want = format!("--{name}");
    std::env::args().any(|a| a == want)
}
