//! Table 2 reproduction: α for the qr_mumps frontal kernel, 1D vs 2D
//! partitioning (regression on p ≤ 10 for 1D, p ≤ 20 for 2D — the
//! paper's protocol). Shape to match: 2D > 1D for every size; the
//! smallest 1D front clearly lowest.

mod bench_util;

use bench_util::{env_usize, header, timed};
use malltree::metrics::fit_alpha;
use malltree::metrics::Table;
use malltree::sim::kerneldag::{timing_curve, KernelDag, MachineModel};

fn main() {
    header("table2", "alpha for qr_mumps frontal tasks (paper Table 2)");
    let machine = MachineModel::default();
    let p_max = env_usize("PMAX", 22);
    let sizes: [(usize, usize); 3] = [(5000, 1000), (10000, 2500), (20000, 5000)];

    let mut table = Table::new(&["matrix size", "alpha 1D", "alpha 2D"]);
    let (mut shape_ok, secs) = timed(|| {
        let mut ok = true;
        for &(m, n) in &sizes {
            let c1 = timing_curve(&KernelDag::frontal(m, n, 32, true), p_max, &machine);
            let c2 = timing_curve(&KernelDag::frontal(m, n, 256, false), p_max, &machine);
            let (a1, _) = fit_alpha(&c1, 10.0).expect("alpha fit");
            let (a2, _) = fit_alpha(&c2, 20.0).expect("alpha fit");
            ok &= a2 > a1;
            table.row(&[format!("{m}x{n}"), format!("{a1:.3}"), format!("{a2:.3}")]);
        }
        ok
    });
    print!("{}", table.render());
    println!("(paper: 1D 0.78/0.88/0.89, 2D 0.93/0.95/0.94)");
    println!("shape check (2D > 1D for every size): {}", if shape_ok { "PASS" } else { "FAIL" });
    shape_ok &= true;
    println!("bench wall time: {secs:.2}s");
    if !shape_ok {
        std::process::exit(1);
    }
}
