//! Cross-module property tests (the coordinator invariants), driven by
//! the in-repo property harness over seeded random trees, profiles and
//! parameters.

use malltree::model::{SpGraph, SpNode, TaskTree};
use malltree::sched::{
    agreg, agreg_full_resolve, divisible::divisible_makespan_tree, pm::PmSolution,
    proportional_makespan, PmSchedule, Profile, SchedWorkspace,
};
use malltree::sim::des::{replay_schedule, simulate, simulate_with_workspace, Policy};
use malltree::util::prop::{check, Config};
use malltree::util::rng::Rng;
use malltree::workload::{generator::random_tree as random_class_tree, TreeClass};

fn random_tree(rng: &mut Rng, max_n: usize) -> TaskTree {
    let n = rng.range(2, max_n);
    let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
    let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.01, 1000.0)).collect();
    TaskTree::from_parents(&parents, &lens).unwrap()
}

fn random_profile(rng: &mut Rng) -> Profile {
    let steps = rng.range(1, 5);
    let v: Vec<(f64, f64)> = (0..steps)
        .map(|_| (rng.log_uniform(0.1, 100.0), rng.range_f64(1.0, 64.0)))
        .collect();
    Profile::steps(&v).unwrap()
}

/// L_G is sandwiched between the critical path and the total work.
/// (Note: L_G is *not* monotone in α — two equal unit tasks give
/// L_{1||2} = 2^α, increasing — because the p^α model is superlinear
/// on sub-processor shares; that is exactly what §7's Agreg corrects.)
#[test]
fn prop_equiv_length_sandwich() {
    check(
        Config { cases: 120, seed: 1 },
        "L_G sandwich",
        |rng| random_tree(rng, 80),
        |tree| {
            let g = SpGraph::from_tree(tree);
            for &alpha in &[0.3, 0.5, 0.7, 0.9, 1.0] {
                let l = PmSolution::solve(&g, alpha).total_len;
                if l < tree.critical_path() - 1e-6 {
                    return Err(format!("L_G {l} below critical path"));
                }
                if l > tree.total_work() * (1.0 + 1e-9) {
                    return Err(format!("L_G {l} above total work"));
                }
            }
            Ok(())
        },
    );
}

/// The materialized PM schedule is valid under random step profiles and
/// its makespan equals the equivalent task's completion (Theorem 6).
#[test]
fn prop_pm_schedule_valid_under_step_profiles() {
    check(
        Config { cases: 80, seed: 2 },
        "PM validity on step profiles",
        |rng| {
            let tree = random_tree(rng, 60);
            let profile = random_profile(rng);
            let alpha = rng.range_f64(0.4, 1.0);
            (tree, profile, alpha)
        },
        |(tree, profile, alpha)| {
            let pm = PmSchedule::for_tree(tree, *alpha, profile);
            pm.schedule
                .validate(tree, *alpha, profile, 1e-6)
                .map_err(|e| e.to_string())?;
            let equiv = profile.completion(*alpha, pm.solution.total_len);
            if (pm.schedule.makespan - equiv).abs() > 1e-6 * equiv {
                return Err(format!(
                    "makespan {} vs equivalent completion {equiv}",
                    pm.schedule.makespan
                ));
            }
            // replay: every task accumulates exactly its length
            let work = replay_schedule(tree, &pm.schedule, *alpha, profile);
            for (i, node) in tree.nodes.iter().enumerate() {
                if (work[i] - node.len).abs() > 1e-6 * node.len.max(1e-9) {
                    return Err(format!("task {i}: work {} != {}", work[i], node.len));
                }
            }
            Ok(())
        },
    );
}

/// PM (pure model) lower-bounds the kink-evaluated baselines.
#[test]
fn prop_pm_is_optimal_vs_baselines() {
    check(
        Config { cases: 100, seed: 3 },
        "PM <= baselines",
        |rng| {
            let tree = random_tree(rng, 60);
            let alpha = rng.range_f64(0.4, 1.0);
            let p = rng.range_f64(1.0, 64.0);
            (tree, alpha, p)
        },
        |(tree, alpha, p)| {
            let g = SpGraph::from_tree(tree);
            let pm = PmSolution::solve(&g, *alpha).makespan_const(*p);
            let prop = proportional_makespan(&g, *alpha, *p);
            let div = divisible_makespan_tree(tree, *alpha, *p);
            let des_eq = simulate(tree, *alpha, *p, Policy::EqualSplit).makespan;
            for (name, other) in [("prop", prop), ("div", div), ("equal", des_eq)] {
                if pm > other * (1.0 + 1e-7) {
                    return Err(format!("PM {pm} beaten by {name} {other}"));
                }
            }
            Ok(())
        },
    );
}

/// Agreg postcondition: every positive-length task gets >= 1 processor;
/// the task multiset is preserved; makespan does not improve.
#[test]
fn prop_agreg_postconditions() {
    check(
        Config { cases: 80, seed: 4 },
        "Agreg fixpoint",
        |rng| {
            let tree = random_tree(rng, 60);
            let alpha = rng.range_f64(0.4, 1.0);
            let p = rng.range_f64(1.0, 16.0);
            (tree, alpha, p)
        },
        |(tree, alpha, p)| {
            let g = SpGraph::from_tree(tree);
            let before = PmSolution::solve(&g, *alpha);
            let (out, stats) = agreg(&g, *alpha, *p);
            if !stats.converged {
                return Err("did not converge".into());
            }
            out.validate().map_err(|e| e.to_string())?;
            let after = PmSolution::solve(&out, *alpha);
            if after.min_task_share(&out, *p) < 1.0 - 1e-6 {
                return Err(format!(
                    "task below one processor: {}",
                    after.min_task_share(&out, *p)
                ));
            }
            if out.num_tasks() != tree.len() {
                return Err("task count changed".into());
            }
            if (out.total_work() - tree.total_work()).abs() > 1e-6 * tree.total_work() {
                return Err("total work changed".into());
            }
            if after.total_len < before.total_len * (1.0 - 1e-9) {
                return Err("aggregation improved the makespan (impossible)".into());
            }
            Ok(())
        },
    );
}

/// The incremental `Agreg` engine reaches the exact fixpoint of the
/// full-resolve reference: same canonical rewritten graph (the
/// normalized arena is a deterministic function of the logical
/// structure), same statistics, same makespan, and the ≥ 1-processor
/// postcondition — across all `TreeClass` shapes and
/// α ∈ {0.5, 0.9, 1.0}.
///
/// Caveat: from round 2 on the engines compute ratios with different
/// float groupings (delta updates vs fresh sums), so a branch whose
/// share sits within a few ULPs of the `1 − 1e-9` threshold could in
/// principle be partitioned differently. Lengths here are continuous
/// random draws under fixed seeds, so the test is deterministic and
/// the measure of such ties is ~0; a genuine logic divergence shows up
/// as a macroscopic shape/stats mismatch, which is what this guards.
#[test]
fn prop_incremental_agreg_matches_full_resolve() {
    let classes = [
        TreeClass::Uniform,
        TreeClass::Recent,
        TreeClass::Deep,
        TreeClass::Binary,
    ];
    check(
        Config { cases: 90, seed: 8 },
        "incremental Agreg == full-resolve Agreg",
        |rng| {
            let class = classes[rng.below(classes.len())];
            let n = rng.range(2, 400);
            let alpha = [0.5, 0.9, 1.0][rng.below(3)];
            let p = rng.range_f64(1.0, 16.0);
            (random_class_tree(class, n, rng), alpha, p, class)
        },
        |(tree, alpha, p, class)| {
            let g = SpGraph::from_tree(tree);
            let (inc, si) = agreg(&g, *alpha, *p);
            let (full, sf) = agreg_full_resolve(&g, *alpha, *p);
            if si != sf {
                return Err(format!("stats diverge ({class:?}): {si:?} vs {sf:?}"));
            }
            let (inc, full) = (inc.normalized(), full.normalized());
            if inc.root != full.root || inc.nodes != full.nodes {
                return Err(format!(
                    "graph shapes diverge ({class:?}, α={alpha}, p={p})"
                ));
            }
            let sol = PmSolution::solve(&inc, *alpha);
            let full_ms = PmSolution::solve(&full, *alpha).makespan_const(*p);
            if (sol.makespan_const(*p) - full_ms).abs() > 1e-9 * full_ms.max(1e-12) {
                return Err("makespans diverge".into());
            }
            if inc.num_tasks() != tree.len() {
                return Err("task count changed".into());
            }
            if sol.min_task_share(&inc, *p) < 1.0 - 1e-6 {
                return Err(format!(
                    "sub-processor share {} after incremental Agreg",
                    sol.min_task_share(&inc, *p)
                ));
            }
            Ok(())
        },
    );
}

/// DES of the PM policy through a single workspace reused across every
/// case equals both the plain engine (bit-for-bit) and, whenever the
/// allocation stays ≥ 1 processor, the closed-form makespan.
#[test]
fn prop_des_pm_workspace_reuse_matches_closed_form() {
    let mut ws = SchedWorkspace::new();
    check(
        Config { cases: 60, seed: 9 },
        "DES(PM, workspace) == closed form",
        |rng| {
            let tree = random_tree(rng, 60);
            let alpha = rng.range_f64(0.4, 1.0);
            let p = rng.range_f64(1.0, 64.0);
            (tree, alpha, p)
        },
        |(tree, alpha, p)| {
            let plain = simulate(tree, *alpha, *p, Policy::Pm);
            let with_ws = simulate_with_workspace(tree, *alpha, *p, Policy::Pm, &mut ws);
            if plain.makespan.to_bits() != with_ws.makespan.to_bits() {
                return Err(format!(
                    "workspace path diverged: {} vs {}",
                    with_ws.makespan, plain.makespan
                ));
            }
            let g = SpGraph::from_tree(tree);
            let sol = PmSolution::solve(&g, *alpha);
            // the kinked DES speedup only matches p^α when every share
            // stays >= 1 processor (that is exactly what Agreg ensures;
            // raw random trees may dip below, in which case only the
            // engine-equality above is asserted)
            if sol.min_task_share(&g, *p) >= 1.0 {
                let cf = sol.makespan_const(*p);
                if (with_ws.makespan - cf).abs() > 1e-6 * cf {
                    return Err(format!("DES {} vs closed form {cf}", with_ws.makespan));
                }
            }
            Ok(())
        },
    );
}

/// DES and the analytic evaluators agree for the baseline policies.
#[test]
fn prop_des_matches_closed_forms() {
    check(
        Config { cases: 80, seed: 5 },
        "DES == closed forms",
        |rng| {
            let tree = random_tree(rng, 50);
            let alpha = rng.range_f64(0.4, 1.0);
            let p = rng.range_f64(1.0, 64.0);
            (tree, alpha, p)
        },
        |(tree, alpha, p)| {
            let g = SpGraph::from_tree(tree);
            let des_prop = simulate(tree, *alpha, *p, Policy::Proportional).makespan;
            let cf_prop = proportional_makespan(&g, *alpha, *p);
            if (des_prop - cf_prop).abs() > 1e-6 * cf_prop {
                return Err(format!("prop: DES {des_prop} vs closed form {cf_prop}"));
            }
            let des_div = simulate(tree, *alpha, *p, Policy::Divisible).makespan;
            let cf_div = divisible_makespan_tree(tree, *alpha, *p);
            if (des_div - cf_div).abs() > 1e-6 * cf_div {
                return Err(format!("div: DES {des_div} vs closed form {cf_div}"));
            }
            Ok(())
        },
    );
}

/// Parallel composition ratios: siblings' ratios sum to the parent's
/// and are ordered by equivalent length (Lemma 4 structure).
#[test]
fn prop_ratio_flow_conservation() {
    check(
        Config { cases: 80, seed: 6 },
        "ratio conservation",
        |rng| (random_tree(rng, 60), rng.range_f64(0.4, 1.0)),
        |(tree, alpha)| {
            let g = SpGraph::from_tree(tree);
            let sol = PmSolution::solve(&g, *alpha);
            for &v in &g.topo_down() {
                if let SpNode::Parallel(children) = &g.nodes[v as usize] {
                    let sum: f64 = children.iter().map(|&c| sol.ratio[c as usize]).sum();
                    if (sum - sol.ratio[v as usize]).abs() > 1e-9 {
                        return Err(format!(
                            "children ratios sum {sum} != parent {}",
                            sol.ratio[v as usize]
                        ));
                    }
                    // ordering: larger equivalent length ⇒ larger ratio
                    let mut pairs: Vec<(f64, f64)> = children
                        .iter()
                        .map(|&c| (sol.equiv_len[c as usize], sol.ratio[c as usize]))
                        .collect();
                    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    for w in pairs.windows(2) {
                        if w[0].1 > w[1].1 + 1e-12 {
                            return Err("ratio not monotone in equivalent length".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Makespan monotonicity: more processors never hurt; scaling all
/// lengths scales the makespan linearly.
#[test]
fn prop_makespan_scaling_laws() {
    check(
        Config { cases: 80, seed: 7 },
        "makespan scaling",
        |rng| (random_tree(rng, 60), rng.range_f64(0.4, 1.0)),
        |(tree, alpha)| {
            let g = SpGraph::from_tree(tree);
            let sol = PmSolution::solve(&g, *alpha);
            let m4 = sol.makespan_const(4.0);
            let m8 = sol.makespan_const(8.0);
            if m8 > m4 * (1.0 + 1e-12) {
                return Err("more processors increased makespan".into());
            }
            // linear scaling in lengths
            let scaled_lens: Vec<f64> = tree.nodes.iter().map(|n| n.len * 3.0).collect();
            let parents: Vec<usize> = tree
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| n.parent.map(|p| p as usize).unwrap_or(i))
                .collect();
            let scaled = TaskTree::from_parents(&parents, &scaled_lens).unwrap();
            let g2 = SpGraph::from_tree(&scaled);
            let m_scaled = PmSolution::solve(&g2, *alpha).makespan_const(4.0);
            if (m_scaled - 3.0 * m4).abs() > 1e-9 * m_scaled {
                return Err(format!("scaling violated: {m_scaled} vs {}", 3.0 * m4));
            }
            Ok(())
        },
    );
}
