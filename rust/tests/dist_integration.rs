//! Integration: the §6 distributed algorithms on real assembly trees,
//! the Theorem 7 reduction round-trip, the λ-guarantee on the trimmed
//! enumeration path, the sub-forest scheduler conservativity property,
//! and the N-node `distribute` pipeline end to end.

use malltree::dist::{
    distribute, het_schedule, homog_approx, independent_optimal, partition_reduction,
    subset_sum_exact, MappingStrategy,
};
use malltree::model::{Platform, SpGraph};
use malltree::sched::{pm::PmSolution, SchedWorkspace};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::prop::{check, Config};
use malltree::util::rng::Rng;

#[test]
fn homog_approx_on_assembly_trees_meets_bound_chain() {
    // guarantee chain: makespan in [L_G/(2p)^α, (4/3)^α · L_G/p^α]
    for k in [10usize, 16, 24] {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        for alpha in [0.6, 0.9] {
            for p in [4.0, 16.0] {
                let s = homog_approx(&at.tree, alpha, p);
                assert!(
                    s.makespan >= s.lower_bound * (1.0 - 1e-9),
                    "k={k} α={alpha} p={p}: below lower bound"
                );
                let g = malltree::model::SpGraph::from_tree(&at.tree);
                let single_node =
                    malltree::sched::pm::PmSolution::solve(&g, alpha).total_len / p.powf(alpha);
                let cap = (4.0f64 / 3.0).powf(alpha) * single_node;
                assert!(
                    s.makespan <= cap * (1.0 + 1e-9),
                    "k={k} α={alpha} p={p}: {} > {cap}",
                    s.makespan
                );
            }
        }
    }
}

#[test]
fn theorem7_reduction_agrees_with_subset_sum() {
    // The schedule decides Partition iff subset-sum can hit s/2.
    let mut rng = Rng::new(99);
    for _ in 0..50 {
        let n = rng.range(4, 12);
        let a: Vec<u64> = (0..n).map(|_| rng.range(1, 40) as u64).collect();
        let s: u64 = a.iter().sum();
        let alpha = rng.range_f64(0.5, 1.0);
        let (lens, p, t) = partition_reduction(&a, alpha);
        let (_, opt) = independent_optimal(&lens, alpha, p, p);
        let schedule_says_yes = opt <= t + 1e-9;
        let xs: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let (_, best) = subset_sum_exact(&xs, s as f64 / 2.0);
        let subset_sum_says_yes = (best - s as f64 / 2.0).abs() < 1e-9 && s % 2 == 0;
        assert_eq!(
            schedule_says_yes, subset_sum_says_yes,
            "a={a:?} α={alpha}: schedule {schedule_says_yes} vs subset-sum {subset_sum_says_yes}"
        );
    }
}

#[test]
fn het_schedule_beats_single_node_when_balanced() {
    // two similar nodes: using both must beat the best single node
    let mut rng = Rng::new(7);
    let lens: Vec<f64> = (0..14).map(|_| rng.log_uniform(1.0, 50.0)).collect();
    let alpha = 0.9;
    let (p, q) = (8.0, 7.0);
    let s = het_schedule(&lens, alpha, p, q, 1.05);
    let inv = 1.0 / alpha;
    let single = lens.iter().map(|l| l.powf(inv)).sum::<f64>().powf(alpha) / p.powf(alpha);
    assert!(
        s.makespan < single,
        "two nodes {} should beat one node {single}",
        s.makespan
    );
}

#[test]
fn het_lambda_sweep_is_monotone_in_quality_bound() {
    let mut rng = Rng::new(8);
    let lens: Vec<f64> = (0..10).map(|_| rng.log_uniform(1.0, 80.0)).collect();
    let (p, q) = (10.0, 3.0);
    let alpha = 0.8;
    let (_, opt) = independent_optimal(&lens, alpha, p, q);
    for lambda in [3.0, 2.0, 1.5, 1.2, 1.05] {
        let s = het_schedule(&lens, alpha, p, q, lambda);
        assert!(
            s.makespan <= lambda * opt * (1.0 + 1e-9),
            "λ={lambda}: {} > {}",
            s.makespan,
            lambda * opt
        );
        // partition is a real partition
        let mut seen = vec![false; lens.len()];
        for &i in &s.on_p {
            assert!(!seen[i], "duplicate task in partition");
            seen[i] = true;
        }
    }
}

#[test]
fn het_lambda_guarantee_holds_on_trimmed_path() {
    // n > 20 forces the λ-trimmed enumeration (the exact branch is
    // unreachable); n ≤ 24 keeps the exhaustive reference affordable.
    // Property: makespan ≤ λ · independent_optimal on random instances.
    check(
        Config { cases: 5, seed: 0x7A11 },
        "λ-guarantee on the trimmed path",
        |rng: &mut Rng| {
            let n = rng.range(21, 22); // inclusive: strictly above the exact cutoff
            let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 80.0)).collect();
            let alpha = rng.range_f64(0.55, 1.0);
            let p = rng.range_f64(2.0, 12.0);
            let q = rng.range_f64(1.0, 8.0);
            (lens, alpha, p, q)
        },
        |(lens, alpha, p, q)| {
            let (_, opt) = independent_optimal(lens, *alpha, *p, *q);
            for lambda in [2.0, 1.3, 1.05] {
                let s = het_schedule(lens, *alpha, *p, *q, lambda);
                if s.makespan > lambda * opt * (1.0 + 1e-9) {
                    return Err(format!(
                        "λ={lambda}: {} > {} (opt {opt})",
                        s.makespan,
                        lambda * opt
                    ));
                }
                // and the reported partition must realize the makespan
                let inv = 1.0 / alpha;
                let on: f64 = s.on_p.iter().map(|&i| lens[i].powf(inv)).sum();
                let total: f64 = lens.iter().map(|l| l.powf(inv)).sum();
                let realized = (on.powf(*alpha) / p.powf(*alpha))
                    .max((total - on).powf(*alpha) / q.powf(*alpha));
                if (realized - s.makespan).abs() > 1e-6 * s.makespan {
                    return Err(format!(
                        "λ={lambda}: partition realizes {realized}, reported {}",
                        s.makespan
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sub_forest_refactor_is_conservative() {
    // the whole tree solved as a single-root forest through the new
    // API must be bit-identical to the classic whole-tree path —
    // graph arena, solution arrays, and DES replay alike
    check(
        Config { cases: 25, seed: 0xF0BE },
        "single-root forest == whole tree (bitwise)",
        |rng: &mut Rng| {
            let n = rng.range(2, 200);
            let parents: Vec<usize> =
                (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
            let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.1, 100.0)).collect();
            let alpha = rng.range_f64(0.4, 1.0);
            (malltree::model::TaskTree::from_parents(&parents, &lens).unwrap(), alpha)
        },
        |(tree, alpha)| {
            let whole = SpGraph::from_tree(tree);
            let forest = SpGraph::from_forest(tree, &[tree.root]);
            if forest.nodes != whole.nodes || forest.root != whole.root {
                return Err("forest arena differs from the whole-tree arena".into());
            }
            let mut ws = SchedWorkspace::new();
            let got = ws.solve_forest(tree, &[tree.root], *alpha);
            let want = PmSolution::solve(&whole, *alpha);
            if got.total_len.to_bits() != want.total_len.to_bits() {
                return Err(format!(
                    "total_len {} != {}",
                    got.total_len, want.total_len
                ));
            }
            if got.ratio != want.ratio
                || got.theta_start != want.theta_start
                || got.theta_end != want.theta_end
            {
                return Err("solution arrays differ".into());
            }
            // the 1-node distributed DES path equals the shared engine
            let plat = Platform::Shared { p: 7.0 };
            let node_of = vec![0usize; tree.len()];
            let dd = malltree::sim::des::simulate_distributed(
                tree,
                *alpha,
                &plat,
                &node_of,
                malltree::sim::Policy::Pm,
            );
            let sd = malltree::sim::des::simulate(tree, *alpha, 7.0, malltree::sim::Policy::Pm);
            if dd.makespan.to_bits() != sd.makespan.to_bits() {
                return Err(format!(
                    "distributed 1-node {} != shared {}",
                    dd.makespan, sd.makespan
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn distribute_pipeline_end_to_end_on_assembly_tree() {
    // acceptance chain on a real analysis tree: pooled lower bound ≤
    // mapped DES makespan ≤ single-node PM makespan, per-node
    // schedules partition the task set, makespans are consistent
    let a = gen::grid_laplacian_2d(24);
    let perm = order::nested_dissection_2d(24);
    let at = symbolic::analyze(&a, &perm, 4).unwrap();
    for nodes in [2usize, 4] {
        let plat = Platform::Homogeneous { nodes, p: 8.0 };
        for alpha in [0.7, 0.9] {
            let d = distribute(&at.tree, &plat, alpha, MappingStrategy::Pm, 1.1).unwrap();
            assert!(d.makespan >= d.lower_bound * (1.0 - 1e-9));
            assert!(d.makespan <= d.single_node_makespan * (1.0 + 1e-9));
            let mut seen = vec![false; at.tree.len()];
            for (k, sched) in d.per_node.iter().enumerate() {
                for s in &sched.spans {
                    assert_eq!(d.mapping.node_of[s.task as usize], k);
                    assert!(!seen[s.task as usize]);
                    seen[s.task as usize] = true;
                }
            }
            assert!(seen.into_iter().all(|b| b));
            // the per-node local makespans never exceed the stall-aware
            // DES finish of that node
            for (k, sched) in d.per_node.iter().enumerate() {
                assert!(
                    sched.makespan <= d.sim.node_finish[k] * (1.0 + 1e-9) + 1e-12,
                    "node {k}: local plan {} vs DES finish {}",
                    sched.makespan,
                    d.sim.node_finish[k]
                );
            }
        }
    }
}

#[test]
fn distribute_beats_proportional_mapping_on_root_shape_mix() {
    // the speedup-aware mapping's whole point: on a root-dominated
    // tree whose equal-work branches differ in *shape*, balancing
    // power-lengths beats balancing raw work for α < 1 (work-LPT
    // pairs the chain branches on a node; power-LPT separates them)
    for nodes in [2usize, 4] {
        let plat = Platform::Homogeneous { nodes, p: 8.0 };
        for alpha in [0.7, 0.9] {
            let tree = malltree::workload::generator::root_shape_mix(nodes, 3.7, 3, 3);
            let pm = distribute(&tree, &plat, alpha, MappingStrategy::Pm, 1.1).unwrap();
            let prop =
                distribute(&tree, &plat, alpha, MappingStrategy::Proportional, 1.1).unwrap();
            let gain = pm.gain_over(prop.makespan);
            assert!(
                gain > 0.5,
                "N={nodes} α={alpha}: pm should beat prop clearly, gain {gain:+.3}%"
            );
        }
        // at α = 1 power-lengths equal works: the strategies tie
        let tree = malltree::workload::generator::root_shape_mix(nodes, 3.7, 3, 3);
        let pm = distribute(&tree, &plat, 1.0, MappingStrategy::Pm, 1.1).unwrap();
        let prop = distribute(&tree, &plat, 1.0, MappingStrategy::Proportional, 1.1).unwrap();
        assert!(pm.gain_over(prop.makespan).abs() < 1e-9);
    }
}

#[test]
fn homog_chain_heavy_trees() {
    // trees dominated by a chain stress the Lemma-9 normalization path
    let n = 200;
    let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
    let mut rng = Rng::new(11);
    let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.1, 10.0)).collect();
    let tree = malltree::model::TaskTree::from_parents(&parents, &lens).unwrap();
    let s = homog_approx(&tree, 0.9, 8.0);
    // a pure chain cannot use the second node: optimal = Σ L_i / p^α
    let expect: f64 = tree.total_work() / 8f64.powf(0.9);
    assert!(
        (s.makespan - expect).abs() < 1e-9 * expect,
        "chain: {} vs {expect}",
        s.makespan
    );
}
