//! Integration: the §6 distributed algorithms on real assembly trees
//! and the Theorem 7 reduction round-trip.

use malltree::dist::{
    het_schedule, homog_approx, independent_optimal, partition_reduction, subset_sum_exact,
};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::rng::Rng;

#[test]
fn homog_approx_on_assembly_trees_meets_bound_chain() {
    // guarantee chain: makespan in [L_G/(2p)^α, (4/3)^α · L_G/p^α]
    for k in [10usize, 16, 24] {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        for alpha in [0.6, 0.9] {
            for p in [4.0, 16.0] {
                let s = homog_approx(&at.tree, alpha, p);
                assert!(
                    s.makespan >= s.lower_bound * (1.0 - 1e-9),
                    "k={k} α={alpha} p={p}: below lower bound"
                );
                let g = malltree::model::SpGraph::from_tree(&at.tree);
                let single_node =
                    malltree::sched::pm::PmSolution::solve(&g, alpha).total_len / p.powf(alpha);
                let cap = (4.0f64 / 3.0).powf(alpha) * single_node;
                assert!(
                    s.makespan <= cap * (1.0 + 1e-9),
                    "k={k} α={alpha} p={p}: {} > {cap}",
                    s.makespan
                );
            }
        }
    }
}

#[test]
fn theorem7_reduction_agrees_with_subset_sum() {
    // The schedule decides Partition iff subset-sum can hit s/2.
    let mut rng = Rng::new(99);
    for _ in 0..50 {
        let n = rng.range(4, 12);
        let a: Vec<u64> = (0..n).map(|_| rng.range(1, 40) as u64).collect();
        let s: u64 = a.iter().sum();
        let alpha = rng.range_f64(0.5, 1.0);
        let (lens, p, t) = partition_reduction(&a, alpha);
        let (_, opt) = independent_optimal(&lens, alpha, p, p);
        let schedule_says_yes = opt <= t + 1e-9;
        let xs: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let (_, best) = subset_sum_exact(&xs, s as f64 / 2.0);
        let subset_sum_says_yes = (best - s as f64 / 2.0).abs() < 1e-9 && s % 2 == 0;
        assert_eq!(
            schedule_says_yes, subset_sum_says_yes,
            "a={a:?} α={alpha}: schedule {schedule_says_yes} vs subset-sum {subset_sum_says_yes}"
        );
    }
}

#[test]
fn het_schedule_beats_single_node_when_balanced() {
    // two similar nodes: using both must beat the best single node
    let mut rng = Rng::new(7);
    let lens: Vec<f64> = (0..14).map(|_| rng.log_uniform(1.0, 50.0)).collect();
    let alpha = 0.9;
    let (p, q) = (8.0, 7.0);
    let s = het_schedule(&lens, alpha, p, q, 1.05);
    let inv = 1.0 / alpha;
    let single = lens.iter().map(|l| l.powf(inv)).sum::<f64>().powf(alpha) / p.powf(alpha);
    assert!(
        s.makespan < single,
        "two nodes {} should beat one node {single}",
        s.makespan
    );
}

#[test]
fn het_lambda_sweep_is_monotone_in_quality_bound() {
    let mut rng = Rng::new(8);
    let lens: Vec<f64> = (0..10).map(|_| rng.log_uniform(1.0, 80.0)).collect();
    let (p, q) = (10.0, 3.0);
    let alpha = 0.8;
    let (_, opt) = independent_optimal(&lens, alpha, p, q);
    for lambda in [3.0, 2.0, 1.5, 1.2, 1.05] {
        let s = het_schedule(&lens, alpha, p, q, lambda);
        assert!(
            s.makespan <= lambda * opt * (1.0 + 1e-9),
            "λ={lambda}: {} > {}",
            s.makespan,
            lambda * opt
        );
        // partition is a real partition
        let mut seen = vec![false; lens.len()];
        for &i in &s.on_p {
            assert!(!seen[i], "duplicate task in partition");
            seen[i] = true;
        }
    }
}

#[test]
fn homog_chain_heavy_trees() {
    // trees dominated by a chain stress the Lemma-9 normalization path
    let n = 200;
    let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
    let mut rng = Rng::new(11);
    let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.1, 10.0)).collect();
    let tree = malltree::model::TaskTree::from_parents(&parents, &lens).unwrap();
    let s = homog_approx(&tree, 0.9, 8.0);
    // a pure chain cannot use the second node: optimal = Σ L_i / p^α
    let expect: f64 = tree.total_work() / 8f64.powf(0.9);
    assert!(
        (s.makespan - expect).abs() < 1e-9 * expect,
        "chain: {} vs {expect}",
        s.makespan
    );
}
