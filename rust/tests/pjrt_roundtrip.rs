//! Integration: the PJRT runtime path — load AOT HLO-text artifacts,
//! compile, execute, and compare against the pure-Rust oracle.
//!
//! Skips gracefully (with a message) when `artifacts/` has not been
//! built (`make artifacts`); CI runs it after the Python AOT step.

use std::path::Path;
use std::sync::Arc;

use malltree::frontal::{dense, FrontBackend, PjrtBackend, RustBackend};
use malltree::runtime::Runtime;
use malltree::util::rng::Rng;

fn runtime() -> Option<Arc<Runtime>> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Runtime::cpu(dir).expect("pjrt runtime")))
}

fn random_spd(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
    let mut a = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += m[i * n + k] * m[j * n + k];
            }
            a[i * n + j] = s / n as f64 + if i == j { 2.0 } else { 0.0 };
        }
    }
    a
}

#[test]
fn manifest_loads_and_variants_compile() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.specs.len() >= 8, "expected the full variant menu");
    let compiled = rt.warm_up().expect("warm up");
    assert_eq!(compiled, rt.manifest.specs.len());
}

#[test]
fn partial_factor_matches_rust_backend_exact_sizes() {
    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(rt);
    for (n, k) in [(32usize, 16usize), (64, 32), (128, 64)] {
        let a = random_spd(n, (n + k) as u64);
        let got = backend.partial(&a, n, k).expect("pjrt partial");
        let want = RustBackend::default().partial(&a, n, k).unwrap();
        let max_dev = |x: &[f64], y: &[f64]| {
            x.iter()
                .zip(y)
                .map(|(a, b)| (a - b).abs() / a.abs().max(1.0))
                .fold(0.0f64, f64::max)
        };
        assert!(max_dev(&got.l11, &want.l11) < 1e-4, "L11 deviates (n={n})");
        assert!(max_dev(&got.l21, &want.l21) < 1e-4, "L21 deviates (n={n})");
        assert!(max_dev(&got.schur, &want.schur) < 1e-4, "S deviates (n={n})");
    }
}

#[test]
fn padded_sizes_are_exact() {
    // off-menu sizes exercise the identity-padding embedding
    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(rt);
    for (n, k) in [(20usize, 7usize), (48, 16), (100, 40), (33, 17)] {
        let a = random_spd(n, (3 * n + k) as u64);
        let got = backend.partial(&a, n, k).expect("pjrt partial padded");
        let want = RustBackend::default().partial(&a, n, k).unwrap();
        let max_dev = got
            .schur
            .iter()
            .zip(&want.schur)
            .map(|(a, b)| (a - b).abs() / a.abs().max(1.0))
            .fold(0.0f64, f64::max);
        assert!(max_dev < 1e-4, "padded (n={n},k={k}) schur deviates {max_dev}");
    }
}

#[test]
fn full_factor_reconstructs() {
    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(rt);
    for n in [24usize, 64, 100] {
        let a = random_spd(n, n as u64);
        let l = backend.full(&a, n).expect("pjrt full");
        let llt = dense::matmul_nt(&l, &l, n, n, n);
        let rel = a
            .iter()
            .zip(&llt)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(rel < 1e-3, "n={n}: reconstruction error {rel}");
    }
}

#[test]
fn executable_cache_hits() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.specs[0].clone();
    let k1 = rt.kernel(&spec).unwrap();
    let k2 = rt.kernel(&spec).unwrap();
    assert!(Arc::ptr_eq(&k1, &k2), "second lookup must hit the cache");
}

#[test]
fn rejects_wrong_input_size() {
    let Some(rt) = runtime() else { return };
    let spec = rt
        .manifest
        .specs
        .iter()
        .find(|s| s.name == "partial_n32_k16")
        .unwrap()
        .clone();
    let kernel = rt.kernel(&spec).unwrap();
    assert!(kernel.run_f32(&vec![0f32; 7]).is_err());
}
