//! Integration: the full analysis → scheduling → simulation → execution
//! pipeline over several problem classes, cross-checking every layer
//! against every other.

use malltree::exec::{execute_malleable, execute_parallel, execute_serial};
use malltree::frontal::{factorize, multifrontal::residual, RustBackend};
use malltree::model::SpGraph;
use malltree::sched::{
    divisible::divisible_makespan_tree, pm::PmSolution, proportional_makespan, relative_distances,
    PmSchedule, Profile,
};
use malltree::sim::des::{simulate, Policy};
use malltree::sparse::{gen, order, symbolic};
use malltree::util::approx_eq;

fn problems() -> Vec<(String, malltree::sparse::AssemblyTree, malltree::sparse::CscMatrix)> {
    let mut out = Vec::new();
    for k in [8usize, 12, 16] {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        out.push((format!("grid2d_{k}"), at, ap));
    }
    {
        let a = gen::grid_laplacian_3d(4);
        let perm = order::nested_dissection_3d(4);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        out.push(("grid3d_4".into(), at, ap));
    }
    {
        let mut rng = malltree::util::rng::Rng::new(5);
        let a = gen::random_spd(120, 4, &mut rng);
        let perm = order::reverse_cuthill_mckee(&a);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        out.push(("random_spd_120".into(), at, ap));
    }
    out
}

#[test]
fn schedule_validates_and_des_agrees_everywhere() {
    for (name, at, _) in problems() {
        for alpha in [0.6, 0.9, 1.0] {
            for p in [4.0, 16.0] {
                let profile = Profile::constant(p);
                let pm = PmSchedule::for_tree(&at.tree, alpha, &profile);
                pm.schedule
                    .validate(&at.tree, alpha, &profile, 1e-7)
                    .unwrap_or_else(|e| panic!("{name} α={alpha} p={p}: {e}"));
                // DES replay of the PM policy agrees with the closed form
                // (shares can dip below 1 → kinked DES may exceed it, so
                // only assert when min share >= 1)
                let g = SpGraph::from_tree(&at.tree);
                let sol = PmSolution::solve(&g, alpha);
                if sol.min_task_share(&g, p) >= 1.0 {
                    let des = simulate(&at.tree, alpha, p, Policy::Pm);
                    assert!(
                        approx_eq(des.makespan, pm.schedule.makespan, 1e-6),
                        "{name} α={alpha} p={p}: DES {} vs analytic {}",
                        des.makespan,
                        pm.schedule.makespan
                    );
                }
            }
        }
    }
}

#[test]
fn pm_dominates_baselines_on_real_trees() {
    for (name, at, _) in problems() {
        let g = SpGraph::from_tree(&at.tree);
        for alpha in [0.5, 0.8, 0.95] {
            let p = 40.0;
            let pm = PmSolution::solve(&g, alpha).makespan_const(p);
            let prop = proportional_makespan(&g, alpha, p);
            let div = divisible_makespan_tree(&at.tree, alpha, p);
            assert!(pm <= prop * (1.0 + 1e-9), "{name}: pm {pm} > prop {prop}");
            assert!(pm <= div * (1.0 + 1e-9), "{name}: pm {pm} > div {div}");
            // relative distances are the Figure 13 quantities: >= 0
            let (d, pr) = relative_distances(&at.tree, alpha, p);
            assert!(d >= -1e-6, "{name}: negative Divisible distance {d}");
            assert!(pr >= -1e-6, "{name}: negative Proportional distance {pr}");
        }
    }
}

#[test]
fn executors_match_reference_on_every_problem() {
    for (name, at, ap) in problems() {
        let pm = PmSchedule::for_tree(&at.tree, 0.9, &Profile::constant(8.0));
        let reference = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let (serial, _) = execute_serial(&at, &ap, &pm.schedule, &RustBackend::default()).unwrap();
        let (parallel, _) =
            execute_parallel(&at, &ap, &pm.schedule, &RustBackend::default(), 4).unwrap();
        let (malleable, report) =
            execute_malleable(&at, &ap, &pm.schedule, &RustBackend::default(), 4).unwrap();
        let r_ref = residual(&at, &ap, &reference);
        let r_ser = residual(&at, &ap, &serial);
        let r_par = residual(&at, &ap, &parallel);
        assert!(r_ref < 1e-11, "{name}: reference residual {r_ref}");
        assert!(r_ser < 1e-11, "{name}: serial residual {r_ser}");
        assert!(r_par < 1e-11, "{name}: parallel residual {r_par}");
        // the malleable team path must be *bit-identical* to the
        // serial blocked factorization, whatever teams formed
        for (s, (a, b)) in serial.panels.iter().zip(&malleable.panels).enumerate() {
            assert_eq!(a.len(), b.len(), "{name}: snode {s} panel length");
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "{name}: snode {s} entry {i}: {x} vs {y}"
                );
            }
        }
        assert_eq!(report.team_log.len(), at.tree.len(), "{name}: team log incomplete");
    }
}

#[test]
fn alpha_one_collapses_all_strategies() {
    // with perfect speedup every work-conserving strategy matches
    for (name, at, _) in problems() {
        let g = SpGraph::from_tree(&at.tree);
        let p = 16.0;
        let pm = PmSolution::solve(&g, 1.0).makespan_const(p);
        let div = divisible_makespan_tree(&at.tree, 1.0, p);
        assert!(approx_eq(pm, div, 1e-9), "{name}: pm {pm} vs div {div}");
    }
}

#[test]
fn step_profiles_preserve_theorem6_on_real_trees() {
    let (_, at, _) = problems().swap_remove(1);
    let alpha = 0.85;
    for profile in [
        Profile::steps(&[(1e4, 4.0), (1e4, 16.0), (1.0, 8.0)]).unwrap(),
        Profile::steps(&[(5e3, 40.0), (2e4, 2.0), (1.0, 40.0)]).unwrap(),
    ] {
        let pm = PmSchedule::for_tree(&at.tree, alpha, &profile);
        pm.schedule.validate(&at.tree, alpha, &profile, 1e-6).unwrap();
        let equiv = profile.completion(alpha, pm.solution.total_len);
        assert!(
            approx_eq(pm.schedule.makespan, equiv, 1e-9),
            "makespan {} != equivalent-task completion {}",
            pm.schedule.makespan,
            equiv
        );
    }
}
