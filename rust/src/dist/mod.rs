//! Distributed-memory scheduling (paper §6): trees of malleable tasks
//! on platforms of several multicore nodes, where a task may not span
//! nodes and the `p^α` model applies within a node.
//!
//! Module tree:
//!
//! * [`homog`] — Algorithm 11, the `(4/3)^α`-approximation for trees
//!   on two *homogeneous* nodes (closed-form analysis);
//! * [`het`] — Algorithm 12, the λ-approximation scheme for
//!   *independent* tasks on two heterogeneous nodes via trimmed
//!   enumeration of achievable power-sums (exact below 20 tasks);
//! * [`subset`] — the PARTITION gadget behind Theorem 7's NP-hardness
//!   proof plus exact / FPTAS subset-sum solvers;
//! * [`mapping`] — the N-node generalization: assign sibling subtrees
//!   to nodes by LPT over pseudo-tree power-lengths `Leq^{1/α}`
//!   (speedup-aware), with `Proportional` (work-LPT) and
//!   `CriticalPath` baselines, and the Algorithm-12 trimmed split on
//!   two heterogeneous nodes.
//!
//! [`distribute`] is the end-to-end pipeline: map the tree onto a
//! [`Platform`], solve one Prasanna–Musicus schedule per node over the
//! node-local sub-forest, replay the whole thing through the
//! cross-node DES ([`crate::sim::des::simulate_distributed`]) and
//! return a [`DistSchedule`] — per-node [`Schedule`]s plus the
//! stall-aware makespan, the pooled `L_G/(Σp)^α` lower bound, and the
//! single-node fallback comparison (for the `Pm` strategy the returned
//! makespan never exceeds the best single node's, Algorithm 11 style).
//!
//! [`distribute_networked`] is the network-aware variant (DESIGN.md
//! §15): the same candidate sweep plus the [`comm_avoiding`] edge-cut
//! refinement, all replayed through the *priced* network DES
//! ([`crate::net::simulate_networked`]) so the selection sees latency,
//! bandwidth and link sharing — the result never loses to the
//! comm-blind Pm mapping or to the best single node under that DES.
//!
//! Throughout, a set `S` of independent tasks on one node of `p` cores
//! completes no earlier than `PL(S)/p^α` where `PL(S) = (Σ_{i∈S}
//! L_i^{1/α})^α` is the parallel equivalent length (Definition 1), and
//! that bound is achieved by the PM schedule — so node-level
//! scheduling reduces to partitioning power-lengths.

pub mod het;
pub mod homog;
pub mod mapping;
pub mod subset;

pub use het::{het_schedule, independent_optimal, HetSchedule};
pub use homog::{homog_approx, HomogSchedule};
pub use mapping::{
    comm_avoiding, map_tree, pseudo_equiv_lens, remap_lost, root_chain, MappingStrategy,
    TreeMapping,
};
pub use subset::{partition_reduction, subset_sum_exact, subset_sum_fptas};

use anyhow::Result;

use crate::mem::MemWeights;
use crate::model::{Platform, SpGraph, TaskTree};
use crate::net::{simulate_networked_with_workspace, NetDesResult, NetModel, NetSimConfig};
use crate::sched::pm::PmSchedule;
use crate::sched::{Profile, Schedule, SchedWorkspace};
use crate::sim::des::{simulate_distributed_with_workspace, DistDesResult, Policy};

/// A distributed schedule: one per-node PM schedule over the
/// node-local sub-forest, plus the cross-node DES replay that prices
/// the dependency stalls between nodes.
#[derive(Debug, Clone)]
pub struct DistSchedule {
    /// The platform the schedule was built for.
    pub platform: Platform,
    /// The task → node assignment (after candidate selection / the
    /// single-node fallback; `mapping.strategy` names the heuristic
    /// that generated the winning candidate).
    pub mapping: TreeMapping,
    /// One [`Schedule`] per node: the node-local PM spans under a
    /// constant profile of that node's cores, on the node-local
    /// timeline (t = 0 is when the node's first local root may start;
    /// the DES shifts starts by cross-node stalls when replaying).
    /// Nodes without tasks hold an empty schedule.
    pub per_node: Vec<Schedule>,
    /// DES makespan of the mapped run (cross-node stalls included).
    pub makespan: f64,
    /// Pooled lower bound `L_G / (Σ_k cores_k)^α` — `L_G/(Np)^α` on a
    /// homogeneous platform.
    pub lower_bound: f64,
    /// DES makespan of the best single node running the whole tree
    /// (the fallback candidate of Algorithm 11).
    pub single_node_makespan: f64,
    /// True when the single-node candidate won and replaced the
    /// mapping (only ever set for [`MappingStrategy::Pm`]).
    pub fell_back: bool,
    /// The full DES replay (per-node finish times, cross-edge count,
    /// accumulated stall time).
    pub sim: DistDesResult,
}

impl DistSchedule {
    /// `makespan / lower_bound` — the approximation-ratio estimate the
    /// `dist_sim` bench tracks (≥ 1 by construction).
    pub fn approx_ratio(&self) -> f64 {
        self.makespan / self.lower_bound
    }

    /// Relative gain (%) of this schedule over another makespan
    /// (positive when this one is faster).
    pub fn gain_over(&self, other_makespan: f64) -> f64 {
        100.0 * (other_makespan - self.makespan) / other_makespan
    }
}

/// End-to-end distributed pipeline (the CLI `distribute` command):
/// map, solve per-node PM schedules, replay through the cross-node
/// DES. `lambda` parameterizes the Algorithm-12 trimmed split used on
/// two heterogeneous nodes.
///
/// [`MappingStrategy::Pm`] is *makespan-aware* in the Algorithm-11
/// sense of keeping fallback candidates: it generates the power-length
/// LPT partition (or the Alg-12 trimmed split), the two baseline
/// partitions and the all-on-the-fastest-node mapping, replays each
/// through the DES (which prices the realistic sub-processor kink and
/// the cross-node stalls the closed forms cannot see) and returns the
/// best — so its makespan never exceeds the single-node PM makespan
/// *or* either baseline's, all measured by the same DES. The baseline
/// strategies are returned as mapped, so their true cost is visible.
pub fn distribute(
    tree: &TaskTree,
    platform: &Platform,
    alpha: f64,
    strategy: MappingStrategy,
    lambda: f64,
) -> Result<DistSchedule> {
    platform.validate()?;
    let n_nodes = platform.num_nodes();
    let mut ws = SchedWorkspace::new();

    let total_len = ws.solve_forest(tree, &[tree.root], alpha).total_len;
    let lower_bound = platform.pooled_lower_bound(total_len, alpha);

    let mut mapping = map_tree(tree, platform, alpha, strategy, lambda);
    let mut sim =
        simulate_distributed_with_workspace(tree, alpha, platform, &mapping.node_of, Policy::Pm, &mut ws);

    if strategy == MappingStrategy::Pm && n_nodes > 1 {
        // candidate sweep: the baseline partitions can win once the
        // realistic kink is priced in; strict `<` keeps the power-LPT
        // attribution on ties, and identical partitions are skipped
        // rather than replayed
        for cand in [MappingStrategy::Proportional, MappingStrategy::CriticalPath] {
            let m = map_tree(tree, platform, alpha, cand, lambda);
            if m.node_of == mapping.node_of {
                continue;
            }
            let s = simulate_distributed_with_workspace(
                tree,
                alpha,
                platform,
                &m.node_of,
                Policy::Pm,
                &mut ws,
            );
            if s.makespan < sim.makespan {
                mapping = m;
                sim = s;
            }
        }
    }

    // Single-node fallback candidate (Algorithm 11 keeps it too). When
    // the current mapping already is that single-node mapping (1-node
    // platforms, pure chains), its replay is the run we just did.
    let best_node = platform.fastest_node();
    let single = TreeMapping::single_node(tree, best_node, strategy);
    let mut fell_back = false;
    let single_node_makespan = if single.node_of == mapping.node_of {
        sim.makespan
    } else {
        let sim_single = simulate_distributed_with_workspace(
            tree,
            alpha,
            platform,
            &single.node_of,
            Policy::Pm,
            &mut ws,
        );
        let ms = sim_single.makespan;
        if strategy == MappingStrategy::Pm && ms < sim.makespan {
            mapping = single;
            sim = sim_single;
            fell_back = true;
        }
        ms
    };

    // Materialize the per-node PM schedules.
    let masks = mapping.node_members(n_nodes);
    let mut per_node = Vec::with_capacity(n_nodes);
    for (k, mask) in masks.iter().enumerate() {
        match SpGraph::from_induced(tree, mask) {
            Some(gk) => {
                let pm =
                    PmSchedule::for_graph(&gk, alpha, &Profile::constant(platform.node_cores(k)));
                per_node.push(pm.schedule);
            }
            None => per_node.push(Schedule::new(Vec::new())),
        }
    }

    Ok(DistSchedule {
        platform: platform.clone(),
        mapping,
        per_node,
        makespan: sim.makespan,
        lower_bound,
        single_node_makespan,
        fell_back,
        sim,
    })
}

/// A network-aware distributed schedule: the winning mapping and its
/// priced-DES replay, plus the reference makespans the selection was
/// measured against.
#[derive(Debug, Clone)]
pub struct NetDistSchedule {
    /// The platform the schedule was built for.
    pub platform: Platform,
    /// The winning task → node assignment.
    pub mapping: TreeMapping,
    /// The priced network replay of the winning mapping
    /// (`bytes_moved`, `transfer_stall`, retransmit/remap counters).
    pub sim: NetDesResult,
    /// Networked makespan of the network-*blind* Pm mapping — the
    /// incumbent every candidate had to beat, so `sim.makespan` never
    /// exceeds it.
    pub comm_blind_makespan: f64,
    /// Networked makespan of the whole tree on the fastest node (zero
    /// transfers); `sim.makespan` never exceeds this either.
    pub single_node_makespan: f64,
    /// Which candidate won: `pm | comm-avoiding | prop | cp |
    /// single-node`.
    pub chose: &'static str,
    /// True when the single-node candidate won.
    pub fell_back: bool,
    /// Pooled compute lower bound `L_G / (Σ_k cores_k)^α` (transfers
    /// only add to it).
    pub lower_bound: f64,
}

impl NetDistSchedule {
    /// Relative gain (%) of the selected schedule over the
    /// network-blind Pm mapping under the same priced DES (≥ 0 by
    /// construction).
    pub fn gain_comm_aware_vs_blind_pct(&self) -> f64 {
        100.0 * (self.comm_blind_makespan - self.sim.makespan) / self.comm_blind_makespan
    }
}

/// Network-aware `distribute` (DESIGN.md §15): candidate mappings —
/// the network-blind Pm power-LPT, its [`comm_avoiding`] refinement,
/// the `Proportional` / `CriticalPath` baselines, and the single-node
/// fallback — are each replayed through the *priced* network DES
/// ([`crate::net::simulate_networked`]), and the best one is kept
/// (strict `<`, so attribution stays with the earlier candidate on
/// ties). Selection by replay makes two bounds structural: the result
/// never loses to the comm-blind mapping, and never loses to the best
/// single node.
pub fn distribute_networked(
    tree: &TaskTree,
    platform: &Platform,
    alpha: f64,
    lambda: f64,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
) -> Result<NetDistSchedule> {
    platform.validate()?;
    let mut ws = SchedWorkspace::new();
    let total_len = ws.solve_forest(tree, &[tree.root], alpha).total_len;
    let lower_bound = platform.pooled_lower_bound(total_len, alpha);

    let blind = map_tree(tree, platform, alpha, MappingStrategy::Pm, lambda);
    let mut sim = simulate_networked_with_workspace(
        tree, alpha, platform, &blind.node_of, Policy::Pm, weights, net, cfg, &mut ws,
    )?;
    let comm_blind_makespan = sim.makespan;
    let mut mapping = blind;
    let mut chose = "pm";

    let ca = comm_avoiding(tree, platform, alpha, weights, net, lambda);
    if ca.node_of != mapping.node_of {
        let s = simulate_networked_with_workspace(
            tree, alpha, platform, &ca.node_of, Policy::Pm, weights, net, cfg, &mut ws,
        )?;
        if s.makespan < sim.makespan {
            mapping = ca;
            sim = s;
            chose = "comm-avoiding";
        }
    }

    for (name, cand) in [
        ("prop", MappingStrategy::Proportional),
        ("cp", MappingStrategy::CriticalPath),
    ] {
        let m = map_tree(tree, platform, alpha, cand, lambda);
        if m.node_of == mapping.node_of {
            continue;
        }
        let s = simulate_networked_with_workspace(
            tree, alpha, platform, &m.node_of, Policy::Pm, weights, net, cfg, &mut ws,
        )?;
        if s.makespan < sim.makespan {
            mapping = m;
            sim = s;
            chose = name;
        }
    }

    let single = TreeMapping::single_node(tree, platform.fastest_node(), MappingStrategy::Pm);
    let mut fell_back = false;
    let single_node_makespan = if single.node_of == mapping.node_of {
        sim.makespan
    } else {
        let s = simulate_networked_with_workspace(
            tree, alpha, platform, &single.node_of, Policy::Pm, weights, net, cfg, &mut ws,
        )?;
        let ms = s.makespan;
        if ms < sim.makespan {
            mapping = single;
            sim = s;
            chose = "single-node";
            fell_back = true;
        }
        ms
    };

    Ok(NetDistSchedule {
        platform: platform.clone(),
        mapping,
        sim,
        comm_blind_makespan,
        single_node_makespan,
        chose,
        fell_back,
        lower_bound,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;
    use crate::util::rng::Rng;
    use crate::workload::generator::random_tree;
    use crate::workload::TreeClass;

    #[test]
    fn distribute_bounds_hold_on_random_trees() {
        let mut rng = Rng::new(41);
        for (i, class) in [
            TreeClass::Uniform,
            TreeClass::Recent,
            TreeClass::Deep,
            TreeClass::Binary,
        ]
        .iter()
        .enumerate()
        {
            let tree = random_tree(*class, 400 + 100 * i, &mut rng);
            for alpha in [0.7, 0.9, 1.0] {
                for nodes in [2usize, 4] {
                    let plat = Platform::Homogeneous { nodes, p: 8.0 };
                    let d = distribute(&tree, &plat, alpha, MappingStrategy::Pm, 1.1).unwrap();
                    assert!(
                        d.makespan >= d.lower_bound * (1.0 - 1e-9),
                        "{class:?} α={alpha} N={nodes}: below pooled bound"
                    );
                    assert!(
                        d.makespan <= d.single_node_makespan * (1.0 + 1e-9),
                        "{class:?} α={alpha} N={nodes}: mapped {} worse than single node {}",
                        d.makespan,
                        d.single_node_makespan
                    );
                    assert!(d.approx_ratio() >= 1.0 - 1e-9);
                }
            }
        }
    }

    #[test]
    fn distribute_shared_platform_equals_whole_tree_pm() {
        let mut rng = Rng::new(43);
        let tree = random_tree(TreeClass::Uniform, 300, &mut rng);
        let p = 16.0;
        let d = distribute(
            &tree,
            &Platform::Shared { p },
            0.9,
            MappingStrategy::Pm,
            1.1,
        )
        .unwrap();
        let shared = crate::sim::des::simulate(&tree, 0.9, p, Policy::Pm);
        assert_eq!(d.makespan.to_bits(), shared.makespan.to_bits());
        assert_eq!(d.per_node.len(), 1);
        assert_eq!(d.per_node[0].spans.len(), tree.len());
        assert_eq!(d.sim.cross_edges, 0);
    }

    #[test]
    fn per_node_schedules_partition_the_task_set() {
        let mut rng = Rng::new(47);
        let tree = random_tree(TreeClass::Uniform, 500, &mut rng);
        let plat = Platform::Heterogeneous { speeds: vec![8.0, 4.0, 4.0] };
        let d = distribute(&tree, &plat, 0.9, MappingStrategy::Pm, 1.1).unwrap();
        let mut seen = vec![false; tree.len()];
        for (k, sched) in d.per_node.iter().enumerate() {
            for s in &sched.spans {
                assert_eq!(d.mapping.node_of[s.task as usize], k, "span on wrong node");
                assert!(!seen[s.task as usize], "task {} scheduled twice", s.task);
                seen[s.task as usize] = true;
            }
        }
        assert!(seen.into_iter().all(|b| b), "every task scheduled somewhere");
    }

    #[test]
    fn pm_strategy_never_loses_to_baselines_or_single_node() {
        // the Pm candidate sweep replays the baseline partitions too,
        // so under the same DES it can never end up strictly worse
        let mut rng = Rng::new(53);
        for (n, nodes) in [(600usize, 4usize), (350, 2), (500, 3)] {
            let tree = random_tree(TreeClass::Uniform, n, &mut rng);
            let plat = Platform::Homogeneous { nodes, p: 8.0 };
            let pm = distribute(&tree, &plat, 0.9, MappingStrategy::Pm, 1.1).unwrap();
            assert!(pm.makespan <= pm.single_node_makespan * (1.0 + 1e-9));
            for s in [MappingStrategy::Proportional, MappingStrategy::CriticalPath] {
                let base = distribute(&tree, &plat, 0.9, s, 1.1).unwrap();
                assert!(base.makespan >= base.lower_bound * (1.0 - 1e-9));
                assert!(
                    pm.makespan <= base.makespan * (1.0 + 1e-9),
                    "pm {} lost to {} {}",
                    pm.makespan,
                    s.name(),
                    base.makespan
                );
            }
        }
    }

    #[test]
    fn chain_heavy_tree_falls_back_to_single_node() {
        // a pure chain cannot use a second node; the mapping layer
        // already returns the single-node mapping, and distribute
        // reports the exact single-node PM makespan
        let n = 120;
        let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
        let mut rng = Rng::new(59);
        let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.5, 5.0)).collect();
        let tree = TaskTree::from_parents(&parents, &lens).unwrap();
        let plat = Platform::Homogeneous { nodes: 4, p: 8.0 };
        let d = distribute(&tree, &plat, 0.9, MappingStrategy::Pm, 1.1).unwrap();
        let expect = tree.total_work() / 8f64.powf(0.9);
        assert!(approx_eq(d.makespan, expect, 1e-9));
        assert_eq!(d.sim.cross_edges, 0);
    }

    #[test]
    fn networked_distribute_bounds_hold_on_random_trees() {
        // selection by priced replay makes these structural: never
        // worse than the comm-blind Pm mapping, never worse than the
        // best single node, never below the pooled compute bound
        let mut rng = Rng::new(61);
        let cfg = NetSimConfig::default();
        for (i, class) in [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary]
            .iter()
            .enumerate()
        {
            let tree = random_tree(*class, 250 + 80 * i, &mut rng);
            let weights = MemWeights::from_task_lens(&tree);
            for nodes in [2usize, 4] {
                let plat = Platform::Homogeneous { nodes, p: 8.0 };
                for (lat, bw) in [(0.0, f64::INFINITY), (0.05, 2.0), (5.0, 0.05)] {
                    let net = NetModel::uniform(nodes, lat, bw);
                    let d = distribute_networked(&tree, &plat, 0.9, 1.1, &weights, &net, &cfg)
                        .unwrap();
                    assert!(
                        d.sim.makespan <= d.comm_blind_makespan * (1.0 + 1e-9),
                        "{class:?} N={nodes} lat={lat}: {} lost to comm-blind {}",
                        d.sim.makespan,
                        d.comm_blind_makespan
                    );
                    assert!(
                        d.sim.makespan <= d.single_node_makespan * (1.0 + 1e-9),
                        "{class:?} N={nodes} lat={lat}: {} lost to single node {}",
                        d.sim.makespan,
                        d.single_node_makespan
                    );
                    assert!(d.sim.makespan >= d.lower_bound * (1.0 - 1e-9));
                    assert!(d.gain_comm_aware_vs_blind_pct() >= -1e-9);
                    assert_eq!(d.fell_back, d.chose == "single-node");
                }
            }
        }
    }

    #[test]
    fn networked_distribute_on_a_free_net_keeps_the_blind_mapping_cost() {
        // with free links comm_avoiding returns the Pm mapping
        // unchanged and no transfer is priced, so the winner costs
        // exactly what the comm-blind replay (= plain distributed DES)
        // reports
        let mut rng = Rng::new(67);
        let tree = random_tree(TreeClass::Uniform, 400, &mut rng);
        let weights = MemWeights::from_task_lens(&tree);
        let plat = Platform::Homogeneous { nodes: 3, p: 8.0 };
        let net = NetModel::free(3);
        let d = distribute_networked(&tree, &plat, 0.9, 1.1, &weights, &net, &NetSimConfig::default())
            .unwrap();
        assert!(d.sim.makespan <= d.comm_blind_makespan);
        assert_eq!(d.sim.bytes_moved, 0.0);
        assert_eq!(d.sim.retransmits, 0);
        assert_eq!(d.sim.remaps, 0);
        // the comm-blind reference is exactly the free-net delegation
        // of the Pm mapping, i.e. the network-blind distributed DES
        let m = map_tree(&tree, &plat, 0.9, MappingStrategy::Pm, 1.1);
        let mut ws = SchedWorkspace::new();
        let plain =
            simulate_distributed_with_workspace(&tree, 0.9, &plat, &m.node_of, Policy::Pm, &mut ws);
        assert_eq!(d.comm_blind_makespan.to_bits(), plain.makespan.to_bits());
    }

    #[test]
    fn brutal_network_forces_the_single_node_fallback() {
        // latency and bandwidth so bad that any cross edge dwarfs the
        // compute: the whole tree must land on one node, makespan equal
        // to the single-node candidate, and zero words on the wire
        let mut rng = Rng::new(71);
        let tree = random_tree(TreeClass::Uniform, 200, &mut rng);
        let weights = MemWeights::from_task_lens(&tree);
        let plat = Platform::Homogeneous { nodes: 4, p: 8.0 };
        let net = NetModel::uniform(4, 1e9, 1e-9);
        let d = distribute_networked(&tree, &plat, 0.9, 1.1, &weights, &net, &NetSimConfig::default())
            .unwrap();
        assert!(d.mapping.node_of.iter().all(|&k| k == d.mapping.node_of[0]));
        assert_eq!(d.sim.cross_edges, 0);
        assert_eq!(d.sim.bytes_moved, 0.0);
        assert!(approx_eq(d.sim.makespan, d.single_node_makespan, 1e-12));
        assert!(d.gain_comm_aware_vs_blind_pct() > 0.0, "blind mapping pays the wire");
    }

    #[test]
    fn pm_total_len_drives_the_lower_bound() {
        use crate::sched::pm::PmSolution;
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 4.0, 4.0]).unwrap();
        let plat = Platform::Homogeneous { nodes: 2, p: 2.0 };
        let d = distribute(&t, &plat, 0.5, MappingStrategy::Pm, 1.1).unwrap();
        let lg = PmSolution::solve(&SpGraph::from_tree(&t), 0.5).total_len;
        assert!(approx_eq(d.lower_bound, lg / 4f64.powf(0.5), 1e-12));
    }
}
