//! Two-node distributed-memory extensions (paper §6).
//!
//! Tasks may not span nodes: each malleable task runs entirely on one
//! multicore node, and the `p^α` model applies within a node. The paper
//! proves that even two homogeneous nodes make the problem NP-hard
//! (Theorem 7, by reduction from PARTITION) and gives:
//!
//! * **Algorithm 11** ([`homog_approx`]) — a `(4/3)^α`-approximation
//!   for trees on two *homogeneous* nodes: split the sibling subtrees
//!   below the root chain across the nodes by longest-processing-time
//!   (LPT) balancing in `L^{1/α}` ("power-length") space, then run the
//!   serial root chain on the first node. LPT on two machines is a
//!   `7/6`-approximation of the balancing step in power space, which
//!   the `x ↦ x^α` map (α ≤ 1) contracts to `(7/6)^α ≤ (4/3)^α`;
//! * **Algorithm 12** ([`het_schedule`]) — a λ-approximation scheme for
//!   *independent* tasks on two heterogeneous nodes `(p, q)`, via
//!   trimmed enumeration of achievable power-sums (an FPTAS; exact
//!   exhaustive search below 20 tasks);
//! * the PARTITION gadget ([`partition_reduction`]) behind Theorem 7,
//!   plus exact ([`subset_sum_exact`]) and FPTAS
//!   ([`subset_sum_fptas`]) subset-sum solvers used by the reduction
//!   cross-checks and quality benches.
//!
//! Throughout, a set `S` of independent tasks on one node of `p` cores
//! completes no earlier than `PL(S)/p^α` where `PL(S) = (Σ_{i∈S}
//! L_i^{1/α})^α` is the parallel equivalent length (Definition 1), and
//! that bound is achieved by the PM schedule — so two-node scheduling
//! of independent tasks reduces to partitioning power-lengths.

use crate::model::TaskTree;

/// Result of the homogeneous two-node approximation (Algorithm 11).
#[derive(Debug, Clone)]
pub struct HomogSchedule {
    /// Achieved makespan of the constructed feasible schedule.
    pub makespan: f64,
    /// Pooled-platform lower bound `L_G / (2p)^α` (no schedule on two
    /// `p`-core nodes can beat the shared-memory optimum on `2p`).
    pub lower_bound: f64,
    /// Tree node ids of the subtree roots offloaded to the second node.
    pub on_second: Vec<u32>,
    /// 1 when everything stayed on one node, 2 when both nodes run.
    pub phases: usize,
}

/// Result of the heterogeneous two-node scheme (Algorithm 12).
#[derive(Debug, Clone)]
pub struct HetSchedule {
    /// Achieved makespan `max(PL(S)/p^α, PL(S̄)/q^α)`.
    pub makespan: f64,
    /// Indices of the tasks placed on the `p`-core node.
    pub on_p: Vec<usize>,
    /// The approximation parameter the schedule was built for.
    pub lambda: f64,
}

/// Exhaustive optimum for independent tasks on nodes of `p` and `q`
/// cores: minimizes `max(PL(S)/p^α, PL(S̄)/q^α)` over all `2^n`
/// subsets. Returns the `p`-node subset and the optimal makespan.
/// Intended for the small instances of the §6 evaluation (n ≤ 24).
pub fn independent_optimal(lens: &[f64], alpha: f64, p: f64, q: f64) -> (Vec<usize>, f64) {
    let n = lens.len();
    assert!(n <= 24, "independent_optimal is exhaustive; got n = {n} > 24");
    let inv = 1.0 / alpha;
    let xs: Vec<f64> = lens.iter().map(|l| l.powf(inv)).collect();
    let total: f64 = xs.iter().sum();
    let pa = p.powf(alpha);
    let qa = q.powf(alpha);
    let mut best = f64::INFINITY;
    let mut best_mask: u32 = 0;
    for mask in 0u32..(1u32 << n) {
        let mut a = 0.0;
        for (i, x) in xs.iter().enumerate() {
            if mask >> i & 1 == 1 {
                a += x;
            }
        }
        let ms = (a.powf(alpha) / pa).max((total - a).powf(alpha) / qa);
        if ms < best {
            best = ms;
            best_mask = mask;
        }
    }
    let on_p = (0..n).filter(|&i| best_mask >> i & 1 == 1).collect();
    (on_p, best)
}

/// Algorithm 11: trees of malleable tasks on two homogeneous `p`-core
/// nodes, guarantee `makespan ≤ (4/3)^α · L_G / p^α` (and trivially
/// `≥ L_G / (2p)^α`).
///
/// Structure: descend the single-child chain from the root to the
/// first branching node `b`; the chain (including `b`) must run after
/// everything below it and cannot be split across nodes without idling.
/// The sibling subtrees below `b` are independent; balance their
/// power-lengths over the two nodes with LPT, run the remainder tree on
/// node 1 and the offloaded set on node 2, then the chain on node 1
/// once both sides complete. The all-on-one-node PM schedule is kept as
/// a fallback candidate, so the result never exceeds `L_G / p^α`.
pub fn homog_approx(tree: &TaskTree, alpha: f64, p: f64) -> HomogSchedule {
    let inv = 1.0 / alpha;
    let pa = p.powf(alpha);

    // Bottom-up pseudo-tree equivalent lengths:
    // Leq(v) = len(v) + (Σ_c Leq(c)^{1/α})^α.
    let n = tree.len();
    let mut leq = vec![0f64; n];
    for &v in &tree.topo_up() {
        let vi = v as usize;
        let node = &tree.nodes[vi];
        let kids: f64 = node
            .children
            .iter()
            .map(|&c| leq[c as usize].powf(inv))
            .sum();
        leq[vi] = node.len + if kids > 0.0 { kids.powf(alpha) } else { 0.0 };
    }
    let total_equiv = leq[tree.root as usize];
    let lower_bound = total_equiv / (2.0 * p).powf(alpha);
    let single_node = total_equiv / pa;

    // Root chain: follow single children to the first branching node.
    let mut chain_work = 0.0;
    let mut b = tree.root;
    loop {
        chain_work += tree.nodes[b as usize].len;
        match tree.nodes[b as usize].children.as_slice() {
            [only] => b = *only,
            _ => break,
        }
    }
    let branches = &tree.nodes[b as usize].children;
    if branches.len() < 2 {
        // pure chain (or the branching node is a leaf): one node is
        // optimal, the second cannot help.
        return HomogSchedule {
            makespan: single_node,
            lower_bound,
            on_second: Vec::new(),
            phases: 1,
        };
    }

    // LPT balance of subtree power-lengths across the two nodes.
    let mut items: Vec<(f64, u32)> = branches
        .iter()
        .map(|&c| (leq[c as usize].powf(inv), c))
        .collect();
    items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let (mut load1, mut load2) = (0f64, 0f64);
    let mut on_second = Vec::new();
    for &(x, c) in &items {
        if load1 <= load2 {
            load1 += x;
        } else {
            load2 += x;
            on_second.push(c);
        }
    }
    // Both nodes run their forests from t=0 (PM within the node); the
    // chain starts on node 1 when the slower side finishes.
    let split = (load1.max(load2).powf(alpha) + chain_work) / pa;

    if split < single_node {
        HomogSchedule { makespan: split, lower_bound, on_second, phases: 2 }
    } else {
        HomogSchedule {
            makespan: single_node,
            lower_bound,
            on_second: Vec::new(),
            phases: 1,
        }
    }
}

/// Evaluate a `p`-node power-sum `a` against the complement under the
/// two-node objective.
fn het_objective(a: f64, total: f64, alpha: f64, pa: f64, qa: f64) -> f64 {
    (a.powf(alpha) / pa).max(((total - a).max(0.0)).powf(alpha) / qa)
}

/// Algorithm 12: independent tasks on two heterogeneous nodes `(p, q)`
/// with guarantee `makespan ≤ λ · optimal` (λ > 1).
///
/// The objective `max(A^α/p^α, (X−A)^α/q^α)` over achievable power-sums
/// `A` is evaluated on a trimmed enumeration of subset power-sums; the
/// trimming step keeps a `(1+δ)`-net with `δ = (λ^{1/α}−1)/(2n)`, run
/// from both sides (tracking the `p`-side and the `q`-side sums) so the
/// multiplicative error bounds whichever side carries at least half the
/// total. Below 20 tasks the enumeration is exact, so the returned
/// schedule is optimal regardless of λ.
pub fn het_schedule(lens: &[f64], alpha: f64, p: f64, q: f64, lambda: f64) -> HetSchedule {
    assert!(lambda > 1.0, "lambda must exceed 1");
    let n = lens.len();
    if n <= 20 {
        // exact: also what the §6 evaluation instances exercise
        let (on_p, opt) = independent_optimal(lens, alpha, p, q);
        return HetSchedule { makespan: opt, on_p, lambda };
    }
    let inv = 1.0 / alpha;
    let xs: Vec<f64> = lens.iter().map(|l| l.powf(inv)).collect();
    let total: f64 = xs.iter().sum();
    let pa = p.powf(alpha);
    let qa = q.powf(alpha);
    let eps = (lambda.powf(inv) - 1.0) / 2.0;
    let delta = eps / n as f64;

    // Trimmed enumeration of achievable power-sums, built once. The
    // (1+δ)-net keeps the *smallest* representative of each cluster,
    // which multiplicatively under-approximates whichever side the
    // tracked sum represents — so the same net is evaluated under both
    // orientations (tracked sum on the p-node, or on the q-node) and
    // the better schedule wins; the analysis bound holds for the
    // orientation whose side carries at least half the total.
    // arena of (sum, parent index, item index)
    let mut arena: Vec<(f64, usize, usize)> = vec![(0.0, usize::MAX, usize::MAX)];
    let mut cur: Vec<usize> = vec![0];
    for (i, &x) in xs.iter().enumerate() {
        let mut merged: Vec<usize> = Vec::with_capacity(2 * cur.len());
        let mut with: Vec<usize> = Vec::with_capacity(cur.len());
        for &e in &cur {
            arena.push((arena[e].0 + x, e, i));
            with.push(arena.len() - 1);
        }
        // merge two sorted lists by sum
        let (mut a, mut bq) = (0usize, 0usize);
        while a < cur.len() || bq < with.len() {
            let take_a =
                bq >= with.len() || (a < cur.len() && arena[cur[a]].0 <= arena[with[bq]].0);
            let e = if take_a {
                let e = cur[a];
                a += 1;
                e
            } else {
                let e = with[bq];
                bq += 1;
                e
            };
            match merged.last() {
                Some(&last) if arena[e].0 <= arena[last].0 * (1.0 + delta) => {}
                _ => merged.push(e),
            }
        }
        cur = merged;
    }

    let pick = |swap: bool| -> (Vec<usize>, f64) {
        let mut best = f64::INFINITY;
        let mut best_entry = 0usize;
        for &e in &cur {
            let a = arena[e].0;
            let ms = if swap {
                het_objective(total - a, total, alpha, pa, qa)
            } else {
                het_objective(a, total, alpha, pa, qa)
            };
            if ms < best {
                best = ms;
                best_entry = e;
            }
        }
        // reconstruct the enumerated subset
        let mut subset = Vec::new();
        let mut e = best_entry;
        while arena[e].1 != usize::MAX {
            subset.push(arena[e].2);
            e = arena[e].1;
        }
        subset.sort_unstable();
        if swap {
            // enumerated sums were the q-side; the p-side is the complement
            let mut on_p = Vec::new();
            let mut it = subset.iter().peekable();
            for i in 0..n {
                if it.peek() == Some(&&i) {
                    it.next();
                } else {
                    on_p.push(i);
                }
            }
            (on_p, best)
        } else {
            (subset, best)
        }
    };

    let (on_a, ms_a) = pick(false);
    let (on_b, ms_b) = pick(true);
    if ms_a <= ms_b {
        HetSchedule { makespan: ms_a, on_p: on_a, lambda }
    } else {
        HetSchedule { makespan: ms_b, on_p: on_b, lambda }
    }
}

/// Theorem 7 gadget: map a PARTITION instance `a` to an independent-
/// task scheduling instance on two identical single-core nodes.
/// Returns `(lens, p, deadline)` with `lens_i = a_i^α`, `p = 1`: the
/// optimal two-node makespan is `≤ deadline = (Σa/2)^α` **iff** `a`
/// splits into two halves of equal sum.
pub fn partition_reduction(a: &[u64], alpha: f64) -> (Vec<f64>, f64, f64) {
    let lens: Vec<f64> = a.iter().map(|&x| (x as f64).powf(alpha)).collect();
    let s: f64 = a.iter().map(|&x| x as f64).sum();
    (lens, 1.0, (s / 2.0).powf(alpha))
}

/// Exact subset sum: the subset of `xs` with the largest sum `≤ target`
/// (branch and bound over descending items). Returns
/// `(indices, best_sum)`.
///
/// Exactness holds whenever the search finishes within the internal
/// 20M-node budget — comfortably true for every `n ≤ ~24` instance the
/// Theorem 7 reduction uses (`2^n` nodes). On adversarially dense
/// large instances the budget may trip and the best subset found so
/// far is returned (a valid, possibly sub-optimal subset); callers
/// needing guaranteed bounds at scale should use
/// [`subset_sum_fptas`].
pub fn subset_sum_exact(xs: &[f64], target: f64) -> (Vec<usize>, f64) {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| xs[j].partial_cmp(&xs[i]).unwrap());
    let sorted: Vec<f64> = order.iter().map(|&i| xs[i]).collect();
    // suffix sums for the bounding rule
    let mut suffix = vec![0f64; n + 1];
    for i in (0..n).rev() {
        suffix[i] = suffix[i + 1] + sorted[i];
    }

    struct State {
        best: f64,
        best_set: Vec<usize>,
        target: f64,
        done: bool,
        nodes: usize,
    }
    // Node budget: exhaustive below it (covers every instance the
    // reduction tests use, 2^n ≪ budget), graceful best-so-far above it
    // so dense bench instances stay bounded.
    const NODE_BUDGET: usize = 20_000_000;
    fn go(
        i: usize,
        sum: f64,
        chosen: &mut Vec<usize>,
        sorted: &[f64],
        suffix: &[f64],
        st: &mut State,
    ) {
        if st.done {
            return;
        }
        st.nodes += 1;
        if st.nodes > NODE_BUDGET {
            st.done = true;
            return;
        }
        if sum > st.best {
            st.best = sum;
            st.best_set = chosen.clone();
            if st.best >= st.target - 1e-12 * st.target.abs().max(1.0) {
                st.done = true; // cannot do better than hitting the target
                return;
            }
        }
        if i == sorted.len() || sum + suffix[i] <= st.best {
            return; // no remaining item set can improve
        }
        if sum + sorted[i] <= st.target {
            chosen.push(i);
            go(i + 1, sum + sorted[i], chosen, sorted, suffix, st);
            chosen.pop();
        }
        go(i + 1, sum, chosen, sorted, suffix, st);
    }

    let mut st = State { best: 0.0, best_set: Vec::new(), target, done: false, nodes: 0 };
    let mut chosen = Vec::new();
    go(0, 0.0, &mut chosen, &sorted, &suffix, &mut st);
    let mut indices: Vec<usize> = st.best_set.iter().map(|&k| order[k]).collect();
    indices.sort_unstable();
    (indices, st.best)
}

/// FPTAS subset sum (CLRS-style trimmed enumeration): returns a subset
/// with sum `≥ (1−eps) · OPT` and `≤ target`, in time
/// `O(n² ln(target) / eps)`.
pub fn subset_sum_fptas(xs: &[f64], target: f64, eps: f64) -> (Vec<usize>, f64) {
    assert!(eps > 0.0 && eps < 1.0, "eps in (0, 1)");
    let n = xs.len().max(1);
    let delta = eps / (2.0 * n as f64);
    // arena of (sum, parent, item) with backpointers for reconstruction
    let mut arena: Vec<(f64, usize, usize)> = vec![(0.0, usize::MAX, usize::MAX)];
    let mut cur: Vec<usize> = vec![0];
    for (i, &x) in xs.iter().enumerate() {
        if x > target {
            continue;
        }
        let mut with: Vec<usize> = Vec::with_capacity(cur.len());
        for &e in &cur {
            let s = arena[e].0 + x;
            if s <= target {
                arena.push((s, e, i));
                with.push(arena.len() - 1);
            }
        }
        let mut merged: Vec<usize> = Vec::with_capacity(cur.len() + with.len());
        let (mut a, mut b) = (0usize, 0usize);
        while a < cur.len() || b < with.len() {
            let take_a =
                b >= with.len() || (a < cur.len() && arena[cur[a]].0 <= arena[with[b]].0);
            let e = if take_a {
                let e = cur[a];
                a += 1;
                e
            } else {
                let e = with[b];
                b += 1;
                e
            };
            match merged.last() {
                Some(&last)
                    if arena[e].0 <= arena[last].0 * (1.0 + delta)
                        && arena[last].0 > 0.0 => {}
                Some(&last) if arena[e].0 == arena[last].0 => {}
                _ => merged.push(e),
            }
        }
        cur = merged;
    }
    let &best_entry = cur
        .iter()
        .max_by(|&&a, &&b| arena[a].0.partial_cmp(&arena[b].0).unwrap())
        .unwrap();
    let mut indices = Vec::new();
    let mut e = best_entry;
    while arena[e].1 != usize::MAX {
        indices.push(arena[e].2);
        e = arena[e].1;
    }
    indices.sort_unstable();
    (indices, arena[best_entry].0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;
    use crate::util::rng::Rng;

    #[test]
    fn independent_optimal_two_equal_tasks() {
        // two equal tasks, equal nodes: one per node
        let (on_p, opt) = independent_optimal(&[8.0, 8.0], 0.5, 2.0, 2.0);
        assert_eq!(on_p.len(), 1);
        // each node: L/p^α = 8 / sqrt(2)
        assert!(approx_eq(opt, 8.0 / 2f64.sqrt(), 1e-12));
    }

    #[test]
    fn homog_respects_guarantee_on_star() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = rng.range(3, 12);
            let alpha = rng.range_f64(0.5, 1.0);
            let p = rng.range_f64(1.0, 16.0);
            let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.5, 100.0)).collect();
            let mut parents = vec![0usize];
            parents.extend(std::iter::repeat(0).take(n));
            let mut all = vec![0.0];
            all.extend_from_slice(&lens);
            let tree = TaskTree::from_parents(&parents, &all).unwrap();
            let s = homog_approx(&tree, alpha, p);
            let (_, opt) = independent_optimal(&lens, alpha, p, p);
            assert!(
                s.makespan <= (4.0f64 / 3.0).powf(alpha) * opt * (1.0 + 1e-9),
                "ratio {} exceeds guarantee",
                s.makespan / opt
            );
            assert!(s.makespan >= s.lower_bound * (1.0 - 1e-9));
        }
    }

    #[test]
    fn homog_chain_is_single_node_exact() {
        let n = 50;
        let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
        let lens = vec![2.0; n];
        let tree = TaskTree::from_parents(&parents, &lens).unwrap();
        let s = homog_approx(&tree, 0.9, 4.0);
        assert!(approx_eq(s.makespan, 100.0 / 4f64.powf(0.9), 1e-12));
        assert_eq!(s.phases, 1);
        assert!(s.on_second.is_empty());
    }

    #[test]
    fn het_exact_below_threshold_matches_optimal() {
        let mut rng = Rng::new(5);
        let lens: Vec<f64> = (0..10).map(|_| rng.log_uniform(1.0, 40.0)).collect();
        let (alpha, p, q) = (0.8, 6.0, 3.0);
        let (_, opt) = independent_optimal(&lens, alpha, p, q);
        let s = het_schedule(&lens, alpha, p, q, 1.5);
        assert!(approx_eq(s.makespan, opt, 1e-12));
        // the reported partition realizes the reported makespan
        let inv = 1.0 / alpha;
        let on: f64 = s.on_p.iter().map(|&i| lens[i].powf(inv)).sum();
        let total: f64 = lens.iter().map(|l| l.powf(inv)).sum();
        let realized = (on.powf(alpha) / p.powf(alpha))
            .max((total - on).powf(alpha) / q.powf(alpha));
        assert!(approx_eq(realized, s.makespan, 1e-9));
    }

    #[test]
    fn het_fptas_respects_lambda_above_threshold() {
        let mut rng = Rng::new(9);
        let lens: Vec<f64> = (0..26).map(|_| rng.log_uniform(1.0, 60.0)).collect();
        let (alpha, p, q) = (0.9, 8.0, 5.0);
        // brute-force optimum is out of reach at n=26 through the public
        // API; a tight FPTAS run upper-bounds it, and the λ-guarantee is
        // relative to the true optimum ≤ tight, so the chain
        // `s.makespan ≤ λ·opt ≤ λ·tight` must hold.
        let tight = het_schedule(&lens, alpha, p, q, 1.01);
        for lambda in [2.0, 1.3, 1.05] {
            let s = het_schedule(&lens, alpha, p, q, lambda);
            assert!(
                s.makespan <= lambda * tight.makespan * (1.0 + 1e-6),
                "λ={lambda}: {} vs tight {}",
                s.makespan,
                tight.makespan
            );
        }
    }

    #[test]
    fn partition_gadget_decides_small_instances() {
        // YES: {3,1,2,2} -> {3,1} vs {2,2}
        let (lens, p, t) = partition_reduction(&[3, 1, 2, 2], 0.7);
        let (_, opt) = independent_optimal(&lens, 0.7, p, p);
        assert!(opt <= t + 1e-9);
        // NO: odd total sum
        let (lens, p, t) = partition_reduction(&[3, 1, 1], 0.7);
        let (_, opt) = independent_optimal(&lens, 0.7, p, p);
        assert!(opt > t + 1e-9);
    }

    #[test]
    fn subset_sum_exact_hits_partition() {
        let xs = [3.0, 1.0, 2.0, 2.0];
        let (idx, best) = subset_sum_exact(&xs, 4.0);
        assert!(approx_eq(best, 4.0, 1e-12));
        let s: f64 = idx.iter().map(|&i| xs[i]).sum();
        assert!(approx_eq(s, best, 1e-12));
    }

    #[test]
    fn subset_sum_fptas_meets_guarantee() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..40).map(|_| rng.log_uniform(1.0, 500.0)).collect();
        let target = xs.iter().sum::<f64>() * 0.37;
        let (_, exact) = subset_sum_exact(&xs, target);
        for eps in [0.3, 0.1, 0.01] {
            let (idx, got) = subset_sum_fptas(&xs, target, eps);
            assert!(got <= target * (1.0 + 1e-12));
            assert!(
                got >= (1.0 - eps) * exact - 1e-9,
                "eps={eps}: {got} vs exact {exact}"
            );
            let s: f64 = idx.iter().map(|&i| xs[i]).sum();
            assert!(approx_eq(s, got, 1e-9));
        }
    }
}
