//! Tree → node mapping for distributed platforms (Algorithm 11
//! generalized to N nodes, paper §6).
//!
//! Tasks may not span nodes, so the unit of placement is a whole
//! subtree. The mapping descends the single-child chain from the root
//! to the first branching task `b` (the chain must run after
//! everything below it and is kept on one node — the fastest), then
//! assigns the independent sibling subtrees below `b` to nodes:
//!
//! * [`MappingStrategy::Pm`] — LPT over pseudo-tree *power-lengths*
//!   `Leq(c)^{1/α}` (speedup-aware: a node's forest of subtrees `S`
//!   finishes at `(Σ_{c∈S} Leq(c)^{1/α})^α / p^α` under PM, so
//!   balancing power-sums balances actual completion times). On two
//!   heterogeneous nodes the split instead runs Algorithm 12's
//!   λ-trimmed subset enumeration over the subtree equivalent lengths
//!   (exact below 20 subtrees) — the two-sided case where greedy LPT
//!   loses its guarantee;
//! * [`MappingStrategy::Proportional`] — LPT over subtree *work*
//!   `Σ L_i` (the α-unaware baseline: what a Pothen–Sun-style runtime
//!   balances);
//! * [`MappingStrategy::CriticalPath`] — LPT over subtree critical
//!   paths (a depth-aware but speedup-unaware baseline).
//!
//! All sorts use `f64::total_cmp` — a NaN task length must degrade the
//! mapping, not panic it.

use anyhow::{bail, Result};

use crate::model::{Platform, TaskTree};

use super::het::het_schedule;

/// How sibling subtrees are weighed when balancing them over nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingStrategy {
    /// Speedup-aware: balance pseudo-tree power-lengths `Leq^{1/α}`.
    Pm,
    /// α-unaware baseline: balance subtree total work.
    Proportional,
    /// Depth-aware baseline: balance subtree critical paths.
    CriticalPath,
}

impl MappingStrategy {
    /// Parse the CLI spelling (`pm | prop | cp`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pm" => Ok(MappingStrategy::Pm),
            "prop" | "proportional" => Ok(MappingStrategy::Proportional),
            "cp" | "critical-path" => Ok(MappingStrategy::CriticalPath),
            other => bail!("unknown mapping strategy {other:?} (pm|prop|cp)"),
        }
    }

    /// Stable short name (the CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            MappingStrategy::Pm => "pm",
            MappingStrategy::Proportional => "prop",
            MappingStrategy::CriticalPath => "cp",
        }
    }
}

/// A task → node assignment for one tree on one platform.
#[derive(Debug, Clone)]
pub struct TreeMapping {
    /// Node index per task id.
    pub node_of: Vec<usize>,
    /// Root chain (root down to and including the first branching
    /// task); runs on [`TreeMapping::chain_node`] after all subtrees.
    pub chain: Vec<u32>,
    /// Sibling subtree roots below the chain (empty when the tree is a
    /// pure chain or the platform has one node).
    pub branch_roots: Vec<u32>,
    /// Node the chain (and any single-node fallback) runs on.
    pub chain_node: usize,
    /// The strategy that produced this mapping.
    pub strategy: MappingStrategy,
}

impl TreeMapping {
    /// All tasks on one node (the mapping every `Platform::Shared` run
    /// and the single-node fallback use).
    pub fn single_node(tree: &TaskTree, node: usize, strategy: MappingStrategy) -> TreeMapping {
        TreeMapping {
            node_of: vec![node; tree.len()],
            chain: Vec::new(),
            branch_roots: Vec::new(),
            chain_node: node,
            strategy,
        }
    }

    /// Per-node membership masks (`masks[k][t]` ⇔ task `t` on node `k`).
    pub fn node_members(&self, n_nodes: usize) -> Vec<Vec<bool>> {
        let mut masks = vec![vec![false; self.node_of.len()]; n_nodes];
        for (t, &k) in self.node_of.iter().enumerate() {
            masks[k][t] = true;
        }
        masks
    }
}

/// Bottom-up pseudo-tree equivalent lengths (Definition 1 on the
/// Figure-7 pseudo-tree):
/// `Leq(v) = len(v) + (Σ_c Leq(c)^{1/α})^α`.
pub fn pseudo_equiv_lens(tree: &TaskTree, alpha: f64) -> Vec<f64> {
    let inv = 1.0 / alpha;
    let n = tree.len();
    let mut leq = vec![0f64; n];
    for &v in &tree.topo_up() {
        let vi = v as usize;
        let node = &tree.nodes[vi];
        let kids: f64 = node
            .children
            .iter()
            .map(|&c| leq[c as usize].powf(inv))
            .sum();
        leq[vi] = node.len + if kids > 0.0 { kids.powf(alpha) } else { 0.0 };
    }
    leq
}

/// Root chain of a tree: the tasks from the root down to (and
/// including) the first task with ≠ 1 children. Returns the chain and
/// the sibling subtree roots below it (children of the last chain
/// task; empty for pure chains).
pub fn root_chain(tree: &TaskTree) -> (Vec<u32>, Vec<u32>) {
    let mut chain = Vec::new();
    let mut b = tree.root;
    loop {
        chain.push(b);
        match tree.nodes[b as usize].children.as_slice() {
            [only] => b = *only,
            _ => break,
        }
    }
    let branches = tree.nodes[b as usize].children.clone();
    (chain, branches)
}

/// Per-subtree critical path (max root-to-leaf length sum), bottom-up.
fn subtree_critical_paths(tree: &TaskTree) -> Vec<f64> {
    let mut cp = vec![0f64; tree.len()];
    for &v in &tree.topo_up() {
        let node = &tree.nodes[v as usize];
        let child_max = node
            .children
            .iter()
            .map(|&c| cp[c as usize])
            .fold(0f64, f64::max);
        cp[v as usize] = node.len + child_max;
    }
    cp
}

/// Map `tree` onto `platform` (Algorithm 11 generalized): chain on the
/// fastest node, sibling subtrees balanced by `strategy`; `lambda` is
/// the Algorithm-12 approximation parameter used on the heterogeneous
/// two-node Pm path (values ≤ 1 are clamped just above 1).
pub fn map_tree(
    tree: &TaskTree,
    platform: &Platform,
    alpha: f64,
    strategy: MappingStrategy,
    lambda: f64,
) -> TreeMapping {
    let n_nodes = platform.num_nodes();
    let chain_node = platform.fastest_node();
    if n_nodes == 1 {
        return TreeMapping::single_node(tree, chain_node, strategy);
    }
    let (chain, branches) = root_chain(tree);
    if branches.len() < 2 {
        // pure chain (or a single branch): one node is all the tree
        // can use
        return TreeMapping::single_node(tree, chain_node, strategy);
    }

    // branch index -> node index
    let assign: Vec<usize> = match platform {
        Platform::Heterogeneous { speeds }
            if speeds.len() == 2 && strategy == MappingStrategy::Pm =>
        {
            // two-sided heterogeneous case: λ-trimmed subset
            // enumeration over the subtree equivalent lengths
            // (Algorithm 12; exact below 20 subtrees)
            let leq = pseudo_equiv_lens(tree, alpha);
            let lens: Vec<f64> = branches.iter().map(|&c| leq[c as usize]).collect();
            let lam = if lambda > 1.0 { lambda } else { 1.000001 };
            let het = het_schedule(&lens, alpha, speeds[0], speeds[1], lam);
            let mut a = vec![1usize; branches.len()];
            for &i in &het.on_p {
                a[i] = 0;
            }
            a
        }
        _ => {
            // per-branch balance weights
            let weights: Vec<f64> = match strategy {
                MappingStrategy::Pm => {
                    let inv = 1.0 / alpha;
                    let leq = pseudo_equiv_lens(tree, alpha);
                    branches.iter().map(|&c| leq[c as usize].powf(inv)).collect()
                }
                MappingStrategy::Proportional => {
                    let w = tree.subtree_work();
                    branches.iter().map(|&c| w[c as usize]).collect()
                }
                MappingStrategy::CriticalPath => {
                    let cp = subtree_critical_paths(tree);
                    branches.iter().map(|&c| cp[c as usize]).collect()
                }
            };
            greedy_lpt(&weights, platform)
        }
    };

    let mut node_of = vec![chain_node; tree.len()];
    for (bi, &c) in branches.iter().enumerate() {
        for t in tree.subtree_tasks(c) {
            node_of[t as usize] = assign[bi];
        }
    }
    for &t in &chain {
        node_of[t as usize] = chain_node;
    }
    TreeMapping { node_of, chain, branch_roots: branches, chain_node, strategy }
}

/// Greedy LPT: weights in descending order, each to the node whose
/// projected finish time grows least. The finish proxy is
/// `(load_k + w) / p_k` for every strategy: for `Pm` the weights live
/// in power space where node `k` finishes at `(load_k)^α / p_k^α`,
/// and taking the α-th root of that (monotone, α > 0) gives exactly
/// `load_k / p_k`; the α-unaware strategies balance work or critical
/// path per core, the same proxy.
fn greedy_lpt(weights: &[f64], platform: &Platform) -> Vec<usize> {
    let n_nodes = platform.num_nodes();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&i, &j| weights[j].total_cmp(&weights[i]));
    let scale: Vec<f64> = (0..n_nodes).map(|k| platform.node_cores(k)).collect();
    let mut load = vec![0f64; n_nodes];
    let mut assign = vec![0usize; weights.len()];
    for &bi in &order {
        let w = weights[bi];
        let mut best = 0usize;
        let mut best_t = f64::INFINITY;
        for k in 0..n_nodes {
            let t = (load[k] + w) / scale[k];
            if t < best_t {
                best_t = t;
                best = k;
            }
        }
        load[best] += w;
        assign[bi] = best;
    }
    assign
}

/// Re-map the subtrees lost to a node crash onto the survivors
/// (DESIGN.md §13).
///
/// `needed[t]` marks the tasks whose results were lost (they lived on
/// the dead node and must re-run); `remaining[t]` is the work each
/// needs. The unit of placement is a *component*: a maximal
/// needed-connected subtree (a needed task whose parent is absent or
/// not needed roots one). Components are balanced over the alive
/// nodes by the same power-space LPT as [`map_tree`]'s Pm strategy —
/// component weight `Σ remaining^{1/α}` — except the per-node loads
/// start from `node_load` (the survivors' own residual power-load), so
/// lost work lands on the least-busy survivor, not merely the largest.
///
/// Returns `(component_root, node)` pairs; the caller re-assigns every
/// needed task in each component's needed-descent to the chosen node.
///
/// Errors when no alive node with positive capacity exists (there is
/// nowhere to put the lost work) — a typed failure the replay surfaces
/// instead of a panic.
pub fn remap_lost(
    tree: &TaskTree,
    needed: &[bool],
    remaining: &[f64],
    alpha: f64,
    alive: &[bool],
    cores: &[f64],
    node_load: &[f64],
) -> Result<Vec<(u32, usize)>> {
    let inv = 1.0 / alpha;
    let n = tree.len();
    // component roots and their power-weights (needed-only descent)
    let mut roots: Vec<u32> = Vec::new();
    let mut weights: Vec<f64> = Vec::new();
    for v in 0..n {
        if !needed[v] {
            continue;
        }
        let is_root = match tree.nodes[v].parent {
            None => true,
            Some(p) => !needed[p as usize],
        };
        if !is_root {
            continue;
        }
        let mut w = 0f64;
        let mut stack = vec![v as u32];
        while let Some(t) = stack.pop() {
            let ti = t as usize;
            w += remaining[ti].max(0.0).powf(inv);
            for &c in &tree.nodes[ti].children {
                if needed[c as usize] {
                    stack.push(c);
                }
            }
        }
        roots.push(v as u32);
        weights.push(w);
    }
    // LPT over alive nodes, loads seeded with the survivors' own queues
    let mut order: Vec<usize> = (0..roots.len()).collect();
    order.sort_by(|&i, &j| weights[j].total_cmp(&weights[i]));
    let mut load = node_load.to_vec();
    let mut out = vec![(0u32, 0usize); roots.len()];
    for &i in &order {
        let w = weights[i];
        let mut best = usize::MAX;
        let mut best_t = f64::INFINITY;
        for k in 0..alive.len() {
            if !alive[k] || cores[k] <= 0.0 {
                continue;
            }
            let t = (load[k] + w) / cores[k];
            if t < best_t {
                best_t = t;
                best = k;
            }
        }
        if best == usize::MAX {
            bail!("remap_lost: no surviving node with positive capacity");
        }
        load[best] += w;
        out[i] = (roots[i], best);
    }
    Ok(out)
}

/// Communication-avoiding refinement of the Pm mapping (DESIGN.md
/// §15): start from [`map_tree`]'s power-LPT partition and greedily
/// pull branches back onto the chain node whenever the network price
/// of their cross edge exceeds the compute price of co-locating them.
///
/// A branch parked on node `k ≠ chain_node` ships its root's
/// contribution block over the `k → chain_node` link once, costing
/// `lat + cb/bw` seconds of pure waiting. Moving the branch instead
/// raises the chain node's PM finish time by the marginal
/// `((load + w)^α − load^α) / p^α` with `w = Leq(branch)^{1/α}` (the
/// same power space the LPT balanced). Branches are visited in
/// descending transfer-cost order, and each move updates the load, so
/// the refinement is a standard greedy edge-cut descent. On a
/// [`NetModel::free`] network no edge has a price and the Pm mapping
/// comes back unchanged.
///
/// This is a *candidate*, not a decision: `distribute --net` replays
/// it (and the comm-blind Pm mapping, and single-node) through the
/// priced DES and keeps the best, so network awareness can refine the
/// mapping but never worsen the selected schedule.
pub fn comm_avoiding(
    tree: &TaskTree,
    platform: &Platform,
    alpha: f64,
    weights: &crate::mem::MemWeights,
    net: &crate::net::NetModel,
    lambda: f64,
) -> TreeMapping {
    let mut m = map_tree(tree, platform, alpha, MappingStrategy::Pm, lambda);
    if m.branch_roots.is_empty() || net.is_free() {
        return m;
    }
    let inv = 1.0 / alpha;
    let leq = pseudo_equiv_lens(tree, alpha);
    let cn = m.chain_node;
    let p_cn = platform.node_cores(cn).powf(alpha);
    // power-load per node from the LPT partition
    let mut load = vec![0f64; platform.num_nodes()];
    let w_of: Vec<f64> = m
        .branch_roots
        .iter()
        .map(|&c| leq[c as usize].powf(inv))
        .collect();
    for (bi, &c) in m.branch_roots.iter().enumerate() {
        load[m.node_of[c as usize]] += w_of[bi];
    }
    // costliest cross edges first (a branch's price depends only on
    // its own placement, so the upfront prices stay valid as other
    // branches move)
    let price: Vec<f64> = m
        .branch_roots
        .iter()
        .map(|&c| {
            let k = m.node_of[c as usize];
            if k == cn {
                return 0.0;
            }
            let bw = net.bw(k, cn);
            net.lat(k, cn) + if bw.is_infinite() { 0.0 } else { weights.cb[c as usize] / bw }
        })
        .collect();
    let mut order: Vec<usize> = (0..m.branch_roots.len()).collect();
    order.sort_by(|&i, &j| price[j].total_cmp(&price[i]));
    for bi in order {
        let c = m.branch_roots[bi];
        let k = m.node_of[c as usize];
        if k == cn {
            continue;
        }
        let transfer = price[bi];
        let w = w_of[bi];
        let marginal = ((load[cn] + w).powf(alpha) - load[cn].powf(alpha)) / p_cn;
        if transfer > marginal {
            for t in tree.subtree_tasks(c) {
                m.node_of[t as usize] = cn;
            }
            load[cn] += w;
            load[k] -= w;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Star of `k` leaf branches under a root.
    fn star(lens: &[f64]) -> TaskTree {
        let parents = vec![0usize; lens.len() + 1];
        let mut all = vec![1.0];
        all.extend_from_slice(lens);
        TaskTree::from_parents(&parents, &all).unwrap()
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [
            MappingStrategy::Pm,
            MappingStrategy::Proportional,
            MappingStrategy::CriticalPath,
        ] {
            assert_eq!(MappingStrategy::parse(s.name()).unwrap(), s);
        }
        assert!(MappingStrategy::parse("lpt").is_err());
    }

    #[test]
    fn shared_platform_maps_everything_to_node_zero() {
        let t = star(&[1.0, 2.0, 3.0]);
        let m = map_tree(&t, &Platform::Shared { p: 8.0 }, 0.9, MappingStrategy::Pm, 1.1);
        assert!(m.node_of.iter().all(|&k| k == 0));
    }

    #[test]
    fn pure_chain_stays_on_the_fastest_node() {
        let parents: Vec<usize> = (0..20).map(|i: usize| i.saturating_sub(1)).collect();
        let t = TaskTree::from_parents(&parents, &[1.0; 20]).unwrap();
        let plat = Platform::Heterogeneous { speeds: vec![2.0, 8.0, 4.0] };
        let m = map_tree(&t, &plat, 0.9, MappingStrategy::Pm, 1.1);
        assert!(m.node_of.iter().all(|&k| k == 1), "fastest node is index 1");
        assert!(m.branch_roots.is_empty());
    }

    #[test]
    fn mapping_assigns_whole_subtrees_and_chain() {
        // root -> a -> {b-subtree, c-subtree}: chain is {root, a}
        let t = TaskTree::from_parents(&[0, 0, 1, 1, 2, 2, 3, 3], &[1.0; 8]).unwrap();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let m = map_tree(&t, &plat, 0.9, MappingStrategy::Pm, 1.1);
        assert_eq!(m.chain, vec![0, 1]);
        assert_eq!(m.branch_roots, vec![2, 3]);
        assert_eq!(m.node_of[0], 0);
        assert_eq!(m.node_of[1], 0);
        // each branch's tasks share the branch's node
        for &b in &m.branch_roots {
            let k = m.node_of[b as usize];
            for t_id in t.subtree_tasks(b) {
                assert_eq!(m.node_of[t_id as usize], k);
            }
        }
        // both nodes used (two equal branches)
        assert_ne!(m.node_of[2], m.node_of[3]);
        // masks partition the task set
        let masks = m.node_members(2);
        for t_id in 0..t.len() {
            let owners = masks.iter().filter(|mk| mk[t_id]).count();
            assert_eq!(owners, 1);
        }
    }

    #[test]
    fn pm_lpt_meets_list_scheduling_bound_on_stars() {
        // greedy list scheduling guarantee on m identical machines:
        // max load ≤ total/m + w_max·(m−1)/m — holds for every order,
        // so in particular for the LPT order the Pm strategy uses
        let mut rng = Rng::new(17);
        for _ in 0..30 {
            let k = rng.range(4, 12);
            let lens: Vec<f64> = (0..k).map(|_| rng.log_uniform(0.5, 200.0)).collect();
            let t = star(&lens);
            let alpha = rng.range_f64(0.5, 1.0);
            let inv = 1.0 / alpha;
            let m = 3usize;
            let plat = Platform::Homogeneous { nodes: m, p: 4.0 };
            let pm = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
            let mut load = vec![0f64; m];
            for &c in &pm.branch_roots {
                load[pm.node_of[c as usize]] += lens[c as usize - 1].powf(inv);
            }
            let max_load = load.into_iter().fold(0f64, f64::max);
            let total: f64 = lens.iter().map(|l| l.powf(inv)).sum();
            let w_max = lens.iter().map(|l| l.powf(inv)).fold(0f64, f64::max);
            let bound = total / m as f64 + w_max * (m as f64 - 1.0) / m as f64;
            assert!(
                max_load <= bound * (1.0 + 1e-9),
                "alpha={alpha}: max load {max_load} exceeds list bound {bound}"
            );
        }
    }

    #[test]
    fn pm_beats_prop_when_subtree_shapes_differ() {
        // Two chain-shaped branches (Leq = work = 4) and one bushy,
        // work-heaviest branch (work 8.5 but Leq ≈ 2 at α = 0.5): the
        // work balancer places the bushy branch alone and pairs the two
        // chains — in power space (where node finish times live) that
        // node carries 16+16 = 32; the power-length balancer separates
        // the chains for a max power-sum of 20.25.
        // tree: root 0 with branch roots {1, 2, 3}
        let mut parents = vec![0usize; 4];
        let mut lens = vec![0.0, 1.0, 0.0, 1.0];
        // chain below 1: tasks 4,5,6 (branch work 4)
        parents.extend([1, 4, 5]);
        lens.extend([1.0, 1.0, 1.0]);
        // 17 leaves below 2: tasks 7..=23 (branch work 8.5)
        parents.extend([2; 17]);
        lens.extend([0.5; 17]);
        // chain below 3: tasks 24,25,26 (branch work 4)
        parents.extend([3, 24, 25]);
        lens.extend([1.0, 1.0, 1.0]);
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let alpha = 0.5;
        let inv = 1.0 / alpha;
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let leq = pseudo_equiv_lens(&t, alpha);
        let max_power = |m: &TreeMapping| -> f64 {
            let mut load = vec![0f64; 2];
            for &c in &m.branch_roots {
                load[m.node_of[c as usize]] += leq[c as usize].powf(inv);
            }
            load.into_iter().fold(0f64, f64::max)
        };
        let pm = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
        let prop = map_tree(&t, &plat, alpha, MappingStrategy::Proportional, 1.1);
        assert_eq!(
            prop.node_of[1], prop.node_of[3],
            "work balancing pairs the chains on this instance"
        );
        assert_ne!(pm.node_of[1], pm.node_of[3], "Pm must separate the chains");
        assert!(
            max_power(&pm) < max_power(&prop) * (1.0 - 1e-9),
            "pm {} should beat prop {}",
            max_power(&pm),
            max_power(&prop)
        );
    }

    #[test]
    fn het_greedy_scales_finish_by_cores_not_power_cores() {
        // speeds [4,1,1], α=0.5: branch power-lengths [16,4,4] → the
        // correct finish proxy (load/p) gives one branch per node and
        // max finish 2.0; scaling loads by p^{1/α} instead would pile
        // every branch onto the fast node (finish ≈ 2.45)
        let t = star(&[4.0, 2.0, 2.0]);
        let plat = Platform::Heterogeneous { speeds: vec![4.0, 1.0, 1.0] };
        let alpha = 0.5;
        let m = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
        let inv = 1.0 / alpha;
        let mut load = vec![0f64; 3];
        for &c in &m.branch_roots {
            load[m.node_of[c as usize]] += t.nodes[c as usize].len.powf(inv);
        }
        let finish = load
            .iter()
            .enumerate()
            .map(|(k, l)| l.powf(alpha) / plat.node_cores(k).powf(alpha))
            .fold(0f64, f64::max);
        assert!((finish - 2.0).abs() < 1e-12, "max finish {finish}");
    }

    #[test]
    fn het_two_node_pm_uses_optimal_partition_below_threshold() {
        // ≤ 20 branches: the Algorithm-12 path is exact, so the achieved
        // two-node objective equals the independent optimum over the
        // branch equivalent lengths
        let mut rng = Rng::new(23);
        let lens: Vec<f64> = (0..10).map(|_| rng.log_uniform(1.0, 60.0)).collect();
        let t = star(&lens);
        let (alpha, p, q) = (0.8, 8.0, 3.0);
        let plat = Platform::Heterogeneous { speeds: vec![p, q] };
        let m = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.5);
        let inv = 1.0 / alpha;
        let mut a = 0f64;
        let mut b = 0f64;
        for &c in &m.branch_roots {
            let x = lens[c as usize - 1].powf(inv);
            if m.node_of[c as usize] == 0 {
                a += x;
            } else {
                b += x;
            }
        }
        let achieved = (a.powf(alpha) / p.powf(alpha)).max(b.powf(alpha) / q.powf(alpha));
        let (_, opt) = crate::dist::independent_optimal(&lens, alpha, p, q);
        assert!(
            (achieved - opt).abs() <= 1e-9 * opt,
            "achieved {achieved} vs optimal {opt}"
        );
    }

    #[test]
    fn remap_lost_splits_components_and_prefers_idle_survivors() {
        // star: root 0 (chain node), branches {1, 2, 3} each a single
        // leaf; node 2 (dead) held branches 2 and 3 — two components
        let t = star(&[4.0, 8.0, 8.0]);
        let needed = vec![false, false, true, true];
        let remaining = vec![1.0, 4.0, 8.0, 8.0];
        let alive = vec![true, true, false];
        let cores = vec![4.0, 4.0, 4.0];
        let alpha = 1.0;
        // node 0 carries heavy residual load, node 1 is idle
        let assign =
            remap_lost(&t, &needed, &remaining, alpha, &alive, &cores, &[20.0, 0.0]).unwrap();
        assert_eq!(assign.len(), 2, "two lost components");
        for &(root, k) in &assign {
            assert!(root == 2 || root == 3);
            assert_eq!(k, 1, "lost work must land on the idle survivor");
        }
        // balanced residuals → components split across survivors
        let assign =
            remap_lost(&t, &needed, &remaining, alpha, &alive, &cores, &[0.0, 0.0]).unwrap();
        assert_ne!(assign[0].1, assign[1].1, "equal survivors each take one component");
    }

    #[test]
    fn remap_lost_with_no_survivors_errors_instead_of_panicking() {
        // regression: every node dead (or capacity-less) must surface a
        // typed error, not a debug-assert panic / garbage assignment
        let t = star(&[4.0, 8.0]);
        let needed = vec![false, true, true];
        let remaining = vec![1.0, 4.0, 8.0];
        let dead = remap_lost(
            &t,
            &needed,
            &remaining,
            0.8,
            &[false, false],
            &[4.0, 4.0],
            &[0.0, 0.0],
        );
        assert!(dead.is_err());
        // alive but with zero cores is just as unusable
        let zero = remap_lost(
            &t,
            &needed,
            &remaining,
            0.8,
            &[true, true],
            &[0.0, 0.0],
            &[0.0, 0.0],
        );
        assert!(zero.is_err());
    }

    #[test]
    fn remap_lost_keeps_nested_needed_tasks_in_one_component() {
        // chain 0 <- 1 <- 2: tasks 1 and 2 both lost → one component
        // rooted at 1 with power-weight remaining(1)^{1/α}+remaining(2)^{1/α}
        let t = TaskTree::from_parents(&[0, 0, 1], &[1.0, 2.0, 3.0]).unwrap();
        let needed = vec![false, true, true];
        let remaining = vec![1.0, 2.0, 3.0];
        let assign = remap_lost(
            &t,
            &needed,
            &remaining,
            0.5,
            &[true, false],
            &[4.0, 4.0],
            &[0.0, 0.0],
        )
        .unwrap();
        assert_eq!(assign, vec![(1, 0)]);
    }

    #[test]
    fn nan_branch_length_does_not_panic_mapping() {
        // regression: the LPT sort must tolerate NaN weights
        let t = star(&[1.0, f64::NAN, 3.0, 2.0]);
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        for s in [
            MappingStrategy::Pm,
            MappingStrategy::Proportional,
            MappingStrategy::CriticalPath,
        ] {
            let m = map_tree(&t, &plat, 0.9, s, 1.1);
            assert_eq!(m.node_of.len(), t.len());
        }
    }

    #[test]
    fn comm_avoiding_is_pm_on_a_free_network() {
        let t = star(&[8.0, 6.0, 4.0, 2.0]);
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let w = crate::mem::MemWeights::from_task_lens(&t);
        let net = crate::net::NetModel::free(2);
        let pm = map_tree(&t, &plat, 0.9, MappingStrategy::Pm, 1.1);
        let ca = comm_avoiding(&t, &plat, 0.9, &w, &net, 1.1);
        assert_eq!(ca.node_of, pm.node_of);
        assert_eq!(ca.strategy, MappingStrategy::Pm);
    }

    #[test]
    fn comm_avoiding_pulls_branches_home_when_links_are_expensive() {
        // a brutally slow network: any cross edge costs far more than
        // co-locating the whole forest on the chain node
        let t = star(&[8.0, 6.0, 4.0, 2.0]);
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let w = crate::mem::MemWeights::uniform(t.len(), 200.0, 100.0);
        let net = crate::net::NetModel::uniform(2, 50.0, 0.01);
        let ca = comm_avoiding(&t, &plat, 0.9, &w, &net, 1.1);
        assert!(
            ca.node_of.iter().all(|&k| k == ca.chain_node),
            "expensive links should collapse the mapping onto the chain node: {:?}",
            ca.node_of
        );
        // ...while a fast network keeps the LPT spread across nodes
        let fast = crate::net::NetModel::uniform(2, 1e-6, 1e9);
        let cf = comm_avoiding(&t, &plat, 0.9, &w, &fast, 1.1);
        let pm = map_tree(&t, &plat, 0.9, MappingStrategy::Pm, 1.1);
        assert_eq!(cf.node_of, pm.node_of);
    }
}
