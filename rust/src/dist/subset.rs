//! Subset-sum machinery behind Theorem 7 (paper §6).
//!
//! The PARTITION gadget ([`partition_reduction`]) maps a PARTITION
//! instance to a two-node independent-task scheduling instance; the
//! exact ([`subset_sum_exact`]) and FPTAS ([`subset_sum_fptas`])
//! subset-sum solvers cross-check the reduction and feed the quality
//! benches.

/// Theorem 7 gadget: map a PARTITION instance `a` to an independent-
/// task scheduling instance on two identical single-core nodes.
/// Returns `(lens, p, deadline)` with `lens_i = a_i^α`, `p = 1`: the
/// optimal two-node makespan is `≤ deadline = (Σa/2)^α` **iff** `a`
/// splits into two halves of equal sum.
pub fn partition_reduction(a: &[u64], alpha: f64) -> (Vec<f64>, f64, f64) {
    let lens: Vec<f64> = a.iter().map(|&x| (x as f64).powf(alpha)).collect();
    let s: f64 = a.iter().map(|&x| x as f64).sum();
    (lens, 1.0, (s / 2.0).powf(alpha))
}

/// Exact subset sum: the subset of `xs` with the largest sum `≤ target`
/// (branch and bound over descending items). Returns
/// `(indices, best_sum)`.
///
/// Exactness holds whenever the search finishes within the internal
/// 20M-node budget — comfortably true for every `n ≤ ~24` instance the
/// Theorem 7 reduction uses (`2^n` nodes). On adversarially dense
/// large instances the budget may trip and the best subset found so
/// far is returned (a valid, possibly sub-optimal subset); callers
/// needing guaranteed bounds at scale should use
/// [`subset_sum_fptas`].
pub fn subset_sum_exact(xs: &[f64], target: f64) -> (Vec<usize>, f64) {
    let n = xs.len();
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp, not partial_cmp().unwrap(): a NaN item must not panic
    // the sort (it sorts above every number and is then never chosen,
    // since NaN fails the `sum + x <= target` test)
    order.sort_by(|&i, &j| xs[j].total_cmp(&xs[i]));
    let sorted: Vec<f64> = order.iter().map(|&i| xs[i]).collect();
    // suffix sums for the bounding rule
    let mut suffix = vec![0f64; n + 1];
    for i in (0..n).rev() {
        suffix[i] = suffix[i + 1] + sorted[i];
    }

    struct State {
        best: f64,
        best_set: Vec<usize>,
        target: f64,
        done: bool,
        nodes: usize,
    }
    // Node budget: exhaustive below it (covers every instance the
    // reduction tests use, 2^n ≪ budget), graceful best-so-far above it
    // so dense bench instances stay bounded.
    const NODE_BUDGET: usize = 20_000_000;
    fn go(
        i: usize,
        sum: f64,
        chosen: &mut Vec<usize>,
        sorted: &[f64],
        suffix: &[f64],
        st: &mut State,
    ) {
        if st.done {
            return;
        }
        st.nodes += 1;
        if st.nodes > NODE_BUDGET {
            st.done = true;
            return;
        }
        if sum > st.best {
            st.best = sum;
            st.best_set = chosen.clone();
            if st.best >= st.target - 1e-12 * st.target.abs().max(1.0) {
                st.done = true; // cannot do better than hitting the target
                return;
            }
        }
        if i == sorted.len() || sum + suffix[i] <= st.best {
            return; // no remaining item set can improve
        }
        if sum + sorted[i] <= st.target {
            chosen.push(i);
            go(i + 1, sum + sorted[i], chosen, sorted, suffix, st);
            chosen.pop();
        }
        go(i + 1, sum, chosen, sorted, suffix, st);
    }

    let mut st = State { best: 0.0, best_set: Vec::new(), target, done: false, nodes: 0 };
    let mut chosen = Vec::new();
    go(0, 0.0, &mut chosen, &sorted, &suffix, &mut st);
    let mut indices: Vec<usize> = st.best_set.iter().map(|&k| order[k]).collect();
    indices.sort_unstable();
    (indices, st.best)
}

/// FPTAS subset sum (CLRS-style trimmed enumeration): returns a subset
/// with sum `≥ (1−eps) · OPT` and `≤ target`, in time
/// `O(n² ln(target) / eps)`.
pub fn subset_sum_fptas(xs: &[f64], target: f64, eps: f64) -> (Vec<usize>, f64) {
    assert!(eps > 0.0 && eps < 1.0, "eps in (0, 1)");
    let n = xs.len().max(1);
    let delta = eps / (2.0 * n as f64);
    // arena of (sum, parent, item) with backpointers for reconstruction
    let mut arena: Vec<(f64, usize, usize)> = vec![(0.0, usize::MAX, usize::MAX)];
    let mut cur: Vec<usize> = vec![0];
    for (i, &x) in xs.iter().enumerate() {
        if x > target {
            continue;
        }
        let mut with: Vec<usize> = Vec::with_capacity(cur.len());
        for &e in &cur {
            let s = arena[e].0 + x;
            if s <= target {
                arena.push((s, e, i));
                with.push(arena.len() - 1);
            }
        }
        let mut merged: Vec<usize> = Vec::with_capacity(cur.len() + with.len());
        let (mut a, mut b) = (0usize, 0usize);
        while a < cur.len() || b < with.len() {
            let take_a =
                b >= with.len() || (a < cur.len() && arena[cur[a]].0 <= arena[with[b]].0);
            let e = if take_a {
                let e = cur[a];
                a += 1;
                e
            } else {
                let e = with[b];
                b += 1;
                e
            };
            match merged.last() {
                Some(&last)
                    if arena[e].0 <= arena[last].0 * (1.0 + delta)
                        && arena[last].0 > 0.0 => {}
                Some(&last) if arena[e].0 == arena[last].0 => {}
                _ => merged.push(e),
            }
        }
        cur = merged;
    }
    // total_cmp: a NaN entry (from a NaN input length that slipped the
    // `x > target` guard) must not panic the max scan
    let &best_entry = cur
        .iter()
        .max_by(|&&a, &&b| arena[a].0.total_cmp(&arena[b].0))
        .unwrap();
    let mut indices = Vec::new();
    let mut e = best_entry;
    while arena[e].1 != usize::MAX {
        indices.push(arena[e].2);
        e = arena[e].1;
    }
    indices.sort_unstable();
    (indices, arena[best_entry].0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::independent_optimal;
    use crate::util::approx_eq;
    use crate::util::rng::Rng;

    #[test]
    fn partition_gadget_decides_small_instances() {
        // YES: {3,1,2,2} -> {3,1} vs {2,2}
        let (lens, p, t) = partition_reduction(&[3, 1, 2, 2], 0.7);
        let (_, opt) = independent_optimal(&lens, 0.7, p, p);
        assert!(opt <= t + 1e-9);
        // NO: odd total sum
        let (lens, p, t) = partition_reduction(&[3, 1, 1], 0.7);
        let (_, opt) = independent_optimal(&lens, 0.7, p, p);
        assert!(opt > t + 1e-9);
    }

    #[test]
    fn subset_sum_exact_hits_partition() {
        let xs = [3.0, 1.0, 2.0, 2.0];
        let (idx, best) = subset_sum_exact(&xs, 4.0);
        assert!(approx_eq(best, 4.0, 1e-12));
        let s: f64 = idx.iter().map(|&i| xs[i]).sum();
        assert!(approx_eq(s, best, 1e-12));
    }

    #[test]
    fn subset_sum_fptas_meets_guarantee() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..40).map(|_| rng.log_uniform(1.0, 500.0)).collect();
        let target = xs.iter().sum::<f64>() * 0.37;
        let (_, exact) = subset_sum_exact(&xs, target);
        for eps in [0.3, 0.1, 0.01] {
            let (idx, got) = subset_sum_fptas(&xs, target, eps);
            assert!(got <= target * (1.0 + 1e-12));
            assert!(
                got >= (1.0 - eps) * exact - 1e-9,
                "eps={eps}: {got} vs exact {exact}"
            );
            let s: f64 = idx.iter().map(|&i| xs[i]).sum();
            assert!(approx_eq(s, got, 1e-9));
        }
    }

    #[test]
    fn nan_items_do_not_panic_the_solvers() {
        // regression for the partial_cmp().unwrap() sorts: a NaN item
        // must be ignored, not panic
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let (idx, best) = subset_sum_exact(&xs, 4.0);
        assert!(approx_eq(best, 4.0, 1e-12));
        assert!(!idx.contains(&1), "NaN item must never be chosen");
        let (idx, best) = subset_sum_fptas(&xs, 4.0, 0.1);
        assert!(best.is_finite() && best <= 4.0 + 1e-12);
        assert!(!idx.contains(&1), "NaN item must never be chosen");
    }
}
