//! Algorithm 12: independent malleable tasks on two heterogeneous
//! nodes (paper §6).
//!
//! A set `S` of independent tasks on one node of `p` cores completes
//! no earlier than `PL(S)/p^α` where `PL(S) = (Σ_{i∈S} L_i^{1/α})^α`
//! is the parallel equivalent length (Definition 1), and that bound is
//! achieved by the PM schedule — so two-node scheduling of independent
//! tasks reduces to partitioning power-lengths, which
//! [`het_schedule`] solves by λ-trimmed enumeration (exact below 20
//! tasks).

/// Result of the heterogeneous two-node scheme (Algorithm 12).
#[derive(Debug, Clone)]
pub struct HetSchedule {
    /// Achieved makespan `max(PL(S)/p^α, PL(S̄)/q^α)`.
    pub makespan: f64,
    /// Indices of the tasks placed on the `p`-core node.
    pub on_p: Vec<usize>,
    /// The approximation parameter the schedule was built for.
    pub lambda: f64,
}

/// Exhaustive optimum for independent tasks on nodes of `p` and `q`
/// cores: minimizes `max(PL(S)/p^α, PL(S̄)/q^α)` over all `2^n`
/// subsets. Returns the `p`-node subset and the optimal makespan.
/// Intended for the small instances of the §6 evaluation (n ≤ 24).
pub fn independent_optimal(lens: &[f64], alpha: f64, p: f64, q: f64) -> (Vec<usize>, f64) {
    let n = lens.len();
    assert!(n <= 24, "independent_optimal is exhaustive; got n = {n} > 24");
    let inv = 1.0 / alpha;
    let xs: Vec<f64> = lens.iter().map(|l| l.powf(inv)).collect();
    let total: f64 = xs.iter().sum();
    let pa = p.powf(alpha);
    let qa = q.powf(alpha);
    let mut best = f64::INFINITY;
    let mut best_mask: u32 = 0;
    for mask in 0u32..(1u32 << n) {
        let mut a = 0.0;
        for (i, x) in xs.iter().enumerate() {
            if mask >> i & 1 == 1 {
                a += x;
            }
        }
        let ms = (a.powf(alpha) / pa).max((total - a).powf(alpha) / qa);
        if ms < best {
            best = ms;
            best_mask = mask;
        }
    }
    let on_p = (0..n).filter(|&i| best_mask >> i & 1 == 1).collect();
    (on_p, best)
}

/// Evaluate a `p`-node power-sum `a` against the complement under the
/// two-node objective.
fn het_objective(a: f64, total: f64, alpha: f64, pa: f64, qa: f64) -> f64 {
    (a.powf(alpha) / pa).max(((total - a).max(0.0)).powf(alpha) / qa)
}

/// Algorithm 12: independent tasks on two heterogeneous nodes `(p, q)`
/// with guarantee `makespan ≤ λ · optimal` (λ > 1).
///
/// The objective `max(A^α/p^α, (X−A)^α/q^α)` over achievable power-sums
/// `A` is evaluated on a trimmed enumeration of subset power-sums; the
/// trimming step keeps a `(1+δ)`-net with `δ = (λ^{1/α}−1)/(2n)`, run
/// from both sides (tracking the `p`-side and the `q`-side sums) so the
/// multiplicative error bounds whichever side carries at least half the
/// total. Below 20 tasks the enumeration is exact, so the returned
/// schedule is optimal regardless of λ.
pub fn het_schedule(lens: &[f64], alpha: f64, p: f64, q: f64, lambda: f64) -> HetSchedule {
    assert!(lambda > 1.0, "lambda must exceed 1");
    let n = lens.len();
    if n <= 20 {
        // exact: also what the §6 evaluation instances exercise
        let (on_p, opt) = independent_optimal(lens, alpha, p, q);
        return HetSchedule { makespan: opt, on_p, lambda };
    }
    let inv = 1.0 / alpha;
    let xs: Vec<f64> = lens.iter().map(|l| l.powf(inv)).collect();
    let total: f64 = xs.iter().sum();
    let pa = p.powf(alpha);
    let qa = q.powf(alpha);
    let eps = (lambda.powf(inv) - 1.0) / 2.0;
    let delta = eps / n as f64;

    // Trimmed enumeration of achievable power-sums, built once. The
    // (1+δ)-net keeps the *smallest* representative of each cluster,
    // which multiplicatively under-approximates whichever side the
    // tracked sum represents — so the same net is evaluated under both
    // orientations (tracked sum on the p-node, or on the q-node) and
    // the better schedule wins; the analysis bound holds for the
    // orientation whose side carries at least half the total.
    // arena of (sum, parent index, item index)
    let mut arena: Vec<(f64, usize, usize)> = vec![(0.0, usize::MAX, usize::MAX)];
    let mut cur: Vec<usize> = vec![0];
    for (i, &x) in xs.iter().enumerate() {
        let mut merged: Vec<usize> = Vec::with_capacity(2 * cur.len());
        let mut with: Vec<usize> = Vec::with_capacity(cur.len());
        for &e in &cur {
            arena.push((arena[e].0 + x, e, i));
            with.push(arena.len() - 1);
        }
        // merge two sorted lists by sum
        let (mut a, mut bq) = (0usize, 0usize);
        while a < cur.len() || bq < with.len() {
            let take_a =
                bq >= with.len() || (a < cur.len() && arena[cur[a]].0 <= arena[with[bq]].0);
            let e = if take_a {
                let e = cur[a];
                a += 1;
                e
            } else {
                let e = with[bq];
                bq += 1;
                e
            };
            match merged.last() {
                Some(&last) if arena[e].0 <= arena[last].0 * (1.0 + delta) => {}
                _ => merged.push(e),
            }
        }
        cur = merged;
    }

    let pick = |swap: bool| -> (Vec<usize>, f64) {
        let mut best = f64::INFINITY;
        let mut best_entry = 0usize;
        for &e in &cur {
            let a = arena[e].0;
            let ms = if swap {
                het_objective(total - a, total, alpha, pa, qa)
            } else {
                het_objective(a, total, alpha, pa, qa)
            };
            if ms < best {
                best = ms;
                best_entry = e;
            }
        }
        // reconstruct the enumerated subset
        let mut subset = Vec::new();
        let mut e = best_entry;
        while arena[e].1 != usize::MAX {
            subset.push(arena[e].2);
            e = arena[e].1;
        }
        subset.sort_unstable();
        if swap {
            // enumerated sums were the q-side; the p-side is the complement
            let mut on_p = Vec::new();
            let mut it = subset.iter().peekable();
            for i in 0..n {
                if it.peek() == Some(&&i) {
                    it.next();
                } else {
                    on_p.push(i);
                }
            }
            (on_p, best)
        } else {
            (subset, best)
        }
    };

    let (on_a, ms_a) = pick(false);
    let (on_b, ms_b) = pick(true);
    if ms_a <= ms_b {
        HetSchedule { makespan: ms_a, on_p: on_a, lambda }
    } else {
        HetSchedule { makespan: ms_b, on_p: on_b, lambda }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;
    use crate::util::rng::Rng;

    #[test]
    fn independent_optimal_two_equal_tasks() {
        // two equal tasks, equal nodes: one per node
        let (on_p, opt) = independent_optimal(&[8.0, 8.0], 0.5, 2.0, 2.0);
        assert_eq!(on_p.len(), 1);
        // each node: L/p^α = 8 / sqrt(2)
        assert!(approx_eq(opt, 8.0 / 2f64.sqrt(), 1e-12));
    }

    #[test]
    fn het_exact_below_threshold_matches_optimal() {
        let mut rng = Rng::new(5);
        let lens: Vec<f64> = (0..10).map(|_| rng.log_uniform(1.0, 40.0)).collect();
        let (alpha, p, q) = (0.8, 6.0, 3.0);
        let (_, opt) = independent_optimal(&lens, alpha, p, q);
        let s = het_schedule(&lens, alpha, p, q, 1.5);
        assert!(approx_eq(s.makespan, opt, 1e-12));
        // the reported partition realizes the reported makespan
        let inv = 1.0 / alpha;
        let on: f64 = s.on_p.iter().map(|&i| lens[i].powf(inv)).sum();
        let total: f64 = lens.iter().map(|l| l.powf(inv)).sum();
        let realized = (on.powf(alpha) / p.powf(alpha))
            .max((total - on).powf(alpha) / q.powf(alpha));
        assert!(approx_eq(realized, s.makespan, 1e-9));
    }

    #[test]
    fn het_fptas_respects_lambda_above_threshold() {
        let mut rng = Rng::new(9);
        let lens: Vec<f64> = (0..26).map(|_| rng.log_uniform(1.0, 60.0)).collect();
        let (alpha, p, q) = (0.9, 8.0, 5.0);
        // brute-force optimum is out of reach at n=26 through the public
        // API; a tight FPTAS run upper-bounds it, and the λ-guarantee is
        // relative to the true optimum ≤ tight, so the chain
        // `s.makespan ≤ λ·opt ≤ λ·tight` must hold.
        let tight = het_schedule(&lens, alpha, p, q, 1.01);
        for lambda in [2.0, 1.3, 1.05] {
            let s = het_schedule(&lens, alpha, p, q, lambda);
            assert!(
                s.makespan <= lambda * tight.makespan * (1.0 + 1e-6),
                "λ={lambda}: {} vs tight {}",
                s.makespan,
                tight.makespan
            );
        }
    }
}
