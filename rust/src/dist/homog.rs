//! Algorithm 11: trees of malleable tasks on two homogeneous nodes
//! (paper §6).
//!
//! The paper proves that even two homogeneous nodes make the problem
//! NP-hard (Theorem 7, by reduction from PARTITION); [`homog_approx`]
//! is the `(4/3)^α`-approximation: split the sibling subtrees below
//! the root chain across the nodes by longest-processing-time (LPT)
//! balancing in `L^{1/α}` ("power-length") space, then run the serial
//! root chain on the first node. LPT on two machines is a `7/6`-
//! approximation of the balancing step in power space, which the
//! `x ↦ x^α` map (α ≤ 1) contracts to `(7/6)^α ≤ (4/3)^α`.
//!
//! The N-node generalization (and the α-unaware baselines it is
//! compared against) lives in [`super::mapping`]; this module keeps
//! the closed-form two-node analysis the guarantee is stated for.

use crate::model::TaskTree;

use super::mapping::{pseudo_equiv_lens, root_chain};

/// Result of the homogeneous two-node approximation (Algorithm 11).
#[derive(Debug, Clone)]
pub struct HomogSchedule {
    /// Achieved makespan of the constructed feasible schedule.
    pub makespan: f64,
    /// Pooled-platform lower bound `L_G / (2p)^α` (no schedule on two
    /// `p`-core nodes can beat the shared-memory optimum on `2p`).
    pub lower_bound: f64,
    /// Tree node ids of the subtree roots offloaded to the second node.
    pub on_second: Vec<u32>,
    /// 1 when everything stayed on one node, 2 when both nodes run.
    pub phases: usize,
}

/// Algorithm 11: trees of malleable tasks on two homogeneous `p`-core
/// nodes, guarantee `makespan ≤ (4/3)^α · L_G / p^α` (and trivially
/// `≥ L_G / (2p)^α`).
///
/// Structure: descend the single-child chain from the root to the
/// first branching node `b`; the chain (including `b`) must run after
/// everything below it and cannot be split across nodes without idling.
/// The sibling subtrees below `b` are independent; balance their
/// power-lengths over the two nodes with LPT, run the remainder tree on
/// node 1 and the offloaded set on node 2, then the chain on node 1
/// once both sides complete. The all-on-one-node PM schedule is kept as
/// a fallback candidate, so the result never exceeds `L_G / p^α`.
pub fn homog_approx(tree: &TaskTree, alpha: f64, p: f64) -> HomogSchedule {
    let inv = 1.0 / alpha;
    let pa = p.powf(alpha);

    // Bottom-up pseudo-tree equivalent lengths:
    // Leq(v) = len(v) + (Σ_c Leq(c)^{1/α})^α.
    let leq = pseudo_equiv_lens(tree, alpha);
    let total_equiv = leq[tree.root as usize];
    let lower_bound = total_equiv / (2.0 * p).powf(alpha);
    let single_node = total_equiv / pa;

    // Root chain: follow single children to the first branching node.
    let (chain, branches) = root_chain(tree);
    let chain_work: f64 = chain.iter().map(|&v| tree.nodes[v as usize].len).sum();
    if branches.len() < 2 {
        // pure chain (or the branching node is a leaf): one node is
        // optimal, the second cannot help.
        return HomogSchedule {
            makespan: single_node,
            lower_bound,
            on_second: Vec::new(),
            phases: 1,
        };
    }

    // LPT balance of subtree power-lengths across the two nodes.
    let mut items: Vec<(f64, u32)> = branches
        .iter()
        .map(|&c| (leq[c as usize].powf(inv), c))
        .collect();
    // total_cmp, not partial_cmp().unwrap(): a NaN length must degrade
    // the balance, never panic the sort (PR 3 did the same for
    // `dispatch_order`)
    items.sort_by(|a, b| b.0.total_cmp(&a.0));
    let (mut load1, mut load2) = (0f64, 0f64);
    let mut on_second = Vec::new();
    for &(x, c) in &items {
        if load1 <= load2 {
            load1 += x;
        } else {
            load2 += x;
            on_second.push(c);
        }
    }
    // Both nodes run their forests from t=0 (PM within the node); the
    // chain starts on node 1 when the slower side finishes.
    let split = (load1.max(load2).powf(alpha) + chain_work) / pa;

    if split < single_node {
        HomogSchedule { makespan: split, lower_bound, on_second, phases: 2 }
    } else {
        HomogSchedule {
            makespan: single_node,
            lower_bound,
            on_second: Vec::new(),
            phases: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::independent_optimal;
    use crate::util::approx_eq;
    use crate::util::rng::Rng;

    #[test]
    fn homog_respects_guarantee_on_star() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = rng.range(3, 12);
            let alpha = rng.range_f64(0.5, 1.0);
            let p = rng.range_f64(1.0, 16.0);
            let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.5, 100.0)).collect();
            let parents = vec![0usize; n + 1];
            let mut all = vec![0.0];
            all.extend_from_slice(&lens);
            let tree = TaskTree::from_parents(&parents, &all).unwrap();
            let s = homog_approx(&tree, alpha, p);
            let (_, opt) = independent_optimal(&lens, alpha, p, p);
            assert!(
                s.makespan <= (4.0f64 / 3.0).powf(alpha) * opt * (1.0 + 1e-9),
                "ratio {} exceeds guarantee",
                s.makespan / opt
            );
            assert!(s.makespan >= s.lower_bound * (1.0 - 1e-9));
        }
    }

    #[test]
    fn homog_chain_is_single_node_exact() {
        let n = 50;
        let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
        let lens = vec![2.0; n];
        let tree = TaskTree::from_parents(&parents, &lens).unwrap();
        let s = homog_approx(&tree, 0.9, 4.0);
        assert!(approx_eq(s.makespan, 100.0 / 4f64.powf(0.9), 1e-12));
        assert_eq!(s.phases, 1);
        assert!(s.on_second.is_empty());
    }

    #[test]
    fn nan_length_does_not_panic_lpt() {
        // regression for the partial_cmp().unwrap() LPT sort: a NaN
        // branch length must not panic (the result degrades to NaN /
        // a fallback, but the call returns)
        let parents = vec![0usize; 5];
        let lens = vec![0.0, 3.0, f64::NAN, 2.0, 1.0];
        let tree = TaskTree::from_parents(&parents, &lens).unwrap();
        let s = homog_approx(&tree, 0.9, 4.0);
        // no panic is the contract; the makespan is NaN or finite
        // depending on which side absorbed the NaN — just touch it
        assert_eq!(s.on_second.is_empty(), s.phases == 1);
    }
}
