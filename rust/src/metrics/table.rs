//! Fixed-width text tables for bench/report output (no external
//! dependencies — criterion is unavailable offline, so the benches
//! print the paper's rows through this).

/// Simple left-padded text table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    /// Render with per-column widths and a separator line.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut width = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["N", "alpha"]);
        t.row(&["5000".into(), "0.95".into()]);
        t.row(&["40000".into(), "1.00".into()]);
        let s = t.render();
        assert!(s.contains("    N  alpha"));
        assert!(s.contains("40000   1.00"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(&["x".into()]);
    }
}
