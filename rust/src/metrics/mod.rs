//! Statistics, regression and table rendering for the paper's figures.
//!
//! * [`stats`] — quantiles and the boxplot rows of Figures 13–14;
//! * [`regression`] — the log–log least-squares fit that recovers α
//!   from `T(p)` curves (§3, Tables 1–2);
//! * [`table`] — fixed-width text tables for bench output.

pub mod regression;
pub mod stats;
pub mod table;

pub use regression::{fit_alpha, LinearFit};
pub use stats::{quantile, BoxplotRow};
pub use table::Table;
