//! Least-squares fits — in particular the α regression of paper §3:
//! `T(p) = L / p^α  ⇒  log T = log L − α log p`, fit over `p <=
//! p_cap` ("We have performed a linear regression on the portion where
//! p ≤ 10").

/// Result of a simple linear regression `y = a + b x`.
#[derive(Debug, Clone, Copy)]
pub struct LinearFit {
    pub intercept: f64,
    pub slope: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on `(x, y)` pairs.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    LinearFit { intercept, slope, r2 }
}

/// Fit α from `(p, T(p))` samples with `p <= p_cap`
/// (log–log regression; returns `(alpha, fit)`).
pub fn fit_alpha(samples: &[(f64, f64)], p_cap: f64) -> (f64, LinearFit) {
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .filter(|&&(p, t)| p <= p_cap && p > 0.0 && t > 0.0)
        .map(|&(p, t)| (p.ln(), t.ln()))
        .collect();
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let fit = linear_fit(&xs, &ys);
    (-fit.slope, fit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 3.0).abs() < 1e-12);
        assert!((f.intercept - 2.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_recovered_from_perfect_power_law() {
        let alpha = 0.87;
        let l = 42.0;
        let samples: Vec<(f64, f64)> =
            (1..=40).map(|p| (p as f64, l / (p as f64).powf(alpha))).collect();
        let (a, fit) = fit_alpha(&samples, 10.0);
        assert!((a - alpha).abs() < 1e-9, "fitted {a}");
        assert!(fit.r2 > 0.999999);
    }

    #[test]
    fn p_cap_excludes_saturated_regime() {
        // below cap: perfect α = 0.9; above cap: flat (saturation)
        let alpha = 0.9;
        let mut samples: Vec<(f64, f64)> = (1..=10)
            .map(|p| (p as f64, 100.0 / (p as f64).powf(alpha)))
            .collect();
        let t10 = 100.0 / 10f64.powf(alpha);
        samples.extend((11..=40).map(|p| (p as f64, t10)));
        let (a_capped, _) = fit_alpha(&samples, 10.0);
        let (a_all, _) = fit_alpha(&samples, 40.0);
        assert!((a_capped - alpha).abs() < 1e-9);
        assert!(a_all < alpha - 0.1, "saturation should drag α down: {a_all}");
    }

    #[test]
    fn noisy_fit_r2_reasonable() {
        let mut rng = crate::util::rng::Rng::new(4);
        let samples: Vec<(f64, f64)> = (1..=10)
            .map(|p| {
                let noise = 1.0 + 0.01 * rng.normal();
                (p as f64, 50.0 / (p as f64).powf(0.8) * noise)
            })
            .collect();
        let (a, fit) = fit_alpha(&samples, 10.0);
        assert!((a - 0.8).abs() < 0.05, "fitted {a}");
        assert!(fit.r2 > 0.98);
    }
}
