//! Least-squares fits — in particular the α regression of paper §3:
//! `T(p) = L / p^α  ⇒  log T = log L − α log p`, fit over `p <=
//! p_cap` ("We have performed a linear regression on the portion where
//! p ≤ 10").

use anyhow::{bail, Result};

/// Result of a simple linear regression `y = a + b x`.
#[derive(Debug, Clone, Copy)]
pub struct LinearFit {
    pub intercept: f64,
    pub slope: f64,
    /// Coefficient of determination.
    pub r2: f64,
}

/// Ordinary least squares on `(x, y)` pairs.
///
/// Degenerate inputs are errors, not NaN: fewer than two points, a
/// length mismatch, or all-equal `xs` (`sxx == 0` — the slope would be
/// a silent `NaN`/`inf` division; calibration hits this whenever every
/// traced front ran at the same team size).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Result<LinearFit> {
    if xs.len() != ys.len() {
        bail!("{}:{}: x/y length mismatch ({} vs {})", file!(), line!(), xs.len(), ys.len());
    }
    if xs.len() < 2 {
        bail!("{}:{}: linear fit needs at least two points, got {}", file!(), line!(), xs.len());
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if !(sxx > 0.0) {
        bail!(
            "{}:{}: degenerate fit — all {} x-values equal {mx} (or non-finite), slope undefined",
            file!(),
            line!(),
            xs.len()
        );
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    Ok(LinearFit { intercept, slope, r2 })
}

/// Fit α from `(p, T(p))` samples with `p <= p_cap`
/// (log–log regression; returns `(alpha, fit)`).
///
/// Errors when fewer than two samples survive the `p_cap` filter (the
/// old code panicked on an internal assert) or when every surviving
/// sample has the same `p` (α unidentifiable).
pub fn fit_alpha(samples: &[(f64, f64)], p_cap: f64) -> Result<(f64, LinearFit)> {
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .filter(|&&(p, t)| p <= p_cap && p > 0.0 && t > 0.0)
        .map(|&(p, t)| (p.ln(), t.ln()))
        .collect();
    if pts.len() < 2 {
        bail!(
            "{}:{}: alpha fit needs >= 2 samples with 0 < p <= {p_cap} and t > 0, got {} (of {} raw)",
            file!(),
            line!(),
            pts.len(),
            samples.len()
        );
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let fit = linear_fit(&xs, &ys)?;
    Ok((-fit.slope, fit))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let f = linear_fit(&xs, &ys).unwrap();
        assert!((f.slope - 3.0).abs() < 1e-12);
        assert!((f.intercept - 2.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_are_errors_not_nan() {
        // all-equal xs: sxx == 0 used to yield slope = NaN silently
        let err = linear_fit(&[2.0, 2.0, 2.0], &[1.0, 5.0, 9.0]).unwrap_err();
        assert!(err.to_string().contains("degenerate"), "{err}");
        // too few points (the old code asserted)
        assert!(linear_fit(&[1.0], &[1.0]).is_err());
        assert!(linear_fit(&[], &[]).is_err());
        // length mismatch
        assert!(linear_fit(&[1.0, 2.0], &[1.0]).is_err());
        // non-finite xs make sxx NaN — also caught
        assert!(linear_fit(&[f64::NAN, 1.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn fit_alpha_under_filtering_is_an_error_not_a_panic() {
        // a tight p_cap can leave < 2 samples — must report, not panic
        let samples = [(1.0, 10.0), (8.0, 2.0), (16.0, 1.2)];
        let err = fit_alpha(&samples, 0.5).unwrap_err();
        assert!(err.to_string().contains("alpha fit"), "{err}");
        // single surviving sample
        assert!(fit_alpha(&samples, 1.0).is_err());
        // all samples at one p: unidentifiable
        assert!(fit_alpha(&[(4.0, 3.0), (4.0, 3.1), (4.0, 2.9)], 10.0).is_err());
        // empty input
        assert!(fit_alpha(&[], 10.0).is_err());
    }

    #[test]
    fn alpha_recovered_from_perfect_power_law() {
        let alpha = 0.87;
        let l = 42.0;
        let samples: Vec<(f64, f64)> =
            (1..=40).map(|p| (p as f64, l / (p as f64).powf(alpha))).collect();
        let (a, fit) = fit_alpha(&samples, 10.0).unwrap();
        assert!((a - alpha).abs() < 1e-9, "fitted {a}");
        assert!(fit.r2 > 0.999999);
    }

    #[test]
    fn p_cap_excludes_saturated_regime() {
        // below cap: perfect α = 0.9; above cap: flat (saturation)
        let alpha = 0.9;
        let mut samples: Vec<(f64, f64)> = (1..=10)
            .map(|p| (p as f64, 100.0 / (p as f64).powf(alpha)))
            .collect();
        let t10 = 100.0 / 10f64.powf(alpha);
        samples.extend((11..=40).map(|p| (p as f64, t10)));
        let (a_capped, _) = fit_alpha(&samples, 10.0).unwrap();
        let (a_all, _) = fit_alpha(&samples, 40.0).unwrap();
        assert!((a_capped - alpha).abs() < 1e-9);
        assert!(a_all < alpha - 0.1, "saturation should drag α down: {a_all}");
    }

    #[test]
    fn noisy_fit_r2_reasonable() {
        let mut rng = crate::util::rng::Rng::new(4);
        let samples: Vec<(f64, f64)> = (1..=10)
            .map(|p| {
                let noise = 1.0 + 0.01 * rng.normal();
                (p as f64, 50.0 / (p as f64).powf(0.8) * noise)
            })
            .collect();
        let (a, fit) = fit_alpha(&samples, 10.0).unwrap();
        assert!((a - 0.8).abs() < 0.05, "fitted {a}");
        assert!(fit.r2 > 0.98);
    }
}
