//! Quantiles and boxplot summaries (Figures 13–14 report first/last
//! decile, quartiles and the median of relative distances).

/// Linear-interpolation quantile (`q` in [0, 1]) of unsorted data.
/// NaN-safe: `total_cmp` orders NaN after every finite value instead
/// of panicking mid-sort (the PR 3/4 hardening pattern), so a NaN in
/// the data perturbs only the top quantiles.
pub fn quantile(data: &[f64], q: f64) -> f64 {
    assert!(!data.is_empty(), "quantile of empty data");
    let mut v = data.to_vec();
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Quantile over already-sorted data.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Arithmetic mean.
pub fn mean(data: &[f64]) -> f64 {
    data.iter().sum::<f64>() / data.len().max(1) as f64
}

/// The five-number summary used by the paper's boxplots
/// (first/last decile, first/last quartile, median) plus the mean.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxplotRow {
    pub d10: f64,
    pub q25: f64,
    pub median: f64,
    pub q75: f64,
    pub d90: f64,
    pub mean: f64,
}

impl BoxplotRow {
    pub fn from_data(data: &[f64]) -> BoxplotRow {
        let mut v = data.to_vec();
        v.sort_by(f64::total_cmp);
        BoxplotRow {
            d10: quantile_sorted(&v, 0.10),
            q25: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.50),
            q75: quantile_sorted(&v, 0.75),
            d90: quantile_sorted(&v, 0.90),
            mean: mean(&v),
        }
    }

    /// Render as the figure row: `d10 q25 med q75 d90 (mean)`.
    pub fn render(&self) -> String {
        format!(
            "{:8.3} {:8.3} {:8.3} {:8.3} {:8.3}  (mean {:7.3})",
            self.d10, self.q25, self.median, self.q75, self.d90, self.mean
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_median_of_odd() {
        assert_eq!(quantile(&[3.0, 1.0, 2.0], 0.5), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        // [1,2,3,4]: median = 2.5
        assert!((quantile(&[4.0, 1.0, 3.0, 2.0], 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(quantile(&[4.0, 1.0, 3.0, 2.0], 0.0), 1.0);
        assert_eq!(quantile(&[4.0, 1.0, 3.0, 2.0], 1.0), 4.0);
    }

    #[test]
    fn quantile_single_value() {
        assert_eq!(quantile(&[7.0], 0.3), 7.0);
    }

    #[test]
    fn boxplot_row_ordering() {
        let data: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let r = BoxplotRow::from_data(&data);
        assert!(r.d10 <= r.q25 && r.q25 <= r.median);
        assert!(r.median <= r.q75 && r.q75 <= r.d90);
        assert!((r.median - 50.0).abs() < 1e-12);
        assert!((r.d10 - 10.0).abs() < 1e-12);
        assert!((r.mean - 50.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_survives_nan() {
        // regression: the partial_cmp().unwrap() sort panicked here.
        // total_cmp orders NaN last, so lower quantiles stay correct.
        let data = [2.0, f64::NAN, 1.0, 3.0];
        assert_eq!(quantile(&data, 0.0), 1.0);
        assert_eq!(quantile(&data, 1.0 / 3.0), 2.0);
        let r = BoxplotRow::from_data(&data); // must not panic
        assert_eq!(r.d10, 1.0 + 0.3);
    }

    #[test]
    fn render_contains_fields() {
        let r = BoxplotRow::from_data(&[1.0, 2.0, 3.0]);
        let s = r.render();
        assert!(s.contains("2.000"));
        assert!(s.contains("mean"));
    }
}
