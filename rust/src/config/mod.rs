//! Configuration: a dependency-free `key = value` file format plus a
//! typed view of the settings the launcher understands.
//!
//! Example (`malltree.conf`):
//! ```text
//! # scheduling
//! alpha = 0.9
//! processors = 40
//! strategy = pm        # pm | proportional | divisible
//! amalgamate = 4
//! artifacts_dir = artifacts
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Raw parsed config.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse `key = value` text ('#' comments, blank lines ok).
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {line:?}", no + 1);
            };
            values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not a number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("config {key}={v}: not an integer")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

/// Scheduling strategy selector shared by CLI and config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Pm,
    Proportional,
    Divisible,
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pm" | "prasanna-musicus" => Ok(Strategy::Pm),
            "proportional" | "prop" => Ok(Strategy::Proportional),
            "divisible" | "div" => Ok(Strategy::Divisible),
            other => bail!("unknown strategy {other:?} (pm|proportional|divisible)"),
        }
    }
}

/// Typed settings with defaults (the launcher's view).
#[derive(Debug, Clone)]
pub struct Settings {
    pub alpha: f64,
    pub processors: f64,
    pub strategy: Strategy,
    pub amalgamate: usize,
    pub artifacts_dir: PathBuf,
    pub seed: u64,
}

impl Default for Settings {
    fn default() -> Self {
        Settings {
            alpha: crate::DEFAULT_ALPHA,
            processors: 40.0, // the paper's platform
            strategy: Strategy::Pm,
            amalgamate: 4,
            artifacts_dir: PathBuf::from("artifacts"),
            seed: 0xDA7A,
        }
    }
}

impl Settings {
    pub fn from_config(cfg: &Config) -> Result<Settings> {
        let d = Settings::default();
        Ok(Settings {
            alpha: cfg.get_f64("alpha", d.alpha)?,
            processors: cfg.get_f64("processors", d.processors)?,
            strategy: cfg.get_str("strategy", "pm").parse()?,
            amalgamate: cfg.get_usize("amalgamate", d.amalgamate)?,
            artifacts_dir: PathBuf::from(cfg.get_str("artifacts_dir", "artifacts")),
            seed: cfg.get_usize("seed", d.seed as usize)? as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_keys_and_comments() {
        let c = Config::parse("alpha = 0.8 # speedup\n\n# blank\nprocessors=16\n").unwrap();
        assert_eq!(c.get("alpha"), Some("0.8"));
        assert_eq!(c.get_f64("processors", 1.0).unwrap(), 16.0);
        assert_eq!(c.get_f64("missing", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("no equals sign").is_err());
    }

    #[test]
    fn rejects_bad_numbers() {
        let c = Config::parse("alpha = banana").unwrap();
        assert!(c.get_f64("alpha", 1.0).is_err());
    }

    #[test]
    fn settings_from_config() {
        let c = Config::parse("alpha=0.7\nstrategy = proportional\namalgamate = 8").unwrap();
        let s = Settings::from_config(&c).unwrap();
        assert_eq!(s.alpha, 0.7);
        assert_eq!(s.strategy, Strategy::Proportional);
        assert_eq!(s.amalgamate, 8);
        assert_eq!(s.processors, 40.0); // default
    }

    #[test]
    fn strategy_parse() {
        assert_eq!("pm".parse::<Strategy>().unwrap(), Strategy::Pm);
        assert_eq!("DIV".parse::<Strategy>().unwrap(), Strategy::Divisible);
        assert!("nope".parse::<Strategy>().is_err());
    }
}
