//! Front arena: reusable numeric-assembly memory (DESIGN.md §9).
//!
//! Contribution-block memory layout is a first-class scheduling concern
//! in the memory-aware tree-scheduling literature (Marchal–Sinnen–
//! Vivien; Eyraud-Dubois et al.), so the multifrontal numeric pipeline
//! treats it as an explicit, measurable subsystem rather than a
//! `HashMap<usize, Vec<f64>>`. A [`FrontArena`] owns
//!
//! * the reused **front buffer** (grown once to the widest front),
//! * a **slab pool** of recycled contribution blocks (a child's Schur
//!   complement borrows a slab; the parent's assembly releases it),
//! * the **global-row → front-local scatter map** used for
//!   original-entry assembly (filled per front in O(front), reset by
//!   walking the same rows — never cleared wholesale),
//! * live/peak accounting in f64 words, optionally mirrored into a
//!   shared [`MemGauge`] so the parallel executor's per-worker arenas
//!   report one process-wide peak.
//!
//! In the steady state the serial driver performs no heap allocation
//! per front: slabs cycle through the free list and the front buffer
//! is reused. [`symbolic_peak_f64s`] predicts the serial-path peak
//! from the symbolic structure alone.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::sparse::AssemblyTree;

/// Process-wide live/peak memory gauge shared by per-worker arenas.
#[derive(Debug, Default)]
pub struct MemGauge {
    live: AtomicUsize,
    peak: AtomicUsize,
}

impl MemGauge {
    fn add(&self, n: usize) {
        let cur = self.live.fetch_add(n, Ordering::Relaxed) + n;
        self.peak.fetch_max(cur, Ordering::Relaxed);
    }

    fn sub(&self, n: usize) {
        self.live.fetch_sub(n, Ordering::Relaxed);
    }

    /// Words currently live across every arena sharing this gauge —
    /// what the executor's memory-cap admission gate reads
    /// ([`crate::exec::execute_malleable_capped`]).
    pub fn live_f64s(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    /// High-water mark in f64 words.
    pub fn peak_f64s(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// High-water mark in bytes.
    pub fn peak_bytes(&self) -> usize {
        self.peak_f64s() * std::mem::size_of::<f64>()
    }
}

/// Reusable front + contribution-slab memory for one execution lane
/// (the serial driver, or one worker of the parallel crew).
#[derive(Debug)]
pub struct FrontArena {
    front: Vec<f64>,
    front_len: usize,
    glmap: Vec<u32>,
    free: Vec<Vec<f64>>,
    /// Recycled kernel packing scratch (`dense::pack_len` words per
    /// team job). Deliberately **not** live/peak-accounted: the
    /// pebble-game peak model (and `symbolic_peak_f64s`, which the
    /// measured peak must match exactly) covers fronts and contribution
    /// blocks; this transient is bounded by one O(block·k) panel and
    /// documented as overhead, not schedulable memory.
    scratch: Option<Vec<f64>>,
    live: usize,
    peak: usize,
    shared: Option<Arc<MemGauge>>,
}

impl FrontArena {
    /// Arena for an `n`-column problem (sizes the scatter map).
    pub fn new(n: usize) -> Self {
        FrontArena {
            front: Vec::new(),
            front_len: 0,
            glmap: vec![u32::MAX; n],
            free: Vec::new(),
            scratch: None,
            live: 0,
            peak: 0,
            shared: None,
        }
    }

    /// Arena presized for `at`: the front buffer is reserved at the
    /// widest front so the first traversal already runs allocation-free
    /// on the front path.
    pub fn for_tree(at: &AssemblyTree) -> Self {
        let n = at.symbolic.col_to_snode.len();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap_or(0);
        let mut arena = FrontArena::new(n);
        arena.front.reserve(widest * widest);
        arena
    }

    /// Mirror live/peak accounting into `gauge` (parallel crews share
    /// one gauge across their per-worker arenas).
    pub fn with_gauge(mut self, gauge: Arc<MemGauge>) -> Self {
        self.shared = Some(gauge);
        self
    }

    fn account_add(&mut self, n: usize) {
        self.live += n;
        self.peak = self.peak.max(self.live);
        if let Some(g) = &self.shared {
            g.add(n);
        }
    }

    fn account_sub(&mut self, n: usize) {
        // saturating: a parent's arena may release a slab a sibling
        // worker's arena allocated (migration). The per-arena number is
        // then only a local view — the shared gauge stays exact.
        self.live = self.live.saturating_sub(n);
        if let Some(g) = &self.shared {
            g.sub(n);
        }
    }

    /// Start a front of order `nf`: the front buffer is resized and
    /// zeroed, and `nf * nf` words go live until [`FrontArena::end_front`].
    pub fn begin_front(&mut self, nf: usize) {
        let len = nf * nf;
        self.front.clear();
        self.front.resize(len, 0.0);
        self.front_len = len;
        self.account_add(len);
    }

    /// The current front (valid between `begin_front` and `end_front`).
    pub fn front(&self) -> &[f64] {
        &self.front[..self.front_len]
    }

    /// Split borrow of the current front and the scatter map (both are
    /// needed simultaneously during assembly).
    pub fn front_and_glmap(&mut self) -> (&mut [f64], &mut [u32]) {
        (&mut self.front[..self.front_len], &mut self.glmap[..])
    }

    /// Finish the current front, releasing its words.
    pub fn end_front(&mut self, nf: usize) {
        debug_assert_eq!(self.front_len, nf * nf);
        self.account_sub(nf * nf);
        self.front_len = 0;
    }

    /// Take a contribution slab of exactly `len` words (recycled from
    /// the free list when possible). Contents are zeroed.
    pub fn alloc_block(&mut self, len: usize) -> Vec<f64> {
        let mut b = self.free.pop().unwrap_or_default();
        b.clear();
        b.resize(len, 0.0);
        self.account_add(len);
        b
    }

    /// Return a consumed contribution slab to the pool. Slabs may
    /// migrate between arenas (a child's worker allocates, the parent's
    /// worker releases) and ride through a
    /// [`crate::frontal::FrontTeamJob`] while a team factors the front
    /// that fills them; either way the words stay live from
    /// [`FrontArena::alloc_block`] until this call, so the shared gauge
    /// accounting is exact under the malleable executor too.
    pub fn release_block(&mut self, b: Vec<f64>) {
        self.account_sub(b.len());
        self.free.push(b);
    }

    /// Take the recycled kernel packing scratch (any capacity — the
    /// team job resizes it to its `dense::pack_len`). Unaccounted; see
    /// the field doc for why it sits outside the pebble game.
    pub fn take_scratch(&mut self) -> Vec<f64> {
        self.scratch.take().unwrap_or_default()
    }

    /// Return the packing scratch for reuse by the next front.
    pub fn put_scratch(&mut self, b: Vec<f64>) {
        self.scratch = Some(b);
    }

    /// Words currently live through this arena.
    pub fn live_f64s(&self) -> usize {
        self.live
    }

    /// High-water mark in f64 words seen by this arena.
    pub fn peak_f64s(&self) -> usize {
        self.peak
    }

    /// High-water mark in bytes seen by this arena.
    pub fn peak_bytes(&self) -> usize {
        self.peak * std::mem::size_of::<f64>()
    }
}

/// Predicted serial-path peak (f64 words) from the symbolic structure:
/// replay the `topo_up` traversal, charging each front plus the
/// contribution blocks stacked while it is assembled. This is the
/// number the arena's measured peak must match on the serial driver
/// (tested), and the quantity the memory-aware scheduling literature
/// minimizes by reordering the traversal.
pub fn symbolic_peak_f64s(at: &AssemblyTree) -> usize {
    let sns = &at.symbolic.supernodes;
    let mut live = 0usize;
    let mut peak = 0usize;
    for &v in &at.tree.topo_up() {
        let s = v as usize;
        let sn = &sns[s];
        let nf = sn.front_order();
        // assembly: front + children blocks live together
        live += nf * nf;
        peak = peak.max(live);
        for &c in &at.tree.nodes[s].children {
            let csn = &sns[c as usize];
            let m = csn.front_order() - csn.width;
            live -= m * m;
        }
        // partial factorization: the outgoing Schur slab coexists with
        // the front (the panel is retained factor storage, not arena)
        let m = nf - sn.width;
        live += m * m;
        peak = peak.max(live);
        live -= nf * nf;
    }
    peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, order, symbolic};

    #[test]
    fn slabs_are_recycled_and_accounted() {
        let mut a = FrontArena::new(16);
        let b1 = a.alloc_block(9);
        assert_eq!(b1.len(), 9);
        assert!(b1.iter().all(|&x| x == 0.0));
        assert_eq!(a.live_f64s(), 9);
        a.release_block(b1);
        assert_eq!(a.live_f64s(), 0);
        // the recycled slab is reused (capacity retained) and re-zeroed
        let mut b2 = a.alloc_block(4);
        assert!(b2.iter().all(|&x| x == 0.0));
        b2[0] = 5.0;
        a.release_block(b2);
        assert_eq!(a.peak_f64s(), 9);
    }

    #[test]
    fn front_accounting_peaks_with_blocks() {
        let mut a = FrontArena::new(8);
        let blk = a.alloc_block(4);
        a.begin_front(3);
        assert_eq!(a.live_f64s(), 4 + 9);
        assert_eq!(a.front().len(), 9);
        a.end_front(3);
        a.release_block(blk);
        assert_eq!(a.live_f64s(), 0);
        assert_eq!(a.peak_f64s(), 13);
    }

    #[test]
    fn scratch_recycles_without_accounting() {
        let mut a = FrontArena::new(8);
        let mut s = a.take_scratch();
        assert!(s.is_empty());
        s.resize(128, 0.0);
        a.put_scratch(s);
        // packing scratch never moves the pebble-game accounting
        assert_eq!(a.live_f64s(), 0);
        assert_eq!(a.peak_f64s(), 0);
        // capacity is retained across the cycle
        assert!(a.take_scratch().capacity() >= 128);
    }

    #[test]
    fn gauge_merges_across_arenas() {
        let g = Arc::new(MemGauge::default());
        let mut a1 = FrontArena::new(4).with_gauge(g.clone());
        let mut a2 = FrontArena::new(4).with_gauge(g.clone());
        let b1 = a1.alloc_block(10);
        let b2 = a2.alloc_block(20);
        // slab migration: a2 releases what a1 allocated
        a2.release_block(b1);
        a1.release_block(b2);
        assert_eq!(g.peak_f64s(), 30);
        assert_eq!(g.peak_bytes(), 240);
    }

    #[test]
    fn symbolic_peak_covers_widest_front() {
        let a = gen::grid_laplacian_2d(10);
        let perm = order::nested_dissection_2d(10);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap();
        let peak = symbolic_peak_f64s(&at);
        assert!(peak >= widest * widest, "peak {peak} < widest front {widest}^2");
    }
}
