//! The numeric multifrontal factorization driver.
//!
//! Sequential reference implementation of the algorithm the paper's
//! task trees describe: traverse the assembly tree children-first; per
//! supernode assemble the dense front (original matrix entries of the
//! eliminated columns + extend-add of the children's contribution
//! blocks), partially factor it, store the panel, and pass the Schur
//! complement up. The parallel, schedule-driven variant lives in
//! [`crate::exec`]; both produce identical factors.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::sparse::{AssemblyTree, CscMatrix};

use super::backend::FrontBackend;
use super::dense;

/// Sparse Cholesky factor produced by the multifrontal driver, stored
/// as per-supernode panels.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// Per supernode: row-major `front_order x width` panel holding
    /// `[L11; L21]` (global rows `supernode.rows`, global columns
    /// `first_col..first_col+width`).
    pub panels: Vec<Vec<f64>>,
    /// Matrix order.
    pub n: usize,
}

impl Factorization {
    /// Scatter into a dense lower-triangular `n x n` matrix
    /// (verification / small problems).
    pub fn to_dense(&self, at: &AssemblyTree) -> Vec<f64> {
        let n = self.n;
        let mut l = vec![0f64; n * n];
        for (s, sn) in at.symbolic.supernodes.iter().enumerate() {
            let panel = &self.panels[s];
            let width = sn.width;
            for (li, &gi) in sn.rows.iter().enumerate() {
                for lj in 0..width {
                    let gj = sn.first_col + lj;
                    if gi >= gj {
                        l[gi * n + gj] = panel[li * width + lj];
                    }
                }
            }
        }
        l
    }

    /// Solve `(P A Pᵀ) x = b` via the dense scatter (small problems).
    pub fn solve_dense(&self, at: &AssemblyTree, b: &[f64]) -> Vec<f64> {
        let l = self.to_dense(at);
        let y = dense::forward_solve(&l, self.n, b);
        dense::backward_solve(&l, self.n, &y)
    }
}

/// Assemble the front of supernode `s`: original entries + children
/// contributions (children Schur blocks are consumed from `contrib`).
pub fn assemble_front(
    at: &AssemblyTree,
    ap: &CscMatrix,
    s: usize,
    contrib: &mut HashMap<usize, Vec<f64>>,
) -> Vec<f64> {
    let sn = &at.symbolic.supernodes[s];
    let nf = sn.front_order();
    let width = sn.width;
    let mut front = vec![0f64; nf * nf];
    // global row -> local index
    let local: HashMap<usize, usize> =
        sn.rows.iter().enumerate().map(|(l, &g)| (g, l)).collect();
    // original entries of the eliminated columns (symmetric fill)
    for lj in 0..width {
        let gj = sn.first_col + lj;
        for (gi, v) in ap.col(gj) {
            if gi >= gj {
                if let Some(&li) = local.get(&gi) {
                    front[li * nf + lj] = v;
                    front[lj * nf + li] = v;
                }
            }
        }
    }
    // extend-add children contribution blocks
    for &c in &at.tree.nodes[s].children {
        let c = c as usize;
        let csn = &at.symbolic.supernodes[c];
        let crow = &csn.rows[csn.width..];
        let m = crow.len();
        if m == 0 {
            contrib.remove(&c);
            continue;
        }
        let block = contrib
            .remove(&c)
            .expect("child contribution missing (postorder violated)");
        debug_assert_eq!(block.len(), m * m);
        for (a, &ga) in crow.iter().enumerate() {
            let la = local[&ga];
            for (b, &gb) in crow.iter().enumerate() {
                let lb = local[&gb];
                front[la * nf + lb] += block[a * m + b];
            }
        }
    }
    front
}

/// Run the numeric multifrontal factorization of the permuted matrix
/// `ap` (must be `at.symbolic.perm`-permuted) with `backend`.
pub fn factorize(
    at: &AssemblyTree,
    ap: &CscMatrix,
    backend: &dyn FrontBackend,
) -> Result<Factorization> {
    let ns = at.symbolic.supernodes.len();
    let mut panels: Vec<Vec<f64>> = vec![Vec::new(); ns];
    let mut contrib: HashMap<usize, Vec<f64>> = HashMap::new();
    for &v in &at.tree.topo_up() {
        let s = v as usize;
        let sn = &at.symbolic.supernodes[s];
        let nf = sn.front_order();
        let width = sn.width;
        let front = assemble_front(at, ap, s, &mut contrib);
        if width == nf {
            let l = backend
                .full(&front, nf)
                .with_context(|| format!("full factor of supernode {s} (n={nf})"))?;
            panels[s] = l; // nf x nf == rows x width
        } else {
            let f = backend
                .partial(&front, nf, width)
                .with_context(|| format!("partial factor of supernode {s} (n={nf}, k={width})"))?;
            // stack [L11; L21] into rows x width
            let m = nf - width;
            let mut panel = vec![0f64; nf * width];
            for i in 0..width {
                panel[i * width..(i + 1) * width]
                    .copy_from_slice(&f.l11[i * width..(i + 1) * width]);
            }
            for i in 0..m {
                panel[(width + i) * width..(width + i + 1) * width]
                    .copy_from_slice(&f.l21[i * width..(i + 1) * width]);
            }
            contrib.insert(s, f.schur);
            panels[s] = panel;
        }
    }
    Ok(Factorization { panels, n: ap.n })
}

/// Relative factorization residual `‖P A Pᵀ − L Lᵀ‖_F / ‖A‖_F`
/// via dense reconstruction (use on small/medium problems).
pub fn residual(at: &AssemblyTree, ap: &CscMatrix, f: &Factorization) -> f64 {
    let n = ap.n;
    let l = f.to_dense(at);
    let llt = dense::matmul_nt(&l, &l, n, n, n);
    let a = ap.to_dense();
    let mut num = 0.0;
    for i in 0..n * n {
        let d = a[i] - llt[i];
        num += d * d;
    }
    num.sqrt() / dense::fro_norm(&a).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::backend::RustBackend;
    use crate::sparse::{gen, order, symbolic};

    fn setup(k: usize, amalg: usize) -> (AssemblyTree, CscMatrix) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, amalg).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        (at, ap)
    }

    #[test]
    fn grid_residual_is_tiny() {
        let (at, ap) = setup(8, 0);
        let f = factorize(&at, &ap, &RustBackend).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn amalgamated_residual_is_tiny() {
        let (at, ap) = setup(10, 4);
        let f = factorize(&at, &ap, &RustBackend).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn solve_recovers_solution() {
        let (at, ap) = setup(6, 0);
        let n = ap.n;
        let f = factorize(&at, &ap, &RustBackend).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.37).cos()).collect();
        let b = ap.matvec(&x_true);
        let x = f.solve_dense(&at, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "max err {err}");
    }

    #[test]
    fn random_spd_factorizes() {
        let mut rng = crate::util::rng::Rng::new(77);
        let a = gen::random_spd(60, 4, &mut rng);
        let perm = order::reverse_cuthill_mckee(&a);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn grid_3d_factorizes() {
        let a = gen::grid_laplacian_3d(4);
        let perm = order::nested_dissection_3d(4);
        let at = symbolic::analyze(&a, &perm, 0).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn contribution_blocks_are_all_consumed() {
        let (at, ap) = setup(7, 0);
        let mut contrib = HashMap::new();
        for &v in &at.tree.topo_up() {
            let s = v as usize;
            let sn = &at.symbolic.supernodes[s];
            let front = assemble_front(&at, &ap, s, &mut contrib);
            let nf = sn.front_order();
            if sn.width < nf {
                let f = RustBackend.partial(&front, nf, sn.width).unwrap();
                contrib.insert(s, f.schur);
            }
        }
        // only the root (width == front) may be absent; all children consumed
        assert!(contrib.len() <= 1);
    }
}
