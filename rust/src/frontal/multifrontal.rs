//! The numeric multifrontal factorization driver.
//!
//! Sequential reference implementation of the algorithm the paper's
//! task trees describe: traverse the assembly tree children-first; per
//! supernode assemble the dense front (original matrix entries of the
//! eliminated columns + extend-add of the children's contribution
//! blocks), partially factor it, store the panel, and pass the Schur
//! complement up. The parallel, schedule-driven variants live in
//! [`crate::exec`] — the task-parallel crew and the malleable
//! worker-team executor — and all of them produce bit-identical
//! factors to this driver (tested).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::sparse::{AssemblyTree, CscMatrix};

use super::arena::FrontArena;
use super::backend::FrontBackend;
use super::dense;

/// Sparse Cholesky factor produced by the multifrontal driver, stored
/// as per-supernode panels.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// Per supernode: row-major `front_order x width` panel holding
    /// `[L11; L21]` (global rows `supernode.rows`, global columns
    /// `first_col..first_col+width`).
    pub panels: Vec<Vec<f64>>,
    /// Matrix order.
    pub n: usize,
}

impl Factorization {
    /// Scatter into a dense lower-triangular `n x n` matrix
    /// (verification / small problems).
    pub fn to_dense(&self, at: &AssemblyTree) -> Vec<f64> {
        let n = self.n;
        let mut l = vec![0f64; n * n];
        for (s, sn) in at.symbolic.supernodes.iter().enumerate() {
            let panel = &self.panels[s];
            let width = sn.width;
            for (li, &gi) in sn.rows.iter().enumerate() {
                for lj in 0..width {
                    let gj = sn.first_col + lj;
                    if gi >= gj {
                        l[gi * n + gj] = panel[li * width + lj];
                    }
                }
            }
        }
        l
    }

    /// Solve `(P A Pᵀ) x = b` via the dense scatter (small problems).
    pub fn solve_dense(&self, at: &AssemblyTree, b: &[f64]) -> Vec<f64> {
        let l = self.to_dense(at);
        let y = dense::forward_solve(&l, self.n, b);
        dense::backward_solve(&l, self.n, &y)
    }
}

/// Assemble the front of supernode `s` into `arena`'s front buffer:
/// original matrix entries plus extend-add of the children's
/// contribution blocks (fetched once each via `take_block`, released
/// into the arena after use).
///
/// This is the production assembly path: original entries scatter
/// through the arena's global-row → front-local map (filled in
/// O(front) and reset by walking the same rows), and extend-add is a
/// pure integer-indexed scatter/add over the precomputed relative
/// indices `at.symbolic.rel` — no hashing, no per-front allocation.
/// [`assemble_front`] below is the HashMap reference implementation it
/// is property-tested against.
pub fn assemble_front_arena<F>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    s: usize,
    arena: &mut FrontArena,
    mut take_block: F,
) where
    F: FnMut(usize) -> Option<Vec<f64>>,
{
    let sn = &at.symbolic.supernodes[s];
    let nf = sn.front_order();
    let width = sn.width;
    arena.begin_front(nf);
    {
        let (front, glmap) = arena.front_and_glmap();
        for (l, &g) in sn.rows.iter().enumerate() {
            glmap[g] = l as u32;
        }
        for lj in 0..width {
            let gj = sn.first_col + lj;
            for (gi, v) in ap.col(gj) {
                if gi >= gj {
                    // A's pattern is contained in L's, so the row is
                    // always present in the front
                    let li = glmap[gi] as usize;
                    debug_assert!(li < nf, "row {gi} missing from front {s}");
                    front[li * nf + lj] = v;
                    front[lj * nf + li] = v;
                }
            }
        }
        for &g in &sn.rows {
            glmap[g] = u32::MAX;
        }
    }
    for &c in &at.tree.nodes[s].children {
        let c = c as usize;
        let Some(block) = take_block(c) else {
            // only children without a Schur complement may have no block
            debug_assert!(
                at.symbolic.rel[c].is_empty(),
                "child {c} contribution missing (postorder violated)"
            );
            continue;
        };
        let rel = &at.symbolic.rel[c];
        let m = rel.len();
        debug_assert_eq!(block.len(), m * m);
        {
            let (front, _) = arena.front_and_glmap();
            for (a, &ra) in rel.iter().enumerate() {
                let fa = ra as usize * nf;
                let brow = &block[a * m..(a + 1) * m];
                for (&bv, &rb) in brow.iter().zip(rel.iter()) {
                    front[fa + rb as usize] += bv;
                }
            }
        }
        arena.release_block(block);
    }
}

/// Assemble the front of supernode `s`: original entries + children
/// contributions (children Schur blocks are consumed from `contrib`).
///
/// Reference implementation (per-entry `HashMap` lookups); the hot
/// paths use [`assemble_front_arena`], which must produce bit-identical
/// fronts (see `indexed_assembly_matches_hashmap_reference`).
pub fn assemble_front(
    at: &AssemblyTree,
    ap: &CscMatrix,
    s: usize,
    contrib: &mut HashMap<usize, Vec<f64>>,
) -> Vec<f64> {
    let sn = &at.symbolic.supernodes[s];
    let nf = sn.front_order();
    let width = sn.width;
    let mut front = vec![0f64; nf * nf];
    // global row -> local index
    let local: HashMap<usize, usize> =
        sn.rows.iter().enumerate().map(|(l, &g)| (g, l)).collect();
    // original entries of the eliminated columns (symmetric fill)
    for lj in 0..width {
        let gj = sn.first_col + lj;
        for (gi, v) in ap.col(gj) {
            if gi >= gj {
                if let Some(&li) = local.get(&gi) {
                    front[li * nf + lj] = v;
                    front[lj * nf + li] = v;
                }
            }
        }
    }
    // extend-add children contribution blocks
    for &c in &at.tree.nodes[s].children {
        let c = c as usize;
        let csn = &at.symbolic.supernodes[c];
        let crow = &csn.rows[csn.width..];
        let m = crow.len();
        if m == 0 {
            contrib.remove(&c);
            continue;
        }
        let block = contrib
            .remove(&c)
            .expect("child contribution missing (postorder violated)");
        debug_assert_eq!(block.len(), m * m);
        for (a, &ga) in crow.iter().enumerate() {
            let la = local[&ga];
            for (b, &gb) in crow.iter().enumerate() {
                let lb = local[&gb];
                front[la * nf + lb] += block[a * m + b];
            }
        }
    }
    front
}

/// Assemble + factor one supernode through the arena path: the shared
/// per-front step of the serial drivers ([`factorize_with_arena`] and
/// `exec::execute_serial`). For non-root supernodes the Schur
/// complement lands in `contrib[s]` (an arena slab); the panel — `[l]`
/// for `width == nf`, `[L11; L21]` otherwise — in `panels[s]`. Returns
/// the seconds spent in assembly.
pub(crate) fn factor_front_arena(
    at: &AssemblyTree,
    ap: &CscMatrix,
    s: usize,
    backend: &dyn FrontBackend,
    arena: &mut FrontArena,
    contrib: &mut [Option<Vec<f64>>],
    panels: &mut [Vec<f64>],
) -> Result<f64> {
    let sn = &at.symbolic.supernodes[s];
    let nf = sn.front_order();
    let width = sn.width;
    let t0 = std::time::Instant::now();
    assemble_front_arena(at, ap, s, arena, |c| contrib[c].take());
    let assembly = t0.elapsed().as_secs_f64();
    // end_front / release_block run on the error paths too, so a
    // failed factorization leaves the arena's live accounting at zero
    // (the arena is documented as reusable across traversals)
    if width == nf {
        let result = backend
            .full(arena.front(), nf)
            .with_context(|| format!("full factor of supernode {s} (n={nf})"));
        arena.end_front(nf);
        panels[s] = result?;
    } else {
        let m = nf - width;
        let mut panel = vec![0f64; nf * width];
        let mut schur = arena.alloc_block(m * m);
        let result = backend
            .partial_into(arena.front(), nf, width, &mut panel, &mut schur)
            .with_context(|| format!("partial factor of supernode {s} (n={nf}, k={width})"));
        arena.end_front(nf);
        if let Err(e) = result {
            arena.release_block(schur);
            return Err(e);
        }
        contrib[s] = Some(schur);
        panels[s] = panel;
    }
    Ok(assembly)
}

/// Run the numeric multifrontal factorization of the permuted matrix
/// `ap` (must be `at.symbolic.perm`-permuted) with `backend`, through a
/// caller-provided [`FrontArena`] (the arena's peak accounting then
/// covers the whole traversal).
pub fn factorize_with_arena(
    at: &AssemblyTree,
    ap: &CscMatrix,
    backend: &dyn FrontBackend,
    arena: &mut FrontArena,
) -> Result<Factorization> {
    let ns = at.symbolic.supernodes.len();
    let mut panels: Vec<Vec<f64>> = vec![Vec::new(); ns];
    let mut contrib: Vec<Option<Vec<f64>>> = vec![None; ns];
    for &v in &at.tree.topo_up() {
        if let Err(e) =
            factor_front_arena(at, ap, v as usize, backend, arena, &mut contrib, &mut panels)
        {
            // return the pending contribution slabs so the caller's
            // arena accounting drops back to zero after a failed run
            for block in contrib.iter_mut().filter_map(Option::take) {
                arena.release_block(block);
            }
            return Err(e);
        }
    }
    Ok(Factorization { panels, n: ap.n })
}

/// Run the numeric multifrontal factorization of the permuted matrix
/// `ap` (must be `at.symbolic.perm`-permuted) with `backend`.
pub fn factorize(
    at: &AssemblyTree,
    ap: &CscMatrix,
    backend: &dyn FrontBackend,
) -> Result<Factorization> {
    let mut arena = FrontArena::for_tree(at);
    factorize_with_arena(at, ap, backend, &mut arena)
}

/// Relative factorization residual `‖P A Pᵀ − L Lᵀ‖_F / ‖A‖_F`
/// via dense reconstruction (use on small/medium problems).
pub fn residual(at: &AssemblyTree, ap: &CscMatrix, f: &Factorization) -> f64 {
    let n = ap.n;
    let l = f.to_dense(at);
    let llt = dense::matmul_nt(&l, &l, n, n, n);
    let a = ap.to_dense();
    let mut num = 0.0;
    for i in 0..n * n {
        let d = a[i] - llt[i];
        num += d * d;
    }
    num.sqrt() / dense::fro_norm(&a).max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::backend::RustBackend;
    use crate::sparse::{gen, order, symbolic};

    fn setup(k: usize, amalg: usize) -> (AssemblyTree, CscMatrix) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, amalg).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        (at, ap)
    }

    #[test]
    fn grid_residual_is_tiny() {
        let (at, ap) = setup(8, 0);
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn amalgamated_residual_is_tiny() {
        let (at, ap) = setup(10, 4);
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn solve_recovers_solution() {
        let (at, ap) = setup(6, 0);
        let n = ap.n;
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.37).cos()).collect();
        let b = ap.matvec(&x_true);
        let x = f.solve_dense(&at, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9, "max err {err}");
    }

    #[test]
    fn random_spd_factorizes() {
        let mut rng = crate::util::rng::Rng::new(77);
        let a = gen::random_spd(60, 4, &mut rng);
        let perm = order::reverse_cuthill_mckee(&a);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn grid_3d_factorizes() {
        let a = gen::grid_laplacian_3d(4);
        let perm = order::nested_dissection_3d(4);
        let at = symbolic::analyze(&a, &perm, 0).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let r = residual(&at, &ap, &f);
        assert!(r < 1e-12, "residual {r}");
    }

    #[test]
    fn indexed_assembly_matches_hashmap_reference() {
        // the arena/relative-index assembly must produce bit-identical
        // fronts to the HashMap reference, on grids (fundamental and
        // amalgamated) and random SPD matrices
        let mut cases: Vec<(AssemblyTree, CscMatrix)> = vec![setup(9, 0), setup(10, 4)];
        let mut rng = crate::util::rng::Rng::new(99);
        for seed in 0..4usize {
            let a = gen::random_spd(50 + seed * 13, 4, &mut rng);
            let perm = order::reverse_cuthill_mckee(&a);
            let at = symbolic::analyze(&a, &perm, seed).unwrap();
            let ap = a.permute_sym(&at.symbolic.perm).unwrap();
            cases.push((at, ap));
        }
        for (case, (at, ap)) in cases.iter().enumerate() {
            let ns = at.symbolic.supernodes.len();
            let mut contrib_ref: HashMap<usize, Vec<f64>> = HashMap::new();
            let mut contrib_new: Vec<Option<Vec<f64>>> = vec![None; ns];
            let mut arena = FrontArena::for_tree(at);
            for &v in &at.tree.topo_up() {
                let s = v as usize;
                let sn = &at.symbolic.supernodes[s];
                let nf = sn.front_order();
                let width = sn.width;
                let f_ref = assemble_front(at, ap, s, &mut contrib_ref);
                assemble_front_arena(at, ap, s, &mut arena, |c| contrib_new[c].take());
                for (i, (&x, &y)) in f_ref.iter().zip(arena.front()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "case {case} snode {s} entry {i}: {x} vs {y}"
                    );
                }
                // advance both paths with the same naive kernels so the
                // next fronts see identical inputs
                if width < nf {
                    let (_, _, schur) = dense::partial_factor(&f_ref, nf, width).unwrap();
                    contrib_ref.insert(s, schur.clone());
                    contrib_new[s] = Some(schur);
                }
                arena.end_front(nf);
            }
        }
    }

    #[test]
    fn serial_arena_peak_matches_symbolic_prediction() {
        use crate::frontal::arena::symbolic_peak_f64s;
        for (at, ap) in [setup(8, 0), setup(10, 4)] {
            let mut arena = FrontArena::for_tree(&at);
            let f = factorize_with_arena(&at, &ap, &RustBackend::default(), &mut arena).unwrap();
            assert!(residual(&at, &ap, &f) < 1e-12);
            assert_eq!(arena.peak_f64s(), symbolic_peak_f64s(&at));
            assert_eq!(arena.live_f64s(), 0, "arena leaked live words");
        }
    }

    #[test]
    fn failed_factorization_leaves_arena_clean() {
        use crate::frontal::backend::{FrontFactor, NaiveBackend};
        use std::sync::atomic::{AtomicUsize, Ordering};

        /// Succeeds for the first few fronts (so contribution slabs
        /// accumulate), then fails mid-traversal.
        struct FailAfter(AtomicUsize);
        impl FrontBackend for FailAfter {
            fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
                if self.0.fetch_add(1, Ordering::Relaxed) >= 5 {
                    anyhow::bail!("injected mid-traversal failure");
                }
                NaiveBackend.partial(front, n, k)
            }
            fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
                NaiveBackend.full(front, n)
            }
            fn name(&self) -> &'static str {
                "fail-after"
            }
        }

        let (at, ap) = setup(8, 0);
        let mut arena = FrontArena::for_tree(&at);
        let err = factorize_with_arena(&at, &ap, &FailAfter(AtomicUsize::new(0)), &mut arena)
            .expect_err("backend stops after 5 fronts");
        assert!(format!("{err:#}").contains("injected mid-traversal failure"));
        assert_eq!(arena.live_f64s(), 0, "failed run left live words in the arena");
        // the same arena stays usable for a subsequent successful run
        let f = factorize_with_arena(&at, &ap, &RustBackend::default(), &mut arena).unwrap();
        assert!(residual(&at, &ap, &f) < 1e-12);
        assert_eq!(arena.live_f64s(), 0);
    }

    #[test]
    fn contribution_blocks_are_all_consumed() {
        let (at, ap) = setup(7, 0);
        let mut contrib = HashMap::new();
        for &v in &at.tree.topo_up() {
            let s = v as usize;
            let sn = &at.symbolic.supernodes[s];
            let front = assemble_front(&at, &ap, s, &mut contrib);
            let nf = sn.front_order();
            if sn.width < nf {
                let f = RustBackend::default().partial(&front, nf, sn.width).unwrap();
                contrib.insert(s, f.schur);
            }
        }
        // only the root (width == front) may be absent; all children consumed
        assert!(contrib.len() <= 1);
    }
}
