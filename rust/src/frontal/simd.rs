//! SIMD microkernels for the blocked front kernels (DESIGN.md §16).
//!
//! Every hot inner loop of the tile primitives in `frontal::dense` is
//! one of two shapes: a **pure dot** (`s = Σ x[t]·y[t]`, subtracted
//! from the output once) or a **fold-sub** (`s -= x[t]·y[t]` folded
//! into a live accumulator). Both are exposed here on [`Isa`], which is
//! resolved **once** at backend construction (runtime feature
//! detection, never per-tile):
//!
//! * `Isa::Scalar` keeps the exact sequential loops the kernels have
//!   always run — bit-for-bit, so every bit-identity guarantee
//!   (serial == team, oracle comparisons) is preserved when SIMD is
//!   off.
//! * `Isa::Avx2` runs f64x4 lanes (`_mm256_fmadd_pd`, two independent
//!   accumulators to cover the FMA latency chain).
//! * `Isa::Avx512` runs f64x8 lanes (`_mm512_fmadd_pd`).
//!
//! SIMD reassociates the reduction (lane-parallel partial sums), so
//! with `simd != off` correctness gating switches from bit-identity to
//! a normwise epsilon against the naive oracle — see the dual-gating
//! tests in `frontal::dense`. Serial-vs-team bit-identity still holds
//! *within* a fixed [`KernelCfg`], because tile ownership (not
//! reduction order) is what the team partitions.

use anyhow::{bail, Result};

use super::dense::BLOCK;

/// SIMD dispatch policy, set per backend (CLI: `--simd auto|off|force`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Scalar loops only: all bit-identity guarantees hold.
    Off,
    /// Use the best ISA the CPU reports; fall back to scalar.
    #[default]
    Auto,
    /// Require a SIMD ISA; resolving fails on plain-scalar hardware.
    Force,
}

impl SimdMode {
    /// Parse a CLI/env spelling (`auto`, `off`, `force`).
    pub fn parse(s: &str) -> Result<SimdMode> {
        match s {
            "off" => Ok(SimdMode::Off),
            "auto" => Ok(SimdMode::Auto),
            "force" => Ok(SimdMode::Force),
            other => bail!("bad simd mode {other:?} (want auto|off|force)"),
        }
    }

    /// Canonical spelling (inverse of [`SimdMode::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Off => "off",
            SimdMode::Auto => "auto",
            SimdMode::Force => "force",
        }
    }
}

/// Instruction set the microkernels dispatch to. Resolved once (at
/// backend construction) and threaded through the tile primitives —
/// the per-tile code never re-detects features.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Isa {
    /// Portable sequential loops (the PR 2/3 kernels, bit-for-bit).
    #[default]
    Scalar,
    /// AVX2 + FMA, f64x4.
    Avx2,
    /// AVX-512F, f64x8.
    Avx512,
}

impl Isa {
    /// Resolve a policy against the running CPU.
    pub fn detect(mode: SimdMode) -> Isa {
        match mode {
            SimdMode::Off => Isa::Scalar,
            SimdMode::Auto | SimdMode::Force => best_available(),
        }
    }

    /// Human-readable name for occupancy printouts and bench rows.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2+fma f64x4",
            Isa::Avx512 => "avx512f f64x8",
        }
    }

    /// Short machine-readable tag (bench JSON, backend names).
    pub fn tag(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    /// True for any non-scalar dispatch.
    pub fn is_simd(self) -> bool {
        !matches!(self, Isa::Scalar)
    }

    /// `Σ x[t]·y[t]` over `min(x.len(), y.len())` terms.
    ///
    /// The scalar branch is the exact sequential `+=` loop of the
    /// original kernels (ascending `t`, one accumulator), so pure-dot
    /// call sites are bitwise unchanged under `Isa::Scalar`.
    #[inline]
    pub fn dot(self, x: &[f64], y: &[f64]) -> f64 {
        match self {
            Isa::Scalar => {
                let mut s = 0.0;
                for (&a, &b) in x.iter().zip(y.iter()) {
                    s += a * b;
                }
                s
            }
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the variant is only constructed after the
            // matching runtime feature check in `best_available`.
            Isa::Avx2 => unsafe { x86::dot_avx2(x, y) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: as above.
            Isa::Avx512 => unsafe { x86::dot_avx512(x, y) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => unreachable!("non-scalar Isa on a non-x86_64 build"),
        }
    }

    /// `init - Σ x[t]·y[t]`.
    ///
    /// The scalar branch keeps the original *sequential subtract*
    /// (`s -= x[t]·y[t]` per term) — which is **not** the same bit
    /// pattern as `init - dot(x, y)` — so fold-sub call sites
    /// (`factor_diag`, the trsm solves) are also bitwise unchanged
    /// under `Isa::Scalar`.
    #[inline]
    pub fn fold_sub(self, init: f64, x: &[f64], y: &[f64]) -> f64 {
        match self {
            Isa::Scalar => {
                let mut s = init;
                for (&a, &b) in x.iter().zip(y.iter()) {
                    s -= a * b;
                }
                s
            }
            _ => init - self.dot(x, y),
        }
    }
}

/// Best ISA the running CPU supports (x86_64 only; everything else is
/// scalar). Called once per backend construction, not per tile.
#[cfg(target_arch = "x86_64")]
fn best_available() -> Isa {
    if is_x86_feature_detected!("avx512f") {
        Isa::Avx512
    } else if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn best_available() -> Isa {
    Isa::Scalar
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// f64x4 dot product: two independent FMA accumulators (the FMA
    /// latency chain is ~4 cycles, throughput 2/cycle — one chain
    /// would leave half the units idle), scalar tail.
    ///
    /// # Safety
    /// Caller must have verified `avx2` + `fma` at runtime.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_avx2(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len().min(y.len());
        let px = x.as_ptr();
        let py = y.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(px.add(i)), _mm256_loadu_pd(py.add(i)), acc0);
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(px.add(i + 4)),
                _mm256_loadu_pd(py.add(i + 4)),
                acc1,
            );
            i += 8;
        }
        if i + 4 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(px.add(i)), _mm256_loadu_pd(py.add(i)), acc0);
            i += 4;
        }
        let acc = _mm256_add_pd(acc0, acc1);
        let lo = _mm256_castpd256_pd128(acc);
        let hi = _mm256_extractf128_pd::<1>(acc);
        let pair = _mm_add_pd(lo, hi);
        let one = _mm_add_sd(pair, _mm_unpackhi_pd(pair, pair));
        let mut s = _mm_cvtsd_f64(one);
        while i < n {
            s += *px.add(i) * *py.add(i);
            i += 1;
        }
        s
    }

    /// f64x8 dot product, same accumulator scheme as [`dot_avx2`].
    ///
    /// # Safety
    /// Caller must have verified `avx512f` at runtime.
    #[target_feature(enable = "avx512f")]
    pub unsafe fn dot_avx512(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len().min(y.len());
        let px = x.as_ptr();
        let py = y.as_ptr();
        let mut acc0 = _mm512_setzero_pd();
        let mut acc1 = _mm512_setzero_pd();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm512_fmadd_pd(_mm512_loadu_pd(px.add(i)), _mm512_loadu_pd(py.add(i)), acc0);
            acc1 = _mm512_fmadd_pd(
                _mm512_loadu_pd(px.add(i + 8)),
                _mm512_loadu_pd(py.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm512_fmadd_pd(_mm512_loadu_pd(px.add(i)), _mm512_loadu_pd(py.add(i)), acc0);
            i += 8;
        }
        let mut s = _mm512_reduce_add_pd(_mm512_add_pd(acc0, acc1));
        while i < n {
            s += *px.add(i) * *py.add(i);
            i += 1;
        }
        s
    }
}

/// Kernel configuration as the user states it (CLI `--block`/`--simd`);
/// [`FrontConfig::resolve`] turns it into a dispatched [`KernelCfg`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrontConfig {
    /// Tile edge for the blocked kernels.
    pub block: usize,
    /// SIMD policy.
    pub simd: SimdMode,
}

impl Default for FrontConfig {
    fn default() -> FrontConfig {
        FrontConfig { block: BLOCK, simd: SimdMode::Auto }
    }
}

impl FrontConfig {
    /// The historical configuration: `BLOCK`-edge tiles, scalar loops.
    pub fn scalar() -> FrontConfig {
        FrontConfig { block: BLOCK, simd: SimdMode::Off }
    }

    /// Resolve the policy against the running CPU. Fails on an
    /// out-of-range block or on `force` without SIMD hardware.
    pub fn resolve(self) -> Result<KernelCfg> {
        if !(8..=1024).contains(&self.block) {
            bail!("front block size {} out of range (want 8..=1024)", self.block);
        }
        let isa = Isa::detect(self.simd);
        if self.simd == SimdMode::Force && !isa.is_simd() {
            bail!("simd=force but no SIMD ISA is available on this CPU");
        }
        Ok(KernelCfg { block: self.block, isa })
    }
}

/// Resolved kernel configuration: what the tile primitives actually
/// run. One value per backend, shared verbatim between the serial path
/// and every [`super::FrontTeamJob`] it plans — serial == team
/// bit-identity is *per configuration*.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCfg {
    /// Tile edge.
    pub block: usize,
    /// Dispatched instruction set.
    pub isa: Isa,
}

impl Default for KernelCfg {
    fn default() -> KernelCfg {
        KernelCfg { block: BLOCK, isa: Isa::Scalar }
    }
}

impl KernelCfg {
    /// Resolve the `MALLTREE_SIMD` env override (used by the CI test
    /// matrix to run the whole suite under both gates). Unset or
    /// unparsable values mean scalar — the historical default — so
    /// plain `cargo test` keeps its bit-identity semantics.
    pub fn from_env() -> KernelCfg {
        let mode = std::env::var("MALLTREE_SIMD")
            .ok()
            .and_then(|v| SimdMode::parse(&v).ok())
            .unwrap_or(SimdMode::Off);
        // env force is best-effort (CI images vary); the CLI's `--simd
        // force` goes through FrontConfig::resolve and stays strict
        KernelCfg { block: BLOCK, isa: Isa::detect(mode) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize, phase: f64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64) * 0.37 + phase).sin()).collect()
    }

    #[test]
    fn scalar_dot_matches_sequential_loop_bitwise() {
        let x = ramp(131, 0.1);
        let y = ramp(131, 2.3);
        let mut want = 0.0;
        for t in 0..x.len() {
            want += x[t] * y[t];
        }
        assert_eq!(Isa::Scalar.dot(&x, &y).to_bits(), want.to_bits());
    }

    #[test]
    fn scalar_fold_sub_matches_sequential_loop_bitwise() {
        let x = ramp(77, 0.4);
        let y = ramp(77, 1.9);
        let mut want = 42.5;
        for t in 0..x.len() {
            want -= x[t] * y[t];
        }
        assert_eq!(Isa::Scalar.fold_sub(42.5, &x, &y).to_bits(), want.to_bits());
    }

    #[test]
    fn detected_isa_dot_matches_scalar_normwise() {
        let isa = Isa::detect(SimdMode::Auto);
        // covers every tail length around the 4/8/16 lane boundaries
        for n in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 64, 100, 257] {
            let x = ramp(n, 0.2);
            let y = ramp(n, 4.1);
            let simd = isa.dot(&x, &y);
            let scalar = Isa::Scalar.dot(&x, &y);
            let scale = scalar.abs().max(1.0);
            assert!(
                (simd - scalar).abs() / scale < 1e-13 * (n.max(1) as f64),
                "isa={isa:?} n={n}: {simd} vs {scalar}"
            );
        }
    }

    #[test]
    fn simd_mode_parse_round_trips_and_rejects() {
        for m in [SimdMode::Off, SimdMode::Auto, SimdMode::Force] {
            assert_eq!(SimdMode::parse(m.name()).unwrap(), m);
        }
        assert!(SimdMode::parse("fast").is_err());
        assert!(SimdMode::parse("").is_err());
    }

    #[test]
    fn front_config_resolves_and_validates() {
        assert_eq!(
            FrontConfig::scalar().resolve().unwrap(),
            KernelCfg { block: BLOCK, isa: Isa::Scalar }
        );
        assert!(FrontConfig { block: 4, simd: SimdMode::Off }.resolve().is_err());
        assert!(FrontConfig { block: 2048, simd: SimdMode::Off }.resolve().is_err());
        // auto never fails, whatever the hardware
        FrontConfig { block: 32, simd: SimdMode::Auto }.resolve().unwrap();
    }

    #[test]
    fn off_mode_always_resolves_scalar() {
        assert_eq!(Isa::detect(SimdMode::Off), Isa::Scalar);
    }
}
