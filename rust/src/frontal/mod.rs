//! Dense frontal-matrix math and the numeric multifrontal driver.
//!
//! * [`dense`] — pure-Rust dense Cholesky building blocks: cache-blocked
//!   tiled production kernels plus the unblocked reference versions
//!   (the property-test oracle, and what the PJRT path is validated
//!   against), and the team-parallel tile-cursor protocol
//!   ([`dense::FrontTeamJob`], DESIGN.md §10) that lets a worker team
//!   share one front's tiles bit-identically to the serial path;
//! * [`arena`] — the front arena: reused front buffer, recycled
//!   contribution-block slabs, global-row scatter map, and live/peak
//!   memory accounting (DESIGN.md §9);
//! * [`simd`] — the SIMD microkernel layer (DESIGN.md §16): runtime
//!   ISA dispatch (`Isa`: scalar / AVX2 f64x4 / AVX-512 f64x8), the
//!   `dot`/`fold_sub` primitives every blocked inner loop routes
//!   through, and the `FrontConfig { block, simd }` → `KernelCfg`
//!   resolution backends perform once at construction;
//! * [`backend`] — the `FrontBackend` abstraction: `RustBackend`
//!   (blocked in-process f64 under a resolved `KernelCfg`),
//!   `NaiveBackend` (unblocked oracle) and `PjrtBackend` (AOT HLO
//!   artifacts via [`crate::runtime`], the TPU-shaped path);
//! * [`multifrontal`] — the numeric factorization: assemble fronts in
//!   assembly-tree postorder, extend-add children contributions via
//!   precomputed relative indices, partial-factor each front, and emit
//!   the sparse factor.

pub mod arena;
pub mod backend;
pub mod dense;
pub mod multifrontal;
pub mod simd;
pub mod solve;

pub use arena::{FrontArena, MemGauge};
pub use backend::{FrontBackend, NaiveBackend, PjrtBackend, RustBackend};
pub use dense::FrontTeamJob;
pub use simd::{FrontConfig, Isa, KernelCfg, SimdMode};
pub use multifrontal::{factorize, factorize_with_arena, Factorization};
pub use solve::{backward_solve_sn, forward_solve_sn, solve_sn};
