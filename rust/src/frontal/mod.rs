//! Dense frontal-matrix math and the numeric multifrontal driver.
//!
//! * [`dense`] — pure-Rust dense Cholesky building blocks (the fallback
//!   backend, and the oracle the PJRT path is validated against);
//! * [`backend`] — the `FrontBackend` abstraction: `RustBackend`
//!   (in-process f64) vs `PjrtBackend` (AOT HLO artifacts via
//!   [`crate::runtime`], the TPU-shaped path);
//! * [`multifrontal`] — the numeric factorization: assemble fronts in
//!   assembly-tree postorder, extend-add children contributions,
//!   partial-factor each front, and emit the sparse factor.

pub mod backend;
pub mod dense;
pub mod multifrontal;
pub mod solve;

pub use backend::{FrontBackend, PjrtBackend, RustBackend};
pub use multifrontal::{factorize, Factorization};
pub use solve::{backward_solve_sn, forward_solve_sn, solve_sn};
