//! Front-factorization backends.
//!
//! The multifrontal driver and the executor are generic over
//! [`FrontBackend`]: `RustBackend` computes in-process (f64, exact
//! oracle), `PjrtBackend` routes through the AOT HLO artifacts (f32,
//! the TPU-shaped request path). Tests compare the two on identical
//! fronts.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{FrontKernels, Runtime};

use super::dense;

/// Output of a partial front factorization in f64 row-major buffers.
#[derive(Debug, Clone)]
pub struct FrontFactor {
    pub l11: Vec<f64>,
    pub l21: Vec<f64>,
    pub schur: Vec<f64>,
    pub n: usize,
    pub k: usize,
}

/// A backend that can factorize dense fronts.
///
/// Deliberately *not* `Send + Sync`: the `xla` crate's PJRT client is
/// single-threaded (`Rc` internals), so the PJRT backend behaves like
/// one accelerator command queue. Parallel execution with a thread
/// crew is available for backends that additionally implement
/// `Send + Sync` (e.g. [`RustBackend`]) via `exec::execute_parallel`.
pub trait FrontBackend {
    /// Eliminate the leading `k < n` columns.
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor>;

    /// Full factorization (`k == n`); returns the lower factor.
    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>>;

    /// Human-readable name for logs and reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct RustBackend;

impl FrontBackend for RustBackend {
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
        let (l11, l21, schur) = dense::partial_factor(front, n, k)?;
        Ok(FrontFactor { l11, l21, schur, n, k })
    }

    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
        dense::full_factor(front, n)
    }

    fn name(&self) -> &'static str {
        "rust-f64"
    }
}

/// PJRT backend: pads fronts into the AOT artifact menu and executes
/// the XLA-compiled Pallas kernels.
pub struct PjrtBackend {
    kernels: FrontKernels,
}

impl PjrtBackend {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtBackend { kernels: FrontKernels::new(rt) }
    }

    /// Largest front the artifact menu accepts.
    pub fn max_front(&self) -> usize {
        self.kernels.max_front()
    }
}

impl FrontBackend for PjrtBackend {
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
        let f32buf: Vec<f32> = front.iter().map(|&x| x as f32).collect();
        let r = self.kernels.partial_factor(&f32buf, n, k)?;
        Ok(FrontFactor {
            l11: r.l11.iter().map(|&x| x as f64).collect(),
            l21: r.l21.iter().map(|&x| x as f64).collect(),
            schur: r.schur.iter().map(|&x| x as f64).collect(),
            n,
            k,
        })
    }

    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
        let f32buf: Vec<f32> = front.iter().map(|&x| x as f32).collect();
        let l = self.kernels.full_factor(&f32buf, n)?;
        Ok(l.iter().map(|&x| x as f64).collect())
    }

    fn name(&self) -> &'static str {
        "pjrt-xla-f32"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_backend_partial_matches_dense() {
        let n = 12;
        let k = 5;
        // diagonally dominant SPD
        let mut a = vec![0.1f64; n * n];
        for i in 0..n {
            a[i * n + i] = n as f64;
        }
        let b = RustBackend;
        let f = b.partial(&a, n, k).unwrap();
        let (l11, l21, schur) = dense::partial_factor(&a, n, k).unwrap();
        assert_eq!(f.l11, l11);
        assert_eq!(f.l21, l21);
        assert_eq!(f.schur, schur);
        assert_eq!(b.name(), "rust-f64");
    }

    #[test]
    fn rust_backend_full_matches_dense() {
        let n = 9;
        let mut a = vec![0.2f64; n * n];
        for i in 0..n {
            a[i * n + i] = 5.0;
        }
        let b = RustBackend;
        assert_eq!(b.full(&a, n).unwrap(), dense::full_factor(&a, n).unwrap());
    }
}
