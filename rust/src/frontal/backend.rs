//! Front-factorization backends.
//!
//! The multifrontal driver and the executor are generic over
//! [`FrontBackend`]: `RustBackend` computes in-process (f64, exact
//! oracle), `PjrtBackend` routes through the AOT HLO artifacts (f32,
//! the TPU-shaped request path). Tests compare the two on identical
//! fronts.

use std::sync::Arc;
use std::sync::OnceLock;

use anyhow::Result;

use crate::runtime::{FrontKernels, Runtime};

use super::dense;
use super::simd::{FrontConfig, Isa, KernelCfg};

/// Output of a partial front factorization in f64 row-major buffers.
#[derive(Debug, Clone)]
pub struct FrontFactor {
    pub l11: Vec<f64>,
    pub l21: Vec<f64>,
    pub schur: Vec<f64>,
    pub n: usize,
    pub k: usize,
}

/// A backend that can factorize dense fronts.
///
/// Deliberately *not* `Send + Sync`: the `xla` crate's PJRT client is
/// single-threaded (`Rc` internals), so the PJRT backend behaves like
/// one accelerator command queue. Parallel execution with a thread
/// crew is available for backends that additionally implement
/// `Send + Sync` (e.g. [`RustBackend`]) via `exec::execute_parallel`.
pub trait FrontBackend {
    /// Eliminate the leading `k < n` columns.
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor>;

    /// Full factorization (`k == n`); returns the lower factor.
    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>>;

    /// Partial factorization into caller-owned buffers: `panel` (`n x
    /// k` row-major, receives `[L11; L21]`) and `schur` (`(n-k)²`).
    /// The default routes through [`FrontBackend::partial`] and copies;
    /// allocation-free backends override it. This is the call the
    /// multifrontal drivers make on their hot path — `panel` is the
    /// retained factor storage, `schur` an arena slab.
    fn partial_into(
        &self,
        front: &[f64],
        n: usize,
        k: usize,
        panel: &mut [f64],
        schur: &mut [f64],
    ) -> Result<()> {
        anyhow::ensure!(
            k <= n && panel.len() == n * k && schur.len() == (n - k) * (n - k),
            "partial_into: output buffer mismatch (n={n}, k={k})"
        );
        let f = self.partial(front, n, k)?;
        panel[..k * k].copy_from_slice(&f.l11);
        panel[k * k..].copy_from_slice(&f.l21);
        schur.copy_from_slice(&f.schur);
        Ok(())
    }

    /// True when [`FrontBackend::factor_front_team`] actually exploits
    /// a worker team. The executor only recruits helpers (publishes
    /// team seats) for such backends; everyone else runs the serial
    /// default below untouched.
    fn team_capable(&self) -> bool {
        false
    }

    /// Factor one front through a [`dense::FrontTeamJob`] — the
    /// malleable executor's per-front entry point. The job carries the
    /// output buffers (panel, Schur slab) and, for team-capable
    /// backends, the tile-cursor protocol helpers cooperate through.
    ///
    /// Serial default: run [`FrontBackend::partial_into`] /
    /// [`FrontBackend::full`] into the job's buffers and close it. The
    /// executor guarantees no helper ever joins a job of a backend
    /// whose `team_capable()` is false.
    fn factor_front_team(&self, front: &[f64], job: &dense::FrontTeamJob) -> Result<()> {
        job.run_serial(|n, k, panel, schur| {
            if k == n {
                let l = self.full(front, n)?;
                panel.copy_from_slice(&l);
                Ok(())
            } else {
                self.partial_into(front, n, k, panel, schur)
            }
        })
    }

    /// Tile geometry + SIMD dispatch this backend's kernels run under.
    /// The executor plans every [`dense::FrontTeamJob`] with this value
    /// (tile-cursor geometry follows the configured block), so the team
    /// path and the backend's serial path share one configuration —
    /// serial == team bit-identity is per configuration. Backends
    /// without tunable kernels report the scalar default.
    fn kernel_cfg(&self) -> KernelCfg {
        KernelCfg::default()
    }

    /// Human-readable name for logs and reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust production backend: cache-blocked tiled kernels
/// (`dense::potrf_blocked_cfg` and friends) under a [`KernelCfg`]
/// resolved **once** at construction (tile edge + runtime-dispatched
/// SIMD ISA — DESIGN.md §16), allocation-free through
/// [`FrontBackend::partial_into`] up to the O(block·k) packing scratch.
#[derive(Debug, Clone, Copy)]
pub struct RustBackend {
    cfg: KernelCfg,
}

impl Default for RustBackend {
    /// Tile edge [`dense::BLOCK`] under the `MALLTREE_SIMD` env policy
    /// (scalar when unset or unparsable — the historical default, so
    /// plain `cargo test` keeps its bit-identity semantics; the CI
    /// test matrix sets `MALLTREE_SIMD=force` to run the whole suite
    /// under the SIMD gate). Resolved once per process.
    fn default() -> RustBackend {
        static CFG: OnceLock<KernelCfg> = OnceLock::new();
        RustBackend { cfg: *CFG.get_or_init(KernelCfg::from_env) }
    }
}

impl RustBackend {
    /// Backend under an explicit, validated configuration — the CLI's
    /// `--block`/`--simd` path. Fails on an out-of-range block or on
    /// `simd=force` without SIMD hardware.
    pub fn with_config(cfg: FrontConfig) -> Result<RustBackend> {
        Ok(RustBackend { cfg: cfg.resolve()? })
    }

    /// The resolved kernel configuration.
    pub fn cfg(&self) -> KernelCfg {
        self.cfg
    }

    /// The dispatched instruction set (occupancy printouts, bench rows).
    pub fn isa(&self) -> Isa {
        self.cfg.isa
    }
}

impl FrontBackend for RustBackend {
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
        let m = n - k;
        let mut panel = vec![0f64; n * k];
        let mut schur = vec![0f64; m * m];
        dense::partial_factor_into_cfg(front, n, k, &mut panel, &mut schur, self.cfg)?;
        let l21 = panel.split_off(k * k);
        Ok(FrontFactor { l11: panel, l21, schur, n, k })
    }

    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
        dense::full_factor_blocked_cfg(front, n, self.cfg)
    }

    fn partial_into(
        &self,
        front: &[f64],
        n: usize,
        k: usize,
        panel: &mut [f64],
        schur: &mut [f64],
    ) -> Result<()> {
        dense::partial_factor_into_cfg(front, n, k, panel, schur, self.cfg)
    }

    fn team_capable(&self) -> bool {
        true
    }

    fn factor_front_team(&self, front: &[f64], job: &dense::FrontTeamJob) -> Result<()> {
        // the job *is* the blocked tiled algorithm, driven by this
        // thread as team leader; helpers share the tile cursor
        job.run_leader(front)
    }

    fn kernel_cfg(&self) -> KernelCfg {
        self.cfg
    }

    fn name(&self) -> &'static str {
        match self.cfg.isa {
            Isa::Scalar => "rust-f64",
            Isa::Avx2 => "rust-f64-avx2",
            Isa::Avx512 => "rust-f64-avx512",
        }
    }
}

/// Unblocked pure-Rust reference backend: the original kernels, kept
/// as the property-test oracle and reachable from the CLI
/// (`--backend naive`).
#[derive(Debug, Default, Clone, Copy)]
pub struct NaiveBackend;

impl FrontBackend for NaiveBackend {
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
        let (l11, l21, schur) = dense::partial_factor(front, n, k)?;
        Ok(FrontFactor { l11, l21, schur, n, k })
    }

    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
        dense::full_factor(front, n)
    }

    fn name(&self) -> &'static str {
        "rust-naive"
    }
}

/// PJRT backend: pads fronts into the AOT artifact menu and executes
/// the XLA-compiled Pallas kernels.
pub struct PjrtBackend {
    kernels: FrontKernels,
}

impl PjrtBackend {
    pub fn new(rt: Arc<Runtime>) -> Self {
        PjrtBackend { kernels: FrontKernels::new(rt) }
    }

    /// Largest front the artifact menu accepts.
    pub fn max_front(&self) -> usize {
        self.kernels.max_front()
    }
}

impl FrontBackend for PjrtBackend {
    fn partial(&self, front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
        let f32buf: Vec<f32> = front.iter().map(|&x| x as f32).collect();
        let r = self.kernels.partial_factor(&f32buf, n, k)?;
        Ok(FrontFactor {
            l11: r.l11.iter().map(|&x| x as f64).collect(),
            l21: r.l21.iter().map(|&x| x as f64).collect(),
            schur: r.schur.iter().map(|&x| x as f64).collect(),
            n,
            k,
        })
    }

    fn full(&self, front: &[f64], n: usize) -> Result<Vec<f64>> {
        let f32buf: Vec<f32> = front.iter().map(|&x| x as f32).collect();
        let l = self.kernels.full_factor(&f32buf, n)?;
        Ok(l.iter().map(|&x| x as f64).collect())
    }

    fn name(&self) -> &'static str {
        "pjrt-xla-f32"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag_dominant(n: usize) -> Vec<f64> {
        let mut a = vec![0.1f64; n * n];
        for i in 0..n {
            a[i * n + i] = n as f64;
        }
        a
    }

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    #[test]
    fn rust_backend_partial_matches_naive_oracle() {
        // the blocked production backend vs the unblocked oracle:
        // equal up to floating-point reassociation
        let n = 12;
        let k = 5;
        let a = diag_dominant(n);
        let b = RustBackend::default();
        let f = b.partial(&a, n, k).unwrap();
        let (l11, l21, schur) = dense::partial_factor(&a, n, k).unwrap();
        assert!(close(&f.l11, &l11, 1e-12));
        assert!(close(&f.l21, &l21, 1e-12));
        assert!(close(&f.schur, &schur, 1e-12));
        // the name carries the dispatched ISA tag (scalar by default,
        // avx2/avx512 under the MALLTREE_SIMD CI legs)
        assert!(b.name().starts_with("rust-f64"), "{}", b.name());
    }

    #[test]
    fn rust_backend_config_is_validated_once_at_construction() {
        use crate::frontal::simd::SimdMode;
        let b = RustBackend::with_config(FrontConfig { block: 32, simd: SimdMode::Off }).unwrap();
        assert_eq!(b.cfg(), KernelCfg { block: 32, isa: Isa::Scalar });
        assert_eq!(b.kernel_cfg(), b.cfg());
        assert!(!b.isa().is_simd());
        assert!(RustBackend::with_config(FrontConfig { block: 0, simd: SimdMode::Off }).is_err());
        // non-tunable backends report the scalar default geometry
        assert_eq!(NaiveBackend.kernel_cfg(), KernelCfg::default());
    }

    #[test]
    fn naive_backend_is_bitwise_the_reference_kernels() {
        let n = 12;
        let k = 5;
        let a = diag_dominant(n);
        let b = NaiveBackend;
        let f = b.partial(&a, n, k).unwrap();
        let (l11, l21, schur) = dense::partial_factor(&a, n, k).unwrap();
        assert_eq!(f.l11, l11);
        assert_eq!(f.l21, l21);
        assert_eq!(f.schur, schur);
        assert_eq!(b.full(&a, n).unwrap(), dense::full_factor(&a, n).unwrap());
        assert_eq!(b.name(), "rust-naive");
    }

    #[test]
    fn rust_backend_full_matches_naive_oracle() {
        let n = 9;
        let a = diag_dominant(n);
        let blocked = RustBackend::default().full(&a, n).unwrap();
        let naive = dense::full_factor(&a, n).unwrap();
        assert!(close(&blocked, &naive, 1e-12));
    }

    #[test]
    fn default_partial_into_stacks_the_panel() {
        // exercised through NaiveBackend, which does not override it
        let n = 10;
        let k = 4;
        let m = n - k;
        let a = diag_dominant(n);
        let mut panel = vec![0f64; n * k];
        let mut schur = vec![0f64; m * m];
        NaiveBackend.partial_into(&a, n, k, &mut panel, &mut schur).unwrap();
        let f = NaiveBackend.partial(&a, n, k).unwrap();
        assert_eq!(&panel[..k * k], &f.l11[..]);
        assert_eq!(&panel[k * k..], &f.l21[..]);
        assert_eq!(schur, f.schur);
        // buffer-size misuse is reported, not UB
        let mut bad = vec![0f64; 1];
        assert!(NaiveBackend.partial_into(&a, n, k, &mut bad, &mut schur).is_err());
    }
}
