//! Pure-Rust dense kernels (row-major, f64).
//!
//! These are the reference implementations for the PJRT path and the
//! numeric engine of the `RustBackend`. They mirror
//! `python/compile/kernels/ref.py` operation by operation.

use anyhow::{bail, Result};

/// In-place lower Cholesky of a symmetric positive-definite `n x n`
/// row-major matrix; the strict upper triangle is zeroed.
pub fn potrf(a: &mut [f64], n: usize) -> Result<()> {
    if a.len() != n * n {
        bail!("potrf: buffer mismatch");
    }
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("potrf: matrix not positive definite at pivot {j} (d={d})");
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in j + 1..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
    }
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Solve `X L^T = B` for X where `l` is `k x k` lower triangular and
/// `b` is `m x k` (the panel TRSM); result overwrites `b`.
pub fn trsm_rt(l: &[f64], k: usize, b: &mut [f64], m: usize) -> Result<()> {
    if l.len() != k * k || b.len() != m * k {
        bail!("trsm: buffer mismatch");
    }
    // row i of X: forward substitution against L
    for i in 0..m {
        for j in 0..k {
            let mut s = b[i * k + j];
            for t in 0..j {
                s -= b[i * k + t] * l[j * k + t];
            }
            b[i * k + j] = s / l[j * k + j];
        }
    }
    Ok(())
}

/// Schur update `C -= A A^T` where `a` is `m x k`, `c` is `m x m`.
pub fn syrk_sub(c: &mut [f64], a: &[f64], m: usize, k: usize) -> Result<()> {
    if c.len() != m * m || a.len() != m * k {
        bail!("syrk: buffer mismatch");
    }
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * a[j * k + t];
            }
            c[i * m + j] -= s;
        }
    }
    Ok(())
}

/// Partial factorization: eliminate the leading `k` columns of the
/// `n x n` front. Returns `(l11 [k x k], l21 [(n-k) x k], schur
/// [(n-k) x (n-k)])`.
pub fn partial_factor(front: &[f64], n: usize, k: usize) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
    if front.len() != n * n || k == 0 || k > n {
        bail!("partial_factor: bad arguments n={n} k={k}");
    }
    let m = n - k;
    let mut l11 = vec![0f64; k * k];
    for i in 0..k {
        l11[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
    }
    potrf(&mut l11, k)?;
    let mut l21 = vec![0f64; m * k];
    for i in 0..m {
        l21[i * k..(i + 1) * k].copy_from_slice(&front[(k + i) * n..(k + i) * n + k]);
    }
    trsm_rt(&l11, k, &mut l21, m)?;
    let mut schur = vec![0f64; m * m];
    for i in 0..m {
        for j in 0..m {
            schur[i * m + j] = front[(k + i) * n + (k + j)];
        }
    }
    syrk_sub(&mut schur, &l21, m, k)?;
    Ok((l11, l21, schur))
}

/// Full Cholesky of a front (returns lower factor).
pub fn full_factor(front: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = front.to_vec();
    potrf(&mut l, n)?;
    Ok(l)
}

// ---------------------------------------------------------------------
// Cache-blocked kernels (DESIGN.md §9). Right-looking tiled variants of
// the reference kernels above: the unblocked versions stay as the
// property-test oracle; these are the production path (`RustBackend`).
// Micro-kernel inner loops run over contiguous `t` ranges of both
// operands so the compiler can autovectorize the dot products.
// ---------------------------------------------------------------------

/// Tile edge for the blocked kernels (~64² f64 = 32 KiB per tile pair,
/// sized for L1/L2 residency).
pub const BLOCK: usize = 64;

/// In-place factorization of the `nb x nb` diagonal block at `(j0, j0)`
/// of a matrix with row stride `lda` (inner-product Cholesky; the block
/// is small enough that blocking buys nothing here).
fn factor_diag(a: &mut [f64], lda: usize, j0: usize, nb: usize) -> Result<()> {
    for j in 0..nb {
        let rj = (j0 + j) * lda + j0;
        let mut d = a[rj + j];
        for k in 0..j {
            d -= a[rj + k] * a[rj + k];
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("potrf: matrix not positive definite at pivot {} (d={d})", j0 + j);
        }
        let d = d.sqrt();
        a[rj + j] = d;
        for i in j + 1..nb {
            let ri = (j0 + i) * lda + j0;
            let mut s = a[ri + j];
            for k in 0..j {
                s -= a[ri + k] * a[rj + k];
            }
            a[ri + j] = s / d;
        }
    }
    Ok(())
}

/// Solve the panel rows `i0..i0+m` against the factored diagonal block
/// at `(j0, j0)` (width `nb`), in place, row stride `lda`.
fn trsm_tile(a: &mut [f64], lda: usize, j0: usize, nb: usize, i0: usize, m: usize) {
    for i in 0..m {
        let ri = (i0 + i) * lda + j0;
        for j in 0..nb {
            let rj = (j0 + j) * lda + j0;
            let mut s = a[ri + j];
            for t in 0..j {
                s -= a[ri + t] * a[rj + t];
            }
            a[ri + j] = s / a[rj + j];
        }
    }
}

/// Trailing update `A22 -= L21 L21ᵀ` for the panel of width `kb` at
/// column `j0`: tiled over the `m x m` trailing block starting at
/// `(i0, i0)`, lower block-triangle only (the upper triangle is never
/// read and is zeroed at the end of the factorization).
fn syrk_tile(a: &mut [f64], lda: usize, j0: usize, kb: usize, i0: usize, m: usize) {
    let mut bi = 0;
    while bi < m {
        let ib = BLOCK.min(m - bi);
        let mut bj = 0;
        while bj <= bi {
            let jb = BLOCK.min(m - bj);
            for i in 0..ib {
                let ri = (i0 + bi + i) * lda;
                let li = ri + j0;
                let ci = ri + i0 + bj;
                let jmax = if bj == bi { i + 1 } else { jb };
                for j in 0..jmax {
                    let lj = (i0 + bj + j) * lda + j0;
                    let mut s = 0.0;
                    for t in 0..kb {
                        s += a[li + t] * a[lj + t];
                    }
                    a[ci + j] -= s;
                }
            }
            bj += BLOCK;
        }
        bi += BLOCK;
    }
}

/// Cache-blocked in-place lower Cholesky (right-looking, tile edge
/// [`BLOCK`]); the strict upper triangle is zeroed. Agrees with
/// [`potrf`] up to floating-point reassociation.
pub fn potrf_blocked(a: &mut [f64], n: usize) -> Result<()> {
    if a.len() != n * n {
        bail!("potrf_blocked: buffer mismatch");
    }
    let mut j0 = 0;
    while j0 < n {
        let jb = BLOCK.min(n - j0);
        factor_diag(a, n, j0, jb)?;
        let i0 = j0 + jb;
        if i0 < n {
            trsm_tile(a, n, j0, jb, i0, n - i0);
            syrk_tile(a, n, j0, jb, i0, n - i0);
        }
        j0 = i0;
    }
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Cache-blocked `X Lᵀ = B` panel solve (same contract as [`trsm_rt`]):
/// each column panel folds in the already-solved columns with a dense
/// dot (the GEMM part), then solves against its diagonal block.
pub fn trsm_rt_blocked(l: &[f64], k: usize, b: &mut [f64], m: usize) -> Result<()> {
    if l.len() != k * k || b.len() != m * k {
        bail!("trsm_rt_blocked: buffer mismatch");
    }
    let mut j0 = 0;
    while j0 < k {
        let jb = BLOCK.min(k - j0);
        for i in 0..m {
            let bi = i * k;
            for j in 0..jb {
                let lj = (j0 + j) * k;
                let mut s = 0.0;
                for t in 0..j0 {
                    s += b[bi + t] * l[lj + t];
                }
                b[bi + j0 + j] -= s;
            }
            for j in 0..jb {
                let lj = (j0 + j) * k;
                let mut s = b[bi + j0 + j];
                for t in 0..j {
                    s -= b[bi + j0 + t] * l[lj + j0 + t];
                }
                b[bi + j0 + j] = s / l[lj + j0 + j];
            }
        }
        j0 += jb;
    }
    Ok(())
}

/// Cache-blocked Schur update `C -= A Aᵀ` (same contract as
/// [`syrk_sub`]): tiled over the inner dimension and the columns of C
/// so each `A` panel stays cache-resident across a column tile.
pub fn syrk_sub_blocked(c: &mut [f64], a: &[f64], m: usize, k: usize) -> Result<()> {
    if c.len() != m * m || a.len() != m * k {
        bail!("syrk_sub_blocked: buffer mismatch");
    }
    let mut t0 = 0;
    while t0 < k {
        let tb = BLOCK.min(k - t0);
        let mut j0 = 0;
        while j0 < m {
            let jb = BLOCK.min(m - j0);
            for i in 0..m {
                let ai = i * k + t0;
                let ci = i * m + j0;
                for j in 0..jb {
                    let aj = (j0 + j) * k + t0;
                    let mut s = 0.0;
                    for t in 0..tb {
                        s += a[ai + t] * a[aj + t];
                    }
                    c[ci + j] -= s;
                }
            }
            j0 += jb;
        }
        t0 += tb;
    }
    Ok(())
}

/// Blocked partial factorization writing straight into caller buffers:
/// `panel` receives `[L11; L21]` row-major (`n x k`), `schur` the
/// `(n-k) x (n-k)` Schur complement. Zero heap allocation — the hot
/// path of the multifrontal drivers (the arena owns `schur`, the
/// factorization output owns `panel`).
pub fn partial_factor_into(
    front: &[f64],
    n: usize,
    k: usize,
    panel: &mut [f64],
    schur: &mut [f64],
) -> Result<()> {
    if front.len() != n * n || k == 0 || k > n {
        bail!("partial_factor_into: bad arguments n={n} k={k}");
    }
    let m = n - k;
    if panel.len() != n * k || schur.len() != m * m {
        bail!("partial_factor_into: output buffer mismatch");
    }
    for i in 0..n {
        panel[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
    }
    {
        let (l11, l21) = panel.split_at_mut(k * k);
        potrf_blocked(l11, k)?;
        trsm_rt_blocked(l11, k, l21, m)?;
    }
    for i in 0..m {
        let src = (k + i) * n + k;
        schur[i * m..(i + 1) * m].copy_from_slice(&front[src..src + m]);
    }
    syrk_sub_blocked(schur, &panel[k * k..], m, k)?;
    Ok(())
}

/// Blocked full Cholesky of a front (returns lower factor).
pub fn full_factor_blocked(front: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = front.to_vec();
    potrf_blocked(&mut l, n)?;
    Ok(l)
}

/// `C = A B^T` helper for tests.
pub fn matmul_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * b[j * k + t];
            }
            c[i * n + j] = s;
        }
    }
    c
}

/// Frobenius norm.
pub fn fro_norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Forward solve `L y = b` (lower, row-major dense).
pub fn forward_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * y[j];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Backward solve `L^T x = y`.
pub fn backward_solve(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s / n as f64 + if i == j { 2.0 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let n = 24;
        let a = random_spd(n, 1);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let llt = matmul_nt(&l, &l, n, n, n);
        let diff: f64 = a.iter().zip(&llt).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(potrf(&mut a, 2).is_err());
    }

    #[test]
    fn potrf_identity() {
        let n = 5;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let want = a.clone();
        potrf(&mut a, n).unwrap();
        assert_eq!(a, want);
    }

    #[test]
    fn trsm_solves() {
        let k = 8;
        let m = 12;
        let a = random_spd(k, 2);
        let mut l = a.clone();
        potrf(&mut l, k).unwrap();
        let mut rng = Rng::new(3);
        let x_true: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        // B = X L^T
        let mut b = vec![0f64; m * k];
        for i in 0..m {
            for j in 0..k {
                let mut s = 0.0;
                for t in 0..=j {
                    s += x_true[i * k + t] * l[j * k + t];
                }
                b[i * k + j] = s;
            }
        }
        trsm_rt(&l, k, &mut b, m).unwrap();
        let diff: f64 = b.iter().zip(&x_true).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn partial_factor_composes_to_full() {
        let n = 20;
        let k = 8;
        let a = random_spd(n, 4);
        let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
        let l22 = full_factor(&schur, n - k).unwrap();
        // stitch L and compare against direct potrf
        let mut l = vec![0f64; n * n];
        for i in 0..k {
            for j in 0..=i {
                l[i * n + j] = l11[i * k + j];
            }
        }
        for i in 0..n - k {
            for j in 0..k {
                l[(k + i) * n + j] = l21[i * k + j];
            }
            for j in 0..=i {
                l[(k + i) * n + (k + j)] = l22[i * (n - k) + j];
            }
        }
        let mut direct = a.clone();
        potrf(&mut direct, n).unwrap();
        let diff: f64 = l.iter().zip(&direct).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9, "max diff {diff}");
    }

    #[test]
    fn solves_round_trip() {
        let n = 16;
        let a = random_spd(n, 5);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 2.0).collect();
        // b = A x
        let mut b = vec![0f64; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let y = forward_solve(&l, n, &b);
        let x = backward_solve(&l, n, &y);
        let diff: f64 = x.iter().zip(&x_true).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9);
    }

    #[test]
    fn fro_norm_basics() {
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(fro_norm(&[]), 0.0);
    }

    fn max_rel_diff(a: &[f64], b: &[f64]) -> f64 {
        let norm = fro_norm(a).max(1.0);
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
            / norm
    }

    #[test]
    fn blocked_potrf_matches_naive_oracle() {
        // sizes below, at, just above and at multiple tile edges
        for &n in &[1usize, 5, 63, 64, 65, 130] {
            let a = random_spd(n, 11 + n as u64);
            let mut naive = a.clone();
            potrf(&mut naive, n).unwrap();
            let mut blocked = a.clone();
            potrf_blocked(&mut blocked, n).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "n={n}: rel diff {d}");
        }
    }

    #[test]
    fn blocked_potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(potrf_blocked(&mut a, 2).is_err());
    }

    #[test]
    fn blocked_trsm_matches_naive_oracle() {
        for &(k, m) in &[(7usize, 13usize), (64, 40), (100, 70)] {
            let a = random_spd(k, 21 + k as u64);
            let mut l = a.clone();
            potrf(&mut l, k).unwrap();
            let mut rng = Rng::new(5);
            let b0: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let mut naive = b0.clone();
            trsm_rt(&l, k, &mut naive, m).unwrap();
            let mut blocked = b0.clone();
            trsm_rt_blocked(&l, k, &mut blocked, m).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "k={k} m={m}: rel diff {d}");
        }
    }

    #[test]
    fn blocked_syrk_matches_naive_oracle() {
        for &(m, k) in &[(9usize, 4usize), (70, 64), (65, 130)] {
            let mut rng = Rng::new(31);
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f64> = (0..m * m).map(|_| rng.normal()).collect();
            let mut naive = c0.clone();
            syrk_sub(&mut naive, &a, m, k).unwrap();
            let mut blocked = c0.clone();
            syrk_sub_blocked(&mut blocked, &a, m, k).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "m={m} k={k}: rel diff {d}");
        }
    }

    #[test]
    fn partial_factor_into_matches_naive_partial() {
        for &(n, k) in &[(20usize, 8usize), (130, 64), (96, 96)] {
            let a = random_spd(n, 40 + n as u64);
            let m = n - k;
            let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
            let mut panel = vec![0f64; n * k];
            let mut schur_b = vec![0f64; m * m];
            partial_factor_into(&a, n, k, &mut panel, &mut schur_b).unwrap();
            let d11 = max_rel_diff(&l11, &panel[..k * k]);
            let d21 = max_rel_diff(&l21, &panel[k * k..]);
            let ds = max_rel_diff(&schur, &schur_b);
            assert!(d11 < 1e-12 && d21 < 1e-12 && ds < 1e-11, "n={n} k={k}: {d11} {d21} {ds}");
        }
    }

    #[test]
    fn blocked_full_factor_reconstructs() {
        let n = 100;
        let a = random_spd(n, 77);
        let l = full_factor_blocked(&a, n).unwrap();
        let llt = matmul_nt(&l, &l, n, n, n);
        let d = max_rel_diff(&a, &llt);
        assert!(d < 1e-12, "rel diff {d}");
    }
}
