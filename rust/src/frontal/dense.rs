//! Pure-Rust dense kernels (row-major, f64).
//!
//! These are the reference implementations for the PJRT path and the
//! numeric engine of the `RustBackend`. They mirror
//! `python/compile/kernels/ref.py` operation by operation.

use anyhow::{bail, Result};

/// In-place lower Cholesky of a symmetric positive-definite `n x n`
/// row-major matrix; the strict upper triangle is zeroed.
pub fn potrf(a: &mut [f64], n: usize) -> Result<()> {
    if a.len() != n * n {
        bail!("potrf: buffer mismatch");
    }
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("potrf: matrix not positive definite at pivot {j} (d={d})");
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in j + 1..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
    }
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Solve `X L^T = B` for X where `l` is `k x k` lower triangular and
/// `b` is `m x k` (the panel TRSM); result overwrites `b`.
pub fn trsm_rt(l: &[f64], k: usize, b: &mut [f64], m: usize) -> Result<()> {
    if l.len() != k * k || b.len() != m * k {
        bail!("trsm: buffer mismatch");
    }
    // row i of X: forward substitution against L
    for i in 0..m {
        for j in 0..k {
            let mut s = b[i * k + j];
            for t in 0..j {
                s -= b[i * k + t] * l[j * k + t];
            }
            b[i * k + j] = s / l[j * k + j];
        }
    }
    Ok(())
}

/// Schur update `C -= A A^T` where `a` is `m x k`, `c` is `m x m`.
pub fn syrk_sub(c: &mut [f64], a: &[f64], m: usize, k: usize) -> Result<()> {
    if c.len() != m * m || a.len() != m * k {
        bail!("syrk: buffer mismatch");
    }
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * a[j * k + t];
            }
            c[i * m + j] -= s;
        }
    }
    Ok(())
}

/// Partial factorization: eliminate the leading `k` columns of the
/// `n x n` front. Returns `(l11 [k x k], l21 [(n-k) x k], schur
/// [(n-k) x (n-k)])`.
pub fn partial_factor(front: &[f64], n: usize, k: usize) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
    if front.len() != n * n || k == 0 || k > n {
        bail!("partial_factor: bad arguments n={n} k={k}");
    }
    let m = n - k;
    let mut l11 = vec![0f64; k * k];
    for i in 0..k {
        l11[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
    }
    potrf(&mut l11, k)?;
    let mut l21 = vec![0f64; m * k];
    for i in 0..m {
        l21[i * k..(i + 1) * k].copy_from_slice(&front[(k + i) * n..(k + i) * n + k]);
    }
    trsm_rt(&l11, k, &mut l21, m)?;
    let mut schur = vec![0f64; m * m];
    for i in 0..m {
        for j in 0..m {
            schur[i * m + j] = front[(k + i) * n + (k + j)];
        }
    }
    syrk_sub(&mut schur, &l21, m, k)?;
    Ok((l11, l21, schur))
}

/// Full Cholesky of a front (returns lower factor).
pub fn full_factor(front: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = front.to_vec();
    potrf(&mut l, n)?;
    Ok(l)
}

// ---------------------------------------------------------------------
// Cache-blocked kernels (DESIGN.md §9, §16). Right-looking tiled
// variants of the reference kernels above: the unblocked versions stay
// as the property-test oracle; these are the production path
// (`RustBackend`). Tile geometry and inner-loop dispatch come from a
// `KernelCfg` (tunable tile edge + runtime-resolved SIMD ISA): every
// hot inner loop is `Isa::dot` or `Isa::fold_sub`, whose scalar
// branches are the exact historical sequential loops — so
// `KernelCfg::default()` (BLOCK tiles, scalar) reproduces the PR 2/3
// kernels bit for bit, and all bit-identity guarantees are stated per
// configuration.
// ---------------------------------------------------------------------

use super::simd::{Isa, KernelCfg};

/// Default tile edge for the blocked kernels (~64² f64 = 32 KiB per
/// tile pair, sized for L1/L2 residency). Tunable per backend via
/// `FrontConfig { block, .. }`.
pub const BLOCK: usize = 64;

/// Packing scratch (f64 words) the blocked Cholesky of a `k x k` block
/// needs under tile edge `block`: one panel-major copy of the current
/// diagonal block or trailing panel (the two reuse the same buffer —
/// they are never live together). Zero when the block fits one tile.
pub fn pack_len(block: usize, k: usize) -> usize {
    if k > block {
        block * k
    } else {
        0
    }
}

/// Pack the factored `jb x jb` diagonal block at `(j0, j0)` (row stride
/// `lda`) into a contiguous `jb`-stride buffer so the trailing tile
/// solves stream it instead of striding `lda`. Pure data movement —
/// values (and therefore every downstream bit pattern) are unchanged.
fn pack_diag(a: &[f64], lda: usize, j0: usize, jb: usize, pack: &mut [f64]) {
    for j in 0..jb {
        let src = (j0 + j) * lda + j0;
        pack[j * jb..(j + 1) * jb].copy_from_slice(&a[src..src + jb]);
    }
}

/// Pack the solved `m x jb` panel rows `i0..i0+m` of column block `j0`
/// into a contiguous panel-major buffer (row `i0 + r` at `pack[r*jb]`).
fn pack_panel(a: &[f64], lda: usize, j0: usize, jb: usize, i0: usize, m: usize, pack: &mut [f64]) {
    for r in 0..m {
        let src = (i0 + r) * lda + j0;
        pack[r * jb..(r + 1) * jb].copy_from_slice(&a[src..src + jb]);
    }
}

/// In-place factorization of the `nb x nb` diagonal block at `(j0, j0)`
/// of a matrix with row stride `lda` (inner-product Cholesky; the block
/// is small enough that blocking buys nothing here).
fn factor_diag(a: &mut [f64], lda: usize, j0: usize, nb: usize, isa: Isa) -> Result<()> {
    for j in 0..nb {
        let rj = (j0 + j) * lda + j0;
        let d = {
            let row = &a[rj..rj + j];
            isa.fold_sub(a[rj + j], row, row)
        };
        if d <= 0.0 || !d.is_finite() {
            bail!("potrf: matrix not positive definite at pivot {} (d={d})", j0 + j);
        }
        let d = d.sqrt();
        a[rj + j] = d;
        for i in j + 1..nb {
            let ri = (j0 + i) * lda + j0;
            let s = isa.fold_sub(a[ri + j], &a[ri..ri + j], &a[rj..rj + j]);
            a[ri + j] = s / d;
        }
    }
    Ok(())
}

/// Solve the panel rows `i0..i0+m` against the *packed* factored
/// diagonal block `diag` (`nb x nb`, contiguous stride `nb`), in
/// place, row stride `lda`. `diag` holds exactly the values of the
/// factored block at `(j0, j0)`, so the result matches the historical
/// strided read bit for bit.
fn trsm_tile(
    a: &mut [f64],
    lda: usize,
    j0: usize,
    nb: usize,
    i0: usize,
    m: usize,
    diag: &[f64],
    isa: Isa,
) {
    for i in 0..m {
        let ri = (i0 + i) * lda + j0;
        for j in 0..nb {
            let dj = j * nb;
            let s = isa.fold_sub(a[ri + j], &a[ri..ri + j], &diag[dj..dj + j]);
            a[ri + j] = s / diag[dj + j];
        }
    }
}

/// One `(bi, bj)` tile of the trailing update `A22 -= L21 L21ᵀ` for a
/// panel of width `kb` (`bi`/`bj` are element offsets into the `m x m`
/// trailing block at `(i0, i0)`, `bj <= bi`, lower block-triangle
/// only). The panel operand arrives packed (`pack[r*kb]` holds trailing
/// row `i0 + r` of the solved panel) so both lanes of the dot stream
/// contiguously instead of striding `lda`. Shared by the serial sweep
/// [`syrk_tile`] and the team dispatch ([`FrontTeamJob`]) so both
/// produce bit-identical entries for a fixed `KernelCfg`.
fn syrk_block(
    a: &mut [f64],
    lda: usize,
    i0: usize,
    m: usize,
    bi: usize,
    bj: usize,
    kb: usize,
    pack: &[f64],
    block: usize,
    isa: Isa,
) {
    let ib = block.min(m - bi);
    let jb = block.min(m - bj);
    for i in 0..ib {
        let px = (bi + i) * kb;
        let ci = (i0 + bi + i) * lda + i0 + bj;
        let jmax = if bj == bi { i + 1 } else { jb };
        for j in 0..jmax {
            let py = (bj + j) * kb;
            let s = isa.dot(&pack[px..px + kb], &pack[py..py + kb]);
            a[ci + j] -= s;
        }
    }
}

/// Trailing update `A22 -= L21 L21ᵀ` for a packed panel of width `kb`:
/// tiled over the `m x m` trailing block starting at `(i0, i0)`, lower
/// block-triangle only (the upper triangle is never read and is zeroed
/// at the end of the factorization).
fn syrk_tile(
    a: &mut [f64],
    lda: usize,
    kb: usize,
    i0: usize,
    m: usize,
    pack: &[f64],
    block: usize,
    isa: Isa,
) {
    let mut bi = 0;
    while bi < m {
        let mut bj = 0;
        while bj <= bi {
            syrk_block(a, lda, i0, m, bi, bj, kb, pack, block, isa);
            bj += block;
        }
        bi += block;
    }
}

/// [`potrf_blocked`] under an explicit kernel configuration.
pub fn potrf_blocked_cfg(a: &mut [f64], n: usize, cfg: KernelCfg) -> Result<()> {
    if a.len() != n * n {
        bail!("potrf_blocked: buffer mismatch");
    }
    let mut pack = vec![0f64; pack_len(cfg.block, n)];
    potrf_blocked_scratch(a, n, cfg, &mut pack)
}

/// Blocked Cholesky body over caller-owned packing scratch (at least
/// [`pack_len`] words). The serial entry point above allocates a
/// transient buffer (O(block·n) words, deliberately *not*
/// arena-accounted: the pebble-game peak model covers fronts and
/// contribution blocks, and this scratch is bounded by one panel); the
/// team path recycles its [`FrontTeamJob`] pack buffer through the same
/// staging.
fn potrf_blocked_scratch(a: &mut [f64], n: usize, cfg: KernelCfg, pack: &mut [f64]) -> Result<()> {
    let (b, isa) = (cfg.block, cfg.isa);
    let mut j0 = 0;
    while j0 < n {
        let jb = b.min(n - j0);
        factor_diag(a, n, j0, jb, isa)?;
        let i0 = j0 + jb;
        if i0 < n {
            let m = n - i0;
            pack_diag(a, n, j0, jb, &mut pack[..jb * jb]);
            trsm_tile(a, n, j0, jb, i0, m, &pack[..jb * jb], isa);
            pack_panel(a, n, j0, jb, i0, m, &mut pack[..m * jb]);
            syrk_tile(a, n, jb, i0, m, &pack[..m * jb], b, isa);
        }
        j0 = i0;
    }
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Cache-blocked in-place lower Cholesky (right-looking, tile edge
/// [`BLOCK`], scalar loops); the strict upper triangle is zeroed.
/// Agrees with [`potrf`] up to floating-point reassociation and is the
/// bit-identity reference for `simd=off` gating.
pub fn potrf_blocked(a: &mut [f64], n: usize) -> Result<()> {
    potrf_blocked_cfg(a, n, KernelCfg::default())
}

/// Rows `r0..r0+rows` of the blocked `X Lᵀ = B` panel solve. Rows are
/// mutually independent (each row solves against `l` alone), so any
/// row partition — the serial full-range call in [`trsm_rt_blocked`] or
/// one row tile of a team dispatch — produces bit-identical entries for
/// a fixed `KernelCfg`: the per-row operation sequence (column panels
/// in ascending order) is fixed here. Both operands already stream
/// contiguously (`l` and `b` have row stride `k`), so no packing is
/// needed.
fn trsm_rt_rows(l: &[f64], k: usize, b: &mut [f64], r0: usize, rows: usize, block: usize, isa: Isa) {
    let mut j0 = 0;
    while j0 < k {
        let jb = block.min(k - j0);
        for i in r0..r0 + rows {
            let bi = i * k;
            for j in 0..jb {
                let lj = (j0 + j) * k;
                let s = isa.dot(&b[bi..bi + j0], &l[lj..lj + j0]);
                b[bi + j0 + j] -= s;
            }
            for j in 0..jb {
                let lj = (j0 + j) * k;
                let s = isa.fold_sub(
                    b[bi + j0 + j],
                    &b[bi + j0..bi + j0 + j],
                    &l[lj + j0..lj + j0 + j],
                );
                b[bi + j0 + j] = s / l[lj + j0 + j];
            }
        }
        j0 += jb;
    }
}

/// [`trsm_rt_blocked`] under an explicit kernel configuration.
pub fn trsm_rt_blocked_cfg(
    l: &[f64],
    k: usize,
    b: &mut [f64],
    m: usize,
    cfg: KernelCfg,
) -> Result<()> {
    if l.len() != k * k || b.len() != m * k {
        bail!("trsm_rt_blocked: buffer mismatch");
    }
    trsm_rt_rows(l, k, b, 0, m, cfg.block, cfg.isa);
    Ok(())
}

/// Cache-blocked `X Lᵀ = B` panel solve (same contract as [`trsm_rt`]):
/// each column panel folds in the already-solved columns with a dense
/// dot (the GEMM part), then solves against its diagonal block.
pub fn trsm_rt_blocked(l: &[f64], k: usize, b: &mut [f64], m: usize) -> Result<()> {
    trsm_rt_blocked_cfg(l, k, b, m, KernelCfg::default())
}

/// One `(i0, j0)` output tile of the Schur update `C -= A Aᵀ`: rows
/// `i0..i0+ib`, columns `j0..j0+jb`, folding the whole inner dimension
/// in ascending `block` panels. Every entry's accumulation sequence is
/// fixed here (inner panels in ascending `t0` order), so any tiling of
/// the output — the serial column sweep in [`syrk_sub_blocked`] or a
/// team's 2-D tile grid — produces bit-identical results for a fixed
/// `KernelCfg`. `A` rows already stream contiguously (stride `k`).
fn syrk_sub_block(
    c: &mut [f64],
    a: &[f64],
    m: usize,
    k: usize,
    i0: usize,
    ib: usize,
    j0: usize,
    jb: usize,
    block: usize,
    isa: Isa,
) {
    let mut t0 = 0;
    while t0 < k {
        let tb = block.min(k - t0);
        for i in i0..i0 + ib {
            let ai = i * k + t0;
            let ci = i * m + j0;
            for j in 0..jb {
                let aj = (j0 + j) * k + t0;
                let s = isa.dot(&a[ai..ai + tb], &a[aj..aj + tb]);
                c[ci + j] -= s;
            }
        }
        t0 += tb;
    }
}

/// [`syrk_sub_blocked`] under an explicit kernel configuration.
pub fn syrk_sub_blocked_cfg(
    c: &mut [f64],
    a: &[f64],
    m: usize,
    k: usize,
    cfg: KernelCfg,
) -> Result<()> {
    if c.len() != m * m || a.len() != m * k {
        bail!("syrk_sub_blocked: buffer mismatch");
    }
    let mut j0 = 0;
    while j0 < m {
        let jb = cfg.block.min(m - j0);
        syrk_sub_block(c, a, m, k, 0, m, j0, jb, cfg.block, cfg.isa);
        j0 += jb;
    }
    Ok(())
}

/// Cache-blocked Schur update `C -= A Aᵀ` (same contract as
/// [`syrk_sub`]): tiled over the inner dimension and the columns of C
/// so each `A` panel stays cache-resident across a column tile.
pub fn syrk_sub_blocked(c: &mut [f64], a: &[f64], m: usize, k: usize) -> Result<()> {
    syrk_sub_blocked_cfg(c, a, m, k, KernelCfg::default())
}

/// [`partial_factor_into`] under an explicit kernel configuration.
pub fn partial_factor_into_cfg(
    front: &[f64],
    n: usize,
    k: usize,
    panel: &mut [f64],
    schur: &mut [f64],
    cfg: KernelCfg,
) -> Result<()> {
    if front.len() != n * n || k == 0 || k > n {
        bail!("partial_factor_into: bad arguments n={n} k={k}");
    }
    let m = n - k;
    if panel.len() != n * k || schur.len() != m * m {
        bail!("partial_factor_into: output buffer mismatch");
    }
    for i in 0..n {
        panel[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
    }
    {
        let (l11, l21) = panel.split_at_mut(k * k);
        potrf_blocked_cfg(l11, k, cfg)?;
        trsm_rt_blocked_cfg(l11, k, l21, m, cfg)?;
    }
    for i in 0..m {
        let src = (k + i) * n + k;
        schur[i * m..(i + 1) * m].copy_from_slice(&front[src..src + m]);
    }
    syrk_sub_blocked_cfg(schur, &panel[k * k..], m, k, cfg)?;
    Ok(())
}

/// Blocked partial factorization writing straight into caller buffers:
/// `panel` receives `[L11; L21]` row-major (`n x k`), `schur` the
/// `(n-k) x (n-k)` Schur complement. The hot path of the multifrontal
/// drivers (the arena owns `schur`, the factorization output owns
/// `panel`); the only transient allocation is the O(block·k) packing
/// scratch inside the leading Cholesky.
pub fn partial_factor_into(
    front: &[f64],
    n: usize,
    k: usize,
    panel: &mut [f64],
    schur: &mut [f64],
) -> Result<()> {
    partial_factor_into_cfg(front, n, k, panel, schur, KernelCfg::default())
}

/// [`full_factor_blocked`] under an explicit kernel configuration.
pub fn full_factor_blocked_cfg(front: &[f64], n: usize, cfg: KernelCfg) -> Result<Vec<f64>> {
    let mut l = front.to_vec();
    potrf_blocked_cfg(&mut l, n, cfg)?;
    Ok(l)
}

/// Blocked full Cholesky of a front (returns lower factor).
pub fn full_factor_blocked(front: &[f64], n: usize) -> Result<Vec<f64>> {
    full_factor_blocked_cfg(front, n, KernelCfg::default())
}

// ---------------------------------------------------------------------
// Team-parallel blocked factorization (DESIGN.md §10). A front's tiles
// are dispatched over a worker *team* through an atomic tile cursor;
// tile ownership — not reduction order — is partitioned, so the result
// is bit-identical to the serial blocked path above (both run the same
// per-tile primitives: `factor_diag` / `trsm_tile` / `syrk_block` /
// `trsm_rt_rows` / `syrk_sub_block`).
// ---------------------------------------------------------------------

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Kind of one parallel step of a team factorization.
#[derive(Debug, Clone, Copy)]
enum StepKind {
    /// Row tiles of the trailing panel solve below diagonal block `j0`
    /// of the in-place Cholesky of the leading `k x k` block.
    CholTrsm { j0: usize, jb: usize },
    /// Lower-triangle `(bi, bj)` tiles of the trailing Schur update for
    /// panel `j0` of the in-place Cholesky.
    CholSyrk { j0: usize, jb: usize },
    /// Row tiles of the `L21 L11ᵀ = A21` panel solve (partial path).
    PanelTrsm,
    /// `(ti, tj)` output tiles of the front's Schur complement
    /// `C -= L21 L21ᵀ` (partial path).
    SchurSyrk,
}

/// One parallel step: a contiguous range of global tile ids.
#[derive(Debug, Clone, Copy)]
struct Step {
    kind: StepKind,
    /// First global tile id of the step.
    base: usize,
    /// Number of tiles.
    tiles: usize,
}

/// `t`-th pair of a row-major lower-triangle enumeration:
/// `(0,0) (1,0) (1,1) (2,0) ...` — the exact order the serial
/// [`syrk_tile`] sweep visits tiles in.
fn tri_index(t: usize) -> (usize, usize) {
    let mut bi = (((8 * t + 1) as f64).sqrt() as usize).saturating_sub(1) / 2;
    while (bi + 1) * (bi + 2) / 2 <= t {
        bi += 1;
    }
    while bi * (bi + 1) / 2 > t {
        bi -= 1;
    }
    (bi, t - bi * (bi + 1) / 2)
}

/// Interior-mutable buffer shared across a team.
///
/// Safety contract (upheld by the [`FrontTeamJob`] protocol): during a
/// parallel step every claimed tile writes a disjoint region and reads
/// only regions finalized by earlier steps; between steps only the
/// leader touches the buffer.
struct BufCell(UnsafeCell<Vec<f64>>);

impl BufCell {
    fn new(v: Vec<f64>) -> BufCell {
        BufCell(UnsafeCell::new(v))
    }

    /// Raw view of the buffer. Callers must respect the tile
    /// disjointness contract above; the protocol (gate, done counter,
    /// helper drain) provides the required happens-before edges.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice(&self) -> &mut [f64] {
        (*self.0.get()).as_mut_slice()
    }
}

// SAFETY: see the BufCell contract — all cross-thread access is
// tile-disjoint and ordered by the job's atomics.
unsafe impl Sync for BufCell {}
unsafe impl Send for BufCell {}

/// A team-parallel blocked front factorization in flight.
///
/// The *leader* (the worker that owns the front) drives the job through
/// [`FrontTeamJob::run_leader`]; any number of *helpers* may join at
/// any time via [`FrontTeamJob::help`] and leave when the job closes.
/// Work is split into **steps** (panel solves, trailing updates) whose
/// tiles are claimed through a single monotonically increasing atomic
/// cursor bounded by a `gate`:
///
/// * the step table is immutable after construction, so a claimed tile
///   id maps to its step parameters without any cross-thread handshake;
/// * the leader raises the gate to the end of step *s* only after every
///   tile of step *s − 1* is done (`done` counter), which both orders
///   the numeric dependencies (Release/Acquire on `gate`/`done`) and
///   makes stale claims impossible — the cursor can never pass the gate;
/// * tile ownership is exclusive (CAS on the cursor) and the per-tile
///   code is byte-for-byte the serial blocked path, so the factor is
///   bit-identical to [`partial_factor_into`] / [`full_factor_blocked`]
///   regardless of team size or interleaving.
///
/// A helper that panics mid-tile marks the job `aborted` (its unwind
/// guard) and the leader fails the front instead of waiting forever; a
/// leader that unwinds closes the job so helpers never hang.
pub struct FrontTeamJob {
    n: usize,
    k: usize,
    /// `n*k` row-major `[L11; L21]` output (the retained panel; the
    /// whole `L` when `k == n`).
    panel: BufCell,
    /// `(n-k)²` Schur complement output (empty when `k == n`).
    schur: BufCell,
    /// Panel-major packing scratch for the leading Cholesky's trailing
    /// solves/updates ([`pack_len`] words; empty when the leading block
    /// is a single tile). Leader-written between steps — the
    /// Release/Acquire pair on `gate` publishes it — and read-only
    /// inside tiles.
    pack: BufCell,
    /// Tile geometry + SIMD dispatch; shared verbatim with the serial
    /// path it must be bit-identical to.
    cfg: KernelCfg,
    steps: Vec<Step>,
    /// Highest tile id currently claimable (end of the open step).
    gate: AtomicUsize,
    /// Next tile id to claim; monotonic, never passes `gate`.
    cursor: AtomicUsize,
    /// Completed tiles; monotonic.
    done: AtomicUsize,
    /// Set once, when the job is over (success, error or unwind).
    closed: AtomicBool,
    /// Set when a team member panicked mid-tile.
    aborted: AtomicBool,
    /// Helpers currently inside [`FrontTeamJob::help`].
    helpers: AtomicUsize,
    /// Helpers that ever joined (occupancy statistics).
    joined: AtomicUsize,
    /// Test hook: global tile id whose execution panics.
    poison: AtomicUsize,
}

impl FrontTeamJob {
    /// Plan the team factorization of an `n x n` front eliminating `k`
    /// columns (`k == n` plans a full Cholesky) under the default
    /// kernel configuration. `panel` must hold `n*k` f64s and `schur`
    /// `(n-k)²` (both typically recycled buffers; contents are
    /// overwritten).
    pub fn new(n: usize, k: usize, panel: Vec<f64>, schur: Vec<f64>) -> FrontTeamJob {
        FrontTeamJob::with_cfg(KernelCfg::default(), n, k, panel, schur, Vec::new())
    }

    /// [`FrontTeamJob::new`] under an explicit kernel configuration:
    /// the step table's tile geometry follows `cfg.block` and every
    /// tile dispatches through `cfg.isa`. `pack` is recycled packing
    /// scratch of any length (it is resized to [`pack_len`] words; the
    /// executor routes arena scratch here and reclaims it with
    /// [`FrontTeamJob::take_pack`]).
    pub fn with_cfg(
        cfg: KernelCfg,
        n: usize,
        k: usize,
        panel: Vec<f64>,
        schur: Vec<f64>,
        mut pack: Vec<f64>,
    ) -> FrontTeamJob {
        assert!(k > 0 && k <= n, "FrontTeamJob: bad arguments n={n} k={k}");
        assert_eq!(panel.len(), n * k, "FrontTeamJob: panel buffer mismatch");
        assert_eq!(schur.len(), (n - k) * (n - k), "FrontTeamJob: schur buffer mismatch");
        let block = cfg.block;
        pack.clear();
        pack.resize(pack_len(block, k), 0.0);
        let mut steps = Vec::new();
        let mut base = 0usize;
        // in-place Cholesky of the leading k x k block (row stride k)
        let mut j0 = 0;
        while j0 < k {
            let jb = block.min(k - j0);
            let i0 = j0 + jb;
            if i0 < k {
                let m = k - i0;
                let t = m.div_ceil(block);
                steps.push(Step { kind: StepKind::CholTrsm { j0, jb }, base, tiles: t });
                base += t;
                let nb = m.div_ceil(block);
                let t = nb * (nb + 1) / 2;
                steps.push(Step { kind: StepKind::CholSyrk { j0, jb }, base, tiles: t });
                base += t;
            }
            j0 = i0;
        }
        if k < n {
            let m = n - k;
            let t = m.div_ceil(block);
            steps.push(Step { kind: StepKind::PanelTrsm, base, tiles: t });
            base += t;
            let nb = m.div_ceil(block);
            let t = nb * nb;
            steps.push(Step { kind: StepKind::SchurSyrk, base, tiles: t });
        }
        FrontTeamJob {
            n,
            k,
            panel: BufCell::new(panel),
            schur: BufCell::new(schur),
            pack: BufCell::new(pack),
            cfg,
            steps,
            gate: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            aborted: AtomicBool::new(false),
            helpers: AtomicUsize::new(0),
            joined: AtomicUsize::new(0),
            poison: AtomicUsize::new(usize::MAX),
        }
    }

    /// Front order.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Columns eliminated (`k == n` for a full factorization).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Helpers that ever joined this job (for occupancy reports).
    pub fn joined(&self) -> usize {
        self.joined.load(Ordering::Relaxed)
    }

    /// Largest team size this front's tile grid can keep busy under
    /// the default tile edge [`BLOCK`].
    pub fn max_useful_team(n: usize, k: usize) -> usize {
        FrontTeamJob::max_useful_team_cfg(BLOCK, n, k)
    }

    /// Largest team size this front's tile grid can keep busy under
    /// tile edge `block`: the widest single step. Teams beyond this
    /// would only spin.
    pub fn max_useful_team_cfg(block: usize, n: usize, k: usize) -> usize {
        let mut widest = 1usize;
        let trail = k.saturating_sub(block);
        if trail > 0 {
            let nb = trail.div_ceil(block);
            widest = widest.max(nb).max(nb * (nb + 1) / 2);
        }
        if k < n {
            let nb = (n - k).div_ceil(block);
            widest = widest.max(nb).max(nb * nb);
        }
        widest
    }

    /// Drive the factorization as the team leader: stage the front into
    /// the output buffers, factor panel by panel opening parallel steps
    /// for the trailing tiles, and close the job (also on error or
    /// unwind) so helpers always return. On success the buffers hold
    /// exactly what [`partial_factor_into`] (or
    /// [`full_factor_blocked`] for `k == n`) would have produced.
    pub fn run_leader(&self, front: &[f64]) -> Result<()> {
        struct CloseGuard<'a>(&'a FrontTeamJob);
        impl Drop for CloseGuard<'_> {
            fn drop(&mut self) {
                self.0.closed.store(true, Ordering::Release);
                // drain helpers before the caller reclaims the buffers
                while self.0.helpers.load(Ordering::Acquire) != 0 {
                    std::thread::yield_now();
                }
            }
        }
        let _close = CloseGuard(self);
        self.drive(front)
    }

    fn drive(&self, front: &[f64]) -> Result<()> {
        let (n, k) = (self.n, self.k);
        if front.len() != n * n {
            bail!("team factor: front buffer mismatch (n={n})");
        }
        // leader-exclusive staging: no tile is claimable yet (gate = 0)
        // SAFETY: helpers only touch the buffers through claimed tiles.
        let panel = unsafe { self.panel.slice() };
        for i in 0..n {
            panel[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
        }
        // blocked Cholesky of the leading k x k block: the diagonal
        // factor and the pack staging are serial (leader, between steps
        // — the gate is saturated so no helper is inside a tile, and
        // the Release store opening the next step publishes the pack);
        // trailing trsm/syrk tiles are team steps
        let (b, isa) = (self.cfg.block, self.cfg.isa);
        let mut next_step = 0usize;
        let mut j0 = 0;
        while j0 < k {
            let jb = b.min(k - j0);
            factor_diag(panel, k, j0, jb, isa)?;
            let i0 = j0 + jb;
            if i0 < k {
                let m = k - i0;
                // SAFETY: leader-exclusive between steps (see above).
                let pack = unsafe { self.pack.slice() };
                pack_diag(panel, k, j0, jb, &mut pack[..jb * jb]);
                self.run_step(next_step)?;
                pack_panel(panel, k, j0, jb, i0, m, &mut pack[..m * jb]);
                self.run_step(next_step + 1)?;
                next_step += 2;
            }
            j0 = i0;
        }
        // potrf contract: zero the strict upper triangle of L11
        for i in 0..k {
            for j in i + 1..k {
                panel[i * k + j] = 0.0;
            }
        }
        if k < n {
            // L21 solve over row tiles
            self.run_step(next_step)?;
            // leader-exclusive staging of the Schur block (between
            // steps the gate is saturated, so no helper is in a tile)
            let m = n - k;
            // SAFETY: leader-exclusive between steps.
            let schur = unsafe { self.schur.slice() };
            for i in 0..m {
                let src = (k + i) * n + k;
                schur[i * m..(i + 1) * m].copy_from_slice(&front[src..src + m]);
            }
            self.run_step(next_step + 1)?;
        }
        Ok(())
    }

    /// Open step `ix`, work its tiles alongside any helpers, and wait
    /// for stragglers before returning.
    fn run_step(&self, ix: usize) -> Result<()> {
        let step = self.steps[ix];
        let hi = step.base + step.tiles;
        debug_assert_eq!(self.gate.load(Ordering::Relaxed), step.base);
        self.gate.store(hi, Ordering::Release);
        self.work_tiles();
        while self.done.load(Ordering::Acquire) < hi {
            if self.aborted.load(Ordering::Relaxed) {
                bail!("team worker panicked mid-front");
            }
            std::thread::yield_now();
        }
        if self.aborted.load(Ordering::Relaxed) {
            bail!("team worker panicked mid-front");
        }
        Ok(())
    }

    /// Claim the next tile below the gate, if any.
    fn claim(&self) -> Option<usize> {
        loop {
            let gate = self.gate.load(Ordering::Acquire);
            let c = self.cursor.load(Ordering::Relaxed);
            if c >= gate {
                return None;
            }
            if self
                .cursor
                .compare_exchange_weak(c, c + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                return Some(c);
            }
        }
    }

    /// Claim-and-execute until the current step is drained.
    fn work_tiles(&self) {
        while let Some(t) = self.claim() {
            // a panicking tile must not strand the leader's wait loop
            struct TileGuard<'a>(&'a FrontTeamJob, bool);
            impl Drop for TileGuard<'_> {
                fn drop(&mut self) {
                    if self.1 {
                        self.0.aborted.store(true, Ordering::Release);
                    }
                }
            }
            let mut guard = TileGuard(self, true);
            self.exec_tile(t);
            guard.1 = false;
            drop(guard);
            self.done.fetch_add(1, Ordering::Release);
        }
    }

    /// Execute global tile `t` (the step table is immutable, so the
    /// mapping needs no synchronization).
    fn exec_tile(&self, t: usize) {
        if self.poison.load(Ordering::Relaxed) == t {
            panic!("injected tile panic (tile {t})");
        }
        let ix = self.steps.partition_point(|s| s.base + s.tiles <= t);
        let step = self.steps[ix];
        let local = t - step.base;
        let k = self.k;
        let (b, isa) = (self.cfg.block, self.cfg.isa);
        // SAFETY: exclusive tile ownership via the claimed cursor slot;
        // reads are confined to regions finalized by earlier steps.
        let panel = unsafe { self.panel.slice() };
        match step.kind {
            StepKind::CholTrsm { j0, jb } => {
                let i0 = j0 + jb;
                let r0 = i0 + local * b;
                let rows = b.min(k - r0);
                // SAFETY: the leader packed the diagonal block before
                // opening this step; tiles only read it.
                let pack = unsafe { self.pack.slice() };
                trsm_tile(panel, k, j0, jb, r0, rows, &pack[..jb * jb], isa);
            }
            StepKind::CholSyrk { j0, jb } => {
                let i0 = j0 + jb;
                let m = k - i0;
                let (ti, tj) = tri_index(local);
                // SAFETY: the leader packed the solved panel before
                // opening this step; tiles only read it.
                let pack = unsafe { self.pack.slice() };
                syrk_block(panel, k, i0, m, ti * b, tj * b, jb, &pack[..m * jb], b, isa);
            }
            StepKind::PanelTrsm => {
                let m = self.n - k;
                let r0 = local * b;
                let rows = b.min(m - r0);
                let (l11, l21) = panel.split_at_mut(k * k);
                trsm_rt_rows(l11, k, l21, r0, rows, b, isa);
            }
            StepKind::SchurSyrk => {
                let m = self.n - k;
                let nb = m.div_ceil(b);
                let (ti, tj) = (local / nb, local % nb);
                let (i0, j0) = (ti * b, tj * b);
                let (ib, jb) = (b.min(m - i0), b.min(m - j0));
                // SAFETY: same contract as `panel`.
                let schur = unsafe { self.schur.slice() };
                let l21 = &panel[k * k..];
                syrk_sub_block(schur, l21, m, k, i0, ib, j0, jb, b, isa);
            }
        }
    }

    /// Register this thread with the job *before* it starts helping —
    /// the leader's close-drain then waits for it even if it has not
    /// yet entered [`FrontTeamJob::help_reserved`]. The executor calls
    /// this under its queue lock when a worker claims a team seat, so
    /// there is no window in which a seat has been granted but the
    /// leader cannot see the incoming helper (it would otherwise race
    /// [`FrontTeamJob::take_outputs`]'s exclusivity check). Every
    /// `reserve` must be followed by exactly one `help_reserved`.
    pub fn reserve(&self) {
        self.helpers.fetch_add(1, Ordering::AcqRel);
    }

    /// Join the team as a helper: claim and execute tiles until the
    /// job closes. Returns immediately if it already has. Safe to call
    /// from any thread, at any point of the job's life.
    pub fn help(&self) {
        self.reserve();
        self.help_reserved();
    }

    /// [`FrontTeamJob::help`] after a prior [`FrontTeamJob::reserve`].
    pub fn help_reserved(&self) {
        self.joined.fetch_add(1, Ordering::Relaxed);
        struct HelperGuard<'a>(&'a FrontTeamJob);
        impl Drop for HelperGuard<'_> {
            fn drop(&mut self) {
                self.0.helpers.fetch_sub(1, Ordering::AcqRel);
            }
        }
        let _leave = HelperGuard(self);
        let mut idle = 0u32;
        while !self.closed.load(Ordering::Acquire) {
            let before = self.cursor.load(Ordering::Relaxed);
            self.work_tiles();
            if self.cursor.load(Ordering::Relaxed) != before {
                idle = 0;
                continue;
            }
            // between steps: the leader is factoring a diagonal block
            // or staging; spin politely, then back off
            idle += 1;
            if idle < 128 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(20));
            }
        }
    }

    /// Serial fallback for backends without team kernels: hand the
    /// caller exclusive access to the output buffers, then close the
    /// job. The executor never publishes helper seats for such
    /// backends, so exclusive access is free.
    pub fn run_serial(
        &self,
        f: impl FnOnce(usize, usize, &mut [f64], &mut [f64]) -> Result<()>,
    ) -> Result<()> {
        struct CloseGuard<'a>(&'a FrontTeamJob);
        impl Drop for CloseGuard<'_> {
            fn drop(&mut self) {
                self.0.closed.store(true, Ordering::Release);
                while self.0.helpers.load(Ordering::Acquire) != 0 {
                    std::thread::yield_now();
                }
            }
        }
        let _close = CloseGuard(self);
        debug_assert_eq!(self.joined(), 0, "helpers joined a serial-fallback job");
        // SAFETY: no seats published — the leader is the only thread.
        let (panel, schur) = unsafe { (self.panel.slice(), self.schur.slice()) };
        f(self.n, self.k, panel, schur)
    }

    /// Reclaim the output buffers. Must only be called after the job
    /// closed and the last helper left (both guaranteed once
    /// [`FrontTeamJob::run_leader`] / [`FrontTeamJob::run_serial`]
    /// returned).
    pub fn take_outputs(&self) -> (Vec<f64>, Vec<f64>) {
        assert!(
            self.closed.load(Ordering::Acquire) && self.helpers.load(Ordering::Acquire) == 0,
            "take_outputs before the job closed"
        );
        // SAFETY: closed + drained — no other thread touches the cells.
        unsafe {
            (
                std::mem::take(&mut *self.panel.0.get()),
                std::mem::take(&mut *self.schur.0.get()),
            )
        }
    }

    /// Reclaim the packing scratch for reuse (same contract as
    /// [`FrontTeamJob::take_outputs`]: only after the job closed and
    /// the last helper left).
    pub fn take_pack(&self) -> Vec<f64> {
        assert!(
            self.closed.load(Ordering::Acquire) && self.helpers.load(Ordering::Acquire) == 0,
            "take_pack before the job closed"
        );
        // SAFETY: closed + drained — no other thread touches the cell.
        unsafe { std::mem::take(&mut *self.pack.0.get()) }
    }

    #[cfg(test)]
    fn poison_tile(&self, t: usize) {
        self.poison.store(t, Ordering::Relaxed);
    }
}

/// `C = A B^T` helper for tests.
pub fn matmul_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * b[j * k + t];
            }
            c[i * n + j] = s;
        }
    }
    c
}

/// Frobenius norm.
pub fn fro_norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Forward solve `L y = b` (lower, row-major dense).
pub fn forward_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * y[j];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Backward solve `L^T x = y`.
pub fn backward_solve(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s / n as f64 + if i == j { 2.0 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let n = 24;
        let a = random_spd(n, 1);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let llt = matmul_nt(&l, &l, n, n, n);
        let diff: f64 = a.iter().zip(&llt).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(potrf(&mut a, 2).is_err());
    }

    #[test]
    fn potrf_identity() {
        let n = 5;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let want = a.clone();
        potrf(&mut a, n).unwrap();
        assert_eq!(a, want);
    }

    #[test]
    fn trsm_solves() {
        let k = 8;
        let m = 12;
        let a = random_spd(k, 2);
        let mut l = a.clone();
        potrf(&mut l, k).unwrap();
        let mut rng = Rng::new(3);
        let x_true: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        // B = X L^T
        let mut b = vec![0f64; m * k];
        for i in 0..m {
            for j in 0..k {
                let mut s = 0.0;
                for t in 0..=j {
                    s += x_true[i * k + t] * l[j * k + t];
                }
                b[i * k + j] = s;
            }
        }
        trsm_rt(&l, k, &mut b, m).unwrap();
        let diff: f64 = b.iter().zip(&x_true).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn partial_factor_composes_to_full() {
        let n = 20;
        let k = 8;
        let a = random_spd(n, 4);
        let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
        let l22 = full_factor(&schur, n - k).unwrap();
        // stitch L and compare against direct potrf
        let mut l = vec![0f64; n * n];
        for i in 0..k {
            for j in 0..=i {
                l[i * n + j] = l11[i * k + j];
            }
        }
        for i in 0..n - k {
            for j in 0..k {
                l[(k + i) * n + j] = l21[i * k + j];
            }
            for j in 0..=i {
                l[(k + i) * n + (k + j)] = l22[i * (n - k) + j];
            }
        }
        let mut direct = a.clone();
        potrf(&mut direct, n).unwrap();
        let diff: f64 = l.iter().zip(&direct).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9, "max diff {diff}");
    }

    #[test]
    fn solves_round_trip() {
        let n = 16;
        let a = random_spd(n, 5);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 2.0).collect();
        // b = A x
        let mut b = vec![0f64; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let y = forward_solve(&l, n, &b);
        let x = backward_solve(&l, n, &y);
        let diff: f64 = x.iter().zip(&x_true).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9);
    }

    #[test]
    fn fro_norm_basics() {
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(fro_norm(&[]), 0.0);
    }

    fn max_rel_diff(a: &[f64], b: &[f64]) -> f64 {
        let norm = fro_norm(a).max(1.0);
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
            / norm
    }

    #[test]
    fn blocked_potrf_matches_naive_oracle() {
        // sizes below, at, just above and at multiple tile edges
        for &n in &[1usize, 5, 63, 64, 65, 130] {
            let a = random_spd(n, 11 + n as u64);
            let mut naive = a.clone();
            potrf(&mut naive, n).unwrap();
            let mut blocked = a.clone();
            potrf_blocked(&mut blocked, n).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "n={n}: rel diff {d}");
        }
    }

    #[test]
    fn blocked_potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0];
        assert!(potrf_blocked(&mut a, 2).is_err());
    }

    #[test]
    fn blocked_trsm_matches_naive_oracle() {
        for &(k, m) in &[(7usize, 13usize), (64, 40), (100, 70)] {
            let a = random_spd(k, 21 + k as u64);
            let mut l = a.clone();
            potrf(&mut l, k).unwrap();
            let mut rng = Rng::new(5);
            let b0: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let mut naive = b0.clone();
            trsm_rt(&l, k, &mut naive, m).unwrap();
            let mut blocked = b0.clone();
            trsm_rt_blocked(&l, k, &mut blocked, m).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "k={k} m={m}: rel diff {d}");
        }
    }

    #[test]
    fn blocked_syrk_matches_naive_oracle() {
        for &(m, k) in &[(9usize, 4usize), (70, 64), (65, 130)] {
            let mut rng = Rng::new(31);
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let c0: Vec<f64> = (0..m * m).map(|_| rng.normal()).collect();
            let mut naive = c0.clone();
            syrk_sub(&mut naive, &a, m, k).unwrap();
            let mut blocked = c0.clone();
            syrk_sub_blocked(&mut blocked, &a, m, k).unwrap();
            let d = max_rel_diff(&naive, &blocked);
            assert!(d < 1e-12, "m={m} k={k}: rel diff {d}");
        }
    }

    #[test]
    fn partial_factor_into_matches_naive_partial() {
        for &(n, k) in &[(20usize, 8usize), (130, 64), (96, 96)] {
            let a = random_spd(n, 40 + n as u64);
            let m = n - k;
            let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
            let mut panel = vec![0f64; n * k];
            let mut schur_b = vec![0f64; m * m];
            partial_factor_into(&a, n, k, &mut panel, &mut schur_b).unwrap();
            let d11 = max_rel_diff(&l11, &panel[..k * k]);
            let d21 = max_rel_diff(&l21, &panel[k * k..]);
            let ds = max_rel_diff(&schur, &schur_b);
            assert!(d11 < 1e-12 && d21 < 1e-12 && ds < 1e-11, "n={n} k={k}: {d11} {d21} {ds}");
        }
    }

    #[test]
    fn blocked_full_factor_reconstructs() {
        let n = 100;
        let a = random_spd(n, 77);
        let l = full_factor_blocked(&a, n).unwrap();
        let llt = matmul_nt(&l, &l, n, n, n);
        let d = max_rel_diff(&a, &llt);
        assert!(d < 1e-12, "rel diff {d}");
    }

    #[test]
    fn tri_index_matches_serial_sweep_order() {
        // the CholSyrk tile enumeration must visit exactly the pairs
        // the serial lower-triangle sweep visits
        let mut t = 0usize;
        for bi in 0..12 {
            for bj in 0..=bi {
                assert_eq!(tri_index(t), (bi, bj), "tile {t}");
                t += 1;
            }
        }
    }

    #[test]
    fn max_useful_team_tracks_tile_grids() {
        // single-tile fronts cannot use helpers
        assert_eq!(FrontTeamJob::max_useful_team(64, 64), 1);
        assert_eq!(FrontTeamJob::max_useful_team(64, 32), 1);
        // a 256-order full front: widest step is the first trailing
        // syrk (192 trailing rows = 3 row tiles → 6 triangle tiles)
        assert_eq!(FrontTeamJob::max_useful_team(256, 256), 6);
        // partial 256/64: Schur grid is 3x3 = 9 tiles
        assert_eq!(FrontTeamJob::max_useful_team(256, 64), 9);
    }

    /// Run a team job with `helpers` live helper threads; returns the
    /// leader's outcome and the output buffers.
    fn run_team(
        front: &[f64],
        n: usize,
        k: usize,
        helpers: usize,
        poison: Option<usize>,
    ) -> (Result<()>, Vec<f64>, Vec<f64>, usize) {
        run_team_cfg(front, n, k, helpers, poison, KernelCfg::default())
    }

    /// [`run_team`] under an explicit kernel configuration.
    fn run_team_cfg(
        front: &[f64],
        n: usize,
        k: usize,
        helpers: usize,
        poison: Option<usize>,
        cfg: KernelCfg,
    ) -> (Result<()>, Vec<f64>, Vec<f64>, usize) {
        let m = n - k;
        let job = FrontTeamJob::with_cfg(cfg, n, k, vec![0f64; n * k], vec![0f64; m * m], Vec::new());
        if let Some(t) = poison {
            job.poison_tile(t);
        }
        let out = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..helpers)
                .map(|_| {
                    scope.spawn(|| {
                        // a poisoned tile panics whoever claims it; the
                        // catch keeps the scope join quiet — the real
                        // executor instead propagates via its own guard
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            job.help()
                        }));
                    })
                })
                .collect();
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                job.run_leader(front)
            }));
            for h in handles {
                h.join().unwrap();
            }
            out
        });
        // flatten: a leader panic counts as an error outcome
        let outcome = match out {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("leader panicked")),
        };
        let joined = job.joined();
        let (panel, schur) = job.take_outputs();
        (outcome, panel, schur, joined)
    }

    #[test]
    fn team_partial_is_bitwise_serial_blocked() {
        // several tile-grid shapes: single tile, tile-edge straddling,
        // multi-tile Cholesky + Schur grids
        for &(n, k, helpers) in &[
            (20usize, 8usize, 2usize),
            (130, 64, 3),
            (150, 70, 4),
            (260, 130, 7),
            (96, 96, 2),
            (200, 200, 3),
        ] {
            let a = random_spd(n, 500 + n as u64);
            let m = n - k;
            let mut want_panel = vec![0f64; n * k];
            let mut want_schur = vec![0f64; m * m];
            if k == n {
                want_panel = full_factor_blocked(&a, n).unwrap();
            } else {
                partial_factor_into(&a, n, k, &mut want_panel, &mut want_schur).unwrap();
            }
            let (outcome, panel, schur, _) = run_team(&a, n, k, helpers, None);
            outcome.unwrap();
            for (i, (x, y)) in want_panel.iter().zip(&panel).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k} panel[{i}]: {x} vs {y}");
            }
            for (i, (x, y)) in want_schur.iter().zip(&schur).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k} schur[{i}]: {x} vs {y}");
            }
        }
    }

    #[test]
    fn team_helpers_actually_join() {
        let n = 260;
        let a = random_spd(n, 33);
        let (outcome, _, _, joined) = run_team(&a, n, 130, 3, None);
        outcome.unwrap();
        assert_eq!(joined, 3, "helpers never joined the job");
    }

    #[test]
    fn team_leader_alone_completes_the_job() {
        let n = 150;
        let a = random_spd(n, 44);
        let (outcome, panel, _, _) = run_team(&a, n, n, 0, None);
        outcome.unwrap();
        let want = full_factor_blocked(&a, n).unwrap();
        assert_eq!(panel.len(), want.len());
        for (x, y) in want.iter().zip(&panel) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn team_worker_panic_mid_front_does_not_hang() {
        // poison a tile of the Schur step: whichever team member claims
        // it panics mid-front. The job must abort (leader error or
        // leader panic), close, and drain — this test *completing* is
        // the property under test.
        let n = 260;
        let k = 130;
        let a = random_spd(n, 55);
        let job_probe = FrontTeamJob::new(n, k, vec![0f64; n * k], vec![0f64; (n - k) * (n - k)]);
        // poison the last tile so earlier steps complete and helpers
        // are deep in the protocol when it fires
        let last = {
            let s = job_probe.steps.last().unwrap();
            s.base + s.tiles - 1
        };
        let (outcome, _, _, _) = run_team(&a, n, k, 3, Some(last));
        let err = outcome.expect_err("poisoned job must not succeed");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("panicked"),
            "unexpected outcome for poisoned job: {msg}"
        );
    }

    #[test]
    fn team_rejects_indefinite_matrices_cleanly() {
        // an indefinite pivot fails factor_diag on the leader; helpers
        // must still be released (the test would hang otherwise)
        let n = 130;
        let mut a = random_spd(n, 66);
        a[0] = -1.0; // break positive definiteness at the first pivot
        let (outcome, _, _, _) = run_team(&a, n, 65, 2, None);
        let msg = format!("{:#}", outcome.expect_err("indefinite must fail"));
        assert!(msg.contains("positive definite"), "{msg}");
    }

    // --- dual correctness gating (DESIGN.md §16) -----------------------
    // simd=off: bit-identity against the serial/team oracle path, for
    // any tile edge. simd=on: normwise epsilon against the naive
    // oracle, plus serial==team bit-identity *within* the configuration.

    use crate::frontal::simd::{FrontConfig, SimdMode};

    fn partial_cfg(a: &[f64], n: usize, k: usize, cfg: KernelCfg) -> (Vec<f64>, Vec<f64>) {
        let m = n - k;
        let mut panel = vec![0f64; n * k];
        let mut schur = vec![0f64; m * m];
        partial_factor_into_cfg(a, n, k, &mut panel, &mut schur, cfg).unwrap();
        (panel, schur)
    }

    #[test]
    fn simd_off_nonstandard_block_stays_bitwise_serial_team() {
        // the bit-identity regression gate: with simd off, the team
        // path must stay bit-identical to the serial blocked path for
        // every tile edge (remainder tiles included), and the default
        // cfg must factor exactly like the legacy wrappers
        for &(n, k, block) in &[(130usize, 64usize, 24usize), (97, 50, 32), (80, 80, 24)] {
            let cfg = KernelCfg { block, isa: Isa::Scalar };
            let a = random_spd(n, 900 + n as u64);
            let (want_panel, want_schur) = if k == n {
                (full_factor_blocked_cfg(&a, n, cfg).unwrap(), Vec::new())
            } else {
                partial_cfg(&a, n, k, cfg)
            };
            let (outcome, panel, schur, _) = run_team_cfg(&a, n, k, 3, None, cfg);
            outcome.unwrap();
            for (i, (x, y)) in want_panel.iter().zip(&panel).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "block={block} n={n} k={k} panel[{i}]");
            }
            for (i, (x, y)) in want_schur.iter().zip(&schur).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "block={block} n={n} k={k} schur[{i}]");
            }
        }
        // legacy wrapper == default cfg, bitwise
        let (n, k) = (130, 64);
        let a = random_spd(n, 77);
        let (p1, s1) = partial_cfg(&a, n, k, KernelCfg::default());
        let mut p2 = vec![0f64; n * k];
        let mut s2 = vec![0f64; (n - k) * (n - k)];
        partial_factor_into(&a, n, k, &mut p2, &mut s2).unwrap();
        assert!(p1.iter().zip(&p2).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(s1.iter().zip(&s2).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn simd_matches_scalar_normwise_epsilon_randomized() {
        // on scalar-only hardware the detected ISA degenerates to
        // Scalar and this check becomes the bitwise case — the CI
        // runners provide the SIMD leg
        let isa = Isa::detect(SimdMode::Auto);
        crate::util::prop::check(
            crate::util::prop::Config { cases: 12, seed: 0x51AD },
            "simd-partial-matches-scalar",
            |r| {
                let n = r.range(1, 150);
                let k = r.range(1, n);
                let block = [8usize, 24, 64][r.below(3)];
                (n, k, block, r.next_u64())
            },
            |&(n, k, block, seed)| {
                let a = random_spd(n, seed);
                let (ps, ss) = partial_cfg(&a, n, k, KernelCfg { block, isa: Isa::Scalar });
                let (pv, sv) = partial_cfg(&a, n, k, KernelCfg { block, isa });
                let dp = max_rel_diff(&ps, &pv);
                let ds = max_rel_diff(&ss, &sv);
                if dp < 1e-11 && ds < 1e-11 {
                    Ok(())
                } else {
                    Err(format!("n={n} k={k} block={block}: panel {dp} schur {ds}"))
                }
            },
        );
    }

    #[test]
    fn simd_one_wide_panels_and_remainder_tiles_match_oracle() {
        let isa = Isa::detect(SimdMode::Auto);
        // 1-wide panels (k=1) and n % block != 0 remainder tiles, vs
        // the *naive* oracle (normwise epsilon — the simd=on gate)
        for &(n, k, block) in &[(65usize, 1usize, 8usize), (70, 1, 64), (65, 33, 8), (130, 64, 24)]
        {
            let a = random_spd(n, 300 + n as u64);
            let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
            let (panel, schur_v) = partial_cfg(&a, n, k, KernelCfg { block, isa });
            let d11 = max_rel_diff(&l11, &panel[..k * k]);
            let d21 = max_rel_diff(&l21, &panel[k * k..]);
            let ds = max_rel_diff(&schur, &schur_v);
            assert!(
                d11 < 1e-11 && d21 < 1e-11 && ds < 1e-11,
                "n={n} k={k} block={block}: {d11} {d21} {ds}"
            );
        }
        // full factorization with remainder tiles
        let n = 90;
        let a = random_spd(n, 4242);
        let mut naive = a.clone();
        potrf(&mut naive, n).unwrap();
        let l = full_factor_blocked_cfg(&a, n, KernelCfg { block: 24, isa }).unwrap();
        let d = max_rel_diff(&naive, &l);
        assert!(d < 1e-11, "rel diff {d}");
    }

    #[test]
    fn team_is_bitwise_serial_within_a_simd_config() {
        // serial == team bit-identity is per configuration: tile
        // ownership, not reduction order, is what the team partitions,
        // so it survives SIMD dispatch too
        let cfg = KernelCfg { block: BLOCK, isa: Isa::detect(SimdMode::Auto) };
        for &(n, k, helpers) in &[(130usize, 64usize, 3usize), (200, 200, 4)] {
            let a = random_spd(n, 600 + n as u64);
            let (want_panel, want_schur) = if k == n {
                (full_factor_blocked_cfg(&a, n, cfg).unwrap(), Vec::new())
            } else {
                partial_cfg(&a, n, k, cfg)
            };
            let (outcome, panel, schur, _) = run_team_cfg(&a, n, k, helpers, None, cfg);
            outcome.unwrap();
            for (i, (x, y)) in want_panel.iter().zip(&panel).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k} panel[{i}]");
            }
            for (i, (x, y)) in want_schur.iter().zip(&schur).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "n={n} k={k} schur[{i}]");
            }
        }
    }

    #[test]
    fn max_useful_team_cfg_follows_block() {
        // block 32 on a 256 full front: 224 trailing rows = 7 row
        // tiles, 28 triangle tiles
        assert_eq!(FrontTeamJob::max_useful_team_cfg(32, 256, 256), 28);
        // partial 256/64 at block 32: Schur grid is 6x6
        assert_eq!(FrontTeamJob::max_useful_team_cfg(32, 256, 64), 36);
        // the default-block wrapper is unchanged
        assert_eq!(FrontTeamJob::max_useful_team(256, 256), 6);
    }

    #[test]
    fn pack_len_covers_staged_panels() {
        assert_eq!(pack_len(64, 64), 0, "single-tile blocks need no packing");
        assert_eq!(pack_len(64, 63), 0);
        assert_eq!(pack_len(64, 65), 64 * 65);
        // widest staged slice is max(jb*jb, m*jb) <= block*k
        assert!(pack_len(24, 100) >= 24 * 24);
        assert!(pack_len(24, 100) >= 76 * 24);
    }

    #[test]
    fn front_config_resolves_against_this_cpu() {
        // auto must resolve on any host; force is strict
        let auto = FrontConfig { block: 64, simd: SimdMode::Auto }.resolve().unwrap();
        match (FrontConfig { block: 64, simd: SimdMode::Force }).resolve() {
            Ok(cfg) => assert!(cfg.isa.is_simd()),
            Err(_) => assert_eq!(auto.isa, Isa::Scalar),
        }
    }
}
