//! Pure-Rust dense kernels (row-major, f64).
//!
//! These are the reference implementations for the PJRT path and the
//! numeric engine of the `RustBackend`. They mirror
//! `python/compile/kernels/ref.py` operation by operation.

use anyhow::{bail, Result};

/// In-place lower Cholesky of a symmetric positive-definite `n x n`
/// row-major matrix; the strict upper triangle is zeroed.
pub fn potrf(a: &mut [f64], n: usize) -> Result<()> {
    if a.len() != n * n {
        bail!("potrf: buffer mismatch");
    }
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("potrf: matrix not positive definite at pivot {j} (d={d})");
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in j + 1..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
    }
    for i in 0..n {
        for j in i + 1..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Solve `X L^T = B` for X where `l` is `k x k` lower triangular and
/// `b` is `m x k` (the panel TRSM); result overwrites `b`.
pub fn trsm_rt(l: &[f64], k: usize, b: &mut [f64], m: usize) -> Result<()> {
    if l.len() != k * k || b.len() != m * k {
        bail!("trsm: buffer mismatch");
    }
    // row i of X: forward substitution against L
    for i in 0..m {
        for j in 0..k {
            let mut s = b[i * k + j];
            for t in 0..j {
                s -= b[i * k + t] * l[j * k + t];
            }
            b[i * k + j] = s / l[j * k + j];
        }
    }
    Ok(())
}

/// Schur update `C -= A A^T` where `a` is `m x k`, `c` is `m x m`.
pub fn syrk_sub(c: &mut [f64], a: &[f64], m: usize, k: usize) -> Result<()> {
    if c.len() != m * m || a.len() != m * k {
        bail!("syrk: buffer mismatch");
    }
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * a[j * k + t];
            }
            c[i * m + j] -= s;
        }
    }
    Ok(())
}

/// Partial factorization: eliminate the leading `k` columns of the
/// `n x n` front. Returns `(l11 [k x k], l21 [(n-k) x k], schur
/// [(n-k) x (n-k)])`.
pub fn partial_factor(front: &[f64], n: usize, k: usize) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
    if front.len() != n * n || k == 0 || k > n {
        bail!("partial_factor: bad arguments n={n} k={k}");
    }
    let m = n - k;
    let mut l11 = vec![0f64; k * k];
    for i in 0..k {
        l11[i * k..(i + 1) * k].copy_from_slice(&front[i * n..i * n + k]);
    }
    potrf(&mut l11, k)?;
    let mut l21 = vec![0f64; m * k];
    for i in 0..m {
        l21[i * k..(i + 1) * k].copy_from_slice(&front[(k + i) * n..(k + i) * n + k]);
    }
    trsm_rt(&l11, k, &mut l21, m)?;
    let mut schur = vec![0f64; m * m];
    for i in 0..m {
        for j in 0..m {
            schur[i * m + j] = front[(k + i) * n + (k + j)];
        }
    }
    syrk_sub(&mut schur, &l21, m, k)?;
    Ok((l11, l21, schur))
}

/// Full Cholesky of a front (returns lower factor).
pub fn full_factor(front: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = front.to_vec();
    potrf(&mut l, n)?;
    Ok(l)
}

/// `C = A B^T` helper for tests.
pub fn matmul_nt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for t in 0..k {
                s += a[i * k + t] * b[j * k + t];
            }
            c[i * n + j] = s;
        }
    }
    c
}

/// Frobenius norm.
pub fn fro_norm(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Forward solve `L y = b` (lower, row-major dense).
pub fn forward_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[i * n + j] * y[j];
        }
        y[i] = s / l[i * n + i];
    }
    y
}

/// Backward solve `L^T x = y`.
pub fn backward_solve(l: &[f64], n: usize, y: &[f64]) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= l[j * n + i] * x[j];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn random_spd(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s / n as f64 + if i == j { 2.0 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn potrf_reconstructs() {
        let n = 24;
        let a = random_spd(n, 1);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let llt = matmul_nt(&l, &l, n, n, n);
        let diff: f64 = a.iter().zip(&llt).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn potrf_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(potrf(&mut a, 2).is_err());
    }

    #[test]
    fn potrf_identity() {
        let n = 5;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let want = a.clone();
        potrf(&mut a, n).unwrap();
        assert_eq!(a, want);
    }

    #[test]
    fn trsm_solves() {
        let k = 8;
        let m = 12;
        let a = random_spd(k, 2);
        let mut l = a.clone();
        potrf(&mut l, k).unwrap();
        let mut rng = Rng::new(3);
        let x_true: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
        // B = X L^T
        let mut b = vec![0f64; m * k];
        for i in 0..m {
            for j in 0..k {
                let mut s = 0.0;
                for t in 0..=j {
                    s += x_true[i * k + t] * l[j * k + t];
                }
                b[i * k + j] = s;
            }
        }
        trsm_rt(&l, k, &mut b, m).unwrap();
        let diff: f64 = b.iter().zip(&x_true).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-10, "max diff {diff}");
    }

    #[test]
    fn partial_factor_composes_to_full() {
        let n = 20;
        let k = 8;
        let a = random_spd(n, 4);
        let (l11, l21, schur) = partial_factor(&a, n, k).unwrap();
        let l22 = full_factor(&schur, n - k).unwrap();
        // stitch L and compare against direct potrf
        let mut l = vec![0f64; n * n];
        for i in 0..k {
            for j in 0..=i {
                l[i * n + j] = l11[i * k + j];
            }
        }
        for i in 0..n - k {
            for j in 0..k {
                l[(k + i) * n + j] = l21[i * k + j];
            }
            for j in 0..=i {
                l[(k + i) * n + (k + j)] = l22[i * (n - k) + j];
            }
        }
        let mut direct = a.clone();
        potrf(&mut direct, n).unwrap();
        let diff: f64 = l.iter().zip(&direct).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9, "max diff {diff}");
    }

    #[test]
    fn solves_round_trip() {
        let n = 16;
        let a = random_spd(n, 5);
        let mut l = a.clone();
        potrf(&mut l, n).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 2.0).collect();
        // b = A x
        let mut b = vec![0f64; n];
        for i in 0..n {
            for j in 0..n {
                b[i] += a[i * n + j] * x_true[j];
            }
        }
        let y = forward_solve(&l, n, &b);
        let x = backward_solve(&l, n, &y);
        let diff: f64 = x.iter().zip(&x_true).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-9);
    }

    #[test]
    fn fro_norm_basics() {
        assert!((fro_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(fro_norm(&[]), 0.0);
    }
}
