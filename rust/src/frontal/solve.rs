//! Supernodal sparse triangular solves.
//!
//! `Factorization::solve_dense` densifies the factor — fine for tests,
//! quadratic in memory for real problems. This module solves
//! `(P A Pᵀ) x = b` directly on the per-supernode panels:
//! forward substitution walks supernodes in postorder (children before
//! parents), backward substitution in reverse, gathering/scattering
//! through each supernode's row list. O(nnz(L)) time, O(n) workspace.

use crate::sparse::AssemblyTree;

use super::multifrontal::Factorization;

/// Forward solve `L y = b` on the supernodal panels.
pub fn forward_solve_sn(at: &AssemblyTree, f: &Factorization, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    for (s, sn) in at.symbolic.supernodes.iter().enumerate() {
        let panel = &f.panels[s];
        let width = sn.width;
        let nf = sn.front_order();
        // diagonal block: dense forward substitution on the k x k part
        for j in 0..width {
            let gj = sn.first_col + j;
            let mut v = y[gj];
            for t in 0..j {
                v -= panel[j * width + t] * y[sn.first_col + t];
            }
            v /= panel[j * width + j];
            y[gj] = v;
        }
        // off-diagonal rows: y[rows] -= L21 * y[cols]
        for li in width..nf {
            let gi = sn.rows[li];
            let mut acc = 0.0;
            for j in 0..width {
                acc += panel[li * width + j] * y[sn.first_col + j];
            }
            y[gi] -= acc;
        }
    }
    y
}

/// Backward solve `Lᵀ x = y` on the supernodal panels.
pub fn backward_solve_sn(at: &AssemblyTree, f: &Factorization, y: &[f64]) -> Vec<f64> {
    let mut x = y.to_vec();
    for (s, sn) in at.symbolic.supernodes.iter().enumerate().rev() {
        let panel = &f.panels[s];
        let width = sn.width;
        let nf = sn.front_order();
        // x[cols] -= L21ᵀ * x[rows below]
        for j in (0..width).rev() {
            let gj = sn.first_col + j;
            let mut v = x[gj];
            for li in width..nf {
                v -= panel[li * width + j] * x[sn.rows[li]];
            }
            // diagonal block (upper part of the transpose)
            for t in j + 1..width {
                v -= panel[t * width + j] * x[sn.first_col + t];
            }
            x[gj] = v / panel[j * width + j];
        }
    }
    x
}

/// Solve `(P A Pᵀ) x = b` via the supernodal panels.
pub fn solve_sn(at: &AssemblyTree, f: &Factorization, b: &[f64]) -> Vec<f64> {
    let y = forward_solve_sn(at, f, b);
    backward_solve_sn(at, f, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::multifrontal::factorize;
    use crate::frontal::RustBackend;
    use crate::sparse::{gen, order, symbolic};

    fn setup(k: usize, amalg: usize) -> (AssemblyTree, crate::sparse::CscMatrix, Factorization) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, amalg).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        (at, ap, f)
    }

    #[test]
    fn supernodal_solve_matches_dense_solve() {
        let (at, ap, f) = setup(8, 0);
        let n = ap.n;
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.13).sin()).collect();
        let x_sn = solve_sn(&at, &f, &b);
        let x_dense = f.solve_dense(&at, &b);
        for (a, b) in x_sn.iter().zip(&x_dense) {
            assert!((a - b).abs() < 1e-9 * a.abs().max(1.0));
        }
    }

    #[test]
    fn solve_recovers_solution_amalgamated() {
        let (at, ap, f) = setup(12, 4);
        let n = ap.n;
        let x_true: Vec<f64> = (0..n).map(|i| 2.0 + (i as f64 * 0.31).cos()).collect();
        let b = ap.matvec(&x_true);
        let x = solve_sn(&at, &f, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-8, "max err {err}");
    }

    #[test]
    fn forward_then_backward_are_inverses_of_llt() {
        let (at, ap, f) = setup(6, 2);
        let n = ap.n;
        let b: Vec<f64> = (0..n).map(|i| (i % 5) as f64 - 2.0).collect();
        let x = solve_sn(&at, &f, &b);
        // A x == b
        let ax = ap.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "Ax != b: {u} vs {v}");
        }
    }

    #[test]
    fn solve_3d_problem() {
        let a = gen::grid_laplacian_3d(4);
        let perm = order::nested_dissection_3d(4);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let f = factorize(&at, &ap, &RustBackend::default()).unwrap();
        let x_true: Vec<f64> = (0..ap.n).map(|i| 1.0 + i as f64 * 0.01).collect();
        let b = ap.matvec(&x_true);
        let x = solve_sn(&at, &f, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-8, "max err {err}");
    }

    #[test]
    fn larger_grid_solve_scales() {
        // 24x24 = 576 unknowns: would be slow to verify densified;
        // the supernodal path handles it directly
        let (at, ap, f) = setup(24, 4);
        let x_true: Vec<f64> = (0..ap.n).map(|i| (i as f64 * 0.017).sin() + 3.0).collect();
        let b = ap.matvec(&x_true);
        let x = solve_sn(&at, &f, &b);
        let err = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-7, "max err {err}");
    }
}
