//! Span records and trace logs (DESIGN.md §17).
//!
//! A [`Span`] is one timed activity of one worker (or node): assembling
//! a front, factoring it with a team, stalling on a dependency or a
//! memory gate, retrying after a fault, or moving bytes across the
//! network. The real executor records spans in **wall clock**
//! (nanoseconds since the run started); the simulation engines emit the
//! *same type* in **model time**, so measured and predicted timelines
//! are directly comparable — that is the whole point of the module
//! (the paper's §3 fits α from exactly such timings).
//!
//! Recording is allocation-light by construction: each worker appends
//! to its own `Vec<Span>` (no shared state, no locks) and the buffers
//! are merged into one [`TraceLog`] when the report is built. The
//! disabled path ([`TraceSink::Null`]) takes zero extra clock reads and
//! zero allocations — the hot executor is unchanged when tracing is
//! off (overhead asserted < 3 % even when it is *on*, `benches/obs_trace.rs`).

use anyhow::{bail, Result};

use crate::model::TaskTree;

/// What a span was doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Extend-add of children contribution blocks into a front.
    Assemble,
    /// Partial factorization of a front (the `T(p) = L/p^α` unit —
    /// Factor spans are what [`crate::obs::calibrate`] fits α from).
    Factor,
    /// Waiting: memory-gate admission, a remote child, a backoff sleep.
    Stall,
    /// A failed factorization attempt that will be re-queued.
    Retry,
    /// A cross-node contribution-block transfer.
    Transfer,
}

impl SpanKind {
    /// Stable lowercase name (used as the Chrome trace `cat`).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Assemble => "assemble",
            SpanKind::Factor => "factor",
            SpanKind::Stall => "stall",
            SpanKind::Retry => "retry",
            SpanKind::Transfer => "transfer",
        }
    }

    /// Inverse of [`SpanKind::name`].
    pub fn from_name(s: &str) -> Option<SpanKind> {
        Some(match s {
            "assemble" => SpanKind::Assemble,
            "factor" => SpanKind::Factor,
            "stall" => SpanKind::Stall,
            "retry" => SpanKind::Retry,
            "transfer" => SpanKind::Transfer,
            _ => return None,
        })
    }
}

/// One timed activity. Times are `f64` in the owning log's
/// [`TimeUnit`]: wall-clock nanoseconds since run start, or model time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub kind: SpanKind,
    /// Task (front / tree node / job) id.
    pub task: u32,
    /// Worker (executor) or node (simulators) that owned the span.
    pub worker: u32,
    /// Processors working the span: an integer team size in wall
    /// traces, a possibly fractional share in model traces, `0.0` when
    /// unknown (e.g. EqualSplit's time-varying share).
    pub team: f64,
    /// Work attributed to the span (flops for Factor/Retry, words for
    /// Transfer, `0.0` otherwise).
    pub flops: f64,
    pub start: f64,
    pub end: f64,
}

impl Span {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Time base of a [`TraceLog`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeUnit {
    /// Wall-clock nanoseconds since the run began (real executor).
    WallNs,
    /// Simulated model time (same unit as `TaskTree` lengths, i.e.
    /// flops at one-processor speed).
    Model,
}

impl TimeUnit {
    pub fn name(self) -> &'static str {
        match self {
            TimeUnit::WallNs => "wall_ns",
            TimeUnit::Model => "model",
        }
    }

    pub fn from_name(s: &str) -> Option<TimeUnit> {
        Some(match s {
            "wall_ns" => TimeUnit::WallNs,
            "model" => TimeUnit::Model,
            _ => return None,
        })
    }
}

/// Where span records go while a run is live.
///
/// `Null` is the zero-cost disabled path: recording sites guard every
/// extra clock read and push behind `sink.enabled()`, so the hot
/// executor performs no tracing work at all. `Buffer` collects spans
/// in per-worker local vectors merged at report time.
///
/// The explicit `*_traced` entry points take the sink verbatim — they
/// do **not** consult the environment, so tests exercise the span
/// content deterministically under any `MALLTREE_TRACE` setting. Only
/// the CLI resolves the env kill-switch, via [`TraceSink::from_env`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceSink {
    Null,
    Buffer,
}

impl TraceSink {
    pub fn enabled(self) -> bool {
        matches!(self, TraceSink::Buffer)
    }

    /// Resolve the CLI sink: `MALLTREE_TRACE=off|0|false` forces
    /// `Null` (the CI null-sink leg), `on|1|force` forces `Buffer`,
    /// anything else (including unset) follows `requested`.
    pub fn from_env(requested: bool) -> TraceSink {
        match std::env::var("MALLTREE_TRACE").ok().as_deref() {
            Some("off") | Some("0") | Some("false") => TraceSink::Null,
            Some("on") | Some("1") | Some("force") => TraceSink::Buffer,
            _ => {
                if requested {
                    TraceSink::Buffer
                } else {
                    TraceSink::Null
                }
            }
        }
    }
}

/// A merged, per-run collection of spans — the common output of the
/// real executor and every simulation engine.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceLog {
    /// Time base of every span in the log.
    pub unit: TimeUnit,
    /// Engine that produced the log (`"exec"`, `"sim-des"`, …).
    pub source: String,
    /// Worker (or node) count — Chrome export emits one track each.
    pub workers: usize,
    pub spans: Vec<Span>,
}

impl TraceLog {
    pub fn new(source: &str, unit: TimeUnit, workers: usize) -> Self {
        TraceLog { unit, source: source.to_string(), workers, spans: Vec::new() }
    }

    pub fn push(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Sort spans by start time (ties by task id) — NaN-safe.
    pub fn sort(&mut self) {
        self.spans
            .sort_by(|a, b| a.start.total_cmp(&b.start).then(a.task.cmp(&b.task)));
    }

    /// Latest span end (0 for an empty log).
    pub fn makespan(&self) -> f64 {
        self.spans.iter().fold(0.0f64, |m, s| m.max(s.end))
    }

    /// Spans of one kind.
    pub fn spans_of(&self, kind: SpanKind) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.kind == kind)
    }

    /// Summed duration of one kind.
    pub fn total(&self, kind: SpanKind) -> f64 {
        self.spans_of(kind).map(|s| s.duration()).sum()
    }

    /// Rebuild the legacy `ExecReport::team_log` view — `(front_width,
    /// team_size)` per Factor span, `widths` indexed by task id — so
    /// the timed log provably subsumes the untimed one
    /// (`occupancy()`/`avg_team()` equivalence is tested in
    /// `exec::report`).
    pub fn team_log(&self, widths: &[usize]) -> Vec<(usize, usize)> {
        self.spans_of(SpanKind::Factor)
            .map(|s| (widths.get(s.task as usize).copied().unwrap_or(0), s.team.round() as usize))
            .collect()
    }

    /// Structural invariants every engine must uphold: finite times,
    /// `end >= start`, workers within the declared track count,
    /// non-negative team/flops. Export refuses invalid logs (NaN would
    /// silently corrupt the JSON).
    pub fn validate(&self) -> Result<()> {
        for (i, s) in self.spans.iter().enumerate() {
            if !s.start.is_finite() || !s.end.is_finite() {
                bail!("{}:{}: span {i} has non-finite time [{}, {}]", file!(), line!(), s.start, s.end);
            }
            if s.end < s.start {
                bail!("{}:{}: span {i} ends before it starts ({} < {})", file!(), line!(), s.end, s.start);
            }
            if (s.worker as usize) >= self.workers.max(1) {
                bail!(
                    "{}:{}: span {i} on worker {} but log declares {} tracks",
                    file!(),
                    line!(),
                    s.worker,
                    self.workers
                );
            }
            if !(s.team >= 0.0) || !(s.flops >= 0.0) {
                bail!("{}:{}: span {i} has negative team/flops", file!(), line!(), s.team);
            }
        }
        Ok(())
    }
}

/// Derive a model-time [`TraceLog`] from per-task completion times —
/// the shared post-hoc path for the DES engines, whose static-share
/// semantics make the start time recoverable: a task starts when its
/// last child completes (time 0 for leaves).
///
/// * `teams` — per-task processor share (`team` field); `None` → 0.0
///   (unknown, e.g. EqualSplit).
/// * `durations` — per-task busy time; when given, `start = end − dur`
///   instead of the ready time (the Divisible engine runs tasks
///   sequentially, so ready time ≠ start time there).
/// * `node_of` — per-task owning node; populates `worker` and emits a
///   Stall span on every parent whose remote children finish after its
///   local ones (`[ready_local, ready_all]` — summed durations equal
///   the distributed engine's `cross_stall` by construction, which the
///   round-trip tests pin).
pub fn from_completions(
    source: &str,
    tree: &TaskTree,
    completion: &[f64],
    teams: Option<&[f64]>,
    durations: Option<&[f64]>,
    node_of: Option<&[usize]>,
) -> TraceLog {
    let n = tree.len();
    assert_eq!(completion.len(), n, "completion must cover every task");
    let workers = node_of.map_or(1, |m| m.iter().copied().max().map_or(1, |w| w + 1));
    let mut log = TraceLog::new(source, TimeUnit::Model, workers);
    for v in 0..n {
        let mut ready_all = 0.0f64;
        let mut ready_local = 0.0f64;
        for &c in &tree.nodes[v].children {
            let ci = c as usize;
            ready_all = ready_all.max(completion[ci]);
            let local = node_of.map_or(true, |m| m[ci] == m[v]);
            if local {
                ready_local = ready_local.max(completion[ci]);
            }
        }
        let worker = node_of.map_or(0, |m| m[v]) as u32;
        let start = match durations {
            Some(d) => (completion[v] - d[v]).max(0.0),
            None => ready_all.min(completion[v]),
        };
        if node_of.is_some() && ready_all > ready_local && durations.is_none() {
            log.push(Span {
                kind: SpanKind::Stall,
                task: v as u32,
                worker,
                team: 0.0,
                flops: 0.0,
                start: ready_local,
                end: ready_all,
            });
        }
        log.push(Span {
            kind: SpanKind::Factor,
            task: v as u32,
            worker,
            team: teams.map_or(0.0, |t| t[v]),
            flops: tree.nodes[v].len,
            start,
            end: completion[v],
        });
    }
    log.sort();
    log
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, task: u32, start: f64, end: f64) -> Span {
        Span { kind, task, worker: 0, team: 1.0, flops: 1.0, start, end }
    }

    #[test]
    fn sink_env_resolution() {
        // explicit sinks never consult the env — only from_env does,
        // and the test env may carry MALLTREE_TRACE (the CI off leg),
        // so only the forced branches are assertable here
        assert!(TraceSink::Buffer.enabled());
        assert!(!TraceSink::Null.enabled());
    }

    #[test]
    fn totals_and_makespan() {
        let mut log = TraceLog::new("test", TimeUnit::Model, 1);
        log.push(span(SpanKind::Factor, 0, 0.0, 2.0));
        log.push(span(SpanKind::Factor, 1, 2.0, 5.0));
        log.push(span(SpanKind::Stall, 1, 1.0, 2.0));
        assert_eq!(log.makespan(), 5.0);
        assert_eq!(log.total(SpanKind::Factor), 5.0);
        assert_eq!(log.total(SpanKind::Stall), 1.0);
        assert_eq!(log.spans_of(SpanKind::Factor).count(), 2);
        log.validate().unwrap();
    }

    #[test]
    fn validate_rejects_malformed() {
        let mut log = TraceLog::new("test", TimeUnit::Model, 1);
        log.push(span(SpanKind::Factor, 0, 3.0, 1.0));
        assert!(log.validate().is_err());
        log.spans.clear();
        log.push(span(SpanKind::Factor, 0, f64::NAN, 1.0));
        assert!(log.validate().is_err());
        log.spans.clear();
        let mut s = span(SpanKind::Factor, 0, 0.0, 1.0);
        s.worker = 7; // only 1 track declared
        log.push(s);
        assert!(log.validate().is_err());
    }

    #[test]
    fn sort_is_nan_safe() {
        let mut log = TraceLog::new("test", TimeUnit::Model, 1);
        log.push(span(SpanKind::Factor, 0, f64::NAN, 1.0));
        log.push(span(SpanKind::Factor, 1, 0.0, 1.0));
        log.sort(); // must not panic; NaN orders after finite values
        assert_eq!(log.spans[0].task, 1);
    }

    #[test]
    fn from_completions_matches_tree_structure() {
        // chain 0 -> 1 -> 2 with unit work each, completions 1,2,3
        let tree = TaskTree::from_parents(&[2, 2, 2], &[1.0, 1.0, 1.0]).unwrap();
        let completion = [1.0, 2.0, 3.0];
        let log = from_completions("t", &tree, &completion, None, None, None);
        let factors: Vec<&Span> = log.spans_of(SpanKind::Factor).collect();
        assert_eq!(factors.len(), 3);
        // root (task 2) starts at its latest child completion
        let root = factors.iter().find(|s| s.task == 2).unwrap();
        assert_eq!(root.start, 2.0);
        assert_eq!(root.end, 3.0);
        log.validate().unwrap();
    }

    #[test]
    fn from_completions_emits_cross_node_stalls() {
        // two leaves on different nodes than the root: the root stalls
        // from its local-ready time to its remote-ready time
        let tree = TaskTree::from_parents(&[2, 2, 2], &[1.0, 1.0, 1.0]).unwrap();
        let completion = [1.0, 4.0, 6.0];
        let node_of = [0usize, 1, 0];
        let log = from_completions("t", &tree, &completion, None, None, Some(&node_of));
        assert_eq!(log.workers, 2);
        let stalls: Vec<&Span> = log.spans_of(SpanKind::Stall).collect();
        assert_eq!(stalls.len(), 1);
        assert_eq!(stalls[0].task, 2);
        assert_eq!(stalls[0].start, 1.0); // local child done
        assert_eq!(stalls[0].end, 4.0); // remote child done
    }

    #[test]
    fn team_log_view_uses_widths() {
        let mut log = TraceLog::new("test", TimeUnit::WallNs, 2);
        let mut s = span(SpanKind::Factor, 0, 0.0, 1.0);
        s.team = 3.0;
        log.push(s);
        let widths = [17usize];
        assert_eq!(log.team_log(&widths), vec![(17, 3)]);
    }
}
