//! Observability: unified span tracing and telemetry-driven α
//! calibration (DESIGN.md §17).
//!
//! One span schema across the whole system: the real executor records
//! [`trace::Span`]s in wall clock around every front, and the
//! simulation engines ([`crate::sim::des`], [`crate::sim::faults`],
//! [`crate::net::sim`], [`crate::sim::online`]) emit the same type in
//! model time — measured and predicted timelines become directly
//! comparable artifacts.
//!
//! * [`trace`] — spans, per-worker lock-free buffers, [`TraceLog`],
//!   the zero-cost [`TraceSink::Null`] disabled path;
//! * [`export`] — Chrome trace-event JSON (Perfetto-loadable, one
//!   track per worker/node, bit-exact round-trip) and a text timeline
//!   summary;
//! * [`calibrate`] — fit α (global + per front width) from Factor
//!   spans via the paper's §3 log–log regression, emit a step
//!   `Profile` from the occupancy curve, and report model drift
//!   (predicted vs executed, assumed vs fitted α).

pub mod calibrate;
pub mod export;
pub mod trace;

pub use calibrate::{calibrate, drift_report, profile_from_trace, Calibration, DriftReport};
pub use export::{chrome_trace, parse_chrome_trace, timeline_summary, write_chrome_trace};
pub use trace::{from_completions, Span, SpanKind, TimeUnit, TraceLog, TraceSink};
