//! Close the α loop: fit the malleability exponent from the system's
//! own Factor spans (DESIGN.md §17).
//!
//! The paper's §3 measures `T(p) = L/p^α` on real kernels and fits α
//! in log–log space; the whole scheduling stack then *consumes* α as
//! an input. This module supplies the measurement side from traced
//! executions: every Factor span carries `(team, duration, flops)`,
//! so `duration/flops` against `team` is exactly the paper's timing
//! curve with the front length normalized out — one
//! [`crate::metrics::regression::fit_alpha`] away from α, globally
//! and per front-width bucket.
//!
//! On top: a *model-drift report* (per-front predicted vs executed
//! duration, and the PM makespan error under the assumed vs the
//! fitted α — the §7 mis-specification cost, measured instead of
//! simulated) and a step [`Profile`] distilled from the trace's
//! worker-occupancy curve, consumable by the existing `--profile`
//! flag — telemetry feeding straight back into the scheduler.

use anyhow::{bail, Result};

use super::trace::{SpanKind, TraceLog};
use crate::metrics::regression::{fit_alpha, LinearFit};
use crate::metrics::Table;
use crate::sched::Profile;

/// Front-width bucket edges — mirrors `exec::team::occupancy_by_width`
/// so calibration tables line up with the occupancy report.
pub const WIDTH_EDGES: [usize; 5] = [64, 128, 256, 512, usize::MAX];

/// Per-front-width-bucket α fit.
#[derive(Debug, Clone, Copy)]
pub struct WidthFit {
    /// Bucket `[lo, hi)` over front width.
    pub lo: usize,
    pub hi: usize,
    pub samples: usize,
    pub alpha: f64,
    pub r2: f64,
}

/// A fitted malleability model.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Global fitted exponent (`T(p) ∝ p^{-α}`).
    pub alpha: f64,
    /// The underlying log–log fit (`r2` is its quality).
    pub fit: LinearFit,
    /// Factor samples that survived filtering.
    pub samples: usize,
    /// Time per flop at one processor (`e^intercept`, in the trace's
    /// time unit) — converts model makespans into predicted times.
    pub unit_cost: f64,
    /// Per-width-bucket fits (buckets without enough spread are
    /// omitted rather than reported as garbage).
    pub per_width: Vec<WidthFit>,
}

/// Extract `(team, time_per_flop)` calibration samples from Factor
/// spans. Spans with unknown team, `team < 1` (the sub-processor kink
/// makes them follow a different law), zero flops, or zero duration
/// are filtered out.
pub fn samples_from(logs: &[&TraceLog]) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    for log in logs {
        for s in log.spans_of(SpanKind::Factor) {
            let d = s.duration();
            if s.team >= 1.0 && s.flops > 0.0 && d > 0.0 {
                out.push((s.team, d / s.flops));
            }
        }
    }
    out
}

/// Fit α — global and per front-width — from the Factor spans of one
/// or more trace logs (typically a `--workers-sweep`: the same fronts
/// executed by teams of different sizes). `widths` maps task id →
/// front width for the bucketed fits; pass `None` to skip them.
pub fn calibrate(logs: &[&TraceLog], widths: Option<&[usize]>) -> Result<Calibration> {
    let samples = samples_from(logs);
    if samples.len() < 2 {
        bail!(
            "{}:{}: calibration needs >= 2 usable Factor spans, got {} — trace a run first",
            file!(),
            line!(),
            samples.len()
        );
    }
    let (alpha, fit) = fit_alpha(&samples, f64::INFINITY)?;
    let mut per_width = Vec::new();
    if let Some(widths) = widths {
        let mut lo = 0usize;
        for &hi in &WIDTH_EDGES {
            let bucket: Vec<(f64, f64)> = logs
                .iter()
                .flat_map(|log| log.spans_of(SpanKind::Factor))
                .filter(|s| {
                    let w = widths.get(s.task as usize).copied().unwrap_or(0);
                    w >= lo && w < hi
                })
                .filter(|s| s.team >= 1.0 && s.flops > 0.0 && s.duration() > 0.0)
                .map(|s| (s.team, s.duration() / s.flops))
                .collect();
            // buckets with no team-size spread cannot identify α —
            // fit_alpha reports the degeneracy and the bucket is omitted
            if let Ok((a, f)) = fit_alpha(&bucket, f64::INFINITY) {
                per_width.push(WidthFit { lo, hi, samples: bucket.len(), alpha: a, r2: f.r2 });
            }
            lo = hi;
        }
    }
    Ok(Calibration { alpha, fit, samples: samples.len(), unit_cost: fit.intercept.exp(), per_width })
}

/// Predicted duration of a front under a calibrated unit cost and an
/// exponent `alpha` (trace time units).
pub fn predicted_duration(cal: &Calibration, flops: f64, team: f64, alpha: f64) -> f64 {
    cal.unit_cost * flops / team.max(1.0).powf(alpha)
}

/// Per-width drift between predicted and executed front durations.
#[derive(Debug, Clone, Copy)]
pub struct DriftRow {
    pub lo: usize,
    pub hi: usize,
    pub fronts: usize,
    /// Mean |predicted − executed|/executed, %, under the assumed α.
    pub err_assumed_pct: f64,
    /// Same under the fitted α.
    pub err_fitted_pct: f64,
}

/// Model-drift report: how far the `L/p^α` model is from the executed
/// timeline, under the α the schedule assumed vs the α the telemetry
/// fits — the measured cost of a mis-specified α.
#[derive(Debug, Clone)]
pub struct DriftReport {
    pub assumed_alpha: f64,
    pub fitted_alpha: f64,
    pub rows: Vec<DriftRow>,
    pub overall_assumed_pct: f64,
    pub overall_fitted_pct: f64,
    /// Measured trace makespan (trace time units).
    pub measured_makespan: f64,
    /// PM-schedule makespan under the assumed α, converted to trace
    /// time units via the calibrated unit cost.
    pub predicted_assumed: f64,
    /// Same under the fitted α.
    pub predicted_fitted: f64,
    pub makespan_err_assumed_pct: f64,
    pub makespan_err_fitted_pct: f64,
}

/// Build the drift report for one traced run. `model_makespan_*` are
/// the PM schedule's closed-form makespans (model units, i.e. flops)
/// solved under the assumed and the fitted α — the caller solves them
/// because only it holds the tree.
pub fn drift_report(
    log: &TraceLog,
    widths: &[usize],
    cal: &Calibration,
    assumed_alpha: f64,
    model_makespan_assumed: f64,
    model_makespan_fitted: f64,
) -> DriftReport {
    let pct = |pred: f64, exec: f64| -> f64 { (pred - exec).abs() / exec * 100.0 };
    let mut rows = Vec::new();
    let (mut sum_a, mut sum_f, mut count) = (0.0f64, 0.0f64, 0usize);
    let mut lo = 0usize;
    for &hi in &WIDTH_EDGES {
        let (mut ba, mut bf, mut n) = (0.0f64, 0.0f64, 0usize);
        for s in log.spans_of(SpanKind::Factor) {
            let w = widths.get(s.task as usize).copied().unwrap_or(0);
            if w < lo || w >= hi || s.duration() <= 0.0 || s.flops <= 0.0 || s.team < 1.0 {
                continue;
            }
            ba += pct(predicted_duration(cal, s.flops, s.team, assumed_alpha), s.duration());
            bf += pct(predicted_duration(cal, s.flops, s.team, cal.alpha), s.duration());
            n += 1;
        }
        if n > 0 {
            rows.push(DriftRow {
                lo,
                hi,
                fronts: n,
                err_assumed_pct: ba / n as f64,
                err_fitted_pct: bf / n as f64,
            });
            sum_a += ba;
            sum_f += bf;
            count += n;
        }
        lo = hi;
    }
    let measured = log.makespan();
    let predicted_assumed = model_makespan_assumed * cal.unit_cost;
    let predicted_fitted = model_makespan_fitted * cal.unit_cost;
    DriftReport {
        assumed_alpha,
        fitted_alpha: cal.alpha,
        rows,
        overall_assumed_pct: if count > 0 { sum_a / count as f64 } else { 0.0 },
        overall_fitted_pct: if count > 0 { sum_f / count as f64 } else { 0.0 },
        measured_makespan: measured,
        predicted_assumed,
        predicted_fitted,
        makespan_err_assumed_pct: if measured > 0.0 { pct(predicted_assumed, measured) } else { 0.0 },
        makespan_err_fitted_pct: if measured > 0.0 { pct(predicted_fitted, measured) } else { 0.0 },
    }
}

/// Distill the trace's worker-occupancy curve into a step [`Profile`]
/// consumable by the CLI `--profile` flag: the summed team size of
/// concurrently running Factor spans, coarsened to at most `max_steps`
/// steps. `time_per_flop > 0` rescales wall durations into model
/// units (pass the calibrated [`Calibration::unit_cost`]); pass `1.0`
/// for model-time logs. Also returns the `d:p[,...]` spec string.
pub fn profile_from_trace(
    log: &TraceLog,
    max_steps: usize,
    time_per_flop: f64,
) -> Result<(Profile, String)> {
    assert!(max_steps >= 1);
    // occupancy deltas at span boundaries
    let mut deltas: Vec<(f64, f64)> = Vec::new();
    for s in log.spans_of(SpanKind::Factor) {
        let team = if s.team >= 1.0 { s.team } else { 1.0 };
        if s.duration() > 0.0 {
            deltas.push((s.start, team));
            deltas.push((s.end, -team));
        }
    }
    if deltas.is_empty() {
        bail!("{}:{}: no Factor spans to build a profile from", file!(), line!());
    }
    deltas.sort_by(|a, b| a.0.total_cmp(&b.0));
    let scale = if time_per_flop > 0.0 { 1.0 / time_per_flop } else { 1.0 };
    // sweep into (duration, level) segments; idle gaps keep capacity 1
    // (the profile models available processors, never zero)
    let mut segs: Vec<(f64, f64)> = Vec::new();
    let mut level = 0.0f64;
    let mut t_prev = deltas[0].0;
    for (t, d) in deltas {
        if t > t_prev {
            segs.push(((t - t_prev) * scale, level.max(1.0)));
        }
        level += d;
        t_prev = t;
    }
    if segs.is_empty() {
        bail!("{}:{}: trace has no positive-duration occupancy segment", file!(), line!());
    }
    // merge equal-level neighbours, then coarsen to max_steps by
    // repeatedly folding the shortest segment into a neighbour
    // (duration-weighted level)
    let mut merged: Vec<(f64, f64)> = Vec::new();
    for (d, p) in segs {
        match merged.last_mut() {
            Some(last) if last.1 == p => last.0 += d,
            _ => merged.push((d, p)),
        }
    }
    while merged.len() > max_steps {
        let i = (0..merged.len())
            .min_by(|&a, &b| merged[a].0.total_cmp(&merged[b].0))
            .unwrap();
        let j = if i == 0 {
            1
        } else if i == merged.len() - 1 {
            i - 1
        } else if merged[i - 1].0 <= merged[i + 1].0 {
            i - 1
        } else {
            i + 1
        };
        let (lo, hi) = (i.min(j), i.max(j));
        let d = merged[lo].0 + merged[hi].0;
        let p = (merged[lo].0 * merged[lo].1 + merged[hi].0 * merged[hi].1) / d;
        merged[lo] = (d, p);
        merged.remove(hi);
    }
    let spec = merged
        .iter()
        .map(|(d, p)| format!("{d:.6e}:{p:.3}"))
        .collect::<Vec<_>>()
        .join(",");
    let profile = Profile::steps(&merged)?;
    Ok((profile, spec))
}

/// Render the per-width fit table.
pub fn width_table(cal: &Calibration) -> String {
    let mut t = Table::new(&["width", "samples", "alpha", "r2"]);
    t.row(&[
        "all".to_string(),
        format!("{}", cal.samples),
        format!("{:.3}", cal.alpha),
        format!("{:.4}", cal.fit.r2),
    ]);
    for w in &cal.per_width {
        let hi = if w.hi == usize::MAX { "inf".to_string() } else { format!("{}", w.hi) };
        t.row(&[
            format!("[{}, {})", w.lo, hi),
            format!("{}", w.samples),
            format!("{:.3}", w.alpha),
            format!("{:.4}", w.r2),
        ]);
    }
    t.render()
}

/// Render the drift report tables.
pub fn drift_table(rep: &DriftReport) -> String {
    let mut out = format!(
        "model drift (assumed alpha = {:.3}, fitted alpha = {:.3}):\n",
        rep.assumed_alpha, rep.fitted_alpha
    );
    let mut t = Table::new(&["width", "fronts", "err% assumed", "err% fitted"]);
    for r in &rep.rows {
        let hi = if r.hi == usize::MAX { "inf".to_string() } else { format!("{}", r.hi) };
        t.row(&[
            format!("[{}, {})", r.lo, hi),
            format!("{}", r.fronts),
            format!("{:.1}", r.err_assumed_pct),
            format!("{:.1}", r.err_fitted_pct),
        ]);
    }
    t.row(&[
        "overall".to_string(),
        format!("{}", rep.rows.iter().map(|r| r.fronts).sum::<usize>()),
        format!("{:.1}", rep.overall_assumed_pct),
        format!("{:.1}", rep.overall_fitted_pct),
    ]);
    out.push_str(&t.render());
    out.push_str(&format!(
        "makespan: measured {:.3e}, PM(assumed) {:.3e} (err {:.1}%), PM(fitted) {:.3e} (err {:.1}%)\n",
        rep.measured_makespan,
        rep.predicted_assumed,
        rep.makespan_err_assumed_pct,
        rep.predicted_fitted,
        rep.makespan_err_fitted_pct,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Span, TimeUnit};

    /// Synthetic `p^α` backend in span form: fronts of varied flops
    /// executed by teams 1..=8, durations exactly `c·L/p^α`.
    fn synthetic_log(alpha: f64, c: f64) -> (TraceLog, Vec<usize>) {
        let mut log = TraceLog::new("synth", TimeUnit::WallNs, 8);
        let widths = vec![16usize, 90, 200, 300, 700];
        let flops = [1.0e6, 5.0e6, 2.0e7, 8.0e7, 3.0e8];
        let mut t = 0.0f64;
        for (i, &l) in flops.iter().enumerate() {
            for team in 1..=8u32 {
                let d = c * l / (team as f64).powf(alpha);
                log.push(Span {
                    kind: SpanKind::Factor,
                    task: i as u32,
                    worker: team % 8,
                    team: team as f64,
                    flops: l,
                    start: t,
                    end: t + d,
                });
                t += d;
            }
        }
        (log, widths)
    }

    #[test]
    fn recovers_synthetic_alpha_exactly() {
        let (log, widths) = synthetic_log(0.85, 120.0);
        let cal = calibrate(&[&log], Some(&widths)).unwrap();
        assert!((cal.alpha - 0.85).abs() < 1e-9, "alpha = {}", cal.alpha);
        assert!(cal.fit.r2 > 0.999999);
        assert!((cal.unit_cost - 120.0).abs() / 120.0 < 1e-9);
        // every populated width bucket recovers the same exponent
        assert!(!cal.per_width.is_empty());
        for w in &cal.per_width {
            assert!((w.alpha - 0.85).abs() < 1e-9);
        }
    }

    #[test]
    fn filters_sub_processor_and_degenerate_spans() {
        let (mut log, _) = synthetic_log(0.9, 1.0);
        let n = samples_from(&[&log]).len();
        // sub-processor shares and zero-flop spans are not samples
        log.push(Span {
            kind: SpanKind::Factor,
            task: 0,
            worker: 0,
            team: 0.5,
            flops: 1e6,
            start: 0.0,
            end: 1.0,
        });
        log.push(Span {
            kind: SpanKind::Factor,
            task: 0,
            worker: 0,
            team: 2.0,
            flops: 0.0,
            start: 0.0,
            end: 1.0,
        });
        assert_eq!(samples_from(&[&log]).len(), n);
    }

    #[test]
    fn degenerate_team_spread_is_an_error_not_nan() {
        // all Factor spans at the same team size: α is unidentifiable,
        // and the hardened linear_fit reports it instead of NaN
        let mut log = TraceLog::new("synth", TimeUnit::WallNs, 1);
        for i in 0..6u32 {
            log.push(Span {
                kind: SpanKind::Factor,
                task: i,
                worker: 0,
                team: 4.0,
                flops: 1e6 * (i + 1) as f64,
                start: 0.0,
                end: 1000.0,
            });
        }
        assert!(calibrate(&[&log], None).is_err());
    }

    #[test]
    fn drift_prefers_fitted_alpha() {
        let (log, widths) = synthetic_log(0.8, 50.0);
        let cal = calibrate(&[&log], Some(&widths)).unwrap();
        let rep = drift_report(&log, &widths, &cal, 1.0, 1.0, 1.0);
        // the data is exactly p^0.8: fitted error ~0, assumed α=1.0 off
        assert!(rep.overall_fitted_pct < 1e-6, "fitted err {}", rep.overall_fitted_pct);
        assert!(rep.overall_assumed_pct > 1.0, "assumed err {}", rep.overall_assumed_pct);
        assert!(!rep.rows.is_empty());
    }

    #[test]
    fn profile_distills_occupancy() {
        // two overlapping 2-team fronts then one solo front:
        // levels 2, 4, 2, 1
        let mut log = TraceLog::new("exec", TimeUnit::Model, 4);
        let mk = |task: u32, team: f64, start: f64, end: f64| Span {
            kind: SpanKind::Factor,
            task,
            worker: task,
            team,
            flops: 1.0,
            start,
            end,
        };
        log.push(mk(0, 2.0, 0.0, 2.0));
        log.push(mk(1, 2.0, 1.0, 3.0));
        log.push(mk(2, 1.0, 3.0, 5.0));
        let (profile, spec) = profile_from_trace(&log, 8, 1.0).unwrap();
        assert_eq!(profile.at(0.5), 2.0);
        assert_eq!(profile.at(1.5), 4.0);
        assert_eq!(profile.at(2.5), 2.0);
        assert_eq!(profile.at(4.0), 1.0);
        assert_eq!(spec.matches(':').count(), 4);
        // coarsening to 2 steps still yields a valid profile
        let (p2, spec2) = profile_from_trace(&log, 2, 1.0).unwrap();
        assert!(p2.min_p() >= 1.0);
        assert_eq!(spec2.matches(':').count(), 2);
    }

    #[test]
    fn tables_render() {
        let (log, widths) = synthetic_log(0.9, 10.0);
        let cal = calibrate(&[&log], Some(&widths)).unwrap();
        let wt = width_table(&cal);
        assert!(wt.contains("all"));
        let rep = drift_report(&log, &widths, &cal, 0.9, 2.0, 2.0);
        let dt = drift_table(&rep);
        assert!(dt.contains("overall"));
        assert!(dt.contains("makespan"));
    }
}
