//! Trace export: Chrome trace-event JSON and a text timeline summary.
//!
//! [`chrome_trace`] renders a [`TraceLog`] as the Chrome trace-event
//! format (an object with a `traceEvents` array of `"ph":"X"` complete
//! events), loadable in Perfetto / `chrome://tracing`, one track
//! (`tid`) per worker or node. Timestamps are microseconds as the
//! format requires; the span's exact original times ride along in
//! `args.t0`/`args.t1` so [`parse_chrome_trace`] round-trips the log
//! **bit-for-bit** (µs conversion alone would lose low bits — the
//! round-trip property is tested per engine in `benches/obs_trace.rs`
//! and the unit tests below).
//!
//! No serde: the writer is string assembly over validated spans, the
//! reader a small field scanner for exactly this writer's output.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::trace::{Span, SpanKind, TimeUnit, TraceLog};
use crate::metrics::Table;

/// Microseconds-per-unit factor for the Chrome `ts`/`dur` fields.
fn us_per_unit(unit: TimeUnit) -> f64 {
    match unit {
        TimeUnit::WallNs => 1e-3,
        // model time unit ≡ 1 second for display purposes
        TimeUnit::Model => 1e6,
    }
}

/// Render `log` as Chrome trace-event JSON. Fails on logs that do not
/// [`TraceLog::validate`] (NaN times would corrupt the JSON silently).
pub fn chrome_trace(log: &TraceLog) -> Result<String> {
    log.validate()?;
    let scale = us_per_unit(log.unit);
    // header fields first: the reader scans them from the prefix
    let mut out = String::with_capacity(128 + 160 * log.spans.len());
    out.push_str(&format!(
        "{{\"source\":\"{}\",\"unit\":\"{}\",\"workers\":{},\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n",
        log.source,
        log.unit.name(),
        log.workers
    ));
    let mut first = true;
    for w in 0..log.workers {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{w},\"args\":{{\"name\":\"worker {w}\"}}}}"
        ));
    }
    for s in &log.spans {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{} t{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{},\"args\":{{\"task\":{},\"team\":{},\"flops\":{},\"t0\":{},\"t1\":{}}}}}",
            s.kind.name(),
            s.task,
            s.kind.name(),
            s.start * scale,
            s.duration() * scale,
            s.worker,
            s.task,
            s.team,
            s.flops,
            s.start,
            s.end,
        ));
    }
    out.push_str("\n]}\n");
    Ok(out)
}

/// Write the Chrome trace to `path`.
pub fn write_chrome_trace(log: &TraceLog, path: &Path) -> Result<()> {
    let json = chrome_trace(log)?;
    std::fs::write(path, json)
        .with_context(|| format!("{}:{}: writing trace to {}", file!(), line!(), path.display()))
}

/// Scan `"key":"value"` out of a JSON fragment (writer's format only).
fn str_field<'a>(s: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let at = s.find(&pat)? + pat.len();
    let rest = &s[at..];
    Some(&rest[..rest.find('"')?])
}

/// Scan `"key":<number>` out of a JSON fragment.
fn num_field(s: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = s.find(&pat)? + pat.len();
    let rest = &s[at..];
    let end = rest
        .find(|c: char| c == ',' || c == '}' || c == ']')
        .unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Parse a trace produced by [`chrome_trace`] back into a [`TraceLog`].
///
/// Not a general JSON parser — it reads exactly the fields this
/// module's writer emits (`t0`/`t1` carry the authoritative times).
pub fn parse_chrome_trace(json: &str) -> Result<TraceLog> {
    let head_end = json
        .find("\"traceEvents\"")
        .ok_or_else(|| anyhow::anyhow!("{}:{}: no traceEvents array", file!(), line!()))?;
    let head = &json[..head_end];
    let source = str_field(head, "source")
        .ok_or_else(|| anyhow::anyhow!("{}:{}: missing source", file!(), line!()))?;
    let unit = str_field(head, "unit")
        .and_then(TimeUnit::from_name)
        .ok_or_else(|| anyhow::anyhow!("{}:{}: missing/unknown unit", file!(), line!()))?;
    let workers = num_field(head, "workers")
        .ok_or_else(|| anyhow::anyhow!("{}:{}: missing workers", file!(), line!()))?
        as usize;
    let mut log = TraceLog::new(source, unit, workers);
    for frag in json[head_end..].split("{\"name\"").skip(1) {
        if !frag.contains("\"ph\":\"X\"") {
            continue; // metadata event
        }
        let kind = str_field(frag, "cat")
            .and_then(SpanKind::from_name)
            .ok_or_else(|| anyhow::anyhow!("{}:{}: event without known cat", file!(), line!()))?;
        let get = |key: &str| -> Result<f64> {
            num_field(frag, key)
                .ok_or_else(|| anyhow::anyhow!("{}:{}: event missing field {key}", file!(), line!()))
        };
        log.push(Span {
            kind,
            task: get("task")? as u32,
            worker: get("tid")? as u32,
            team: get("team")?,
            flops: get("flops")?,
            start: get("t0")?,
            end: get("t1")?,
        });
    }
    log.validate()?;
    Ok(log)
}

/// Render a text Gantt/timeline summary: one row per worker track with
/// per-kind busy time and utilization, in display units (ms for wall
/// traces, model units otherwise).
pub fn timeline_summary(log: &TraceLog) -> String {
    let (scale, unit_name) = match log.unit {
        TimeUnit::WallNs => (1e-6, "ms"),
        TimeUnit::Model => (1.0, "model"),
    };
    let makespan = log.makespan();
    let mut out = format!(
        "trace {}: {} spans, {} tracks, makespan {:.3} {}\n",
        log.source,
        log.spans.len(),
        log.workers,
        makespan * scale,
        unit_name
    );
    let mut t = Table::new(&["worker", "spans", "factor", "assemble", "stall", "retry", "transfer", "busy%"]);
    for w in 0..log.workers {
        let of = |kind: SpanKind| -> f64 {
            log.spans_of(kind)
                .filter(|s| s.worker as usize == w)
                .map(|s| s.duration())
                .sum()
        };
        let (fac, asm, stall, retry, xfer) = (
            of(SpanKind::Factor),
            of(SpanKind::Assemble),
            of(SpanKind::Stall),
            of(SpanKind::Retry),
            of(SpanKind::Transfer),
        );
        let busy = if makespan > 0.0 { (fac + asm) / makespan * 100.0 } else { 0.0 };
        let n = log.spans.iter().filter(|s| s.worker as usize == w).count();
        t.row(&[
            format!("{w}"),
            format!("{n}"),
            format!("{:.3}", fac * scale),
            format!("{:.3}", asm * scale),
            format!("{:.3}", stall * scale),
            format!("{:.3}", retry * scale),
            format!("{:.3}", xfer * scale),
            format!("{busy:.1}"),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> TraceLog {
        let mut log = TraceLog::new("test", TimeUnit::WallNs, 2);
        log.push(Span {
            kind: SpanKind::Assemble,
            task: 0,
            worker: 0,
            team: 1.0,
            flops: 0.0,
            start: 10.0,
            end: 25.5,
        });
        log.push(Span {
            kind: SpanKind::Factor,
            task: 0,
            worker: 0,
            team: 3.0,
            flops: 1.25e6,
            start: 25.5,
            end: 1250.0,
        });
        log.push(Span {
            kind: SpanKind::Stall,
            task: 1,
            worker: 1,
            team: 0.0,
            flops: 0.0,
            start: 0.0,
            end: 700.0,
        });
        log.sort();
        log
    }

    #[test]
    fn chrome_round_trip_is_bitwise() {
        let log = sample_log();
        let json = chrome_trace(&log).unwrap();
        let back = parse_chrome_trace(&json).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn chrome_trace_has_one_track_per_worker() {
        let json = chrome_trace(&sample_log()).unwrap();
        assert!(json.contains("\"name\":\"worker 0\""));
        assert!(json.contains("\"name\":\"worker 1\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"cat\":\"factor\""));
    }

    #[test]
    fn chrome_trace_rejects_invalid_log() {
        let mut log = sample_log();
        log.spans[0].start = f64::NAN;
        assert!(chrome_trace(&log).is_err());
    }

    #[test]
    fn model_unit_round_trips_too() {
        let mut log = TraceLog::new("sim-des", TimeUnit::Model, 1);
        log.push(Span {
            kind: SpanKind::Factor,
            task: 7,
            worker: 0,
            team: 2.375,
            flops: 64.0,
            start: 0.1,
            end: 0.30000000000000004, // a value µs conversion would mangle
        });
        let back = parse_chrome_trace(&chrome_trace(&log).unwrap()).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn summary_renders_all_tracks() {
        let text = timeline_summary(&sample_log());
        assert!(text.contains("2 tracks"));
        assert!(text.contains("busy%"));
        // two worker rows
        assert!(text.lines().count() >= 4);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_chrome_trace("not json at all").is_err());
        assert!(parse_chrome_trace("{\"traceEvents\":[]}").is_err()); // no header
    }
}
