//! Link model for a distributed platform (DESIGN.md §15).
//!
//! [`NetModel`] prices the link between every ordered node pair with a
//! latency (seconds per message) and a bandwidth (words per second).
//! A transfer of `w` words from `a` to `b` costs `lat(a,b) + w/rate`,
//! where the rate is the link bandwidth divided fairly among the
//! transfers concurrently in their word phase on that directed link
//! ([`crate::net::sim`]). The model is symmetric only if constructed
//! so — [`NetModel::uniform`] is; hand-built matrices need not be.

use anyhow::{ensure, Result};

/// Per-node-pair latency and bandwidth (row-major `n × n`; the
/// diagonal is ignored — intra-node edges never transfer).
#[derive(Debug, Clone, PartialEq)]
pub struct NetModel {
    pub n_nodes: usize,
    /// `latency[a * n_nodes + b]`: seconds before the first word of an
    /// `a → b` transfer moves.
    pub latency: Vec<f64>,
    /// `bandwidth[a * n_nodes + b]`: words per second on the `a → b`
    /// link (`f64::INFINITY` models a free link).
    pub bandwidth: Vec<f64>,
}

impl NetModel {
    /// Uniform symmetric network: every inter-node link has latency
    /// `lat` and bandwidth `bw`.
    pub fn uniform(n_nodes: usize, lat: f64, bw: f64) -> NetModel {
        NetModel {
            n_nodes,
            latency: vec![lat; n_nodes * n_nodes],
            bandwidth: vec![bw; n_nodes * n_nodes],
        }
    }

    /// The free network: zero latency, infinite bandwidth. Replaying
    /// it reproduces the network-blind distributed DES bit for bit
    /// (the engine delegates outright).
    pub fn free(n_nodes: usize) -> NetModel {
        NetModel::uniform(n_nodes, 0.0, f64::INFINITY)
    }

    /// Latency of the `a → b` link.
    pub fn lat(&self, a: usize, b: usize) -> f64 {
        self.latency[a * self.n_nodes + b]
    }

    /// Bandwidth of the `a → b` link.
    pub fn bw(&self, a: usize, b: usize) -> f64 {
        self.bandwidth[a * self.n_nodes + b]
    }

    /// True when every link is free (zero latency, infinite
    /// bandwidth): transfers cost nothing and the priced engine
    /// degenerates to the network-blind one.
    pub fn is_free(&self) -> bool {
        self.latency.iter().all(|&l| l == 0.0)
            && self.bandwidth.iter().all(|&b| b == f64::INFINITY)
    }

    /// Check shape and ranges: latencies finite and ≥ 0, bandwidths
    /// > 0 (infinite allowed — a free link).
    pub fn validate(&self) -> Result<()> {
        let n = self.n_nodes;
        ensure!(n > 0, "network needs at least one node");
        ensure!(
            self.latency.len() == n * n && self.bandwidth.len() == n * n,
            "link matrices must be {n}x{n} (got {} latencies, {} bandwidths)",
            self.latency.len(),
            self.bandwidth.len()
        );
        for (i, &l) in self.latency.iter().enumerate() {
            ensure!(l.is_finite() && l >= 0.0, "latency[{i}] = {l} (finite, >= 0 required)");
        }
        for (i, &b) in self.bandwidth.iter().enumerate() {
            ensure!(b > 0.0 && !b.is_nan(), "bandwidth[{i}] = {b} (> 0 required)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_and_free_validate() {
        let m = NetModel::uniform(3, 0.5, 10.0);
        m.validate().unwrap();
        assert_eq!(m.lat(0, 2), 0.5);
        assert_eq!(m.bw(2, 1), 10.0);
        assert!(!m.is_free());
        let f = NetModel::free(2);
        f.validate().unwrap();
        assert!(f.is_free());
        // zero latency alone is not free
        assert!(!NetModel::uniform(2, 0.0, 8.0).is_free());
    }

    #[test]
    fn validate_rejects_bad_links() {
        let mut m = NetModel::uniform(2, 0.1, 4.0);
        m.latency[1] = -0.5;
        assert!(m.validate().is_err());
        let mut m = NetModel::uniform(2, 0.1, 4.0);
        m.latency[2] = f64::INFINITY;
        assert!(m.validate().is_err());
        let mut m = NetModel::uniform(2, 0.1, 4.0);
        m.bandwidth[3] = 0.0;
        assert!(m.validate().is_err());
        let mut m = NetModel::uniform(2, 0.1, 4.0);
        m.bandwidth[0] = f64::NAN;
        assert!(m.validate().is_err());
        let mut m = NetModel::uniform(2, 0.1, 4.0);
        m.latency.pop();
        assert!(m.validate().is_err());
        assert!(NetModel::uniform(0, 0.0, 1.0).validate().is_err());
    }
}
