//! Network-priced distributed DES with link faults (DESIGN.md §15).
//!
//! [`simulate_networked`] extends the cross-node replay of
//! [`crate::sim::des::simulate_distributed`] with a priced network:
//! every cross-node tree edge ships the child's contribution block
//! (`weights.cb[child]` words, [`crate::mem::MemWeights`]) over the
//! [`NetModel`] link between the owning nodes. A transfer starts the
//! instant the child completes, pays the link latency, then streams its
//! words at the link bandwidth divided fairly among the transfers
//! concurrently in their word phase on that directed link. The parent
//! becomes ready only once every child has *delivered* — completed
//! locally, or arrived over the wire.
//!
//! [`replay_link_faults`] drives the same engine through the link
//! events of a [`FaultTrace`] ([`FaultKind::LinkDegrade`] /
//! [`FaultKind::LinkDown`]): windows during which a link runs at
//! `factor ×` its nominal bandwidth (zero for a severed link).
//! Robustness is protocol, not magic:
//!
//! * every transfer is armed with a deadline of `timeout_factor ×` its
//!   nominal fault-free duration; a transfer that misses it aborts and
//!   retries after a [`LinearBackoff`] pause (a retransmit resends the
//!   *whole* block — partial words are wasted bytes);
//! * when the retry budget runs dry the run makes one global recovery
//!   decision: [`NetRecovery::WaitOnly`] disarms the timeouts and rides
//!   the degraded link out; [`NetRecovery::Best`] additionally tries
//!   re-mapping the blocked subtree onto the receiving node (redoing
//!   its compute, but crossing the dead link never again) and keeps
//!   whichever candidate finishes first. Because the wait candidate
//!   *is* the `WaitOnly` continuation, `Best` never loses to
//!   waiting-it-out — by construction, not by tuning.
//!
//! Two delegation guarantees pin the engine to its ancestors: on a
//! [`NetModel::free`] network [`simulate_networked`] returns the
//! network-blind distributed DES bit for bit, and
//! [`replay_link_faults`] on an empty trace returns
//! [`simulate_networked`] verbatim. The priced event loop itself
//! reproduces the free-network completions bitwise too (tested with a
//! far-future fault forcing the real engine).

use anyhow::{bail, ensure, Result};

use crate::mem::MemWeights;
use crate::model::{FaultKind, FaultTrace, Platform, TaskTree};
use crate::net::NetModel;
use crate::sched::SchedWorkspace;
use crate::sim::des::{simulate_distributed_with_workspace, speedup, Policy};
use crate::sim::event::EventHeap;
use crate::util::retry::LinearBackoff;

/// What to do when a transfer exhausts its retry budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetRecovery {
    /// Evaluate both candidates — ride the degraded link out vs re-map
    /// the blocked subtree to the receiving node — and keep the better
    /// (ties prefer the re-map). Never worse than [`Self::WaitOnly`].
    Best,
    /// Disarm the timeouts and wait for the link to recover (the
    /// baseline `Best` is measured against).
    WaitOnly,
}

/// Transfer-robustness knobs of the networked DES.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSimConfig {
    /// A transfer times out after `timeout_factor ×` its nominal
    /// fault-free duration (`lat + words/bw`); `f64::INFINITY` never
    /// times out.
    pub timeout_factor: f64,
    /// Pause schedule between retransmit attempts (`max_retries` is
    /// the retry budget before the recovery decision fires).
    pub backoff: LinearBackoff,
    /// Recovery policy once the budget is exhausted.
    pub recovery: NetRecovery,
}

impl Default for NetSimConfig {
    fn default() -> Self {
        NetSimConfig {
            timeout_factor: 4.0,
            backoff: LinearBackoff::new(0.0, 2),
            recovery: NetRecovery::Best,
        }
    }
}

/// Result of a networked distributed simulation.
#[derive(Debug, Clone)]
pub struct NetDesResult {
    /// Global makespan (last completion over all nodes).
    pub makespan: f64,
    /// Completion time per task (re-run tasks report the final one).
    pub completion: Vec<f64>,
    /// Task completions processed (> n when a re-map re-ran tasks).
    pub events: usize,
    /// Completion time of the last task on each node.
    pub node_finish: Vec<f64>,
    /// Tree edges cut by the *original* mapping.
    pub cross_edges: usize,
    /// Waiting attributable to remote **compute**: per parent,
    /// `max(0, latest child completion − latest local-child
    /// completion)`, summed (the network-blind engine's stall).
    pub cross_stall: f64,
    /// Waiting attributable to the **network** on top of that: per
    /// parent, `max(0, latest child delivery − latest child
    /// completion)`, summed. Zero on a free network.
    pub transfer_stall: f64,
    /// Total words put on the wire, including the partial words of
    /// timed-out or canceled attempts (waste).
    pub bytes_moved: f64,
    /// Transfer attempts beyond each transfer's first.
    pub retransmits: usize,
    /// Subtree re-mappings performed by the recovery path.
    pub remaps: usize,
}

/// Result of a link-fault replay: the disturbed run plus its
/// fault-free reference.
#[derive(Debug, Clone)]
pub struct NetReplay {
    /// The run under the link-fault trace.
    pub sim: NetDesResult,
    /// Makespan of the same configuration with no link faults.
    pub fault_free_makespan: f64,
    /// Link events in the trace.
    pub link_events: usize,
}

impl NetReplay {
    /// Absolute makespan overhead of the faults (seconds).
    pub fn overhead(&self) -> f64 {
        self.sim.makespan - self.fault_free_makespan
    }
}

/// A bandwidth-factor breakpoint on link `a — b` (applied to both
/// directions; overlapping windows resolve last-writer-wins).
#[derive(Debug, Clone, Copy)]
struct Bp {
    time: f64,
    a: usize,
    b: usize,
    factor: f64,
}

/// Transfer phases: latency, then words, with waiting periods between
/// retransmit attempts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Paying the link latency until `phase_at`.
    Latency,
    /// Streaming words at the fair-shared link rate.
    Words,
    /// Backing off until `phase_at`, then restarting from scratch.
    Waiting,
}

/// One in-flight (or finished) cross-node contribution-block transfer.
#[derive(Debug, Clone, Copy)]
struct Transfer {
    child: u32,
    parent: u32,
    from: usize,
    to: usize,
    words: f64,
    remaining: f64,
    phase: Phase,
    /// Latency: when the latency phase ends. Waiting: when to resume.
    phase_at: f64,
    deadline: f64,
    attempt: usize,
    /// Delivered — or canceled by a re-map.
    done: bool,
}

/// Static inputs of one engine run.
struct Ctx<'a> {
    tree: &'a TaskTree,
    alpha: f64,
    policy: Policy,
    cores: Vec<f64>,
    cb: &'a [f64],
    net: &'a NetModel,
    cfg: &'a NetSimConfig,
    /// Link-fault breakpoints, time-sorted.
    bps: Vec<Bp>,
}

/// Full mutable engine state — cloneable so the recovery decision can
/// run both candidate futures to completion and adopt the winner.
#[derive(Clone)]
struct NetState {
    node_of: Vec<usize>,
    share: Vec<f64>,
    remaining: Vec<f64>,
    completed: Vec<bool>,
    completion: Vec<f64>,
    /// Children not yet *delivered* to this parent.
    unfinished: Vec<usize>,
    /// Latest child delivery (completion if local, arrival if cross).
    ready_all: Vec<f64>,
    /// Latest child completion on any node.
    ready_comp: Vec<f64>,
    /// Latest same-node child completion.
    ready_local: Vec<f64>,
    /// Delivery time per task (NaN until delivered to its parent).
    arrived: Vec<f64>,
    run_since: Vec<f64>,
    in_heap: Vec<bool>,
    heap: EventHeap<u32>,
    transfers: Vec<Transfer>,
    /// Current bandwidth factor per directed link (1.0 nominal).
    degrade: Vec<f64>,
    bp_idx: usize,
    /// Set by recovery: no deadline is ever armed again, so the
    /// recovery decision fires at most once per run.
    disarmed: bool,
    t_now: f64,
    events: usize,
    bytes_moved: f64,
    transfer_stall: f64,
    cross_stall: f64,
    retransmits: usize,
    remaps: usize,
    node_finish: Vec<f64>,
}

fn dur_of(share: f64, remaining: f64, alpha: f64) -> f64 {
    if remaining <= 0.0 {
        0.0
    } else {
        remaining / speedup(share, alpha)
    }
}

/// Deadline for a transfer attempt starting at `now`: `timeout_factor
/// ×` the nominal (undegraded, unshared) duration. Free links have
/// zero nominal cost and are never armed, nor is anything after the
/// recovery decision disarmed the run.
fn arm_deadline(ctx: &Ctx, disarmed: bool, from: usize, to: usize, words: f64, now: f64) -> f64 {
    if disarmed || !ctx.cfg.timeout_factor.is_finite() {
        return f64::INFINITY;
    }
    let nominal = ctx.net.lat(from, to) + words / ctx.net.bw(from, to);
    if nominal <= 0.0 {
        f64::INFINITY
    } else {
        now + ctx.cfg.timeout_factor * nominal
    }
}

/// Per-node static shares over the remaining (incomplete) forest —
/// the exact float path of the network-blind distributed engine
/// ([`simulate_distributed_with_workspace`]), which is also how
/// [`crate::sim::faults`] re-solves after a disturbance.
fn solve_shares_net(ctx: &Ctx, st: &mut NetState, ws: &mut SchedWorkspace, tree2: &mut TaskTree) {
    let n = tree2.len();
    for v in 0..n {
        tree2.nodes[v].len = st.remaining[v];
    }
    for s in st.share.iter_mut() {
        *s = 0.0;
    }
    let mut member = vec![false; n];
    for (k, &p_k) in ctx.cores.iter().enumerate() {
        for (t, m) in member.iter_mut().enumerate() {
            *m = !st.completed[t] && st.node_of[t] == k;
        }
        match ctx.policy {
            Policy::Pm => {
                if let Some(r) = ws.induced_task_ratios(tree2, &member, ctx.alpha, n) {
                    for t in 0..n {
                        if member[t] {
                            st.share[t] = r[t] * p_k;
                        }
                    }
                }
            }
            Policy::Proportional => {
                if let Some(g) = crate::model::SpGraph::from_induced(tree2, &member) {
                    let shares = crate::sched::proportional::proportional_shares(&g, p_k);
                    for &v in g.topo() {
                        if let crate::model::SpNode::Leaf { task: Some(t), .. } = g.nodes[v as usize]
                        {
                            // ratio first, share second — the exact float
                            // path of the distributed engine
                            let ratio = shares[v as usize] / p_k;
                            st.share[t as usize] = ratio * p_k;
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
}

/// A parent's last child just delivered: account the stalls and start
/// it at its delivery-ready time.
fn parent_ready(ctx: &Ctx, st: &mut NetState, pi: usize) {
    st.transfer_stall += (st.ready_all[pi] - st.ready_comp[pi]).max(0.0);
    st.cross_stall += (st.ready_comp[pi] - st.ready_local[pi]).max(0.0);
    st.run_since[pi] = st.ready_all[pi];
    let d = dur_of(st.share[pi], st.remaining[pi], ctx.alpha);
    st.heap.push(st.ready_all[pi] + d, pi as u32);
    st.in_heap[pi] = true;
}

/// Start shipping `child`'s contribution block to its parent's node.
fn start_transfer(ctx: &Ctx, st: &mut NetState, child: u32, parent: u32) {
    let from = st.node_of[child as usize];
    let to = st.node_of[parent as usize];
    let words = ctx.cb[child as usize];
    let t = st.t_now;
    let deadline = arm_deadline(ctx, st.disarmed, from, to, words, t);
    st.transfers.push(Transfer {
        child,
        parent,
        from,
        to,
        words,
        remaining: words,
        phase: Phase::Latency,
        phase_at: t + ctx.net.lat(from, to),
        deadline,
        attempt: 0,
        done: false,
    });
}

/// Transfer `ti` arrived: deliver the child to its parent.
fn deliver(ctx: &Ctx, st: &mut NetState, ti: usize) {
    let tr = st.transfers[ti];
    st.transfers[ti].done = true;
    st.bytes_moved += tr.words;
    let (ci, pi) = (tr.child as usize, tr.parent as usize);
    st.arrived[ci] = st.t_now;
    st.ready_all[pi] = st.ready_all[pi].max(st.t_now);
    st.unfinished[pi] -= 1;
    if st.unfinished[pi] == 0 {
        parent_ready(ctx, st, pi);
    }
}

/// Task `vi` completed at `t`: record it and either deliver locally or
/// put its contribution block on the wire.
fn on_complete(ctx: &Ctx, st: &mut NetState, vi: usize, t: f64) {
    st.events += 1;
    st.completed[vi] = true;
    st.completion[vi] = t;
    st.remaining[vi] = 0.0;
    let k = st.node_of[vi];
    st.node_finish[k] = st.node_finish[k].max(t);
    if let Some(p) = ctx.tree.nodes[vi].parent {
        let pi = p as usize;
        st.ready_comp[pi] = st.ready_comp[pi].max(t);
        if st.node_of[pi] == k {
            st.ready_local[pi] = st.ready_local[pi].max(t);
            st.arrived[vi] = t;
            st.ready_all[pi] = st.ready_all[pi].max(t);
            st.unfinished[pi] -= 1;
            if st.unfinished[pi] == 0 {
                parent_ready(ctx, st, pi);
            }
        } else {
            start_transfer(ctx, st, vi as u32, p);
        }
    }
}

/// Wait-it-out recovery: disarm every deadline and restart the
/// exhausted transfers. Deliberately touches nothing else — the
/// continuation is exactly what [`NetRecovery::WaitOnly`] would have
/// done, which is what makes `Best ≤ WaitOnly` exact.
fn prep_wait(ctx: &Ctx, st: &mut NetState, exhausted: &[usize]) {
    st.disarmed = true;
    let t_now = st.t_now;
    for tr in st.transfers.iter_mut() {
        tr.deadline = f64::INFINITY;
    }
    for &i in exhausted {
        let tr = &mut st.transfers[i];
        tr.phase = Phase::Latency;
        tr.phase_at = t_now + ctx.net.lat(tr.from, tr.to);
        tr.remaining = tr.words;
    }
    st.retransmits += exhausted.len();
}

/// Re-map recovery: move the subtree blocked behind the first
/// exhausted transfer onto the *receiving* node (its compute is redone
/// there, but the dead link is never crossed again), re-solve the
/// static shares over the remaining forest, and rebuild the event
/// structures from the delivery state.
fn prep_remap(ctx: &Ctx, st: &mut NetState, exhausted: &[usize], ws: &mut SchedWorkspace) {
    let n = ctx.tree.len();
    let t_now = st.t_now;
    st.disarmed = true;
    st.remaps += 1;
    // Charge partial progress to every running task: shares are about
    // to be re-solved, so the heap's completion times go stale.
    for v in 0..n {
        if st.in_heap[v] {
            let done = (t_now - st.run_since[v]).max(0.0) * speedup(st.share[v], ctx.alpha);
            st.remaining[v] = (st.remaining[v] - done).max(0.0);
            st.in_heap[v] = false;
        }
    }
    st.heap.clear();
    // The blocked subtree restarts from scratch on the receiver.
    let blocked = st.transfers[exhausted[0]];
    let dest = blocked.to;
    let sub = ctx.tree.subtree_tasks(blocked.child);
    let mut in_sub = vec![false; n];
    for &u in &sub {
        in_sub[u as usize] = true;
    }
    for &u in &sub {
        let ui = u as usize;
        st.node_of[ui] = dest;
        st.remaining[ui] = ctx.tree.nodes[ui].len;
        st.completed[ui] = false;
        st.completion[ui] = 0.0;
        st.arrived[ui] = f64::NAN;
    }
    // Cancel the transfers out of the re-run subtree (the blocked one
    // included — the subtree is closed under descendants, so only the
    // blocked edge leaves it). In-flight words are waste.
    let mut waste = 0.0;
    for tr in st.transfers.iter_mut() {
        if !tr.done && in_sub[tr.child as usize] {
            if tr.phase == Phase::Words {
                waste += tr.words - tr.remaining;
            }
            tr.done = true;
        }
    }
    st.bytes_moved += waste;
    // Other exhausted transfers (a multi-link failure) restart with
    // the timeouts disarmed.
    let mut restarted = 0usize;
    for &i in exhausted {
        let tr = &mut st.transfers[i];
        if tr.done {
            continue;
        }
        tr.phase = Phase::Latency;
        tr.phase_at = t_now + ctx.net.lat(tr.from, tr.to);
        tr.remaining = tr.words;
        restarted += 1;
    }
    st.retransmits += restarted;
    for tr in st.transfers.iter_mut() {
        tr.deadline = f64::INFINITY;
    }
    // Rebuild the dependency counters and ready times from the
    // delivery state (`arrived`), not from scratch: deliveries outside
    // the subtree stay delivered, and no stall is re-counted.
    for v in 0..n {
        st.unfinished[v] = 0;
        st.ready_all[v] = 0.0;
        st.ready_comp[v] = 0.0;
        st.ready_local[v] = 0.0;
    }
    for v in 0..n {
        if let Some(p) = ctx.tree.nodes[v].parent {
            let pi = p as usize;
            if st.arrived[v].is_nan() {
                st.unfinished[pi] += 1;
            } else {
                st.ready_all[pi] = st.ready_all[pi].max(st.arrived[v]);
            }
            if st.completed[v] {
                st.ready_comp[pi] = st.ready_comp[pi].max(st.completion[v]);
                if st.node_of[v] == st.node_of[pi] {
                    st.ready_local[pi] = st.ready_local[pi].max(st.completion[v]);
                }
            }
        }
    }
    let mut tree2 = ctx.tree.clone();
    solve_shares_net(ctx, st, ws, &mut tree2);
    for v in 0..n as u32 {
        let vi = v as usize;
        if !st.completed[vi] && st.unfinished[vi] == 0 {
            st.run_since[vi] = t_now.max(st.ready_all[vi]);
            let d = dur_of(st.share[vi], st.remaining[vi], ctx.alpha);
            st.heap.push(st.run_since[vi] + d, v);
            st.in_heap[vi] = true;
        }
    }
}

/// The priced event loop: advance to the next event (compute
/// completion, latency end, word-phase finish, backoff resume, fault
/// breakpoint, or deadline), charge the interval to the in-flight word
/// phases, and process everything due. Equal-time order — latency
/// ends, arrivals, compute completions (inclusive, cascading),
/// resumes, breakpoints, timeouts — means a transfer finishing exactly
/// at its deadline succeeds and completions precede same-time faults
/// (the [`crate::sim::faults`] convention).
fn drive(ctx: &Ctx, st: &mut NetState, ws: &mut SchedWorkspace) -> Result<()> {
    let nn = ctx.net.n_nodes;
    let mut count = vec![0usize; nn * nn];
    loop {
        if st.completed.iter().all(|&c| c) {
            return Ok(());
        }
        // Fair sharing: transfers concurrently in their word phase on
        // a directed link split its (possibly degraded) bandwidth.
        for c in count.iter_mut() {
            *c = 0;
        }
        for tr in &st.transfers {
            if !tr.done && tr.phase == Phase::Words && tr.remaining > 0.0 {
                count[tr.from * nn + tr.to] += 1;
            }
        }
        let mut t_next = f64::INFINITY;
        if let Some(t) = st.heap.peek_time() {
            t_next = t_next.min(t);
        }
        if st.bp_idx < ctx.bps.len() {
            t_next = t_next.min(ctx.bps[st.bp_idx].time);
        }
        let mut rate = vec![0f64; st.transfers.len()];
        let mut finish = vec![f64::INFINITY; st.transfers.len()];
        for (i, tr) in st.transfers.iter().enumerate() {
            if tr.done {
                continue;
            }
            match tr.phase {
                Phase::Latency | Phase::Waiting => t_next = t_next.min(tr.phase_at),
                Phase::Words => {
                    let f = st.degrade[tr.from * nn + tr.to];
                    // explicit zero: factor 0 × infinite bandwidth
                    // must sever the link, not produce NaN
                    let eff = if f == 0.0 { 0.0 } else { f * ctx.net.bw(tr.from, tr.to) };
                    let r = if eff == 0.0 { 0.0 } else { eff / count[tr.from * nn + tr.to] as f64 };
                    rate[i] = r;
                    if tr.remaining <= 0.0 || r.is_infinite() {
                        finish[i] = st.t_now;
                    } else if r > 0.0 {
                        finish[i] = st.t_now + tr.remaining / r;
                    }
                    t_next = t_next.min(finish[i]);
                }
            }
            if tr.deadline.is_finite() {
                t_next = t_next.min(tr.deadline);
            }
        }
        ensure!(
            t_next.is_finite(),
            "networked DES stuck at t={} with incomplete tasks (no future event)",
            st.t_now
        );
        let t_next = t_next.max(st.t_now);
        let dt = t_next - st.t_now;
        for (i, tr) in st.transfers.iter_mut().enumerate() {
            if tr.done || tr.phase != Phase::Words {
                continue;
            }
            // guard both zero-rate (0 × ∞ interval) and infinite-rate
            // (∞ × 0 interval) NaN products
            if dt > 0.0 && rate[i].is_finite() && rate[i] > 0.0 {
                tr.remaining = (tr.remaining - dt * rate[i]).max(0.0);
            }
            if finish[i] <= t_next {
                // the transfer that *defined* t_next lands exactly,
                // float residue notwithstanding
                tr.remaining = 0.0;
            }
        }
        st.t_now = t_next;
        // (1) latency phases ending
        for tr in st.transfers.iter_mut() {
            if !tr.done && tr.phase == Phase::Latency && tr.phase_at <= st.t_now {
                tr.phase = Phase::Words;
            }
        }
        // (2) arrivals
        for i in 0..st.transfers.len() {
            let tr = st.transfers[i];
            if !tr.done && tr.phase == Phase::Words && tr.remaining <= 0.0 {
                deliver(ctx, st, i);
            }
        }
        // (3) compute completions (inclusive: zero-duration parents
        // pushed during the drain cascade within the same instant)
        while let Some(t) = st.heap.peek_time() {
            if t > st.t_now {
                break;
            }
            let (t, v) = st.heap.pop().unwrap();
            let vi = v as usize;
            if st.completed[vi] || !st.in_heap[vi] {
                continue;
            }
            st.in_heap[vi] = false;
            on_complete(ctx, st, vi, t);
        }
        // (4) backoff pauses ending: the retry restarts from scratch
        let disarmed = st.disarmed;
        let t_now = st.t_now;
        for tr in st.transfers.iter_mut() {
            if !tr.done && tr.phase == Phase::Waiting && tr.phase_at <= t_now {
                tr.phase = Phase::Latency;
                tr.phase_at = t_now + ctx.net.lat(tr.from, tr.to);
                tr.remaining = tr.words;
                tr.deadline = arm_deadline(ctx, disarmed, tr.from, tr.to, tr.words, t_now);
            }
        }
        // (5) link-fault breakpoints (both directions)
        while st.bp_idx < ctx.bps.len() && ctx.bps[st.bp_idx].time <= st.t_now {
            let bp = ctx.bps[st.bp_idx];
            st.degrade[bp.a * nn + bp.b] = bp.factor;
            st.degrade[bp.b * nn + bp.a] = bp.factor;
            st.bp_idx += 1;
        }
        // (6) timeouts — after (2), so a transfer landing exactly at
        // its deadline succeeds
        let mut exhausted: Vec<usize> = Vec::new();
        for i in 0..st.transfers.len() {
            let tr = st.transfers[i];
            if tr.done || tr.phase == Phase::Waiting || tr.deadline > st.t_now {
                continue;
            }
            st.bytes_moved += tr.words - tr.remaining; // wasted words
            let tr = &mut st.transfers[i];
            tr.attempt += 1;
            tr.remaining = tr.words;
            tr.deadline = f64::INFINITY;
            match ctx.cfg.backoff.delay(tr.attempt) {
                Some(d) => {
                    tr.phase = Phase::Waiting;
                    tr.phase_at = st.t_now + d;
                    st.retransmits += 1;
                }
                None => exhausted.push(i),
            }
        }
        if !exhausted.is_empty() {
            match ctx.cfg.recovery {
                NetRecovery::WaitOnly => prep_wait(ctx, st, &exhausted),
                NetRecovery::Best => {
                    // One global decision, both futures run to the
                    // end: the wait candidate IS the WaitOnly
                    // continuation, so Best ≤ WaitOnly exactly. Both
                    // candidates disarm, so recursion depth is ≤ 2.
                    let mut w = st.clone();
                    prep_wait(ctx, &mut w, &exhausted);
                    drive(ctx, &mut w, ws)?;
                    let mut r = st.clone();
                    prep_remap(ctx, &mut r, &exhausted, ws);
                    drive(ctx, &mut r, ws)?;
                    let mw = w.completion.iter().fold(0.0f64, |a, &b| a.max(b));
                    let mr = r.completion.iter().fold(0.0f64, |a, &b| a.max(b));
                    *st = if mr <= mw { r } else { w };
                }
            }
        }
    }
}

fn validate_inputs(
    tree: &TaskTree,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
) -> Result<()> {
    net.validate()?;
    ensure!(
        net.n_nodes == platform.num_nodes(),
        "network covers {} nodes, platform has {}",
        net.n_nodes,
        platform.num_nodes()
    );
    weights.validate(tree)?;
    ensure!(node_of.len() == tree.len(), "node_of must cover every task");
    for &k in node_of {
        ensure!(k < net.n_nodes, "task mapped to node {k}, platform has {}", net.n_nodes);
    }
    if !matches!(policy, Policy::Pm | Policy::Proportional) {
        bail!("networked DES replays static-share policies (Pm, Proportional), got {policy:?}");
    }
    ensure!(
        cfg.timeout_factor > 0.0,
        "timeout factor must be positive, got {}",
        cfg.timeout_factor
    );
    Ok(())
}

fn count_cross_edges(tree: &TaskTree, node_of: &[usize]) -> usize {
    tree.nodes
        .iter()
        .enumerate()
        .filter(|(t, node)| {
            node.parent
                .is_some_and(|p| node_of[*t] != node_of[p as usize])
        })
        .count()
}

fn run_engine(ctx: &Ctx, node_of: &[usize], ws: &mut SchedWorkspace) -> Result<NetDesResult> {
    run_engine_state(ctx, node_of, ws).map(|(r, _)| r)
}

/// [`run_engine`] keeping the final [`NetState`] — the span derivation
/// reads per-task delivery/arrival times the public result drops.
fn run_engine_state(
    ctx: &Ctx,
    node_of: &[usize],
    ws: &mut SchedWorkspace,
) -> Result<(NetDesResult, NetState)> {
    let n = ctx.tree.len();
    let nn = ctx.net.n_nodes;
    let mut st = NetState {
        node_of: node_of.to_vec(),
        share: vec![0.0; n],
        remaining: ctx.tree.nodes.iter().map(|t| t.len).collect(),
        completed: vec![false; n],
        completion: vec![0.0; n],
        unfinished: ctx.tree.nodes.iter().map(|t| t.children.len()).collect(),
        ready_all: vec![0.0; n],
        ready_comp: vec![0.0; n],
        ready_local: vec![0.0; n],
        arrived: vec![f64::NAN; n],
        run_since: vec![0.0; n],
        in_heap: vec![false; n],
        heap: EventHeap::with_capacity(n),
        transfers: Vec::new(),
        degrade: vec![1.0; nn * nn],
        bp_idx: 0,
        disarmed: false,
        t_now: 0.0,
        events: 0,
        bytes_moved: 0.0,
        transfer_stall: 0.0,
        cross_stall: 0.0,
        retransmits: 0,
        remaps: 0,
        node_finish: vec![0.0; nn],
    };
    let mut tree2 = ctx.tree.clone();
    solve_shares_net(ctx, &mut st, ws, &mut tree2);
    for v in 0..n as u32 {
        let vi = v as usize;
        if st.unfinished[vi] == 0 {
            let d = dur_of(st.share[vi], st.remaining[vi], ctx.alpha);
            st.heap.push(st.run_since[vi] + d, v);
            st.in_heap[vi] = true;
        }
    }
    drive(ctx, &mut st, ws)?;
    let makespan = st.completion.iter().fold(0.0f64, |a, &b| a.max(b));
    let res = NetDesResult {
        makespan,
        completion: st.completion.clone(),
        events: st.events,
        node_finish: st.node_finish.clone(),
        cross_edges: count_cross_edges(ctx.tree, node_of),
        cross_stall: st.cross_stall,
        transfer_stall: st.transfer_stall,
        bytes_moved: st.bytes_moved,
        retransmits: st.retransmits,
        remaps: st.remaps,
    };
    Ok((res, st))
}

/// Build the model-time span log from a finished engine state: a
/// Factor span `[delivery-ready, completion]` per task on its *final*
/// node (post-remap), a Transfer span per delivered cross edge
/// `[child completion, arrival at the parent's node]` carrying the
/// shipped words in `flops`, and a Stall span per parent that waited
/// on the wire (`[last child computed, last child delivered]`). Shares
/// vary across re-solve segments, so spans carry `team = 0`.
fn trace_from_state(ctx: &Ctx, st: &NetState) -> crate::obs::TraceLog {
    use crate::obs::{Span, SpanKind, TimeUnit, TraceLog};
    let mut log = TraceLog::new("sim-net", TimeUnit::Model, ctx.net.n_nodes);
    for (v, node) in ctx.tree.nodes.iter().enumerate() {
        let worker = st.node_of[v] as u32;
        let end = st.completion[v];
        log.push(Span {
            kind: SpanKind::Factor,
            task: v as u32,
            worker,
            team: 0.0,
            flops: node.len,
            start: st.ready_all[v].min(end),
            end,
        });
        if st.ready_all[v] > st.ready_comp[v] {
            log.push(Span {
                kind: SpanKind::Stall,
                task: v as u32,
                worker,
                team: 0.0,
                flops: 0.0,
                start: st.ready_comp[v],
                end: st.ready_all[v],
            });
        }
        if let Some(p) = node.parent {
            if st.node_of[v] != st.node_of[p as usize] && st.arrived[v].is_finite() {
                log.push(Span {
                    kind: SpanKind::Transfer,
                    task: v as u32,
                    worker: st.node_of[p as usize] as u32,
                    team: 0.0,
                    flops: ctx.cb[v],
                    start: st.completion[v].min(st.arrived[v]),
                    end: st.arrived[v],
                });
            }
        }
    }
    log.sort();
    log
}

/// [`simulate_networked`] with span emission: the same run plus a
/// model-time [`crate::obs::TraceLog`] with one track per network
/// node, Transfer spans for every delivered cross edge, and Stall
/// spans where the wire gated a parent. On a free network this
/// delegates to the network-blind engine (bit-identical result) and
/// derives spans from its completions — transfers are instantaneous
/// there, so none are emitted.
#[allow(clippy::too_many_arguments)]
pub fn simulate_networked_traced(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
) -> Result<(NetDesResult, crate::obs::TraceLog)> {
    let mut ws = SchedWorkspace::new();
    validate_inputs(tree, platform, node_of, policy, weights, net, cfg)?;
    if net.is_free() {
        let res = delegate_free(tree, alpha, platform, node_of, policy, weights, &mut ws);
        let log = crate::obs::from_completions(
            "sim-net",
            tree,
            &res.completion,
            None,
            None,
            Some(node_of),
        );
        return Ok((res, log));
    }
    let ctx = Ctx {
        tree,
        alpha,
        policy,
        cores: (0..platform.num_nodes()).map(|k| platform.node_cores(k)).collect(),
        cb: &weights.cb,
        net,
        cfg,
        bps: Vec::new(),
    };
    let (res, st) = run_engine_state(&ctx, node_of, &mut ws)?;
    let log = trace_from_state(&ctx, &st);
    Ok((res, log))
}

/// Delegate to the network-blind distributed DES (free network): same
/// result bit for bit, with the transfer volume priced after the fact.
fn delegate_free(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    ws: &mut SchedWorkspace,
) -> NetDesResult {
    let d = simulate_distributed_with_workspace(tree, alpha, platform, node_of, policy, ws);
    let mut bytes = 0.0;
    for (t, node) in tree.nodes.iter().enumerate() {
        if let Some(p) = node.parent {
            if node_of[t] != node_of[p as usize] {
                bytes += weights.cb[t];
            }
        }
    }
    NetDesResult {
        makespan: d.makespan,
        completion: d.completion,
        events: d.events,
        node_finish: d.node_finish,
        cross_edges: d.cross_edges,
        cross_stall: d.cross_stall,
        transfer_stall: 0.0,
        bytes_moved: bytes,
        retransmits: 0,
        remaps: 0,
    }
}

/// Replay a distributed mapping through the priced network: cross-node
/// edges ship `weights.cb[child]` words over `net` with latency, fair
/// bandwidth sharing, and the timeout/retransmit protocol of `cfg`.
///
/// On a [`NetModel::free`] network this returns
/// [`crate::sim::des::simulate_distributed`] bit for bit (it
/// delegates). Errors on malformed inputs or a non-static-share
/// policy.
#[allow(clippy::too_many_arguments)]
pub fn simulate_networked(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
) -> Result<NetDesResult> {
    let mut ws = SchedWorkspace::new();
    simulate_networked_with_workspace(tree, alpha, platform, node_of, policy, weights, net, cfg, &mut ws)
}

/// [`simulate_networked`] with a caller-owned workspace (the
/// `distribute --net` candidate sweep reuses solver buffers).
#[allow(clippy::too_many_arguments)]
pub fn simulate_networked_with_workspace(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
    ws: &mut SchedWorkspace,
) -> Result<NetDesResult> {
    validate_inputs(tree, platform, node_of, policy, weights, net, cfg)?;
    if net.is_free() {
        return Ok(delegate_free(tree, alpha, platform, node_of, policy, weights, ws));
    }
    let ctx = Ctx {
        tree,
        alpha,
        policy,
        cores: (0..platform.num_nodes()).map(|k| platform.node_cores(k)).collect(),
        cb: &weights.cb,
        net,
        cfg,
        bps: Vec::new(),
    };
    run_engine(&ctx, node_of, ws)
}

/// Drive [`simulate_networked`] through the link events of `trace`
/// ([`FaultKind::LinkDegrade`] severs partially, [`FaultKind::LinkDown`]
/// fully, both for a bounded window, both directions). Also runs the
/// fault-free reference for the overhead report.
///
/// An empty trace returns the fault-free run verbatim. Errors if the
/// trace carries any non-link event (replay those with
/// [`crate::sim::faults::replay_faults_distributed`]).
#[allow(clippy::too_many_arguments)]
pub fn replay_link_faults(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    weights: &MemWeights,
    net: &NetModel,
    cfg: &NetSimConfig,
    trace: &FaultTrace,
) -> Result<NetReplay> {
    for (i, e) in trace.events.iter().enumerate() {
        ensure!(
            e.kind.is_link(),
            "event {i} ({}) is not a link fault; replay node disturbances with sim::faults",
            e.kind.name()
        );
    }
    trace.validate(platform.num_nodes())?;
    let mut ws = SchedWorkspace::new();
    let fault_free =
        simulate_networked_with_workspace(tree, alpha, platform, node_of, policy, weights, net, cfg, &mut ws)?;
    if trace.is_empty() {
        let fault_free_makespan = fault_free.makespan;
        return Ok(NetReplay { sim: fault_free, fault_free_makespan, link_events: 0 });
    }
    // A non-empty trace always runs the priced engine, free network or
    // not — a severed free link is not free.
    let mut bps = Vec::with_capacity(trace.len() * 2);
    for e in &trace.events {
        match e.kind {
            FaultKind::LinkDegrade { a, b, factor, duration } => {
                bps.push(Bp { time: e.time, a, b, factor });
                bps.push(Bp { time: e.time + duration, a, b, factor: 1.0 });
            }
            FaultKind::LinkDown { a, b, duration } => {
                bps.push(Bp { time: e.time, a, b, factor: 0.0 });
                bps.push(Bp { time: e.time + duration, a, b, factor: 1.0 });
            }
            _ => unreachable!("non-link events rejected above"),
        }
    }
    bps.sort_by(|x, y| x.time.total_cmp(&y.time));
    let ctx = Ctx {
        tree,
        alpha,
        policy,
        cores: (0..platform.num_nodes()).map(|k| platform.node_cores(k)).collect(),
        cb: &weights.cb,
        net,
        cfg,
        bps,
    };
    let sim = run_engine(&ctx, node_of, &mut ws)?;
    Ok(NetReplay {
        sim,
        fault_free_makespan: fault_free.makespan,
        link_events: trace.link_events(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FaultEvent;
    use crate::sim::des::simulate_distributed;
    use crate::util::approx_eq;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::random_link_fault_trace;

    fn star() -> TaskTree {
        TaskTree::from_parents(&[0, 0, 0], &[2.0, 8.0, 8.0]).unwrap()
    }

    fn bits(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn free_network_matches_distributed_bitwise_randomized() {
        check(
            Config { cases: 30, seed: 0x9E7 },
            "free-network DES == network-blind DES",
            |rng: &mut Rng| {
                let n = rng.range(2, 40);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 100.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                let nodes = rng.range(2, 5);
                let node_of: Vec<usize> = (0..n).map(|_| rng.below(nodes)).collect();
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha, nodes, node_of)
            },
            |(tree, alpha, nodes, node_of)| {
                let plat = Platform::Homogeneous { nodes: *nodes, p: 4.0 };
                let net = NetModel::free(*nodes);
                let w = MemWeights::from_task_lens(tree);
                for pol in [Policy::Pm, Policy::Proportional] {
                    let d = simulate_distributed(tree, *alpha, &plat, node_of, pol);
                    let nr = simulate_networked(
                        tree, *alpha, &plat, node_of, pol, &w, &net, &NetSimConfig::default(),
                    )
                    .map_err(|e| e.to_string())?;
                    if nr.makespan.to_bits() != d.makespan.to_bits()
                        || bits(&nr.completion) != bits(&d.completion)
                        || nr.events != d.events
                        || nr.cross_edges != d.cross_edges
                        || nr.cross_stall.to_bits() != d.cross_stall.to_bits()
                    {
                        return Err(format!("{pol:?}: free-net mismatch vs distributed"));
                    }
                    let want_bytes: f64 = tree
                        .nodes
                        .iter()
                        .enumerate()
                        .filter(|(t, nd)| {
                            nd.parent.is_some_and(|p| node_of[*t] != node_of[p as usize])
                        })
                        .map(|(t, _)| w.cb[t])
                        .sum();
                    if nr.bytes_moved.to_bits() != want_bytes.to_bits()
                        || nr.transfer_stall != 0.0
                        || nr.retransmits != 0
                        || nr.remaps != 0
                    {
                        return Err(format!("{pol:?}: free-net metrics not clean"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_trace_replay_matches_plain_networked_bitwise() {
        let t = star();
        let (a, p) = (0.5, 4.0);
        let plat = Platform::Homogeneous { nodes: 2, p };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.5, 1.0);
        let cfg = NetSimConfig::default();
        let plain = simulate_networked(&t, a, &plat, &node_of, Policy::Pm, &w, &net, &cfg).unwrap();
        let rep = replay_link_faults(
            &t, a, &plat, &node_of, Policy::Pm, &w, &net, &cfg, &FaultTrace::empty(),
        )
        .unwrap();
        assert_eq!(rep.sim.makespan.to_bits(), plain.makespan.to_bits());
        assert_eq!(bits(&rep.sim.completion), bits(&plain.completion));
        assert_eq!(rep.sim.transfer_stall.to_bits(), plain.transfer_stall.to_bits());
        assert_eq!(rep.sim.bytes_moved.to_bits(), plain.bytes_moved.to_bits());
        assert_eq!(rep.sim.events, plain.events);
        assert_eq!(rep.link_events, 0);
        assert_eq!(rep.overhead(), 0.0);
    }

    #[test]
    fn traced_networked_run_emits_transfers_and_round_trips() {
        use crate::obs::{chrome_trace, parse_chrome_trace, SpanKind};
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.5, 1.0);
        let cfg = NetSimConfig::default();
        let (res, log) =
            simulate_networked_traced(&t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &cfg)
                .unwrap();
        let plain =
            simulate_networked(&t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &cfg).unwrap();
        assert_eq!(res.makespan.to_bits(), plain.makespan.to_bits());
        log.validate().unwrap();
        assert_eq!(log.workers, 2);
        let factors: Vec<_> = log.spans_of(SpanKind::Factor).collect();
        assert_eq!(factors.len(), t.len());
        for s in &factors {
            assert_eq!(s.worker as usize, node_of[s.task as usize]);
            assert_eq!(s.end.to_bits(), res.completion[s.task as usize].to_bits());
        }
        // one cross edge: task 2 on node 1 feeds the root on node 0
        let transfers: Vec<_> = log.spans_of(SpanKind::Transfer).collect();
        assert_eq!(transfers.len(), res.cross_edges);
        for s in &transfers {
            assert_eq!(s.task, 2);
            assert_eq!(s.worker, 0, "transfer span lands on the parent's node");
            assert!(s.end - s.start >= 0.5, "shipment takes at least the link latency");
            assert_eq!(s.flops.to_bits(), w.cb[2].to_bits());
        }
        assert_eq!(log.makespan().to_bits(), res.makespan.to_bits());
        assert_eq!(parse_chrome_trace(&chrome_trace(&log)).unwrap(), log);
    }

    #[test]
    fn traced_free_network_stalls_match_cross_stall() {
        use crate::obs::SpanKind;
        let t = TaskTree::from_parents(&[0, 0, 0], &[2.0, 1.0, 16.0]).unwrap();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::from_task_lens(&t);
        let net = NetModel::free(2);
        let cfg = NetSimConfig::default();
        let (res, log) =
            simulate_networked_traced(&t, 0.9, &plat, &node_of, Policy::Pm, &w, &net, &cfg)
                .unwrap();
        log.validate().unwrap();
        assert!(res.cross_stall > 0.0, "fixture should make the root wait on node 1");
        assert_eq!(log.spans_of(SpanKind::Transfer).count(), 0);
        assert_eq!(log.spans_of(SpanKind::Factor).count(), t.len());
        assert!(approx_eq(log.total(SpanKind::Stall), res.cross_stall, 1e-12));
        for s in log.spans_of(SpanKind::Factor) {
            assert_eq!(s.end.to_bits(), res.completion[s.task as usize].to_bits());
        }
    }

    #[test]
    fn far_future_fault_forces_real_engine_and_matches_fault_free() {
        // a fault far beyond the makespan exercises the priced event
        // loop (non-empty trace) but cannot change the outcome — this
        // is the deep engine-vs-delegation equivalence check on a free
        // network, and engine-vs-engine on a priced one
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 1e300,
            kind: FaultKind::LinkDown { a: 0, b: 1, duration: 1.0 },
        }]);
        let cfg = NetSimConfig::default();
        check(
            Config { cases: 25, seed: 0xFA4 },
            "far-future link fault is a no-op",
            |rng: &mut Rng| {
                let n = rng.range(2, 30);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 50.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                let node_of: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
                let free = rng.below(2) == 0;
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha, node_of, free)
            },
            |(tree, alpha, node_of, free)| {
                let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
                let net = if *free {
                    NetModel::free(2)
                } else {
                    NetModel::uniform(2, 0.25, 2.0)
                };
                let w = MemWeights::from_task_lens(tree);
                let ff = replay_link_faults(
                    tree, *alpha, &plat, node_of, Policy::Pm, &w, &net, &cfg,
                    &FaultTrace::empty(),
                )
                .map_err(|e| e.to_string())?;
                let far = replay_link_faults(
                    tree, *alpha, &plat, node_of, Policy::Pm, &w, &net, &cfg, &trace,
                )
                .map_err(|e| e.to_string())?;
                if bits(&far.sim.completion) != bits(&ff.sim.completion)
                    || far.sim.makespan.to_bits() != ff.sim.makespan.to_bits()
                    || far.sim.events != ff.sim.events
                {
                    return Err(format!(
                        "free={free}: far-future fault changed the run ({} vs {})",
                        far.sim.makespan, ff.sim.makespan
                    ));
                }
                // sums may associate differently between the engine and
                // the delegated path; values must still agree tightly
                let close = |x: f64, y: f64| (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0);
                if !close(far.sim.bytes_moved, ff.sim.bytes_moved)
                    || !close(far.sim.transfer_stall, ff.sim.transfer_stall)
                    || !close(far.sim.cross_stall, ff.sim.cross_stall)
                    || far.sim.retransmits != 0
                    || far.sim.remaps != 0
                {
                    return Err("far-future fault perturbed the metrics".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn priced_star_matches_closed_form() {
        // node 0: root(2) + leaf(8) chain, node 1: leaf(8); α = 0.5,
        // p = 4 → leaves complete at t = 4; the remote block (4 words,
        // lat 0.5, bw 1) arrives 4 + 0.5 + 4 = 8.5; root runs 1s
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.5, 1.0);
        let r = simulate_networked(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &NetSimConfig::default(),
        )
        .unwrap();
        assert!(approx_eq(r.completion[1], 4.0, 1e-9));
        assert!(approx_eq(r.completion[2], 4.0, 1e-9));
        assert!(approx_eq(r.makespan, 9.5, 1e-9), "makespan {}", r.makespan);
        assert!(approx_eq(r.transfer_stall, 4.5, 1e-9), "stall {}", r.transfer_stall);
        assert_eq!(r.cross_stall, 0.0);
        assert!(approx_eq(r.bytes_moved, 4.0, 1e-12));
        assert_eq!(r.cross_edges, 1);
        assert_eq!((r.retransmits, r.remaps), (0, 0));
        assert!(approx_eq(r.node_finish[0], 9.5, 1e-9));
        assert!(approx_eq(r.node_finish[1], 4.0, 1e-9));
    }

    #[test]
    fn concurrent_transfers_share_the_link_fairly() {
        // both leaves live on node 1 and finish together at 8/√2; their
        // two 4-word blocks split the unit link: rate ½ each, 8s on the
        // wire; root then runs 1s on node 0
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 1, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.0, 1.0);
        let r = simulate_networked(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &NetSimConfig::default(),
        )
        .unwrap();
        let leaves = 8.0 / 2f64.sqrt();
        assert!(approx_eq(r.completion[1], leaves, 1e-9));
        assert!(approx_eq(r.completion[2], leaves, 1e-9));
        assert!(approx_eq(r.makespan, leaves + 8.0 + 1.0, 1e-9), "makespan {}", r.makespan);
        assert!(approx_eq(r.bytes_moved, 8.0, 1e-12));
        assert!(approx_eq(r.transfer_stall, 8.0, 1e-9));
        assert!(approx_eq(r.cross_stall, leaves, 1e-9));
        assert_eq!(r.cross_edges, 2);
    }

    /// Fixture for the timeout walk-through: a degraded link (¼ speed
    /// for 30s from t = 0.5), tight deadline (1 × nominal = 4.5s), one
    /// retry of 1s. The transfer times out at 8.5 and again at 14,
    /// exhausting the budget.
    fn degraded_fixture() -> (TaskTree, Platform, Vec<usize>, MemWeights, NetModel, FaultTrace) {
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.5, 1.0);
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 0.5,
            kind: FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.25, duration: 30.0 },
        }]);
        (t, plat, node_of, w, net, trace)
    }

    #[test]
    fn timeout_retransmit_and_recovery_walk_through() {
        let (t, plat, node_of, w, net, trace) = degraded_fixture();
        let wait_cfg = NetSimConfig {
            timeout_factor: 1.0,
            backoff: LinearBackoff::new(1.0, 1),
            recovery: NetRecovery::WaitOnly,
        };
        // WaitOnly: after exhaustion at t = 14 the restarted attempt
        // streams at rate ¼ from 14.5 and lands exactly as the window
        // closes at 30.5; root finishes at 31.5
        let wr = replay_link_faults(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &wait_cfg, &trace,
        )
        .unwrap();
        assert!(approx_eq(wr.sim.makespan, 31.5, 1e-9), "wait makespan {}", wr.sim.makespan);
        assert_eq!(wr.sim.remaps, 0);
        assert_eq!(wr.sim.retransmits, 2); // the paced retry + the disarmed restart
        assert!(approx_eq(wr.fault_free_makespan, 9.5, 1e-9));
        assert!(wr.overhead() > 0.0);
        // Best: re-mapping the blocked leaf onto node 0 re-runs its 8
        // units as a chain (share 4, 4s) from t = 14 → root at 19
        let best_cfg = NetSimConfig { recovery: NetRecovery::Best, ..wait_cfg };
        let br = replay_link_faults(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &best_cfg, &trace,
        )
        .unwrap();
        assert!(approx_eq(br.sim.makespan, 19.0, 1e-9), "best makespan {}", br.sim.makespan);
        assert_eq!(br.sim.remaps, 1);
        assert!(br.sim.retransmits >= 1);
        assert!(br.sim.makespan <= wr.sim.makespan);
        // wasted attempts moved 1 word each before timing out
        assert!(br.sim.bytes_moved >= 2.0 - 1e-12);
    }

    #[test]
    fn best_recovery_never_loses_to_waiting_randomized() {
        check(
            Config { cases: 20, seed: 0xBE57 },
            "Best recovery <= WaitOnly under link faults",
            |rng: &mut Rng| {
                let n = rng.range(3, 25);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 50.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                let nodes = rng.range(2, 4);
                let node_of: Vec<usize> = (0..n).map(|_| rng.below(nodes)).collect();
                let faults = random_link_fault_trace(nodes, 20.0, rng.range(1, 4), rng);
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha, nodes, node_of, faults)
            },
            |(tree, alpha, nodes, node_of, faults)| {
                let plat = Platform::Homogeneous { nodes: *nodes, p: 4.0 };
                let net = NetModel::uniform(*nodes, 0.1, 0.5);
                let w = MemWeights::from_task_lens(tree);
                let tight = LinearBackoff::new(0.5, 1);
                let wait = replay_link_faults(
                    tree, *alpha, &plat, node_of, Policy::Pm, &w, &net,
                    &NetSimConfig {
                        timeout_factor: 1.5,
                        backoff: tight,
                        recovery: NetRecovery::WaitOnly,
                    },
                    faults,
                )
                .map_err(|e| e.to_string())?;
                let best = replay_link_faults(
                    tree, *alpha, &plat, node_of, Policy::Pm, &w, &net,
                    &NetSimConfig {
                        timeout_factor: 1.5,
                        backoff: tight,
                        recovery: NetRecovery::Best,
                    },
                    faults,
                )
                .map_err(|e| e.to_string())?;
                if best.sim.makespan > wait.sim.makespan * (1.0 + 1e-9) {
                    return Err(format!(
                        "Best {} beat by WaitOnly {}",
                        best.sim.makespan, wait.sim.makespan
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn severed_link_rides_out_on_a_free_network() {
        // LinkDown on a free network is the 0 × ∞ NaN trap: the link
        // must be severed (not free) for the window, then recover
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::free(2);
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 1.0,
            kind: FaultKind::LinkDown { a: 0, b: 1, duration: 10.0 },
        }]);
        // free links are never armed (nominal cost 0), so the engine
        // waits the window out regardless of the recovery policy
        let r = replay_link_faults(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net,
            &NetSimConfig::default(), &trace,
        )
        .unwrap();
        // leaves at t = 4; the block is stuck until the link returns at
        // t = 11, then arrives instantly; root finishes at 12
        assert!(approx_eq(r.sim.makespan, 12.0, 1e-9), "makespan {}", r.sim.makespan);
        assert_eq!(r.sim.retransmits, 0);
        assert_eq!(r.sim.remaps, 0);
        assert!(approx_eq(r.sim.transfer_stall, 7.0, 1e-9));
        assert!(approx_eq(r.fault_free_makespan, 5.0, 1e-9));
    }

    #[test]
    fn rejects_bad_inputs() {
        let t = star();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0usize, 0, 1];
        let w = MemWeights::uniform(3, 8.0, 4.0);
        let net = NetModel::uniform(2, 0.5, 1.0);
        let cfg = NetSimConfig::default();
        // network/platform node-count mismatch
        assert!(simulate_networked(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &NetModel::uniform(3, 0.5, 1.0), &cfg
        )
        .is_err());
        // out-of-range mapping
        assert!(
            simulate_networked(&t, 0.5, &plat, &[0, 0, 2], Policy::Pm, &w, &net, &cfg).is_err()
        );
        // non-static-share policy
        assert!(simulate_networked(&t, 0.5, &plat, &node_of, Policy::Divisible, &w, &net, &cfg)
            .is_err());
        // weights not covering the tree
        assert!(simulate_networked(
            &t, 0.5, &plat, &node_of, Policy::Pm, &MemWeights::uniform(2, 8.0, 4.0), &net, &cfg
        )
        .is_err());
        // bad timeout factor
        assert!(simulate_networked(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net,
            &NetSimConfig { timeout_factor: 0.0, ..cfg }
        )
        .is_err());
        // non-link disturbances belong to sim::faults
        let crash = FaultTrace::new(vec![FaultEvent {
            time: 1.0,
            kind: FaultKind::Crash { node: 1 },
        }]);
        assert!(replay_link_faults(
            &t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &cfg, &crash
        )
        .is_err());
        // link event against a node the platform does not have
        let oob = FaultTrace::new(vec![FaultEvent {
            time: 1.0,
            kind: FaultKind::LinkDown { a: 0, b: 2, duration: 1.0 },
        }]);
        assert!(replay_link_faults(&t, 0.5, &plat, &node_of, Policy::Pm, &w, &net, &cfg, &oob)
            .is_err());
    }
}
