//! Network-aware distributed scheduling (DESIGN.md §15).
//!
//! The distributed layer ([`crate::dist`]) maps subtrees to nodes and
//! replays them through a cross-node DES whose network is free. This
//! module prices that network and makes the schedule survive its
//! faults:
//!
//! * [`model`] — [`NetModel`]: per-node-pair latency and bandwidth,
//!   with fair sharing among concurrent transfers on a link;
//! * [`sim`] — [`simulate_networked`]: the priced DES, where every
//!   cross-node tree edge ships the child's contribution block
//!   ([`crate::mem::MemWeights::cb`] words); and
//!   [`replay_link_faults`]: the same engine under
//!   [`crate::model::FaultKind::LinkDegrade`] /
//!   [`crate::model::FaultKind::LinkDown`] windows, with transfer
//!   timeouts, [`crate::util::retry::LinearBackoff`] retransmits, and
//!   a recovery decision ([`NetRecovery`]) that re-maps the blocked
//!   subtree when that beats waiting the fault out — never worse than
//!   waiting by construction.
//!
//! The communication-avoiding mapping candidate and the
//! network-priced `distribute` pipeline live in [`crate::dist`]
//! (`dist` depends on `net`, not the other way around).

pub mod model;
pub mod sim;

pub use model::NetModel;
pub use sim::{
    replay_link_faults, simulate_networked, simulate_networked_traced,
    simulate_networked_with_workspace, NetDesResult, NetRecovery, NetReplay, NetSimConfig,
};
