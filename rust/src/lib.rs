//! # malltree — Scheduling Trees of Malleable Tasks for Sparse Linear Algebra
//!
//! A production-oriented reproduction of Guermouche, Marchal, Simon &
//! Vivien, *"Scheduling Trees of Malleable Tasks for Sparse Linear
//! Algebra"* (Inria RR-8616, 2014).
//!
//! The library schedules trees (and series-parallel graphs) of
//! **malleable tasks** — tasks whose speedup on a fractional processor
//! share `p` is `p^α` (0 < α ≤ 1) — as they arise in multifrontal sparse
//! Cholesky factorization:
//!
//! * [`model`] — tasks, in-trees, SP-graphs and conversions (paper §4);
//! * [`sched`] — the Prasanna–Musicus optimal schedule and the
//!   `Proportional` / `Divisible` baselines (paper §5, §7), schedule
//!   validation, step processor profiles, the `Agreg` transformation;
//! * [`dist`] — distributed-memory scheduling on N-node platforms
//!   ([`model::Platform`]): the subtree→node mapping layer (Algorithm
//!   11 generalized to N nodes, Algorithm 12's λ-scheme on two
//!   heterogeneous nodes), the `distribute` pipeline producing one PM
//!   schedule per node replayed through the cross-node DES, and the
//!   Partition reduction behind the NP-hardness proof (paper §6);
//! * [`sparse`] — the sparse-linear-algebra substrate: CSC matrices,
//!   Matrix Market I/O, problem generators, elimination trees,
//!   supernode amalgamation and assembly-tree extraction;
//! * [`frontal`] — dense frontal-matrix math and the numeric
//!   multifrontal driver (pure-Rust fallback and PJRT-kernel path);
//! * [`runtime`] — the PJRT bridge that loads the AOT HLO artifacts
//!   produced by `python/compile/aot.py`;
//! * [`exec`] — the malleable work-crew executor realizing fractional
//!   shares as time-sliced integer core assignments (with an optional
//!   memory-cap admission gate);
//! * [`mem`] — memory-aware scheduling: per-task memory weights, Liu's
//!   optimal sequential traversal, and memory-bounded malleable
//!   schedules (the makespan / peak-memory Pareto front);
//! * [`net`] — the priced network model: per-link latency/bandwidth
//!   with fair sharing, contribution-block transfer volumes, link-fault
//!   injection with timeout/retransmit, and communication-avoiding
//!   degradation of the distributed mapping;
//! * [`online`] — the online multi-tenant scheduling service: stochastic
//!   job-arrival streams, admission control from the pooled `L_G/p^α`
//!   bound, deadline timeouts, and reject/defer/degrade backpressure
//!   under overload;
//! * [`sim`] — simulators: a discrete-event engine for malleable
//!   schedules (plus a memory-replay mode), and the tiled kernel-DAG
//!   simulator used to reproduce the paper's §3 speedup measurements;
//! * [`obs`] — observability: one span schema across the real executor
//!   (wall clock) and every simulator (model time), Chrome-trace /
//!   Perfetto export, and α calibrated back from the system's own
//!   Factor spans (global + per front width, with a model-drift
//!   report);
//! * [`workload`] — the assembly-tree dataset surrogate for the
//!   University of Florida collection used in §7;
//! * [`metrics`] — statistics, regression (α fitting) and table/boxplot
//!   rendering for the paper's figures;
//! * [`config`] / [`cli`] — launcher plumbing.

pub mod cli;
pub mod config;
pub mod dist;
pub mod exec;
pub mod frontal;
pub mod mem;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod online;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sparse;
pub mod util;
pub mod workload;

/// Paper-wide default speedup exponent: the value the paper measures on
/// its 40-core platform (§3, "α is in the range 0.85–0.95") and uses as
/// the headline simulation point (§7: "up to 16% for α = 0.9").
pub const DEFAULT_ALPHA: f64 = 0.9;
