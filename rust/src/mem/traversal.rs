//! Sequential traversals of the assembly tree and their memory peaks.
//!
//! A sequential multifrontal factorization is a *postorder*: each
//! subtree is processed contiguously, children before their parent.
//! Different postorders have different peaks — the peak is the maximum,
//! over the traversal, of the live contribution blocks plus the
//! current front. Liu's classical result (the working-storage theorem
//! behind `MA27`-style solvers) gives the exact optimal postorder: at
//! every node, process the children in **decreasing `P(c) − cb(c)`**,
//! where `P(c)` is the child subtree's (recursively optimal) peak and
//! `cb(c)` the residual it leaves behind. [`liu_order`] implements it
//! iteratively (trees here reach 10⁵+ nodes and 10⁴+ depth);
//! [`peak`] evaluates any postorder with the same pebble-game
//! arithmetic as [`crate::frontal::arena::symbolic_peak_f64s`], so the
//! default-order peak of symbolic weights reproduces that prediction
//! exactly.

use crate::model::TaskTree;

use super::model::MemWeights;

/// Peak live words of the pebble game along `order` (a postorder of
/// `tree`): per task, the front goes live over the children's
/// still-live contribution blocks, the children blocks release during
/// assembly, the task's own block goes live, and the front releases.
/// With [`MemWeights::from_symbolic`] weights and the default
/// `topo_up` order this equals `symbolic_peak_f64s` exactly (same
/// arithmetic, tested).
///
/// Panics if `order` is not a postorder permutation of the tree.
pub fn peak(tree: &TaskTree, w: &MemWeights, order: &[u32]) -> f64 {
    let n = tree.len();
    assert_eq!(order.len(), n, "order must cover every task");
    let mut pos = vec![usize::MAX; n];
    for (i, &v) in order.iter().enumerate() {
        assert!(pos[v as usize] == usize::MAX, "task {v} repeated in order");
        pos[v as usize] = i;
    }
    for (i, node) in tree.nodes.iter().enumerate() {
        for &c in &node.children {
            assert!(
                pos[c as usize] < pos[i],
                "not a postorder: child {c} after parent {i}"
            );
        }
    }

    let mut live = 0.0f64;
    let mut pk = 0.0f64;
    for &v in order {
        let vi = v as usize;
        live += w.front[vi];
        pk = pk.max(live);
        for &c in &tree.nodes[vi].children {
            live -= w.cb[c as usize];
        }
        live += w.cb[vi];
        pk = pk.max(live);
        live -= w.front[vi];
    }
    pk
}

/// Per-node sorted child lists and subtree peaks of the Liu-optimal
/// traversal (shared core of [`liu_order`] / [`subtree_peaks`]).
fn liu_plan(tree: &TaskTree, w: &MemWeights) -> (Vec<f64>, Vec<Vec<u32>>) {
    let n = tree.len();
    let mut p = vec![0.0f64; n];
    let mut kids: Vec<Vec<u32>> = tree.nodes.iter().map(|nd| nd.children.clone()).collect();
    for &v in &tree.topo_up() {
        let vi = v as usize;
        // Liu's theorem: decreasing P − cb minimizes the sequential
        // peak over all child orders (ties broken by id: deterministic)
        kids[vi].sort_by(|&a, &b| {
            let ka = p[a as usize] - w.cb[a as usize];
            let kb = p[b as usize] - w.cb[b as usize];
            kb.total_cmp(&ka).then(a.cmp(&b))
        });
        let mut residual = 0.0f64;
        let mut pk = 0.0f64;
        for &c in &kids[vi] {
            pk = pk.max(residual + p[c as usize]);
            residual += w.cb[c as usize];
        }
        // assembly: all children blocks + own front; then front + own block
        pk = pk.max(residual + w.front[vi]);
        pk = pk.max(w.front[vi] + w.cb[vi]);
        p[vi] = pk;
    }
    (p, kids)
}

/// Liu's exact optimal sequential postorder for peak-memory
/// minimization: children at every node in decreasing `P(c) − cb(c)`.
/// `peak(tree, w, &liu_order(..))` is minimal over all postorders — in
/// particular ≤ the default `topo_up` order's peak (property-tested).
pub fn liu_order(tree: &TaskTree, w: &MemWeights) -> Vec<u32> {
    let (_, kids) = liu_plan(tree, w);
    // iterative postorder emission over the sorted child lists
    let mut order = Vec::with_capacity(tree.len());
    let mut stack: Vec<(u32, usize)> = vec![(tree.root, 0)];
    while let Some((v, i)) = stack.last_mut() {
        let vi = *v as usize;
        if *i < kids[vi].len() {
            let c = kids[vi][*i];
            *i += 1;
            stack.push((c, 0));
        } else {
            order.push(*v);
            stack.pop();
        }
    }
    order
}

/// Per-node optimal sequential subtree peaks `P(v)` (the values the
/// Liu order minimizes; `subtree_peaks(..)[root]` equals
/// `peak(tree, w, &liu_order(..))` up to float association).
pub fn subtree_peaks(tree: &TaskTree, w: &MemWeights) -> Vec<f64> {
    liu_plan(tree, w).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{approx_eq, approx_le};
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};

    /// Crafted instance where the default order is strictly worse:
    /// root with leaf children [B, A] where B = (peak 2H, residual H)
    /// and A = (peak G ≫ H, residual ε). Default processes B first
    /// (peak H + G); Liu processes A first (peak max(G, ε + 2H)).
    fn adversarial() -> (TaskTree, MemWeights) {
        let h = 1000.0;
        let g = 4.0 * h;
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 1.0, 1.0]).unwrap();
        let w = MemWeights {
            front: vec![500.0, h, g], // root, B, A
            cb: vec![0.0, h, 1.0],
        };
        (t, w)
    }

    #[test]
    fn liu_strictly_beats_default_on_adversarial_case() {
        let (t, w) = adversarial();
        let default = peak(&t, &w, &t.topo_up());
        let liu = peak(&t, &w, &liu_order(&t, &w));
        // default: B then A -> peak H + (G + 1) = 5001;
        // Liu: A then B -> peak max(G + 1, 1 + 2H) = 4001
        assert_eq!(default, 5001.0);
        assert_eq!(liu, 4001.0);
        assert!(liu < default, "liu {liu} !< default {default}");
        assert!(approx_eq(subtree_peaks(&t, &w)[0], liu, 1e-12));
    }

    #[test]
    fn liu_order_is_a_postorder_and_matches_formula() {
        let mut rng = Rng::new(0x11);
        for class in [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary] {
            let t = random_tree(class, 400, &mut rng);
            let w = synthetic_mem_weights(&t, &mut rng);
            let order = liu_order(&t, &w);
            // `peak` asserts postorder validity internally
            let evaluated = peak(&t, &w, &order);
            let formula = subtree_peaks(&t, &w)[t.root as usize];
            assert!(
                approx_eq(evaluated, formula, 1e-9),
                "{class:?}: evaluated {evaluated} vs formula {formula}"
            );
        }
    }

    #[test]
    fn liu_never_worse_than_default_randomized() {
        check(
            Config { cases: 40, seed: 0x417 },
            "Liu peak <= default postorder peak",
            |rng: &mut Rng| {
                let classes = [
                    TreeClass::Uniform,
                    TreeClass::Recent,
                    TreeClass::Deep,
                    TreeClass::Binary,
                ];
                let class = classes[rng.below(4)];
                let n = rng.range(2, 300);
                let t = random_tree(class, n, rng);
                let w = synthetic_mem_weights(&t, rng);
                (t, w)
            },
            |(t, w)| {
                let default = peak(t, w, &t.topo_up());
                let liu = peak(t, w, &liu_order(t, w));
                if !approx_le(liu, default, 1e-9) {
                    return Err(format!("liu {liu} > default {default}"));
                }
                if liu < w.min_possible_peak() - 1e-9 {
                    return Err(format!(
                        "liu {liu} below the widest working set {}",
                        w.min_possible_peak()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn default_order_reproduces_symbolic_peak_exactly() {
        use crate::frontal::arena::symbolic_peak_f64s;
        use crate::mem::MemWeights;
        use crate::sparse::{gen, order, symbolic};
        for (k, amalg) in [(8usize, 0usize), (10, 4)] {
            let a = gen::grid_laplacian_2d(k);
            let perm = order::nested_dissection_2d(k);
            let at = symbolic::analyze(&a, &perm, amalg).unwrap();
            let w = MemWeights::from_symbolic(&at);
            let got = peak(&at.tree, &w, &at.tree.topo_up());
            assert_eq!(got, symbolic_peak_f64s(&at) as f64, "grid {k} amalg {amalg}");
        }
    }

    #[test]
    fn liu_improves_or_ties_symbolic_trees() {
        use crate::sparse::{gen, order, symbolic};
        let a = gen::grid_laplacian_3d(8);
        let perm = order::nested_dissection_3d(8);
        let at = symbolic::analyze(&a, &perm, 4).unwrap();
        let w = MemWeights::from_symbolic(&at);
        let default = peak(&at.tree, &w, &at.tree.topo_up());
        let liu = peak(&at.tree, &w, &liu_order(&at.tree, &w));
        assert!(approx_le(liu, default, 1e-12), "liu {liu} > default {default}");
    }

    #[test]
    #[should_panic(expected = "not a postorder")]
    fn peak_rejects_non_postorder() {
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 1.0]).unwrap();
        let w = MemWeights::uniform(2, 1.0, 0.5);
        peak(&t, &w, &[0, 1]); // root before its child
    }

    #[test]
    fn deep_chain_no_stack_overflow() {
        let n = 100_000;
        let parents: Vec<usize> = (0..n).map(|i| i.saturating_sub(1)).collect();
        let lens = vec![1.0; n];
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let w = MemWeights::uniform(n, 4.0, 1.0);
        let order = liu_order(&t, &w);
        assert_eq!(order.len(), n);
        // chain: one front + one child block at a time
        assert_eq!(peak(&t, &w, &order), 5.0);
    }
}
