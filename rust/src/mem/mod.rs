//! Memory-aware scheduling (DESIGN.md §12).
//!
//! The multifrontal method's real-world ceiling is memory, not flops:
//! each front plus its children's contribution blocks must be live
//! simultaneously, and parallel tree traversals multiply that peak.
//! This subsystem *plans* for the quantity the numeric layer already
//! measures ([`crate::frontal::arena::MemGauge`],
//! `symbolic_peak_f64s`):
//!
//! * [`model`] — per-task memory weights (front storage `n_i`,
//!   contribution block `f_i`) from real symbolic analyses or the
//!   synthetic family in [`crate::workload::generator`];
//! * [`traversal`] — Liu's exact optimal sequential postorder for peak
//!   minimization, plus `peak(order)` evaluation of any postorder
//!   (the default `topo_up` order is the baseline);
//! * [`bounded`] — memory-bounded malleable schedules: under a cap
//!   `M`, sibling subtrees are packed into concurrency batches and the
//!   PM solver runs on the induced series-parallel structure,
//!   producing the makespan / peak-memory Pareto front.
//!
//! The loop is closed on both ends: [`crate::sim::replay_memory`]
//! replays any schedule's live words over time (the serial-postorder
//! replay pins the arena-measured peak exactly), and
//! [`crate::exec::execute_malleable_capped`] enforces a cap at run
//! time through a `MemGauge`-backed admission gate.

pub mod bounded;
pub mod model;
pub mod traversal;

pub use bounded::{bounded_schedule, pareto_front, BoundedSchedule, ParetoPoint};
pub use model::MemWeights;
pub use traversal::{liu_order, peak, subtree_peaks};
