//! Memory-bounded malleable scheduling (DESIGN.md §12).
//!
//! Given a cap `M` on live words, select which sibling subtrees may be
//! concurrently active and re-run the PM solver on the induced
//! structure. The plan is computed bottom-up: per node, the children
//! are packed (in Liu order — decreasing `m(c) − cb(c)`) into
//! **batches** whose conservative concurrent peak fits under `M`;
//! batch members run in parallel, batches run sequentially. The
//! resulting execution structure *is* a series-parallel graph — a
//! serialized sibling set is a series composition of its batches — so
//! the schedule is just the PM optimum of that constrained graph,
//! solved through the same [`SchedWorkspace`] core as every other
//! schedule in the repo (the single-batch case is the plain
//! sub-forest/parallel composition `solve_forest` handles; a
//! multi-batch node chains those forests in series).
//!
//! Two exact degeneracies anchor the construction:
//!
//! * `M = ∞` (or `M ≥` the unbounded planned peak) makes every node a
//!   single batch in original child order; the graph is then
//!   **bit-identical** to [`SpGraph::from_tree`], so the schedule is
//!   the unbounded PM schedule (tested bitwise);
//! * `M` below everything makes every batch a singleton in Liu order:
//!   the plan degenerates to Liu's optimal sequential traversal, whose
//!   peak is the minimum over all postorders.
//!
//! The per-node bound `m(v)` is conservative (concurrent children are
//! charged the sum of their subtree peaks), so a feasible plan's DES
//! memory replay never exceeds the cap (property-tested).

use crate::model::{SpGraph, SpNode, TaskTree};
use crate::sched::{Profile, SchedWorkspace, Schedule};

use super::model::MemWeights;

/// A cap-constrained PM schedule and its plan metadata.
#[derive(Debug, Clone)]
pub struct BoundedSchedule {
    /// The materialized schedule (PM optimum of the constrained graph).
    pub schedule: Schedule,
    /// Makespan under the given profile.
    pub makespan: f64,
    /// Conservative bound on the schedule's peak live words (`m(root)`).
    pub planned_peak: f64,
    /// Nodes whose children were split into more than one batch.
    pub serialized: usize,
    /// Whether `planned_peak ≤ cap` (false means even full
    /// serialization — Liu's optimal traversal — exceeds the cap; the
    /// returned schedule is then that minimal-memory serial plan).
    pub feasible: bool,
    /// The constrained SP graph the schedule was solved on.
    pub graph: SpGraph,
}

/// One point of the makespan / peak-memory Pareto front.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// The cap this plan was built for (words).
    pub cap: f64,
    /// PM makespan of the constrained schedule.
    pub makespan: f64,
    /// Conservative planned peak (≤ cap when feasible).
    pub planned_peak: f64,
    /// Peak of the DES memory replay of the schedule (≤ planned).
    pub replay_peak: f64,
    /// Nodes with serialized (multi-batch) children.
    pub serialized: usize,
    pub feasible: bool,
}

/// Per-node child batches: members parallel, batches sequential.
struct Plan {
    batches: Vec<Vec<Vec<u32>>>,
    planned_peak: f64,
    serialized: usize,
}

/// Bottom-up batch planning under `cap`. For each node, first try the
/// all-parallel batch in *original* child order (so the unbounded case
/// reproduces `from_tree` exactly); if its conservative peak exceeds
/// the cap, re-sort the children in Liu order and greedily pack.
fn plan(tree: &TaskTree, w: &MemWeights, cap: f64) -> Plan {
    let n = tree.len();
    let mut m = vec![0.0f64; n];
    let mut batches: Vec<Vec<Vec<u32>>> = vec![Vec::new(); n];
    let mut serialized = 0usize;
    for &v in &tree.topo_up() {
        let vi = v as usize;
        let children = &tree.nodes[vi].children;
        if children.is_empty() {
            m[vi] = w.front[vi] + w.cb[vi];
            continue;
        }
        let cb_sum: f64 = children.iter().map(|&c| w.cb[c as usize]).sum();
        // assembly (all children blocks + front), then front + own block
        let own = (cb_sum + w.front[vi]).max(w.front[vi] + w.cb[vi]);
        let par_sum: f64 = children.iter().map(|&c| m[c as usize]).sum();
        if par_sum.max(own) <= cap {
            m[vi] = par_sum.max(own);
            batches[vi] = vec![children.clone()];
            continue;
        }
        // cap binds: Liu-sort, then greedily pack batches that fit
        let mut order = children.clone();
        order.sort_by(|&a, &b| {
            let ka = m[a as usize] - w.cb[a as usize];
            let kb = m[b as usize] - w.cb[b as usize];
            kb.total_cmp(&ka).then(a.cmp(&b))
        });
        let mut bs: Vec<Vec<u32>> = Vec::new();
        let mut residual = 0.0f64; // blocks of completed earlier batches
        let mut pk = 0.0f64;
        let mut cur: Vec<u32> = Vec::new();
        let (mut cur_m, mut cur_cb) = (0.0f64, 0.0f64);
        for &c in &order {
            let mc = m[c as usize];
            if !cur.is_empty() && residual + cur_m + mc > cap {
                pk = pk.max(residual + cur_m);
                residual += cur_cb;
                bs.push(std::mem::take(&mut cur));
                cur_m = 0.0;
                cur_cb = 0.0;
            }
            cur.push(c);
            cur_m += mc;
            cur_cb += w.cb[c as usize];
        }
        pk = pk.max(residual + cur_m);
        residual += cur_cb;
        bs.push(cur);
        if bs.len() > 1 {
            serialized += 1;
        }
        // assembly term from the sorted-order residual: with singleton
        // batches this reproduces the Liu recursion's float ops
        // bit-for-bit, so the serial fallback's planned peak equals
        // `subtree_peaks` exactly (the Pareto front's lower anchor)
        pk = pk.max(residual + w.front[vi]);
        m[vi] = pk.max(w.front[vi] + w.cb[vi]);
        batches[vi] = bs;
    }
    Plan { batches, planned_peak: m[tree.root as usize], serialized }
}

/// Build the constrained SP graph of a plan. Mirrors
/// [`SpGraph::from_tree`]'s arena layout node for node, so a plan with
/// a single all-children batch at every node produces a bit-identical
/// graph (and therefore a bit-identical PM schedule).
fn build_graph(tree: &TaskTree, plan: &Plan) -> SpGraph {
    let n = tree.len();
    let mut sub: Vec<u32> = vec![0; n];
    let mut g = SpGraph::new(Vec::with_capacity(2 * n), 0);
    for &v in &tree.topo_up() {
        let vi = v as usize;
        let node = &tree.nodes[vi];
        let leaf = g.push(SpNode::Leaf { len: node.len, task: Some(v) });
        let id = if node.children.is_empty() {
            leaf
        } else {
            let mut members = Vec::with_capacity(plan.batches[vi].len() + 1);
            for batch in &plan.batches[vi] {
                let kids: Vec<u32> = batch.iter().map(|&c| sub[c as usize]).collect();
                members.push(if kids.len() == 1 {
                    kids[0]
                } else {
                    g.push(SpNode::Parallel(kids))
                });
            }
            members.push(leaf);
            g.push(SpNode::Series(members))
        };
        sub[vi] = id;
    }
    g.root = sub[tree.root as usize];
    g
}

/// Memory-bounded PM schedule for `tree` under `cap` live words
/// (`f64::INFINITY` for unbounded), materialized against `profile`.
pub fn bounded_schedule(
    tree: &TaskTree,
    w: &MemWeights,
    alpha: f64,
    profile: &Profile,
    cap: f64,
) -> BoundedSchedule {
    let mut ws = SchedWorkspace::new();
    bounded_schedule_with_workspace(tree, w, alpha, profile, cap, &mut ws)
}

/// [`bounded_schedule`] with a caller-owned [`SchedWorkspace`] so cap
/// sweeps (the Pareto front, the `mem_sched` bench) reuse the PM
/// solver's SoA buffers across plans.
pub fn bounded_schedule_with_workspace(
    tree: &TaskTree,
    w: &MemWeights,
    alpha: f64,
    profile: &Profile,
    cap: f64,
    ws: &mut SchedWorkspace,
) -> BoundedSchedule {
    debug_assert!(w.front.len() == tree.len() && w.cb.len() == tree.len());
    // The bottom-up packer is context-blind: a child batched right up
    // to the cap can push an ancestor's residual context over it. When
    // that happens, tighten the *packing* budget geometrically (the
    // admission cap stays `cap`) until the composed bound fits; the
    // zero-budget plan is Liu's serial traversal, so any
    // `cap ≥ liu peak` ends feasible.
    let mut pl = plan(tree, w, cap);
    if pl.planned_peak > cap {
        let mut eff = cap;
        for _ in 0..64 {
            eff *= 0.5;
            if eff < f64::MIN_POSITIVE {
                break;
            }
            pl = plan(tree, w, eff);
            if pl.planned_peak <= cap {
                break;
            }
        }
        if pl.planned_peak > cap {
            pl = plan(tree, w, 0.0);
        }
    }
    let graph = build_graph(tree, &pl);
    let spans = ws.task_spans(&graph, alpha, profile).to_vec();
    let schedule = Schedule::new(spans);
    BoundedSchedule {
        makespan: schedule.makespan,
        schedule,
        planned_peak: pl.planned_peak,
        serialized: pl.serialized,
        feasible: pl.planned_peak <= cap,
        graph,
    }
}

/// Makespan / peak-memory Pareto front: caps swept geometrically from
/// the Liu-optimal sequential peak (full serialization — the minimum
/// any schedule can reach) to the unbounded plan's conservative peak,
/// each point DES-replayed to report the realized peak.
pub fn pareto_front(
    tree: &TaskTree,
    w: &MemWeights,
    alpha: f64,
    p: f64,
    points: usize,
) -> Vec<ParetoPoint> {
    let profile = Profile::constant(p);
    let mut ws = SchedWorkspace::new();
    let unbounded =
        bounded_schedule_with_workspace(tree, w, alpha, &profile, f64::INFINITY, &mut ws);
    let hi = unbounded.planned_peak;
    let lo = super::traversal::subtree_peaks(tree, w)[tree.root as usize];
    let points = points.max(2);
    let mut out = Vec::with_capacity(points);
    for i in 0..points {
        let t = i as f64 / (points - 1) as f64;
        // geometric interpolation; degenerate span falls back to `hi`
        let cap = if lo > 0.0 && hi > lo {
            lo * (hi / lo).powf(t)
        } else {
            hi
        };
        let b = bounded_schedule_with_workspace(tree, w, alpha, &profile, cap, &mut ws);
        let replay = crate::sim::replay_memory(tree, w, &b.schedule, None);
        out.push(ParetoPoint {
            cap,
            makespan: b.makespan,
            planned_peak: b.planned_peak,
            replay_peak: replay.peak,
            serialized: b.serialized,
            feasible: b.feasible,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::traversal::{liu_order, peak, subtree_peaks};
    use crate::sim::replay_memory;
    use crate::util::{approx_eq, approx_le};
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};

    fn case(rng: &mut Rng) -> (TaskTree, MemWeights, f64) {
        let classes = [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary];
        let t = random_tree(classes[rng.below(3)], rng.range(2, 120), rng);
        let w = synthetic_mem_weights(&t, rng);
        let alpha = rng.range_f64(0.5, 1.0);
        (t, w, alpha)
    }

    #[test]
    fn unbounded_cap_reproduces_from_tree_bitwise() {
        check(
            Config { cases: 20, seed: 0xB0 },
            "cap >= unbounded peak degenerates to the plain PM schedule",
            case,
            |(t, w, alpha)| {
                let profile = Profile::constant(8.0);
                let unb = bounded_schedule(t, w, *alpha, &profile, f64::INFINITY);
                // cap exactly at the unbounded planned peak: still all-parallel
                let at_peak = bounded_schedule(t, w, *alpha, &profile, unb.planned_peak);
                if at_peak.serialized != 0 || !at_peak.feasible {
                    return Err("cap == unbounded peak still serialized".into());
                }
                let want = SpGraph::from_tree(t);
                if unb.graph.nodes != want.nodes || at_peak.graph.nodes != want.nodes {
                    return Err("constrained graph differs from from_tree".into());
                }
                let pm = crate::sched::PmSchedule::for_tree(t, *alpha, &profile);
                if unb.schedule.spans.len() != pm.schedule.spans.len() {
                    return Err("span count differs".into());
                }
                for (a, b) in unb.schedule.spans.iter().zip(&pm.schedule.spans) {
                    if a.task != b.task
                        || a.start.to_bits() != b.start.to_bits()
                        || a.finish.to_bits() != b.finish.to_bits()
                        || a.ratio.to_bits() != b.ratio.to_bits()
                    {
                        return Err(format!("span for task {} differs", a.task));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn replay_never_exceeds_cap_and_makespan_degrades_monotonically() {
        check(
            Config { cases: 25, seed: 0xB1 },
            "bounded schedules respect the cap in DES replay",
            case,
            |(t, w, alpha)| {
                let profile = Profile::constant(6.0);
                let unb = bounded_schedule(t, w, *alpha, &profile, f64::INFINITY);
                let lo = subtree_peaks(t, w)[t.root as usize];
                let hi = unb.planned_peak;
                for frac in [0.0, 0.3, 0.6, 1.0] {
                    let cap = lo + frac * (hi - lo);
                    let b = bounded_schedule(t, w, *alpha, &profile, cap);
                    if !b.feasible {
                        return Err(format!("cap {cap} >= liu peak {lo} must be feasible"));
                    }
                    if !approx_le(b.planned_peak, cap, 1e-9) {
                        return Err(format!("planned {} > cap {cap}", b.planned_peak));
                    }
                    let r = replay_memory(t, w, &b.schedule, None);
                    if !approx_le(r.peak, b.planned_peak, 1e-9) {
                        return Err(format!(
                            "replay peak {} > planned {} (cap {cap})",
                            r.peak, b.planned_peak
                        ));
                    }
                    // schedule stays valid under the tighter structure
                    if b.schedule
                        .validate(t, *alpha, &profile, 1e-6)
                        .is_err()
                    {
                        return Err(format!("invalid schedule at cap {cap}"));
                    }
                    // tighter caps can only lengthen the makespan
                    if !approx_le(unb.makespan, b.makespan, 1e-9) {
                        return Err(format!(
                            "bounded makespan {} beat unbounded {}",
                            b.makespan, unb.makespan
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tiny_cap_degenerates_to_liu_serial_traversal() {
        let mut rng = Rng::new(0xB2);
        for _ in 0..10 {
            let (t, w, alpha) = case(&mut rng);
            let profile = Profile::constant(4.0);
            let b = bounded_schedule(&t, &w, alpha, &profile, 0.0);
            // fully serialized plan == Liu's optimal sequential peak
            let liu = peak(&t, &w, &liu_order(&t, &w));
            assert!(
                approx_eq(b.planned_peak, liu, 1e-9),
                "fully-serial planned peak {} != liu {liu}",
                b.planned_peak
            );
            assert!(!b.feasible);
            let r = replay_memory(&t, &w, &b.schedule, None);
            assert!(approx_le(r.peak, liu, 1e-9), "replay {} > liu {liu}", r.peak);
        }
    }

    #[test]
    fn serialization_kicks_in_between_extremes() {
        // wide star: many identical children — a mid cap forces batches
        let n = 17;
        let parents = vec![0usize; n]; // node 0 root, 16 leaf children
        let lens = vec![8.0; n];
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let mut w = MemWeights::uniform(n, 100.0, 10.0);
        w.cb[0] = 0.0;
        let profile = Profile::constant(8.0);
        let unb = bounded_schedule(&t, &w, 0.9, &profile, f64::INFINITY);
        // 16 children in parallel: planned peak 16 * 110
        assert_eq!(unb.planned_peak, 16.0 * 110.0);
        let b = bounded_schedule(&t, &w, 0.9, &profile, 500.0);
        assert!(b.feasible);
        assert_eq!(b.serialized, 1);
        assert!(b.planned_peak <= 500.0);
        assert!(b.makespan > unb.makespan);
        let r = replay_memory(&t, &w, &b.schedule, None);
        assert!(r.peak <= 500.0 + 1e-9, "replay {} over cap", r.peak);
    }

    #[test]
    fn pareto_front_is_monotone_in_both_axes() {
        let mut rng = Rng::new(0xB3);
        let t = random_tree(TreeClass::Uniform, 200, &mut rng);
        let w = synthetic_mem_weights(&t, &mut rng);
        let front = pareto_front(&t, &w, 0.9, 8.0, 6);
        assert_eq!(front.len(), 6);
        // the widest cap is the unbounded schedule
        let last = front.last().unwrap();
        assert_eq!(last.serialized, 0);
        assert!(last.feasible);
        for pair in front.windows(2) {
            assert!(pair[0].cap <= pair[1].cap, "caps must increase");
        }
        for pt in &front {
            // every point is feasible (caps start at the Liu peak),
            // respects its cap in replay, and none beats the
            // unbounded PM optimum
            assert!(pt.feasible, "cap {} infeasible", pt.cap);
            assert!(approx_le(pt.replay_peak, pt.cap, 1e-9));
            assert!(approx_le(pt.replay_peak, pt.planned_peak, 1e-9));
            assert!(approx_le(last.makespan, pt.makespan, 1e-9));
        }
    }
}
