//! Per-task memory weights on a [`TaskTree`] (DESIGN.md §12).
//!
//! The multifrontal method's working set at a task is *not* its flop
//! count: while front `i` is assembled, its dense **front storage**
//! `n_i` (the `nf × nf` frontal matrix) and every child's
//! **contribution block** `f_c` (the child's `m × m` Schur complement)
//! are live simultaneously; the front then releases, leaving the
//! task's own contribution block live until the parent consumes it.
//! This is the pebble game of the memory-aware tree-scheduling
//! literature (Liu; Marchal–Sinnen–Vivien; Eyraud-Dubois et al.), and
//! [`MemWeights`] is its per-task weight vector: `front[i]` words of
//! front storage, `cb[i]` words of contribution block.
//!
//! Weights come from two sources:
//!
//! * [`MemWeights::from_symbolic`] — exact words of a real analysis
//!   (`front = nf²`, `cb = m²`), the numbers
//!   [`crate::frontal::arena::symbolic_peak_f64s`] replays and the
//!   [`crate::frontal::FrontArena`] measures;
//! * [`crate::workload::generator::synthetic_mem_weights`] — a
//!   calibrated synthetic family for random trees (dense-front scaling
//!   `mem ∝ flops^{2/3}`).

use anyhow::{ensure, Result};

use crate::model::TaskTree;
use crate::sparse::AssemblyTree;

/// Per-task memory weights in f64 words: `front[i]` is the dense front
/// storage live while task `i` executes, `cb[i]` the contribution
/// block it leaves live until its parent's assembly consumes it.
#[derive(Debug, Clone, PartialEq)]
pub struct MemWeights {
    pub front: Vec<f64>,
    pub cb: Vec<f64>,
}

impl MemWeights {
    /// Exact weights of a real symbolic analysis: `front = nf²`,
    /// `cb = m²` with `m = nf − width` (words of f64). The pebble-game
    /// replay of these weights over the default postorder equals
    /// [`crate::frontal::arena::symbolic_peak_f64s`] exactly (tested).
    pub fn from_symbolic(at: &AssemblyTree) -> MemWeights {
        let mut front = Vec::with_capacity(at.tree.len());
        let mut cb = Vec::with_capacity(at.tree.len());
        for sn in &at.symbolic.supernodes {
            let nf = sn.front_order();
            let m = nf - sn.width;
            front.push((nf * nf) as f64);
            cb.push((m * m) as f64);
        }
        MemWeights { front, cb }
    }

    /// Uniform weights (tests and toy models).
    pub fn uniform(n: usize, front: f64, cb: f64) -> MemWeights {
        MemWeights { front: vec![front; n], cb: vec![cb; n] }
    }

    /// Dense-front surrogate from task lengths alone: a front doing
    /// `len` flops factors an `m × m` dense block with `len ≈ m³`, so
    /// its contribution block holds `cb = len^{2/3}` words and the
    /// front twice that (same scaling as
    /// [`crate::workload::generator::synthetic_mem_weights`], minus
    /// the calibration noise). The root contributes nothing upward.
    /// Used to price cross-node transfers when a tree carries no
    /// measured weights.
    pub fn from_task_lens(tree: &TaskTree) -> MemWeights {
        let n = tree.len();
        let mut front = Vec::with_capacity(n);
        let mut cb = Vec::with_capacity(n);
        for (i, node) in tree.nodes.iter().enumerate() {
            let c = if i as u32 == tree.root { 0.0 } else { node.len.powf(2.0 / 3.0) };
            cb.push(c);
            front.push(2.0 * node.len.powf(2.0 / 3.0));
        }
        MemWeights { front, cb }
    }

    /// Number of tasks covered.
    pub fn len(&self) -> usize {
        self.front.len()
    }

    pub fn is_empty(&self) -> bool {
        self.front.is_empty()
    }

    /// Check the weights cover `tree` and satisfy the multifrontal
    /// invariants: finite, non-negative, and `cb ≤ front` (a
    /// contribution block is a trailing sub-block of its front).
    pub fn validate(&self, tree: &TaskTree) -> Result<()> {
        ensure!(
            self.front.len() == tree.len() && self.cb.len() == tree.len(),
            "weights cover {} fronts / {} blocks for a {}-task tree",
            self.front.len(),
            self.cb.len(),
            tree.len()
        );
        for i in 0..tree.len() {
            let (f, c) = (self.front[i], self.cb[i]);
            ensure!(f.is_finite() && c.is_finite(), "task {i}: non-finite weight");
            ensure!(f >= 0.0 && c >= 0.0, "task {i}: negative weight ({f}, {c})");
            ensure!(c <= f, "task {i}: contribution block {c} exceeds front {f}");
        }
        Ok(())
    }

    /// Largest single-task working set `max_i (front_i + cb_i)` — a
    /// trivial lower bound on any traversal's peak.
    pub fn min_possible_peak(&self) -> f64 {
        self.front
            .iter()
            .zip(&self.cb)
            .map(|(f, c)| f + c)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, order, symbolic};

    #[test]
    fn symbolic_weights_cover_tree_and_validate() {
        let a = gen::grid_laplacian_2d(10);
        let perm = order::nested_dissection_2d(10);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let w = MemWeights::from_symbolic(&at);
        assert_eq!(w.len(), at.tree.len());
        w.validate(&at.tree).unwrap();
        // the root front is full-width: no contribution block
        assert_eq!(w.cb[at.tree.root as usize], 0.0);
        // fronts are squares of the symbolic front orders
        for (i, sn) in at.symbolic.supernodes.iter().enumerate() {
            assert_eq!(w.front[i], (sn.front_order() * sn.front_order()) as f64);
        }
    }

    #[test]
    fn validate_rejects_mismatch_and_bad_values() {
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 2.0]).unwrap();
        assert!(MemWeights::uniform(3, 1.0, 0.5).validate(&t).is_err());
        assert!(MemWeights::uniform(2, 1.0, 2.0).validate(&t).is_err()); // cb > front
        let mut w = MemWeights::uniform(2, 1.0, 0.5);
        w.front[1] = f64::NAN;
        assert!(w.validate(&t).is_err());
        MemWeights::uniform(2, 4.0, 1.0).validate(&t).unwrap();
    }

    #[test]
    fn task_len_surrogate_validates_and_scales() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 8.0, 27.0]).unwrap();
        let w = MemWeights::from_task_lens(&t);
        w.validate(&t).unwrap();
        assert_eq!(w.cb[t.root as usize], 0.0);
        // len = 8 → cb = 8^{2/3} = 4, front = 8; len = 27 → cb = 9
        assert!((w.cb[1] - 4.0).abs() < 1e-12);
        assert!((w.cb[2] - 9.0).abs() < 1e-9);
        assert!((w.front[1] - 8.0).abs() < 1e-12);
    }

    #[test]
    fn min_possible_peak_is_widest_working_set() {
        let w = MemWeights { front: vec![9.0, 16.0, 4.0], cb: vec![4.0, 1.0, 4.0] };
        assert_eq!(w.min_possible_peak(), 17.0);
    }
}
