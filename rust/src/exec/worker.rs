//! Schedule-driven execution of the numeric multifrontal factorization.
//!
//! All executors run the arena assembly path (precomputed relative
//! indices, recycled contribution slabs — see [`crate::frontal::arena`]).
//! The parallel crew is **lock-light**: task outputs live in per-task
//! write-once slots, so extend-add and front factorization run outside
//! any shared lock; only the ready-queue push/pop (plus the dependency
//! counters it guards) is synchronized.
//!
//! The crew is a **two-level scheduler** (DESIGN.md §10):
//!
//! 1. a ready queue of *fronts*, prioritized by schedule dispatch
//!    order (tree parallelism), and
//! 2. inside each front, an atomic *tile cursor*
//!    ([`crate::frontal::FrontTeamJob`]) that a worker **team** shares
//!    (intra-front parallelism).
//!
//! In malleable mode the [`TeamPlan`] converts the schedule's
//! fractional shares into integer team sizes, re-evaluated at every
//! task-completion event, so workers freed near the top of the tree
//! rejoin the live teams of the wide root fronts instead of idling.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::frontal::arena::{FrontArena, MemGauge};
use crate::frontal::backend::FrontBackend;
use crate::frontal::dense::FrontTeamJob;
use crate::frontal::multifrontal::{assemble_front_arena, factor_front_arena, Factorization};
use crate::obs::trace::{Span, SpanKind, TimeUnit, TraceLog, TraceSink};
use crate::sched::Schedule;
use crate::sparse::{AssemblyTree, CscMatrix};

use super::fault::FaultPlan;
use super::team::TeamPlan;

/// Poison-tolerant lock acquisition. Every crew invariant holds at
/// every lock release point (numeric work runs outside the lock), so
/// the state behind a mutex poisoned by a panicking worker is still
/// consistent — recover the guard instead of cascading secondary
/// panics through the rest of the crew. The original panic is still
/// propagated loudly by the scoped join; this only keeps the other
/// workers orderly on their way out.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Order tasks by schedule start time, tie-broken by topological
/// position (children first). For any valid schedule this is a
/// topological order: a parent starts only after its children finish.
fn dispatch_order(at: &AssemblyTree, schedule: &Schedule) -> Vec<u32> {
    let n = at.tree.len();
    let mut start = vec![f64::INFINITY; n];
    for s in &schedule.spans {
        start[s.task as usize] = s.start;
    }
    let mut topo_pos = vec![0usize; n];
    for (i, &v) in at.tree.topo_up().iter().enumerate() {
        topo_pos[v as usize] = i;
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    // total_cmp: a NaN span start (degenerate schedule input) must not
    // panic the executor — NaNs sort last, like tasks missing from the
    // schedule. Dispatch order is only a priority; precedence is
    // enforced by the crew's dependency counters either way.
    order.sort_by(|&a, &b| {
        start[a as usize]
            .total_cmp(&start[b as usize])
            .then(topo_pos[a as usize].cmp(&topo_pos[b as usize]))
    });
    order
}

/// Serial ("accelerator command queue") execution: fronts stream to the
/// backend in schedule-dispatch order. This is the path the PJRT
/// backend uses — the XLA CPU client is one logical device.
pub fn execute_serial(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &dyn FrontBackend,
) -> Result<(Factorization, super::ExecReport)> {
    execute_serial_traced(at, ap, schedule, backend, TraceSink::Null)
}

/// [`execute_serial`] with span tracing: one Assemble + one Factor
/// span per front on the single worker track (`factor_front_arena`
/// reports its assembly seconds, which split the front's wall window).
/// The sink is taken verbatim — the env kill-switch is CLI-level
/// ([`TraceSink::from_env`]).
pub fn execute_serial_traced(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &dyn FrontBackend,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let order = dispatch_order(at, schedule);
    let mut arena = FrontArena::for_tree(at);
    let mut contrib: Vec<Option<Vec<f64>>> = vec![None; n];
    let mut panels: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut flops = 0.0;
    let mut assembly = 0.0;
    let tracing = sink.enabled();
    let mut spans: Vec<Span> = Vec::new();
    let t0 = Instant::now();
    for &v in &order {
        let s = v as usize;
        let f0 = if tracing { t0.elapsed().as_nanos() as f64 } else { 0.0 };
        let asm = factor_front_arena(at, ap, s, backend, &mut arena, &mut contrib, &mut panels)?;
        assembly += asm;
        flops += at.symbolic.supernodes[s].flops();
        if tracing {
            let end = t0.elapsed().as_nanos() as f64;
            let split = (f0 + asm * 1e9).min(end);
            spans.push(Span {
                kind: SpanKind::Assemble,
                task: v,
                worker: 0,
                team: 1.0,
                flops: 0.0,
                start: f0,
                end: split,
            });
            spans.push(Span {
                kind: SpanKind::Factor,
                task: v,
                worker: 0,
                team: 1.0,
                flops: at.symbolic.supernodes[s].flops(),
                start: split,
                end,
            });
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let trace = tracing.then(|| {
        let mut log = TraceLog::new("exec", TimeUnit::WallNs, 1);
        log.spans = spans;
        log.sort();
        log
    });
    Ok((
        Factorization { panels, n: ap.n },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            assembly_seconds: assembly,
            peak_front_bytes: arena.peak_bytes(),
            tasks: n,
            flops,
            backend: backend.name().to_string(),
            workers: 1,
            malleable: false,
            team_log: Vec::new(),
            mem_stalls: 0,
            mem_forced: 0,
            retries: 0,
            lost_flops: 0.0,
            recovery_seconds: 0.0,
            trace,
        },
    ))
}

/// A per-task write-once output cell. The protocol guarantees exactly
/// one `set` (by the task's worker, before the dependency counter it
/// guards is decremented) and at most one `take` (by the parent's
/// worker, after that counter reached zero) — the inner mutex is never
/// contended and is held only for the pointer swap, never during
/// numeric work.
struct OnceSlot(Mutex<Option<Vec<f64>>>);

impl OnceSlot {
    fn new() -> Self {
        OnceSlot(Mutex::new(None))
    }

    fn set(&self, v: Vec<f64>) {
        let mut g = lock_clean(&self.0);
        debug_assert!(g.is_none(), "OnceSlot written twice");
        *g = Some(v);
    }

    fn take(&self) -> Option<Vec<f64>> {
        lock_clean(&self.0).take()
    }

    /// Copy the value without consuming it. The fault-tolerant
    /// assembly path reads children non-destructively so a failed
    /// attempt can re-read them on retry; the slot is consumed (and
    /// its block released) only once the parent succeeds.
    fn cloned(&self) -> Option<Vec<f64>> {
        lock_clean(&self.0).clone()
    }

    fn into_value(self) -> Vec<f64> {
        self.0
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
            .unwrap_or_default()
    }
}

/// Unwind guard for a crew worker: numeric work runs outside the queue
/// lock, so a panicking worker would otherwise exit without waking the
/// crew and leave the remaining workers blocked on the condvar forever.
/// On unwind this records an error and notifies everyone; the scoped
/// join then propagates the panic loudly instead of hanging.
struct PanicGuard<'a> {
    queue: &'a Mutex<ReadyQueue>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // never panic inside an unwinding drop: tolerate poisoning
            let mut st = lock_clean(self.queue);
            if st.error.is_none() {
                st.error = Some("worker panicked during factorization".into());
            }
            drop(st);
            self.cv.notify_all();
        }
    }
}

/// A live team job whose leader published helper seats.
struct OpenTeam {
    task: u32,
    /// Helper seats still free (replanned at completion events).
    seats: usize,
    /// Tile-grid cap on useful team size for this front.
    cap: usize,
    job: Arc<FrontTeamJob>,
}

/// The only shared-mutable state of the crew: the ready queue and the
/// dependency bookkeeping it guards. Everything numeric flows through
/// the per-task [`OnceSlot`]s, per-worker arenas and per-front team
/// jobs.
struct ReadyQueue {
    /// ready tasks, kept sorted descending by dispatch priority so
    /// `pop()` yields the earliest-starting task
    ready: Vec<u32>,
    unfinished_children: Vec<usize>,
    remaining: usize,
    /// tasks currently being factored (the share-replan active set,
    /// together with `ready`)
    running: Vec<u32>,
    /// live team jobs with published seats
    open: Vec<OpenTeam>,
    error: Option<String>,
    flops: f64,
    assembly_seconds: f64,
    /// per completed front: (front order, realized team size)
    team_log: Vec<(usize, usize)>,
    /// memory-cap admission gate (f64 words; `None` = unbounded)
    mem_cap: Option<usize>,
    /// reserved words of admitted tasks: `front + schur` at admission,
    /// front and consumed children blocks returned at completion. The
    /// reservation covers the admit→allocate window the [`MemGauge`]
    /// cannot see, so `planned >= gauge.live` always and an admission
    /// check against `planned` caps the measured peak too.
    planned: usize,
    /// wait episodes caused by the memory gate
    mem_stalls: usize,
    /// admissions forced through an over-cap gate because nothing was
    /// running (a smaller cap would deadlock, not help)
    mem_forced: usize,
    /// live crew-size target (elasticity): worker `w` parks on the
    /// condvar while `w >= crew_target`; worker 0 never parks, so the
    /// crew always makes progress
    crew_target: usize,
    /// completed fronts so far (drives elastic event thresholds)
    completions: usize,
    /// elastic crew events sorted by threshold; `elastic_next` indexes
    /// the first unapplied one
    elastic: Vec<super::fault::ElasticEvent>,
    elastic_next: usize,
    /// per-task injected failures still pending (fault plans only)
    inject_left: Vec<usize>,
    /// per-task failed-execution counts (the retry budget)
    attempts: Vec<usize>,
    /// failed executions that were requeued for another attempt
    retries: usize,
    /// front flops discarded by failed executions
    lost_flops: f64,
    /// wall seconds the crew spent in retry backoff
    recovery_seconds: f64,
    /// merged per-worker span buffers (tracing runs only; workers
    /// append their local vectors here once, at exit)
    spans: Vec<Span>,
}

/// Re-round the schedule shares of the active fronts into team sizes
/// and refresh the open jobs' free seats — called under the queue lock
/// at every task-completion event, so workers idled by a completion
/// can immediately rejoin the live teams.
fn replan(st: &mut ReadyQueue, plan: &TeamPlan) {
    if !plan.malleable() || st.open.is_empty() {
        return;
    }
    let active: Vec<u32> = st.running.iter().chain(st.ready.iter()).copied().collect();
    let sizes = plan.team_sizes_for_crew(&active, st.crew_target);
    for ot in &mut st.open {
        if let Some(pos) = active.iter().position(|&t| t == ot.task) {
            let want = sizes[pos].min(ot.cap);
            let members = 1 + ot.job.joined();
            ot.seats = want.saturating_sub(members);
        }
    }
}

/// What an idle worker decided to do next.
enum Duty {
    /// Lead the factorization of a popped front with this team size;
    /// the flag marks an injected transient failure consumed for this
    /// execution (the attempt dies after assembly, before the backend).
    Run(u32, usize, bool),
    /// Join a live team as a helper.
    Help(Arc<FrontTeamJob>),
}

/// Task-parallel thread-crew execution (one worker per front): real
/// tree parallelism with the schedule's dispatch order as priority.
pub fn execute_parallel<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, false, None, None, TraceSink::Null)
}

/// [`execute_parallel`] with span tracing (see [`execute_malleable_traced`]).
pub fn execute_parallel_traced<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, false, None, None, sink)
}

/// Malleable thread-crew execution: like [`execute_parallel`], but the
/// schedule's fractional shares become integer worker *teams* per
/// front ([`TeamPlan`]), and team-capable backends factor each front's
/// tiles cooperatively ([`FrontTeamJob`]) — bit-identical to the
/// serial blocked path, since tile ownership rather than reduction
/// order is partitioned.
pub fn execute_malleable<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, None, None, TraceSink::Null)
}

/// [`execute_malleable`] with span tracing: with a buffering sink the
/// crew records wall-clock Assemble / Factor / Retry / Stall spans into
/// per-worker local buffers (merged once at worker exit — no shared
/// state on the hot path) and the report carries the sorted
/// [`TraceLog`]. With [`TraceSink::Null`] the per-front cost is one
/// untaken branch; the factors are bit-identical either way. The sink
/// is taken verbatim — `MALLTREE_TRACE` is consulted only by the CLI
/// via [`TraceSink::from_env`].
pub fn execute_malleable_traced<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, None, None, sink)
}

/// [`execute_malleable`] with a **memory-cap admission gate**
/// (DESIGN.md §12): a ready front is only popped while the crew's
/// planned live words (admitted fronts + their Schur slabs +
/// outstanding contribution blocks, the reservation mirror of the
/// shared [`MemGauge`]) plus the front's own `nf² + m²` cost stay
/// under `cap_f64s`. Memory-blocked workers help open teams or wait
/// for a completion; when nothing is running the head task is
/// force-admitted (an infeasibly small cap degrades to near-serial
/// execution instead of deadlocking). When no forced admission
/// occurred, the gauge-measured peak is ≤ the cap (tested). Stall and
/// forced counts are reported in the [`super::ExecReport`].
pub fn execute_malleable_capped<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    cap_f64s: usize,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, Some(cap_f64s), None, TraceSink::Null)
}

/// [`execute_malleable_capped`] with span tracing: memory-gate waits
/// additionally surface as Stall spans (see [`execute_malleable_traced`]).
pub fn execute_malleable_capped_traced<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    cap_f64s: usize,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, Some(cap_f64s), None, sink)
}

/// [`execute_malleable`] under a [`FaultPlan`] — the self-healing mode
/// (DESIGN.md §13). The plan's injected failures kill the chosen
/// fronts' executions transiently; a failed front's partial work is
/// discarded, the front is requeued priority-sorted, and the worker
/// backs off linearly (`attempt × backoff_ms`), up to
/// [`FaultPlan::max_retries`] failures per front before the run errors
/// out. While a plan is active the crew assembles every front from
/// arena-accounted *copies* of its children's contribution blocks —
/// the originals are consumed only on success — so injected *and real*
/// backend failures are both retryable without losing inputs, and the
/// memory gauge stays balanced. The plan's elastic events shrink/grow
/// the live crew at completion thresholds: parked workers block on the
/// queue condvar (worker 0 never parks), and team shares are re-rounded
/// to the live crew at every completion. Retries, lost flops and
/// backoff time land in the [`super::ExecReport`]; factors stay
/// bit-identical to the serial blocked path (tested).
pub fn execute_malleable_faulty<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    plan: &FaultPlan,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, None, Some(plan), TraceSink::Null)
}

/// [`execute_malleable_faulty`] with span tracing: failed attempts
/// surface as Retry spans and backoff sleeps as Stall spans (see
/// [`execute_malleable_traced`]).
pub fn execute_malleable_faulty_traced<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    plan: &FaultPlan,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    run_crew(at, ap, schedule, backend, workers, true, None, Some(plan), sink)
}

/// Lock discipline (both modes): a worker holds the queue mutex only
/// to pop a task / claim a team seat and to publish completion
/// (decrement the parent's counter, push it when ready, replan seats).
/// Assembly (extend-add through the relative indices) and
/// factorization run with no lock held; a child's contribution block
/// is published into its [`OnceSlot`] *before* the counter decrement,
/// so the parent — which can only be popped after the decrement — sees
/// it without further synchronization.
#[allow(clippy::too_many_arguments)]
fn run_crew<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
    malleable: bool,
    mem_cap: Option<usize>,
    fault: Option<&FaultPlan>,
    sink: TraceSink,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let tracing = sink.enabled();
    let workers = workers.max(1);
    // fault plans ride the team path only: retries need the pre-cloned
    // assembly + requeue protocol implemented there
    debug_assert!(fault.is_none() || malleable, "fault plans require the malleable crew");
    let order = dispatch_order(at, schedule);
    // priority = position in dispatch order (lower = sooner)
    let mut prio = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        prio[v as usize] = i;
    }
    let unfinished: Vec<usize> = at.tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut ready: Vec<u32> = (0..n as u32)
        .filter(|&v| unfinished[v as usize] == 0)
        .collect();
    // sorted descending by priority index so pop() gives the smallest
    ready.sort_by(|&a, &b| prio[b as usize].cmp(&prio[a as usize]));

    // memory gate tables: admission reserves `front + schur` words; a
    // completion returns the front and the children blocks its
    // assembly consumed (their reservations were made at the
    // children's own admissions)
    let mem_cost: Vec<usize> = at
        .symbolic
        .supernodes
        .iter()
        .map(|sn| {
            let nf = sn.front_order();
            let m = nf - sn.width;
            nf * nf + m * m
        })
        .collect();
    let mem_release: Vec<usize> = at
        .tree
        .nodes
        .iter()
        .enumerate()
        .map(|(s, node)| {
            let sn = &at.symbolic.supernodes[s];
            let nf = sn.front_order();
            let children: usize = node
                .children
                .iter()
                .map(|&c| {
                    let csn = &at.symbolic.supernodes[c as usize];
                    let m = csn.front_order() - csn.width;
                    m * m
                })
                .sum();
            nf * nf + children
        })
        .collect();

    let plan = TeamPlan::new(schedule, n, workers, malleable);
    let team_backend = backend.team_capable();
    let queue = Mutex::new(ReadyQueue {
        ready,
        unfinished_children: unfinished,
        remaining: n,
        running: Vec::new(),
        open: Vec::new(),
        error: None,
        flops: 0.0,
        assembly_seconds: 0.0,
        team_log: Vec::new(),
        mem_cap,
        planned: 0,
        mem_stalls: 0,
        mem_forced: 0,
        crew_target: workers,
        completions: 0,
        elastic: fault.map(FaultPlan::sorted_elastic).unwrap_or_default(),
        elastic_next: 0,
        inject_left: fault
            .map(|f| f.injected_failures(n))
            .unwrap_or_else(|| vec![0; n]),
        attempts: vec![0usize; n],
        retries: 0,
        lost_flops: 0.0,
        recovery_seconds: 0.0,
        spans: Vec::new(),
    });
    let cv = Condvar::new();
    let contrib: Vec<OnceSlot> = (0..n).map(|_| OnceSlot::new()).collect();
    let panels: Vec<OnceSlot> = (0..n).map(|_| OnceSlot::new()).collect();
    let gauge = Arc::new(MemGauge::default());
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for w in 0..workers {
            let gauge = gauge.clone();
            let queue = &queue;
            let cv = &cv;
            let contrib = &contrib;
            let panels = &panels;
            let prio = &prio;
            let mem_cost = &mem_cost;
            let mem_release = &mem_release;
            let plan = &plan;
            scope.spawn(move || {
                let mut guard = PanicGuard { queue, cv, armed: true };
                let mut arena = FrontArena::for_tree(at).with_gauge(gauge);
                let mut local_flops = 0.0f64;
                let mut local_assembly = 0.0f64;
                let mut local_recovery = 0.0f64;
                let mut local_spans: Vec<Span> = Vec::new();
                loop {
                    // set while this worker sits memory-blocked on the
                    // condvar (tracing runs only); closed into a Stall
                    // span once a duty is found
                    let mut stall_from: Option<f64> = None;
                    let duty = {
                        let mut st = lock_clean(queue);
                        // one stall episode per continuous memory-blocked
                        // wait, not one per condvar wakeup
                        let mut stall_counted = false;
                        loop {
                            if st.remaining == 0 || st.error.is_some() {
                                st.flops += local_flops;
                                st.assembly_seconds += local_assembly;
                                st.recovery_seconds += local_recovery;
                                st.spans.append(&mut local_spans);
                                guard.armed = false;
                                cv.notify_all();
                                return;
                            }
                            // elastic parking: workers beyond the live
                            // crew target sit out on the condvar until a
                            // join event (or the end of the run) wakes
                            // them; worker 0 never parks since the
                            // target is clamped to at least one
                            if w >= st.crew_target {
                                st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                                continue;
                            }
                            // memory-cap admission gate: the head task
                            // is popped only while its reservation fits
                            // under the cap; when nothing is running or
                            // helping, force-admit (a smaller cap must
                            // degrade to serial, never deadlock)
                            let admissible = match (st.mem_cap, st.ready.last()) {
                                (Some(cap), Some(&v)) => {
                                    st.planned + mem_cost[v as usize] <= cap
                                }
                                _ => true,
                            };
                            if admissible || (st.running.is_empty() && st.open.is_empty()) {
                                if let Some(v) = st.ready.pop() {
                                    if !admissible {
                                        st.mem_forced += 1;
                                    }
                                    if st.mem_cap.is_some() {
                                        st.planned += mem_cost[v as usize];
                                    }
                                    // consume one pending injected
                                    // failure for this execution, if any
                                    let injected = st.inject_left[v as usize] > 0;
                                    if injected {
                                        st.inject_left[v as usize] -= 1;
                                    }
                                    st.running.push(v);
                                    let team = if plan.malleable() && team_backend {
                                        let active: Vec<u32> = st
                                            .running
                                            .iter()
                                            .chain(st.ready.iter())
                                            .copied()
                                            .collect();
                                        plan.team_size_of_crew(v, &active, st.crew_target)
                                    } else {
                                        1
                                    };
                                    break Duty::Run(v, team, injected);
                                }
                            }
                            if let Some(ot) = st.open.iter_mut().find(|o| o.seats > 0) {
                                ot.seats -= 1;
                                // register with the job while the lock
                                // is held: the leader's close-drain must
                                // wait for this worker even if it is
                                // descheduled before help_reserved()
                                ot.job.reserve();
                                break Duty::Help(ot.job.clone());
                            }
                            if !admissible && !st.ready.is_empty() && !stall_counted {
                                st.mem_stalls += 1;
                                stall_counted = true;
                                if tracing {
                                    stall_from = Some(t0.elapsed().as_nanos() as f64);
                                }
                            }
                            st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                        }
                    };
                    if let Some(from) = stall_from {
                        // the memory-blocked wait ended: whatever duty
                        // broke it bounds the Stall window (u32::MAX
                        // task = the wait ended in a Help seat)
                        let end = t0.elapsed().as_nanos() as f64;
                        local_spans.push(Span {
                            kind: SpanKind::Stall,
                            task: match &duty {
                                Duty::Run(v, ..) => *v,
                                Duty::Help(_) => u32::MAX,
                            },
                            worker: w as u32,
                            team: 0.0,
                            flops: 0.0,
                            start: from.min(end),
                            end,
                        });
                    }
                    let (task, team, injected) = match duty {
                        Duty::Help(job) => {
                            // cooperate on the live front until it
                            // closes, then rejoin the scheduler (the
                            // seat was reserved under the lock above)
                            job.help_reserved();
                            continue;
                        }
                        Duty::Run(v, team, injected) => (v, team, injected),
                    };
                    let s = task as usize;
                    let sn = &at.symbolic.supernodes[s];
                    let nf = sn.front_order();
                    let width = sn.width;
                    let m = nf - width;
                    // assembly and factorization both run without any
                    // shared lock: children blocks were published to
                    // their slots before this task became ready
                    let ta = Instant::now();
                    if fault.is_some() {
                        // fault-tolerant assembly: consume arena-
                        // accounted *copies* of the children blocks so
                        // a failed attempt can re-read the originals;
                        // they are taken (and released) only on success
                        let kids = &at.tree.nodes[s].children;
                        let mut clones: Vec<Option<Vec<f64>>> =
                            Vec::with_capacity(kids.len());
                        for &c in kids {
                            clones.push(contrib[c as usize].cloned().map(|src| {
                                let mut b = arena.alloc_block(src.len());
                                b.copy_from_slice(&src);
                                b
                            }));
                        }
                        assemble_front_arena(at, ap, s, &mut arena, |c| {
                            let i = kids.iter().position(|&k| k as usize == c)?;
                            clones[i].take()
                        });
                    } else {
                        assemble_front_arena(at, ap, s, &mut arena, |c| contrib[c].take());
                    }
                    let asm = ta.elapsed();
                    local_assembly += asm.as_secs_f64();
                    // factor-phase start in the t0 frame: assembly end
                    // (duration_since is pure arithmetic, no syscall)
                    let f_start = if tracing {
                        let a0 = ta.duration_since(t0).as_nanos() as f64;
                        let a1 = a0 + asm.as_nanos() as f64;
                        local_spans.push(Span {
                            kind: SpanKind::Assemble,
                            task,
                            worker: w as u32,
                            team: 1.0,
                            flops: 0.0,
                            start: a0,
                            end: a1,
                        });
                        a1
                    } else {
                        0.0
                    };
                    if malleable {
                        let mut members = 1usize;
                        let outcome: Result<()> = if injected {
                            // injected transient fault: the attempt dies
                            // after assembly, before the backend runs;
                            // the front's words are simply dropped
                            arena.end_front(nf);
                            Err(anyhow::anyhow!("injected transient fault"))
                        } else {
                            // team path: outputs ride in the job so
                            // helpers can reach them through the tile
                            // cursor; tile geometry and SIMD dispatch
                            // follow the backend's resolved KernelCfg
                            // so serial == team holds per configuration
                            let kcfg = backend.kernel_cfg();
                            let panel_buf = vec![0f64; nf * width];
                            let schur_buf =
                                if m > 0 { arena.alloc_block(m * m) } else { Vec::new() };
                            let job = Arc::new(FrontTeamJob::with_cfg(
                                kcfg,
                                nf,
                                width,
                                panel_buf,
                                schur_buf,
                                arena.take_scratch(),
                            ));
                            let cap = FrontTeamJob::max_useful_team_cfg(kcfg.block, nf, width);
                            let seats = team.min(cap).saturating_sub(1);
                            if seats > 0 && team_backend {
                                let mut st = lock_clean(queue);
                                st.open.push(OpenTeam {
                                    task,
                                    seats,
                                    cap,
                                    job: job.clone(),
                                });
                                drop(st);
                                cv.notify_all();
                            }
                            let outcome = backend.factor_front_team(arena.front(), &job);
                            arena.end_front(nf);
                            // the job closed before factor_front_team
                            // returned (leader guard), so the buffers are
                            // exclusively ours again
                            let (panel, schur) = job.take_outputs();
                            arena.put_scratch(job.take_pack());
                            members = 1 + job.joined();
                            if outcome.is_ok() {
                                // publish before the counter decrement
                                if m > 0 {
                                    contrib[s].set(schur);
                                }
                                panels[s].set(panel);
                            } else if m > 0 {
                                arena.release_block(schur);
                            }
                            outcome
                        };
                        if outcome.is_ok() && fault.is_some() {
                            // success under a fault plan: the originals
                            // the assembly worked from copies of are now
                            // consumed for real
                            for &c in &at.tree.nodes[s].children {
                                if let Some(b) = contrib[c as usize].take() {
                                    arena.release_block(b);
                                }
                            }
                        }
                        if tracing {
                            // one span per execution attempt: Factor on
                            // success, Retry on failure (injected or real)
                            let end = t0.elapsed().as_nanos() as f64;
                            local_spans.push(Span {
                                kind: if outcome.is_ok() {
                                    SpanKind::Factor
                                } else {
                                    SpanKind::Retry
                                },
                                task,
                                worker: w as u32,
                                team: members as f64,
                                flops: sn.flops(),
                                start: f_start.min(end),
                                end,
                            });
                        }
                        let mut backoff: Option<u64> = None;
                        let mut st = lock_clean(queue);
                        st.open.retain(|o| o.task != task);
                        st.running.retain(|&r| r != task);
                        match outcome {
                            Ok(()) => {
                                local_flops += sn.flops();
                                st.team_log.push((nf, members));
                                st.remaining -= 1;
                                complete(&mut st, at, s, prio, mem_release);
                                st.completions += 1;
                                while st.elastic_next < st.elastic.len()
                                    && st.elastic[st.elastic_next].after_completions
                                        <= st.completions
                                {
                                    let d = st.elastic[st.elastic_next].delta;
                                    st.elastic_next += 1;
                                    st.crew_target = (st.crew_target as isize + d)
                                        .clamp(1, workers as isize)
                                        as usize;
                                }
                                replan(&mut st, plan);
                            }
                            Err(e) => {
                                // shared linear-backoff schedule
                                // (util::retry): None both when no
                                // fault plan is active and when the
                                // retry budget is exhausted
                                let retry = fault.and_then(|fp| {
                                    st.attempts[s] += 1;
                                    fp.backoff().delay(st.attempts[s])
                                });
                                match retry {
                                    Some(delay_ms) => {
                                        // transient: discard the attempt,
                                        // requeue priority-sorted, back
                                        // off outside the lock
                                        st.retries += 1;
                                        st.lost_flops += sn.flops();
                                        let pos = st
                                            .ready
                                            .binary_search_by(|&x| {
                                                prio[s].cmp(&prio[x as usize])
                                            })
                                            .unwrap_or_else(|i| i);
                                        st.ready.insert(pos, task);
                                        backoff = Some(delay_ms.round() as u64);
                                    }
                                    None => {
                                        if st.error.is_none() {
                                            st.error = Some(if fault.is_some() {
                                                format!("task {s}: retries exhausted: {e:#}")
                                            } else {
                                                format!("task {s}: {e:#}")
                                            });
                                        }
                                    }
                                }
                            }
                        }
                        drop(st);
                        cv.notify_all();
                        if let Some(ms) = backoff {
                            // bounded linear backoff, reported as
                            // recovery time
                            let tr = Instant::now();
                            if ms > 0 {
                                std::thread::sleep(Duration::from_millis(ms));
                            }
                            let slept = tr.elapsed();
                            local_recovery += slept.as_secs_f64();
                            if tracing {
                                let s0 = tr.duration_since(t0).as_nanos() as f64;
                                local_spans.push(Span {
                                    kind: SpanKind::Stall,
                                    task,
                                    worker: w as u32,
                                    team: 0.0,
                                    flops: 0.0,
                                    start: s0,
                                    end: s0 + slept.as_nanos() as f64,
                                });
                            }
                        }
                    } else {
                        // task-parallel path: one worker per front
                        let outcome: Result<()> = (|| {
                            if width == nf {
                                panels[s].set(backend.full(arena.front(), nf)?);
                            } else {
                                let mut panel = vec![0f64; nf * width];
                                let mut schur = arena.alloc_block(m * m);
                                backend.partial_into(
                                    arena.front(),
                                    nf,
                                    width,
                                    &mut panel,
                                    &mut schur,
                                )?;
                                contrib[s].set(schur);
                                panels[s].set(panel);
                            }
                            Ok(())
                        })();
                        arena.end_front(nf);
                        if tracing {
                            let end = t0.elapsed().as_nanos() as f64;
                            local_spans.push(Span {
                                kind: if outcome.is_ok() {
                                    SpanKind::Factor
                                } else {
                                    SpanKind::Retry
                                },
                                task,
                                worker: w as u32,
                                team: 1.0,
                                flops: sn.flops(),
                                start: f_start.min(end),
                                end,
                            });
                        }
                        let mut st = lock_clean(queue);
                        st.running.retain(|&r| r != task);
                        match outcome {
                            Ok(()) => {
                                local_flops += sn.flops();
                                st.team_log.push((nf, 1));
                                st.remaining -= 1;
                                complete(&mut st, at, s, prio, mem_release);
                            }
                            Err(e) => {
                                // keep the first failure; later ones are
                                // usually casualties of the same root cause
                                if st.error.is_none() {
                                    st.error = Some(format!("task {s}: {e:#}"));
                                }
                            }
                        }
                        drop(st);
                        cv.notify_all();
                    }
                }
            });
        }
    });

    let mut st = queue.into_inner().unwrap_or_else(|p| p.into_inner());
    if let Some(e) = st.error.take() {
        anyhow::bail!("executor failed: {e}");
    }
    let wall = t0.elapsed().as_secs_f64();
    let trace = tracing.then(|| {
        let mut log = TraceLog::new("exec", TimeUnit::WallNs, workers);
        log.spans = std::mem::take(&mut st.spans);
        log.sort();
        log
    });
    Ok((
        Factorization {
            panels: panels.into_iter().map(OnceSlot::into_value).collect(),
            n: ap.n,
        },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            assembly_seconds: st.assembly_seconds,
            peak_front_bytes: gauge.peak_bytes(),
            tasks: n,
            flops: st.flops,
            backend: backend.name().to_string(),
            workers,
            malleable,
            team_log: st.team_log,
            mem_stalls: st.mem_stalls,
            mem_forced: st.mem_forced,
            retries: st.retries,
            lost_flops: st.lost_flops,
            recovery_seconds: st.recovery_seconds,
            trace,
        },
    ))
}

/// Completion bookkeeping under the queue lock: return the task's
/// memory reservation (its front plus the children blocks its assembly
/// consumed), decrement the parent's dependency counter and insert it
/// into the priority-sorted ready list once its last child finished.
fn complete(
    st: &mut ReadyQueue,
    at: &AssemblyTree,
    s: usize,
    prio: &[usize],
    mem_release: &[usize],
) {
    if st.mem_cap.is_some() {
        st.planned = st.planned.saturating_sub(mem_release[s]);
    }
    if let Some(parent) = at.tree.nodes[s].parent {
        let pi = parent as usize;
        st.unfinished_children[pi] -= 1;
        if st.unfinished_children[pi] == 0 {
            let pos = st
                .ready
                .binary_search_by(|&x| prio[pi].cmp(&prio[x as usize]))
                .unwrap_or_else(|e| e);
            st.ready.insert(pos, parent);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::backend::FrontFactor;
    use crate::frontal::multifrontal::{factorize, residual};
    use crate::frontal::RustBackend;
    use crate::sched::{PmSchedule, Profile};
    use crate::sparse::{gen, order, symbolic};
    use crate::util::prop::{check, Config};
    use crate::DEFAULT_ALPHA;

    fn setup(k: usize) -> (AssemblyTree, CscMatrix, Schedule) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let pm = PmSchedule::for_tree(&at.tree, DEFAULT_ALPHA, &Profile::constant(8.0));
        (at, ap, pm.schedule)
    }

    fn assert_bitwise(a: &Factorization, b: &Factorization, what: &str) {
        assert_eq!(a.panels.len(), b.panels.len());
        for (s, (pa, pb)) in a.panels.iter().zip(&b.panels).enumerate() {
            assert_eq!(pa.len(), pb.len(), "{what}: snode {s} panel length");
            for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: snode {s} entry {i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn serial_matches_reference_factorization() {
        let (at, ap, schedule) = setup(8);
        let (f, report) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let reference = factorize(&at, &ap, &RustBackend::default()).unwrap();
        for (a, b) in f.panels.iter().zip(&reference.panels) {
            assert_eq!(a, b);
        }
        assert!(report.flops > 0.0);
        assert_eq!(report.tasks, at.tree.len());
        assert!(report.peak_front_bytes > 0);
        assert!(residual(&at, &ap, &f) < 1e-12);
    }

    #[test]
    fn parallel_matches_reference_factorization() {
        let (at, ap, schedule) = setup(10);
        for workers in [1, 2, 4] {
            let (f, report) =
                execute_parallel(&at, &ap, &schedule, &RustBackend::default(), workers).unwrap();
            let r = residual(&at, &ap, &f);
            assert!(r < 1e-12, "workers={workers}: residual {r}");
            assert_eq!(report.workers, workers);
            assert!(!report.malleable);
        }
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        // deterministic math: each front's panel is a pure function of
        // its subtree (children are extend-added in child-list order on
        // both paths), so panels must agree regardless of interleaving
        let (at, ap, schedule) = setup(8);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (fp, _) = execute_parallel(&at, &ap, &schedule, &RustBackend::default(), 4).unwrap();
        for (a, b) in fs.panels.iter().zip(&fp.panels) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-9 * x.abs().max(1.0));
            }
        }
    }

    #[test]
    fn malleable_equals_serial_bitwise_randomized() {
        // the tentpole invariant: team-parallel factorization is
        // *bit-identical* to the serial blocked backend, across
        // randomized grid sizes, amalgamation settings and crew sizes
        check(
            Config { cases: 6, seed: 0x7EA2 },
            "malleable == serial blocked (bitwise)",
            |rng| (rng.range(6, 12), rng.range(0, 6), rng.range(2, 8)),
            |&(k, amalg, workers)| {
                let a = gen::grid_laplacian_2d(k);
                let perm = order::nested_dissection_2d(k);
                let at = symbolic::analyze(&a, &perm, amalg).unwrap();
                let ap = a.permute_sym(&at.symbolic.perm).unwrap();
                let pm = PmSchedule::for_tree(
                    &at.tree,
                    DEFAULT_ALPHA,
                    &Profile::constant(workers as f64),
                );
                let (fs, _) = execute_serial(&at, &ap, &pm.schedule, &RustBackend::default()).unwrap();
                let (fm, report) =
                    execute_malleable(&at, &ap, &pm.schedule, &RustBackend::default(), workers).unwrap();
                for (s, (pa, pb)) in fs.panels.iter().zip(&fm.panels).enumerate() {
                    if pa.len() != pb.len() {
                        return Err(format!("snode {s}: panel length mismatch"));
                    }
                    for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("snode {s} entry {i}: {x} vs {y}"));
                        }
                    }
                }
                if report.team_log.len() != at.tree.len() {
                    return Err(format!(
                        "team log covers {} of {} fronts",
                        report.team_log.len(),
                        at.tree.len()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn malleable_wide_fronts_match_serial_bitwise() {
        // a 3D problem: the root separator front (~k²) dominates the
        // flops and spans several tiles, so real teams form
        let a = gen::grid_laplacian_3d(10);
        let perm = order::nested_dissection_3d(10);
        let at = symbolic::analyze(&a, &perm, 8).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap();
        assert!(widest > crate::frontal::dense::BLOCK, "widest front {widest} fits one tile");
        let pm = PmSchedule::for_tree(&at.tree, DEFAULT_ALPHA, &Profile::constant(8.0));
        let (fs, _) = execute_serial(&at, &ap, &pm.schedule, &RustBackend::default()).unwrap();
        let (fm, report) = execute_malleable(&at, &ap, &pm.schedule, &RustBackend::default(), 8).unwrap();
        assert_bitwise(&fs, &fm, "grid3d_10");
        assert!(report.malleable);
        assert_eq!(report.team_log.len(), at.tree.len());
        assert!(report.flops > 0.0);
    }

    #[test]
    fn malleable_single_worker_degenerates_to_serial() {
        let (at, ap, schedule) = setup(9);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (fm, report) = execute_malleable(&at, &ap, &schedule, &RustBackend::default(), 1).unwrap();
        assert_bitwise(&fs, &fm, "1 worker");
        assert!(report.team_log.iter().all(|&(_, t)| t == 1));
    }

    #[test]
    fn capped_generous_matches_serial_with_no_gate_activity() {
        let (at, ap, schedule) = setup(10);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (fm, report) =
            execute_malleable_capped(&at, &ap, &schedule, &RustBackend::default(), 4, usize::MAX / 2)
                .unwrap();
        assert_bitwise(&fs, &fm, "generous cap");
        assert_eq!(report.mem_stalls, 0);
        assert_eq!(report.mem_forced, 0);
    }

    #[test]
    fn capped_run_respects_cap_when_not_forced() {
        use crate::frontal::arena::symbolic_peak_f64s;
        // caps from comfortably above the serial-optimal peak down to
        // absurd: factors stay bit-identical; whenever no admission was
        // forced, the gauge-measured peak respects the cap
        let (at, ap, schedule) = setup(12);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let serial_peak = symbolic_peak_f64s(&at);
        for cap in [4 * serial_peak, serial_peak + serial_peak / 4, 1usize] {
            let (fm, report) =
                execute_malleable_capped(&at, &ap, &schedule, &RustBackend::default(), 4, cap).unwrap();
            assert_bitwise(&fs, &fm, "capped");
            if report.mem_forced == 0 {
                assert!(
                    report.peak_front_bytes <= cap * std::mem::size_of::<f64>(),
                    "cap {cap}: measured peak {} bytes over the gate",
                    report.peak_front_bytes
                );
            }
        }
    }

    #[test]
    fn absurd_cap_degrades_to_serial_not_deadlock() {
        let (at, ap, schedule) = setup(8);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (fm, report) =
            execute_malleable_capped(&at, &ap, &schedule, &RustBackend::default(), 4, 1).unwrap();
        assert_bitwise(&fs, &fm, "absurd cap");
        // essentially every front is over the 1-word cap: the gate
        // forces them through one at a time instead of deadlocking
        assert!(report.mem_forced > 0, "1-word cap never forced an admission");
    }

    #[test]
    fn parallel_report_tracks_memory_and_assembly() {
        let (at, ap, schedule) = setup(10);
        let (_, report) = execute_parallel(&at, &ap, &schedule, &RustBackend::default(), 4).unwrap();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap();
        assert!(
            report.peak_front_bytes >= widest * widest * std::mem::size_of::<f64>(),
            "peak {} below widest front {widest}",
            report.peak_front_bytes
        );
        assert!(report.assembly_seconds >= 0.0);
        assert!(report.assembly_fraction() <= 1.0 + 1e-9);
    }

    /// Backend that fails on every front — the executor must surface
    /// the error from every worker without deadlocking the crew.
    struct FailingBackend;

    impl FrontBackend for FailingBackend {
        fn partial(&self, _front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
            anyhow::bail!("injected backend failure (n={n}, k={k})")
        }

        fn full(&self, _front: &[f64], n: usize) -> Result<Vec<f64>> {
            anyhow::bail!("injected backend failure (n={n})")
        }

        fn name(&self) -> &'static str {
            "failing"
        }
    }

    #[test]
    fn parallel_surfaces_backend_errors_without_hanging() {
        let (at, ap, schedule) = setup(8);
        for workers in [1, 4] {
            let err = execute_parallel(&at, &ap, &schedule, &FailingBackend, workers)
                .expect_err("failing backend must fail the run");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("injected backend failure"),
                "workers={workers}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn malleable_surfaces_backend_errors_without_hanging() {
        // FailingBackend is not team-capable: this exercises the
        // serial-fallback job path and its error/cleanup protocol
        let (at, ap, schedule) = setup(8);
        for workers in [1, 4] {
            let err = execute_malleable(&at, &ap, &schedule, &FailingBackend, workers)
                .expect_err("failing backend must fail the run");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("injected backend failure"),
                "workers={workers}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn serial_surfaces_backend_errors() {
        let (at, ap, schedule) = setup(6);
        let err = execute_serial(&at, &ap, &schedule, &FailingBackend)
            .expect_err("failing backend must fail the run");
        assert!(format!("{err:#}").contains("injected backend failure"));
    }

    #[test]
    fn dispatch_order_is_topological() {
        let (at, _, schedule) = setup(6);
        let order = dispatch_order(&at, &schedule);
        let mut pos = vec![0usize; at.tree.len()];
        for (i, &v) in order.iter().enumerate() {
            pos[v as usize] = i;
        }
        for (i, node) in at.tree.nodes.iter().enumerate() {
            for &c in &node.children {
                assert!(pos[c as usize] < pos[i], "child {c} after parent {i}");
            }
        }
    }

    #[test]
    fn dispatch_order_survives_nan_starts() {
        // a degenerate schedule (NaN span starts) must not panic the
        // sort — NaN tasks just sort to the back of the priority, and
        // the executor still runs correctly because precedence comes
        // from the dependency counters, not the priority order
        let (at, ap, mut schedule) = setup(6);
        for span in schedule.spans.iter_mut().take(3) {
            span.start = f64::NAN;
        }
        let order = dispatch_order(&at, &schedule);
        let mut seen = vec![false; at.tree.len()];
        for &v in &order {
            assert!(!std::mem::replace(&mut seen[v as usize], true));
        }
        assert!(seen.iter().all(|&s| s), "order is not a permutation");
        let (f, _) = execute_parallel(&at, &ap, &schedule, &RustBackend::default(), 4).unwrap();
        assert!(residual(&at, &ap, &f) < 1e-12);
    }

    #[test]
    fn empty_fault_plan_matches_plain_malleable_bitwise() {
        // the self-healing machinery (clone-assembly, retry accounting,
        // elastic bookkeeping) must be invisible when nothing is
        // injected
        let (at, ap, schedule) = setup(9);
        let plan = FaultPlan::new();
        assert!(plan.is_noop());
        let (fm, rm) = execute_malleable(&at, &ap, &schedule, &RustBackend::default(), 4).unwrap();
        let (ff, rf) =
            execute_malleable_faulty(&at, &ap, &schedule, &RustBackend::default(), 4, &plan).unwrap();
        assert_bitwise(&fm, &ff, "noop fault plan");
        assert_eq!(rf.retries, 0);
        assert_eq!(rf.lost_flops, 0.0);
        assert_eq!(rf.recovery_seconds, 0.0);
        assert_eq!(rf.team_log.len(), rm.team_log.len());
    }

    #[test]
    fn injected_failures_retry_to_bitwise_identical_factors() {
        let (at, ap, schedule) = setup(8);
        let n = at.tree.len();
        let mut plan = FaultPlan::new();
        plan.parse_inject("every:3:1", n).unwrap();
        let plan = plan.inject_task(n - 1, 2);
        let injected: usize = plan.injected_failures(n).iter().sum();
        assert!(injected > 2, "fixture too small to exercise retries");
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (ff, report) =
            execute_malleable_faulty(&at, &ap, &schedule, &RustBackend::default(), 4, &plan).unwrap();
        assert_bitwise(&fs, &ff, "injected faults");
        // every injected failure burns one retry (counts stay under the
        // per-task budget), the redone flops are accounted, and every
        // front still completes exactly once
        assert_eq!(report.retries, injected);
        assert!(report.lost_flops > 0.0);
        assert!(report.recovery_seconds >= 0.0);
        assert_eq!(report.team_log.len(), n);
        assert!(residual(&at, &ap, &ff) < 1e-12);
    }

    #[test]
    fn exhausted_retry_budget_surfaces_an_error() {
        let (at, ap, schedule) = setup(6);
        let mut plan = FaultPlan::new().inject_task(0, 10);
        plan.max_retries = 2;
        plan.backoff_ms = 0;
        let err = execute_malleable_faulty(&at, &ap, &schedule, &RustBackend::default(), 4, &plan)
            .expect_err("a fault deeper than the retry budget must fail the run");
        let msg = format!("{err:#}");
        assert!(msg.contains("retries exhausted"), "unexpected error: {msg}");
        assert!(msg.contains("injected transient fault"), "unexpected error: {msg}");
    }

    #[test]
    fn elastic_crew_events_keep_factors_bitwise() {
        let (at, ap, schedule) = setup(9);
        let mut plan = FaultPlan::new();
        // shrink the 4-crew to 1 almost immediately, regrow to 3 later
        plan.parse_elastic("-3@2,+2@12").unwrap();
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        let (fm, report) =
            execute_malleable_faulty(&at, &ap, &schedule, &RustBackend::default(), 4, &plan).unwrap();
        assert_bitwise(&fs, &fm, "elastic crew");
        assert_eq!(report.retries, 0);
        assert_eq!(report.team_log.len(), at.tree.len());
    }

    #[test]
    fn once_slot_tolerates_a_poisoned_mutex() {
        // regression for the poison-hardening audit: a worker panic
        // must not turn every subsequent slot access into a second
        // panic — the write-once protocol makes the state consistent
        // at any release point
        let slot = OnceSlot::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = slot.0.lock().unwrap();
            panic!("poison the slot");
        }));
        assert!(caught.is_err());
        assert!(slot.0.is_poisoned());
        slot.set(vec![2.5]);
        assert_eq!(slot.cloned(), Some(vec![2.5]));
        assert_eq!(slot.take(), Some(vec![2.5]));
        assert_eq!(slot.take(), None);
    }

    #[test]
    fn lock_clean_recovers_state_behind_a_poisoned_lock() {
        let m = Mutex::new(vec![1u32, 2]);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(caught.is_err() && m.is_poisoned());
        lock_clean(&m).push(3);
        assert_eq!(*lock_clean(&m), vec![1, 2, 3]);
    }

    /// Backend that panics (rather than erroring) on every front.
    struct PanickingBackend;

    impl FrontBackend for PanickingBackend {
        fn partial(&self, _front: &[f64], _n: usize, _k: usize) -> Result<FrontFactor> {
            panic!("injected backend panic")
        }

        fn full(&self, _front: &[f64], _n: usize) -> Result<Vec<f64>> {
            panic!("injected backend panic")
        }

        fn name(&self) -> &'static str {
            "panicking"
        }
    }

    #[test]
    fn null_sink_reports_no_trace_buffer_records_one() {
        let (at, ap, schedule) = setup(8);
        let (_, r0) = execute_serial(&at, &ap, &schedule, &RustBackend::default()).unwrap();
        assert!(r0.trace.is_none(), "untraced entry point grew a trace");
        let (_, rn) = execute_malleable_traced(
            &at,
            &ap,
            &schedule,
            &RustBackend::default(),
            4,
            TraceSink::Null,
        )
        .unwrap();
        assert!(rn.trace.is_none(), "Null sink recorded spans");
        let (_, rb) =
            execute_serial_traced(&at, &ap, &schedule, &RustBackend::default(), TraceSink::Buffer)
                .unwrap();
        let log = rb.trace.expect("Buffer sink dropped the trace");
        log.validate().unwrap();
        assert_eq!(log.unit, TimeUnit::WallNs);
        assert_eq!(log.workers, 1);
        // serial path: one Assemble + one Factor per front, nothing else
        assert_eq!(log.spans_of(SpanKind::Factor).count(), at.tree.len());
        assert_eq!(log.spans_of(SpanKind::Assemble).count(), at.tree.len());
        assert_eq!(log.spans.len(), 2 * at.tree.len());
    }

    #[test]
    fn traced_crew_covers_every_front_exactly_once() {
        // the span-schema property: across randomized problems and crew
        // sizes, every executed front appears exactly once as a Factor
        // span with end >= start, and tracing never perturbs the factors
        check(
            Config { cases: 4, seed: 0x0B5 },
            "traced crew emits one Factor span per front",
            |rng| (rng.range(6, 11), rng.range(2, 6)),
            |&(k, workers)| {
                let a = gen::grid_laplacian_2d(k);
                let perm = order::nested_dissection_2d(k);
                let at = symbolic::analyze(&a, &perm, 2).unwrap();
                let ap = a.permute_sym(&at.symbolic.perm).unwrap();
                let pm = PmSchedule::for_tree(
                    &at.tree,
                    DEFAULT_ALPHA,
                    &Profile::constant(workers as f64),
                );
                let (fs, _) =
                    execute_serial(&at, &ap, &pm.schedule, &RustBackend::default()).unwrap();
                let (fm, report) = execute_malleable_traced(
                    &at,
                    &ap,
                    &pm.schedule,
                    &RustBackend::default(),
                    workers,
                    TraceSink::Buffer,
                )
                .unwrap();
                for (s, (pa, pb)) in fs.panels.iter().zip(&fm.panels).enumerate() {
                    for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("snode {s} entry {i}: tracing changed the math"));
                        }
                    }
                }
                let log = report.trace.as_ref().ok_or("no trace")?;
                log.validate().map_err(|e| e.to_string())?;
                let n = at.tree.len();
                let mut seen = vec![0usize; n];
                for sp in log.spans_of(SpanKind::Factor) {
                    if sp.end < sp.start {
                        return Err(format!("task {}: end {} < start {}", sp.task, sp.end, sp.start));
                    }
                    if sp.team < 1.0 {
                        return Err(format!("task {}: Factor span with team {}", sp.task, sp.team));
                    }
                    seen[sp.task as usize] += 1;
                }
                if let Some(s) = seen.iter().position(|&c| c != 1) {
                    return Err(format!("front {s} has {} Factor spans, want 1", seen[s]));
                }
                if log.spans_of(SpanKind::Assemble).count() != n {
                    return Err("Assemble spans do not cover every front".into());
                }
                let traced_flops: f64 =
                    log.spans_of(SpanKind::Factor).map(|s| s.flops).sum();
                if (traced_flops - report.flops).abs() > 1e-6 * report.flops.max(1.0) {
                    return Err(format!(
                        "span flops {traced_flops} disagree with report {}",
                        report.flops
                    ));
                }
                // the timed log rebuilds the legacy team_log measurement
                let widths: Vec<usize> =
                    at.symbolic.supernodes.iter().map(|s| s.front_order()).collect();
                let mut rebuilt = log.team_log(&widths);
                let mut legacy = report.team_log.clone();
                rebuilt.sort_unstable();
                legacy.sort_unstable();
                if rebuilt != legacy {
                    return Err("trace team_log view disagrees with legacy log".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn traced_faulty_run_records_retries_and_backoff_stalls() {
        let (at, ap, schedule) = setup(8);
        let n = at.tree.len();
        let mut plan = FaultPlan::new();
        plan.parse_inject("every:4:1", n).unwrap();
        plan.backoff_ms = 0;
        let (_, report) = execute_malleable_faulty_traced(
            &at,
            &ap,
            &schedule,
            &RustBackend::default(),
            4,
            &plan,
            TraceSink::Buffer,
        )
        .unwrap();
        assert!(report.retries > 0, "fixture injected nothing");
        let log = report.trace.expect("no trace from faulty run");
        log.validate().unwrap();
        // one Retry span per failed attempt, one backoff Stall each,
        // and still exactly one Factor span per front
        assert_eq!(log.spans_of(SpanKind::Retry).count(), report.retries);
        assert_eq!(log.spans_of(SpanKind::Stall).count(), report.retries);
        assert_eq!(log.spans_of(SpanKind::Factor).count(), n);
    }

    #[test]
    fn panicking_backend_propagates_without_hanging_the_crew() {
        // the PanicGuard + poison-tolerant locks keep the rest of the
        // crew orderly: they observe the recorded error and exit, the
        // scoped join re-raises the original panic instead of
        // deadlocking on the condvar
        let (at, ap, schedule) = setup(6);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = execute_parallel(&at, &ap, &schedule, &PanickingBackend, 4);
        }));
        assert!(caught.is_err(), "worker panic must propagate, not hang");
    }
}
