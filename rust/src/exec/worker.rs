//! Schedule-driven execution of the numeric multifrontal factorization.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::frontal::backend::FrontBackend;
use crate::frontal::multifrontal::{assemble_front, Factorization};
use crate::sched::Schedule;
use crate::sparse::{AssemblyTree, CscMatrix};

/// Order tasks by schedule start time, tie-broken by topological
/// position (children first). For any valid schedule this is a
/// topological order: a parent starts only after its children finish.
fn dispatch_order(at: &AssemblyTree, schedule: &Schedule) -> Vec<u32> {
    let n = at.tree.len();
    let mut start = vec![f64::INFINITY; n];
    for s in &schedule.spans {
        start[s.task as usize] = s.start;
    }
    let mut topo_pos = vec![0usize; n];
    for (i, &v) in at.tree.topo_up().iter().enumerate() {
        topo_pos[v as usize] = i;
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by(|&a, &b| {
        start[a as usize]
            .partial_cmp(&start[b as usize])
            .unwrap()
            .then(topo_pos[a as usize].cmp(&topo_pos[b as usize]))
    });
    order
}

fn factor_one(
    at: &AssemblyTree,
    ap: &CscMatrix,
    s: usize,
    backend: &dyn FrontBackend,
    contrib: &mut HashMap<usize, Vec<f64>>,
    panels: &mut [Vec<f64>],
) -> Result<f64> {
    let sn = &at.symbolic.supernodes[s];
    let nf = sn.front_order();
    let width = sn.width;
    let front = assemble_front(at, ap, s, contrib);
    let flops = sn.flops();
    if width == nf {
        panels[s] = backend
            .full(&front, nf)
            .with_context(|| format!("full factor of supernode {s}"))?;
    } else {
        let f = backend
            .partial(&front, nf, width)
            .with_context(|| format!("partial factor of supernode {s}"))?;
        let m = nf - width;
        let mut panel = vec![0f64; nf * width];
        panel[..width * width].copy_from_slice(&f.l11);
        for i in 0..m {
            panel[(width + i) * width..(width + i + 1) * width]
                .copy_from_slice(&f.l21[i * width..(i + 1) * width]);
        }
        contrib.insert(s, f.schur);
        panels[s] = panel;
    }
    Ok(flops)
}

/// Serial ("accelerator command queue") execution: fronts stream to the
/// backend in schedule-dispatch order. This is the path the PJRT
/// backend uses — the XLA CPU client is one logical device.
pub fn execute_serial(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &dyn FrontBackend,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let order = dispatch_order(at, schedule);
    let mut contrib: HashMap<usize, Vec<f64>> = HashMap::new();
    let mut panels: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut flops = 0.0;
    let t0 = Instant::now();
    for &v in &order {
        flops += factor_one(at, ap, v as usize, backend, &mut contrib, &mut panels)?;
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((
        Factorization { panels, n: ap.n },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            tasks: n,
            flops,
            backend: backend.name().to_string(),
            workers: 1,
        },
    ))
}

struct CrewState {
    /// ready tasks, kept sorted descending by dispatch priority so
    /// `pop()` yields the earliest-starting task
    ready: Vec<u32>,
    unfinished_children: Vec<usize>,
    contrib: HashMap<usize, Vec<f64>>,
    panels: Vec<Vec<f64>>,
    flops: f64,
    remaining: usize,
    error: Option<String>,
}

/// Thread-crew execution for `Send + Sync` backends: real tree
/// parallelism with the schedule's dispatch order as priority.
pub fn execute_parallel<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let order = dispatch_order(at, schedule);
    // priority = position in dispatch order (lower = sooner)
    let mut prio = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        prio[v as usize] = i;
    }
    let unfinished: Vec<usize> = at.tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut ready: Vec<u32> = (0..n as u32)
        .filter(|&v| unfinished[v as usize] == 0)
        .collect();
    // sorted descending by priority index so pop() gives the smallest
    ready.sort_by(|&a, &b| prio[b as usize].cmp(&prio[a as usize]));

    let state = Mutex::new(CrewState {
        ready,
        unfinished_children: unfinished,
        contrib: HashMap::new(),
        panels: vec![Vec::new(); n],
        flops: 0.0,
        remaining: n,
        error: None,
    });
    let cv = Condvar::new();
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            scope.spawn(|| loop {
                let task = {
                    let mut st = state.lock().unwrap();
                    loop {
                        if st.remaining == 0 || st.error.is_some() {
                            cv.notify_all();
                            return;
                        }
                        if let Some(v) = st.ready.pop() {
                            break v;
                        }
                        st = cv.wait(st).unwrap();
                    }
                };
                let s = task as usize;
                let sn = &at.symbolic.supernodes[s];
                // assemble under the lock (children contributions move
                // out of the shared map), factor outside it
                let front = {
                    let mut st = state.lock().unwrap();
                    assemble_front(at, ap, s, &mut st.contrib)
                };
                let nf = sn.front_order();
                let width = sn.width;
                let result: Result<(Vec<f64>, Option<Vec<f64>>)> = (|| {
                    if width == nf {
                        Ok((backend.full(&front, nf)?, None))
                    } else {
                        let f = backend.partial(&front, nf, width)?;
                        let m = nf - width;
                        let mut panel = vec![0f64; nf * width];
                        panel[..width * width].copy_from_slice(&f.l11);
                        for i in 0..m {
                            panel[(width + i) * width..(width + i + 1) * width]
                                .copy_from_slice(&f.l21[i * width..(i + 1) * width]);
                        }
                        Ok((panel, Some(f.schur)))
                    }
                })();
                let mut st = state.lock().unwrap();
                match result {
                    Ok((panel, schur)) => {
                        st.panels[s] = panel;
                        if let Some(schur) = schur {
                            st.contrib.insert(s, schur);
                        }
                        st.flops += sn.flops();
                        st.remaining -= 1;
                        if let Some(parent) = at.tree.nodes[s].parent {
                            let pi = parent as usize;
                            st.unfinished_children[pi] -= 1;
                            if st.unfinished_children[pi] == 0 {
                                let pos = st
                                    .ready
                                    .binary_search_by(|&x| {
                                        prio[parent as usize].cmp(&prio[x as usize])
                                    })
                                    .unwrap_or_else(|e| e);
                                st.ready.insert(pos, parent);
                            }
                        }
                    }
                    Err(e) => {
                        st.error = Some(format!("task {s}: {e:#}"));
                        st.remaining = 0;
                    }
                }
                cv.notify_all();
            });
        }
    });

    let st = state.into_inner().unwrap();
    if let Some(e) = st.error {
        anyhow::bail!("executor failed: {e}");
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((
        Factorization { panels: st.panels, n: ap.n },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            tasks: n,
            flops: st.flops,
            backend: backend.name().to_string(),
            workers: workers.max(1),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::multifrontal::{factorize, residual};
    use crate::frontal::RustBackend;
    use crate::sched::{PmSchedule, Profile};
    use crate::sparse::{gen, order, symbolic};
    use crate::DEFAULT_ALPHA;

    fn setup(k: usize) -> (AssemblyTree, CscMatrix, Schedule) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let pm = PmSchedule::for_tree(&at.tree, DEFAULT_ALPHA, &Profile::constant(8.0));
        (at, ap, pm.schedule)
    }

    #[test]
    fn serial_matches_reference_factorization() {
        let (at, ap, schedule) = setup(8);
        let (f, report) = execute_serial(&at, &ap, &schedule, &RustBackend).unwrap();
        let reference = factorize(&at, &ap, &RustBackend).unwrap();
        for (a, b) in f.panels.iter().zip(&reference.panels) {
            assert_eq!(a, b);
        }
        assert!(report.flops > 0.0);
        assert_eq!(report.tasks, at.tree.len());
        assert!(residual(&at, &ap, &f) < 1e-12);
    }

    #[test]
    fn parallel_matches_reference_factorization() {
        let (at, ap, schedule) = setup(10);
        for workers in [1, 2, 4] {
            let (f, report) =
                execute_parallel(&at, &ap, &schedule, &RustBackend, workers).unwrap();
            let r = residual(&at, &ap, &f);
            assert!(r < 1e-12, "workers={workers}: residual {r}");
            assert_eq!(report.workers, workers);
        }
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        // deterministic math: panels must be identical regardless of
        // execution interleaving (extend-add is order-dependent in
        // floating point ONLY if siblings overlap rows; grid problems
        // with exact symbolic structure commute here because addition
        // order per entry is child-set dependent... we still assert
        // near-equality to catch logic bugs)
        let (at, ap, schedule) = setup(8);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend).unwrap();
        let (fp, _) = execute_parallel(&at, &ap, &schedule, &RustBackend, 4).unwrap();
        for (a, b) in fs.panels.iter().zip(&fp.panels) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-9 * x.abs().max(1.0));
            }
        }
    }

    #[test]
    fn dispatch_order_is_topological() {
        let (at, _, schedule) = setup(6);
        let order = dispatch_order(&at, &schedule);
        let mut pos = vec![0usize; at.tree.len()];
        for (i, &v) in order.iter().enumerate() {
            pos[v as usize] = i;
        }
        for (i, node) in at.tree.nodes.iter().enumerate() {
            for &c in &node.children {
                assert!(pos[c as usize] < pos[i], "child {c} after parent {i}");
            }
        }
    }
}
