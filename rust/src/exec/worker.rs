//! Schedule-driven execution of the numeric multifrontal factorization.
//!
//! Both executors run the arena assembly path (precomputed relative
//! indices, recycled contribution slabs — see [`crate::frontal::arena`]).
//! The parallel crew is **lock-light**: task outputs live in per-task
//! write-once slots, so extend-add and front factorization run outside
//! any shared lock; only the ready-queue push/pop (plus the dependency
//! counters it guards) is synchronized.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::frontal::arena::{FrontArena, MemGauge};
use crate::frontal::backend::FrontBackend;
use crate::frontal::multifrontal::{assemble_front_arena, factor_front_arena, Factorization};
use crate::sched::Schedule;
use crate::sparse::{AssemblyTree, CscMatrix};

/// Order tasks by schedule start time, tie-broken by topological
/// position (children first). For any valid schedule this is a
/// topological order: a parent starts only after its children finish.
fn dispatch_order(at: &AssemblyTree, schedule: &Schedule) -> Vec<u32> {
    let n = at.tree.len();
    let mut start = vec![f64::INFINITY; n];
    for s in &schedule.spans {
        start[s.task as usize] = s.start;
    }
    let mut topo_pos = vec![0usize; n];
    for (i, &v) in at.tree.topo_up().iter().enumerate() {
        topo_pos[v as usize] = i;
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by(|&a, &b| {
        start[a as usize]
            .partial_cmp(&start[b as usize])
            .unwrap()
            .then(topo_pos[a as usize].cmp(&topo_pos[b as usize]))
    });
    order
}

/// Serial ("accelerator command queue") execution: fronts stream to the
/// backend in schedule-dispatch order. This is the path the PJRT
/// backend uses — the XLA CPU client is one logical device.
pub fn execute_serial(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &dyn FrontBackend,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let order = dispatch_order(at, schedule);
    let mut arena = FrontArena::for_tree(at);
    let mut contrib: Vec<Option<Vec<f64>>> = vec![None; n];
    let mut panels: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut flops = 0.0;
    let mut assembly = 0.0;
    let t0 = Instant::now();
    for &v in &order {
        let s = v as usize;
        assembly += factor_front_arena(at, ap, s, backend, &mut arena, &mut contrib, &mut panels)?;
        flops += at.symbolic.supernodes[s].flops();
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((
        Factorization { panels, n: ap.n },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            assembly_seconds: assembly,
            peak_front_bytes: arena.peak_bytes(),
            tasks: n,
            flops,
            backend: backend.name().to_string(),
            workers: 1,
        },
    ))
}

/// A per-task write-once output cell. The protocol guarantees exactly
/// one `set` (by the task's worker, before the dependency counter it
/// guards is decremented) and at most one `take` (by the parent's
/// worker, after that counter reached zero) — the inner mutex is never
/// contended and is held only for the pointer swap, never during
/// numeric work.
struct OnceSlot(Mutex<Option<Vec<f64>>>);

impl OnceSlot {
    fn new() -> Self {
        OnceSlot(Mutex::new(None))
    }

    fn set(&self, v: Vec<f64>) {
        let mut g = self.0.lock().unwrap();
        debug_assert!(g.is_none(), "OnceSlot written twice");
        *g = Some(v);
    }

    fn take(&self) -> Option<Vec<f64>> {
        self.0.lock().unwrap().take()
    }

    fn into_value(self) -> Vec<f64> {
        self.0.into_inner().unwrap().unwrap_or_default()
    }
}

/// Unwind guard for a crew worker: numeric work runs outside the queue
/// lock, so a panicking worker would otherwise exit without waking the
/// crew and leave the remaining workers blocked on the condvar forever.
/// On unwind this records an error and notifies everyone; the scoped
/// join then propagates the panic loudly instead of hanging.
struct PanicGuard<'a> {
    queue: &'a Mutex<ReadyQueue>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // never panic inside an unwinding drop: tolerate poisoning
            let mut st = match self.queue.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if st.error.is_none() {
                st.error = Some("worker panicked during factorization".into());
            }
            drop(st);
            self.cv.notify_all();
        }
    }
}

/// The only shared-mutable state of the crew: the ready queue and the
/// dependency bookkeeping it guards. Everything numeric flows through
/// the per-task [`OnceSlot`]s and per-worker arenas.
struct ReadyQueue {
    /// ready tasks, kept sorted descending by dispatch priority so
    /// `pop()` yields the earliest-starting task
    ready: Vec<u32>,
    unfinished_children: Vec<usize>,
    remaining: usize,
    error: Option<String>,
    flops: f64,
    assembly_seconds: f64,
}

/// Thread-crew execution for `Send + Sync` backends: real tree
/// parallelism with the schedule's dispatch order as priority.
///
/// Lock discipline: a worker holds the queue mutex only to pop a task
/// and to publish completion (decrement the parent's counter, push it
/// when ready). Assembly (extend-add through the relative indices) and
/// factorization run with no lock held; a child's contribution block
/// is published into its [`OnceSlot`] *before* the counter decrement,
/// so the parent — which can only be popped after the decrement — sees
/// it without further synchronization.
pub fn execute_parallel<B: FrontBackend + Sync>(
    at: &AssemblyTree,
    ap: &CscMatrix,
    schedule: &Schedule,
    backend: &B,
    workers: usize,
) -> Result<(Factorization, super::ExecReport)> {
    let n = at.tree.len();
    let order = dispatch_order(at, schedule);
    // priority = position in dispatch order (lower = sooner)
    let mut prio = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        prio[v as usize] = i;
    }
    let unfinished: Vec<usize> = at.tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut ready: Vec<u32> = (0..n as u32)
        .filter(|&v| unfinished[v as usize] == 0)
        .collect();
    // sorted descending by priority index so pop() gives the smallest
    ready.sort_by(|&a, &b| prio[b as usize].cmp(&prio[a as usize]));

    let queue = Mutex::new(ReadyQueue {
        ready,
        unfinished_children: unfinished,
        remaining: n,
        error: None,
        flops: 0.0,
        assembly_seconds: 0.0,
    });
    let cv = Condvar::new();
    let contrib: Vec<OnceSlot> = (0..n).map(|_| OnceSlot::new()).collect();
    let panels: Vec<OnceSlot> = (0..n).map(|_| OnceSlot::new()).collect();
    let gauge = std::sync::Arc::new(MemGauge::default());
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            scope.spawn(|| {
                let mut guard = PanicGuard { queue: &queue, cv: &cv, armed: true };
                let mut arena = FrontArena::for_tree(at).with_gauge(gauge.clone());
                let mut local_flops = 0.0f64;
                let mut local_assembly = 0.0f64;
                loop {
                    let task = {
                        let mut st = queue.lock().unwrap();
                        loop {
                            if st.remaining == 0 || st.error.is_some() {
                                st.flops += local_flops;
                                st.assembly_seconds += local_assembly;
                                guard.armed = false;
                                cv.notify_all();
                                return;
                            }
                            if let Some(v) = st.ready.pop() {
                                break v;
                            }
                            st = cv.wait(st).unwrap();
                        }
                    };
                    let s = task as usize;
                    let sn = &at.symbolic.supernodes[s];
                    let nf = sn.front_order();
                    let width = sn.width;
                    // assembly and factorization both run without any
                    // shared lock: children blocks were published to
                    // their slots before this task became ready
                    let ta = Instant::now();
                    assemble_front_arena(at, ap, s, &mut arena, |c| contrib[c].take());
                    local_assembly += ta.elapsed().as_secs_f64();
                    let outcome: Result<()> = (|| {
                        if width == nf {
                            panels[s].set(backend.full(arena.front(), nf)?);
                        } else {
                            let m = nf - width;
                            let mut panel = vec![0f64; nf * width];
                            let mut schur = arena.alloc_block(m * m);
                            backend.partial_into(
                                arena.front(),
                                nf,
                                width,
                                &mut panel,
                                &mut schur,
                            )?;
                            contrib[s].set(schur);
                            panels[s].set(panel);
                        }
                        Ok(())
                    })();
                    arena.end_front(nf);
                    let mut st = queue.lock().unwrap();
                    match outcome {
                        Ok(()) => {
                            local_flops += sn.flops();
                            st.remaining -= 1;
                            if let Some(parent) = at.tree.nodes[s].parent {
                                let pi = parent as usize;
                                st.unfinished_children[pi] -= 1;
                                if st.unfinished_children[pi] == 0 {
                                    let pos = st
                                        .ready
                                        .binary_search_by(|&x| {
                                            prio[pi].cmp(&prio[x as usize])
                                        })
                                        .unwrap_or_else(|e| e);
                                    st.ready.insert(pos, parent);
                                }
                            }
                        }
                        Err(e) => {
                            // keep the first failure; later ones are
                            // usually casualties of the same root cause
                            if st.error.is_none() {
                                st.error = Some(format!("task {s}: {e:#}"));
                            }
                        }
                    }
                    drop(st);
                    cv.notify_all();
                }
            });
        }
    });

    let st = queue.into_inner().unwrap();
    if let Some(e) = st.error {
        anyhow::bail!("executor failed: {e}");
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((
        Factorization {
            panels: panels.into_iter().map(OnceSlot::into_value).collect(),
            n: ap.n,
        },
        super::ExecReport {
            virtual_makespan: schedule.makespan,
            wall_seconds: wall,
            assembly_seconds: st.assembly_seconds,
            peak_front_bytes: gauge.peak_bytes(),
            tasks: n,
            flops: st.flops,
            backend: backend.name().to_string(),
            workers: workers.max(1),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::backend::FrontFactor;
    use crate::frontal::multifrontal::{factorize, residual};
    use crate::frontal::RustBackend;
    use crate::sched::{PmSchedule, Profile};
    use crate::sparse::{gen, order, symbolic};
    use crate::DEFAULT_ALPHA;

    fn setup(k: usize) -> (AssemblyTree, CscMatrix, Schedule) {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let ap = a.permute_sym(&at.symbolic.perm).unwrap();
        let pm = PmSchedule::for_tree(&at.tree, DEFAULT_ALPHA, &Profile::constant(8.0));
        (at, ap, pm.schedule)
    }

    #[test]
    fn serial_matches_reference_factorization() {
        let (at, ap, schedule) = setup(8);
        let (f, report) = execute_serial(&at, &ap, &schedule, &RustBackend).unwrap();
        let reference = factorize(&at, &ap, &RustBackend).unwrap();
        for (a, b) in f.panels.iter().zip(&reference.panels) {
            assert_eq!(a, b);
        }
        assert!(report.flops > 0.0);
        assert_eq!(report.tasks, at.tree.len());
        assert!(report.peak_front_bytes > 0);
        assert!(residual(&at, &ap, &f) < 1e-12);
    }

    #[test]
    fn parallel_matches_reference_factorization() {
        let (at, ap, schedule) = setup(10);
        for workers in [1, 2, 4] {
            let (f, report) =
                execute_parallel(&at, &ap, &schedule, &RustBackend, workers).unwrap();
            let r = residual(&at, &ap, &f);
            assert!(r < 1e-12, "workers={workers}: residual {r}");
            assert_eq!(report.workers, workers);
        }
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        // deterministic math: each front's panel is a pure function of
        // its subtree (children are extend-added in child-list order on
        // both paths), so panels must agree regardless of interleaving
        let (at, ap, schedule) = setup(8);
        let (fs, _) = execute_serial(&at, &ap, &schedule, &RustBackend).unwrap();
        let (fp, _) = execute_parallel(&at, &ap, &schedule, &RustBackend, 4).unwrap();
        for (a, b) in fs.panels.iter().zip(&fp.panels) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() <= 1e-9 * x.abs().max(1.0));
            }
        }
    }

    #[test]
    fn parallel_report_tracks_memory_and_assembly() {
        let (at, ap, schedule) = setup(10);
        let (_, report) = execute_parallel(&at, &ap, &schedule, &RustBackend, 4).unwrap();
        let widest = at
            .symbolic
            .supernodes
            .iter()
            .map(|s| s.front_order())
            .max()
            .unwrap();
        assert!(
            report.peak_front_bytes >= widest * widest * std::mem::size_of::<f64>(),
            "peak {} below widest front {widest}",
            report.peak_front_bytes
        );
        assert!(report.assembly_seconds >= 0.0);
        assert!(report.assembly_fraction() <= 1.0 + 1e-9);
    }

    /// Backend that fails on every front — the executor must surface
    /// the error from every worker without deadlocking the crew.
    struct FailingBackend;

    impl FrontBackend for FailingBackend {
        fn partial(&self, _front: &[f64], n: usize, k: usize) -> Result<FrontFactor> {
            anyhow::bail!("injected backend failure (n={n}, k={k})")
        }

        fn full(&self, _front: &[f64], n: usize) -> Result<Vec<f64>> {
            anyhow::bail!("injected backend failure (n={n})")
        }

        fn name(&self) -> &'static str {
            "failing"
        }
    }

    #[test]
    fn parallel_surfaces_backend_errors_without_hanging() {
        let (at, ap, schedule) = setup(8);
        for workers in [1, 4] {
            let err = execute_parallel(&at, &ap, &schedule, &FailingBackend, workers)
                .expect_err("failing backend must fail the run");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("injected backend failure"),
                "workers={workers}: unexpected error {msg}"
            );
        }
    }

    #[test]
    fn serial_surfaces_backend_errors() {
        let (at, ap, schedule) = setup(6);
        let err = execute_serial(&at, &ap, &schedule, &FailingBackend)
            .expect_err("failing backend must fail the run");
        assert!(format!("{err:#}").contains("injected backend failure"));
    }

    #[test]
    fn dispatch_order_is_topological() {
        let (at, _, schedule) = setup(6);
        let order = dispatch_order(&at, &schedule);
        let mut pos = vec![0usize; at.tree.len()];
        for (i, &v) in order.iter().enumerate() {
            pos[v as usize] = i;
        }
        for (i, node) in at.tree.nodes.iter().enumerate() {
            for &c in &node.children {
                assert!(pos[c as usize] < pos[i], "child {c} after parent {i}");
            }
        }
    }
}
