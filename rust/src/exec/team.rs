//! Schedule-share-driven worker-team planning (DESIGN.md §10).
//!
//! The paper's tasks are *malleable*: a front's PM share `p^α` is a
//! fractional slice of the platform. The task-parallel executor
//! ignores that and pins one worker per front, so the wide root fronts
//! — which dominate the flops of any assembly tree — serialize.
//! [`TeamPlan`] closes the loop: at every task-completion event the
//! fractional shares of the *currently active* fronts (running ∪
//! ready) are re-rounded into integer worker-team sizes by the same
//! largest-remainder mechanism the virtual-time model uses
//! ([`integer_shares`]), so freed workers rejoin live teams instead of
//! idling behind an empty ready queue.

use crate::sched::Schedule;

use super::shares::integer_shares;

/// Fractional-share → integer-team mapping for one executor run.
#[derive(Debug, Clone)]
pub struct TeamPlan {
    /// Per-task constant schedule ratio (fraction of the platform).
    ratios: Vec<f64>,
    /// Crew size the shares are scaled to.
    workers: usize,
    /// When false every team has size 1 (the task-parallel baseline).
    malleable: bool,
}

impl TeamPlan {
    /// Plan for `n` tasks under `schedule`, scaling shares to a crew of
    /// `workers`. With `malleable` off the plan degenerates to one
    /// worker per front.
    pub fn new(schedule: &Schedule, n: usize, workers: usize, malleable: bool) -> TeamPlan {
        let mut ratios = schedule.task_ratios(n);
        // degenerate schedules (NaN/∞ spans) must not corrupt the
        // rounding: treat such tasks like tasks without a span — the
        // ≥1 clamp in team_sizes still guarantees them a leader
        for r in &mut ratios {
            if !r.is_finite() {
                *r = 0.0;
            }
        }
        TeamPlan {
            ratios,
            workers: workers.max(1),
            malleable: malleable && workers > 1,
        }
    }

    /// Whether this plan ever forms teams larger than one.
    pub fn malleable(&self) -> bool {
        self.malleable
    }

    /// Integer team sizes for the `active` tasks: each task's schedule
    /// ratio scaled to the crew, rounded by largest remainder
    /// ([`integer_shares`]), clamped to at least one worker (a running
    /// front always owns its leader).
    pub fn team_sizes(&self, active: &[u32]) -> Vec<usize> {
        self.team_sizes_for_crew(active, self.workers)
    }

    /// [`TeamPlan::team_sizes`] scaled to a *live* crew of `crew`
    /// workers instead of the plan's full crew — the elastic executor
    /// re-rounds shares to however many workers are currently serving
    /// ([`crate::exec::FaultPlan`] leave/join events).
    pub fn team_sizes_for_crew(&self, active: &[u32], crew: usize) -> Vec<usize> {
        if !self.malleable || active.is_empty() {
            return vec![1; active.len()];
        }
        let crew = crew.max(1);
        let raw: Vec<f64> = active
            .iter()
            .map(|&t| self.ratios[t as usize] * crew as f64)
            .collect();
        let mut sizes = integer_shares(&raw, crew);
        for s in &mut sizes {
            *s = (*s).max(1);
        }
        sizes
    }

    /// Team size for one task among `active` (which must contain it).
    pub fn team_size_of(&self, task: u32, active: &[u32]) -> usize {
        self.team_size_of_crew(task, active, self.workers)
    }

    /// [`TeamPlan::team_size_of`] against a live crew of `crew`.
    pub fn team_size_of_crew(&self, task: u32, active: &[u32], crew: usize) -> usize {
        let sizes = self.team_sizes_for_crew(active, crew);
        active
            .iter()
            .position(|&t| t == task)
            .map(|i| sizes[i])
            .unwrap_or(1)
    }
}

/// One bucket of the per-width team-occupancy table: fronts whose
/// order falls in `(lo, hi]`, the average and maximum team size they
/// ran with. This is the measurement that shows malleability doing its
/// job — wide (root) fronts get wide teams, leaf fronts stay at one.
#[derive(Debug, Clone, PartialEq)]
pub struct OccupancyRow {
    /// Exclusive lower front-order bound of the bucket.
    pub lo: usize,
    /// Inclusive upper bound (`usize::MAX` for the last bucket).
    pub hi: usize,
    /// Fronts in the bucket.
    pub fronts: usize,
    /// Mean team size over those fronts.
    pub avg_team: f64,
    /// Largest team any of them ran with.
    pub max_team: usize,
}

/// Bucket a `(front_order, team_size)` log by front width. Empty
/// buckets are dropped.
pub fn occupancy_by_width(log: &[(usize, usize)]) -> Vec<OccupancyRow> {
    const EDGES: [usize; 5] = [64, 128, 256, 512, usize::MAX];
    let mut rows = Vec::new();
    let mut lo = 0usize;
    for &hi in &EDGES {
        let bucket: Vec<usize> = log
            .iter()
            .filter(|&&(nf, _)| nf > lo && nf <= hi)
            .map(|&(_, team)| team)
            .collect();
        if !bucket.is_empty() {
            rows.push(OccupancyRow {
                lo,
                hi,
                fronts: bucket.len(),
                avg_team: bucket.iter().sum::<usize>() as f64 / bucket.len() as f64,
                max_team: bucket.iter().copied().max().unwrap_or(1),
            });
        }
        lo = hi;
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::TaskSpan;

    fn sched(ratios: &[f64]) -> Schedule {
        Schedule::new(
            ratios
                .iter()
                .enumerate()
                .map(|(i, &r)| TaskSpan {
                    task: i as u32,
                    start: 0.0,
                    finish: 1.0,
                    ratio: r,
                })
                .collect(),
        )
    }

    #[test]
    fn shares_scale_to_the_crew() {
        // root at 80%, two small children: an 8-crew gives the root ~6
        let s = sched(&[0.8, 0.1, 0.1]);
        let plan = TeamPlan::new(&s, 3, 8, true);
        let sizes = plan.team_sizes(&[0, 1, 2]);
        assert_eq!(sizes.iter().sum::<usize>(), 8);
        assert!(sizes[0] >= 6, "root share under-realized: {sizes:?}");
        assert!(sizes[1] >= 1 && sizes[2] >= 1);
    }

    #[test]
    fn lone_active_task_gets_every_worker_of_its_share() {
        let s = sched(&[1.0, 0.5]);
        let plan = TeamPlan::new(&s, 2, 4, true);
        assert_eq!(plan.team_sizes(&[0]), vec![4]);
        assert_eq!(plan.team_size_of(1, &[1]), 2);
    }

    #[test]
    fn non_malleable_plan_pins_one_worker() {
        let s = sched(&[0.9, 0.1]);
        let plan = TeamPlan::new(&s, 2, 8, false);
        assert!(!plan.malleable());
        assert_eq!(plan.team_sizes(&[0, 1]), vec![1, 1]);
    }

    #[test]
    fn single_worker_crew_never_forms_teams() {
        let s = sched(&[1.0]);
        let plan = TeamPlan::new(&s, 1, 1, true);
        assert!(!plan.malleable());
        assert_eq!(plan.team_sizes(&[0]), vec![1]);
    }

    #[test]
    fn nan_ratios_are_neutralized() {
        let s = Schedule::new(vec![
            TaskSpan { task: 0, start: 0.0, finish: 1.0, ratio: f64::NAN },
            TaskSpan { task: 1, start: 0.0, finish: 1.0, ratio: 0.5 },
        ]);
        let plan = TeamPlan::new(&s, 2, 4, true);
        let sizes = plan.team_sizes(&[0, 1]);
        assert!(sizes.iter().all(|&t| t >= 1), "{sizes:?}");
        assert!(sizes.iter().sum::<usize>() <= 4 + 1, "{sizes:?}");
    }

    #[test]
    fn crew_parameterized_sizes_follow_the_live_crew() {
        let s = sched(&[0.8, 0.1, 0.1]);
        let plan = TeamPlan::new(&s, 3, 8, true);
        // full crew: the default methods are the crew == workers case
        assert_eq!(
            plan.team_sizes_for_crew(&[0, 1, 2], 8),
            plan.team_sizes(&[0, 1, 2])
        );
        // a shrunken live crew of 4: shares re-round to the 4 workers
        let shrunk = plan.team_sizes_for_crew(&[0, 1, 2], 4);
        assert!(shrunk.iter().all(|&t| t >= 1), "{shrunk:?}");
        assert!(shrunk[0] >= 2, "root share lost in the shrink: {shrunk:?}");
        assert!(shrunk.iter().sum::<usize>() <= 4 + 2, "{shrunk:?}");
        // a lone task gets its share of whatever crew is live
        assert_eq!(plan.team_size_of_crew(0, &[0], 2), 2);
        // zero crews are clamped, never divide the plan by zero
        assert_eq!(plan.team_sizes_for_crew(&[0], 0), vec![1]);
    }

    #[test]
    fn tiny_shares_are_clamped_to_one() {
        let s = sched(&[0.96, 0.01, 0.01, 0.01, 0.01]);
        let plan = TeamPlan::new(&s, 5, 4, true);
        let sizes = plan.team_sizes(&[0, 1, 2, 3, 4]);
        assert!(sizes.iter().all(|&s| s >= 1), "{sizes:?}");
    }

    #[test]
    fn occupancy_buckets_by_front_width() {
        let log = vec![(10, 1), (50, 1), (100, 2), (300, 6), (300, 8)];
        let rows = occupancy_by_width(&log);
        assert_eq!(rows.len(), 3);
        assert_eq!((rows[0].fronts, rows[0].max_team), (2, 1));
        assert_eq!((rows[1].fronts, rows[1].max_team), (1, 2));
        assert_eq!(rows[2].fronts, 2);
        assert!((rows[2].avg_team - 7.0).abs() < 1e-12);
        assert_eq!(rows[2].max_team, 8);
    }
}
