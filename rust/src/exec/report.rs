//! Execution reports.

/// What an executor run produced, beyond the factorization itself.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Makespan in the malleable model's units (from the schedule).
    pub virtual_makespan: f64,
    /// Real wall-clock seconds spent executing fronts.
    pub wall_seconds: f64,
    /// Number of tasks (supernodes) executed.
    pub tasks: usize,
    /// Total front flops executed.
    pub flops: f64,
    /// Backend used.
    pub backend: String,
    /// Worker threads (1 for the serial accelerator-queue path).
    pub workers: usize,
}

impl ExecReport {
    /// Achieved flop rate (flops per wall second).
    pub fn flop_rate(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.flops / self.wall_seconds
        } else {
            0.0
        }
    }

    pub fn render(&self) -> String {
        format!(
            "backend={} workers={} tasks={} flops={:.3e} wall={:.3}s ({:.2} Gflop/s) virtual_makespan={:.3e}",
            self.backend,
            self.workers,
            self.tasks,
            self.flops,
            self.wall_seconds,
            self.flop_rate() / 1e9,
            self.virtual_makespan,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_rate_handles_zero_time() {
        let r = ExecReport {
            virtual_makespan: 1.0,
            wall_seconds: 0.0,
            tasks: 0,
            flops: 0.0,
            backend: "x".into(),
            workers: 1,
        };
        assert_eq!(r.flop_rate(), 0.0);
    }

    #[test]
    fn render_mentions_backend() {
        let r = ExecReport {
            virtual_makespan: 2.0,
            wall_seconds: 1.0,
            tasks: 3,
            flops: 2e9,
            backend: "rust-f64".into(),
            workers: 4,
        };
        let s = r.render();
        assert!(s.contains("rust-f64"));
        assert!(s.contains("workers=4"));
        assert!(s.contains("2.00 Gflop/s"));
    }
}
