//! Execution reports.

use super::team::{occupancy_by_width, OccupancyRow};

/// What an executor run produced, beyond the factorization itself.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Makespan in the malleable model's units (from the schedule).
    pub virtual_makespan: f64,
    /// Real wall-clock seconds spent executing fronts.
    pub wall_seconds: f64,
    /// CPU seconds spent in front assembly (scatter + extend-add),
    /// summed over all workers.
    pub assembly_seconds: f64,
    /// High-water mark of the front arena(s): fronts plus outstanding
    /// contribution blocks, in bytes (one shared gauge across the crew
    /// in the parallel path).
    pub peak_front_bytes: usize,
    /// Number of tasks (supernodes) executed.
    pub tasks: usize,
    /// Total front flops executed.
    pub flops: f64,
    /// Backend used.
    pub backend: String,
    /// Worker threads (1 for the serial accelerator-queue path).
    pub workers: usize,
    /// Whether schedule shares were realized as worker teams.
    pub malleable: bool,
    /// Per completed front: `(front order, realized team size)` — the
    /// measurement behind [`ExecReport::occupancy`]. Empty for the
    /// serial path.
    pub team_log: Vec<(usize, usize)>,
    /// Wait episodes at the memory-cap admission gate
    /// ([`crate::exec::execute_malleable_capped`]; 0 without a cap).
    pub mem_stalls: usize,
    /// Over-cap admissions forced because nothing was running (an
    /// infeasibly small cap degrades to serial execution, never
    /// deadlocks).
    pub mem_forced: usize,
    /// Failed front executions requeued for another attempt under a
    /// [`crate::exec::FaultPlan`]
    /// ([`crate::exec::execute_malleable_faulty`]; 0 without a plan).
    pub retries: usize,
    /// Front flops discarded by those failed executions (work that had
    /// to be redone).
    pub lost_flops: f64,
    /// Wall seconds the crew spent in retry backoff, summed over
    /// workers.
    pub recovery_seconds: f64,
    /// Wall-clock span trace of the run (`None` unless a buffering
    /// [`crate::obs::TraceSink`] was passed to a `*_traced` entry
    /// point). Sorted; one Assemble + one Factor span per executed
    /// front, Retry per failed attempt, Stall per memory-gate wait and
    /// backoff sleep.
    pub trace: Option<crate::obs::TraceLog>,
}

impl ExecReport {
    /// Achieved flop rate (flops per wall second).
    pub fn flop_rate(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.flops / self.wall_seconds
        } else {
            0.0
        }
    }

    /// Fraction of the crew's busy budget (`wall × workers`) spent in
    /// assembly rather than factorization kernels.
    pub fn assembly_fraction(&self) -> f64 {
        let budget = self.wall_seconds * self.workers.max(1) as f64;
        if budget > 0.0 {
            self.assembly_seconds / budget
        } else {
            0.0
        }
    }

    /// Team occupancy bucketed by front width: the evidence that the
    /// malleable executor gives wide (root) fronts wide teams while
    /// leaf fronts keep one worker.
    pub fn occupancy(&self) -> Vec<OccupancyRow> {
        occupancy_by_width(&self.team_log)
    }

    /// Mean team size across completed fronts (1.0 when no teams were
    /// formed or the log is empty).
    pub fn avg_team(&self) -> f64 {
        if self.team_log.is_empty() {
            1.0
        } else {
            self.team_log.iter().map(|&(_, t)| t).sum::<usize>() as f64
                / self.team_log.len() as f64
        }
    }

    /// Largest team any front ran with.
    pub fn max_team(&self) -> usize {
        self.team_log.iter().map(|&(_, t)| t).max().unwrap_or(1)
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "backend={} workers={} tasks={} flops={:.3e} wall={:.3}s ({:.2} Gflop/s) \
             assembly={:.1}% peak_front={:.1} MiB virtual_makespan={:.3e}",
            self.backend,
            self.workers,
            self.tasks,
            self.flops,
            self.wall_seconds,
            self.flop_rate() / 1e9,
            100.0 * self.assembly_fraction(),
            self.peak_front_bytes as f64 / (1024.0 * 1024.0),
            self.virtual_makespan,
        );
        if self.malleable {
            s.push_str(&format!(
                " avg_team={:.2} max_team={}",
                self.avg_team(),
                self.max_team()
            ));
        }
        if self.mem_stalls > 0 || self.mem_forced > 0 {
            s.push_str(&format!(
                " mem_stalls={} mem_forced={}",
                self.mem_stalls, self.mem_forced
            ));
        }
        if self.retries > 0 {
            s.push_str(&format!(
                " retries={} lost_flops={:.3e} recovery={:.3}s",
                self.retries, self.lost_flops, self.recovery_seconds
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExecReport {
        ExecReport {
            virtual_makespan: 1.0,
            wall_seconds: 0.0,
            assembly_seconds: 0.0,
            peak_front_bytes: 0,
            tasks: 0,
            flops: 0.0,
            backend: "x".into(),
            workers: 1,
            malleable: false,
            team_log: Vec::new(),
            mem_stalls: 0,
            mem_forced: 0,
            retries: 0,
            lost_flops: 0.0,
            recovery_seconds: 0.0,
            trace: None,
        }
    }

    #[test]
    fn flop_rate_handles_zero_time() {
        let r = base();
        assert_eq!(r.flop_rate(), 0.0);
        assert_eq!(r.assembly_fraction(), 0.0);
        assert_eq!(r.avg_team(), 1.0);
        assert_eq!(r.max_team(), 1);
        assert!(r.occupancy().is_empty());
    }

    #[test]
    fn render_mentions_backend() {
        let r = ExecReport {
            virtual_makespan: 2.0,
            wall_seconds: 1.0,
            assembly_seconds: 0.25,
            peak_front_bytes: 1024 * 1024,
            tasks: 3,
            flops: 2e9,
            backend: "rust-f64".into(),
            workers: 4,
            ..base()
        };
        let s = r.render();
        assert!(s.contains("rust-f64"));
        assert!(s.contains("workers=4"));
        assert!(s.contains("2.00 Gflop/s"));
        // 0.25 s of assembly across a 4 s busy budget
        assert!((r.assembly_fraction() - 0.0625).abs() < 1e-12);
        assert!(s.contains("peak_front=1.0 MiB"));
        assert!(!s.contains("avg_team"), "non-malleable run rendered team stats");
    }

    #[test]
    fn render_includes_fault_stats_only_when_faulted() {
        let clean = base();
        assert!(!clean.render().contains("retries="), "{}", clean.render());
        let r = ExecReport {
            retries: 3,
            lost_flops: 1e7,
            recovery_seconds: 0.25,
            ..base()
        };
        let s = r.render();
        assert!(s.contains("retries=3"), "{s}");
        assert!(s.contains("lost_flops=1.000e7"), "{s}");
        assert!(s.contains("recovery=0.250s"), "{s}");
    }

    #[test]
    fn timed_trace_subsumes_legacy_team_log() {
        use crate::obs::{Span, SpanKind, TimeUnit, TraceLog};
        // three fronts straddling two occupancy buckets
        let widths = [32usize, 300, 32];
        let teams = [1usize, 6, 2];
        let team_log: Vec<(usize, usize)> =
            widths.iter().copied().zip(teams.iter().copied()).collect();
        let mut trace = TraceLog::new("exec", TimeUnit::WallNs, 8);
        for (i, &t) in teams.iter().enumerate() {
            // Assemble spans are noise the rebuilt view must ignore
            trace.push(Span {
                kind: SpanKind::Assemble,
                task: i as u32,
                worker: i as u32,
                team: 1.0,
                flops: 0.0,
                start: 2.0 * i as f64,
                end: 2.0 * i as f64 + 0.5,
            });
            trace.push(Span {
                kind: SpanKind::Factor,
                task: i as u32,
                worker: i as u32,
                team: t as f64,
                flops: 1e6,
                start: 2.0 * i as f64 + 0.5,
                end: 2.0 * i as f64 + 1.5,
            });
        }
        let rebuilt = trace.team_log(&widths);
        assert_eq!(rebuilt, team_log, "Factor spans must rebuild the legacy log");
        let r = ExecReport {
            malleable: true,
            team_log: team_log.clone(),
            trace: Some(trace),
            ..base()
        };
        // both views agree bucket-for-bucket and in the mean
        assert_eq!(occupancy_by_width(&rebuilt), r.occupancy());
        let avg_from_spans =
            rebuilt.iter().map(|&(_, t)| t).sum::<usize>() as f64 / rebuilt.len() as f64;
        assert!((avg_from_spans - r.avg_team()).abs() < 1e-12);
    }

    #[test]
    fn render_includes_team_stats_for_malleable_runs() {
        let r = ExecReport {
            malleable: true,
            team_log: vec![(32, 1), (32, 1), (300, 6)],
            ..base()
        };
        let s = r.render();
        assert!(s.contains("max_team=6"), "{s}");
        assert!((r.avg_team() - 8.0 / 3.0).abs() < 1e-12);
        let occ = r.occupancy();
        assert_eq!(occ.len(), 2);
        assert_eq!(occ[1].max_team, 6);
    }
}
