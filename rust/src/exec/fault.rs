//! Fault-injection and elasticity plans for the malleable executor
//! (DESIGN.md §13).
//!
//! A [`FaultPlan`] makes the crew's failure handling *testable and
//! benchmarkable*: it injects deterministic transient failures into
//! chosen fronts (the first `F` executions of a front fail, then it
//! succeeds) and moves the live crew size at completion thresholds
//! (workers leave and rejoin mid-run). The executor treats an injected
//! failure exactly like a real backend error under an active plan:
//! discard the attempt, requeue the front, back off, retry up to
//! [`FaultPlan::max_retries`] times — so the same machinery covers
//! genuinely flaky backends.

use anyhow::{anyhow, bail, Result};

use crate::util::retry::LinearBackoff;

/// One elasticity event: after `after_completions` fronts have
/// completed, the live crew target moves by `delta` workers (clamped
/// to `1..=workers` by the executor — the crew never empties and never
/// exceeds the threads actually spawned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticEvent {
    /// Completion-count threshold at which the event fires.
    pub after_completions: usize,
    /// Signed crew-size change (workers joining `> 0`, leaving `< 0`).
    pub delta: isize,
}

/// Deterministic disturbance plan for one executor run: injected
/// transient failures, the retry budget/backoff that answers them, and
/// elastic crew events.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// `(task, failures)` pairs: the first `failures` executions of
    /// `task` fail with an injected transient error. Repeated entries
    /// for one task accumulate.
    pub inject: Vec<(usize, usize)>,
    /// Failed executions tolerated per task before the run errors out.
    pub max_retries: usize,
    /// Base backoff before a retry; attempt `k` sleeps `k * backoff_ms`
    /// (bounded linear backoff).
    pub backoff_ms: u64,
    /// Crew-size events, in any order (the executor sorts them).
    pub elastic: Vec<ElasticEvent>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::new()
    }
}

impl FaultPlan {
    /// An empty plan: nothing injected, no elasticity, 3 retries with
    /// 1 ms base backoff (the defaults real transient faults get).
    pub fn new() -> FaultPlan {
        FaultPlan {
            inject: Vec::new(),
            max_retries: 3,
            backoff_ms: 1,
            elastic: Vec::new(),
        }
    }

    /// Builder: inject `failures` transient failures into `task`.
    pub fn inject_task(mut self, task: usize, failures: usize) -> FaultPlan {
        self.inject.push((task, failures));
        self
    }

    /// Builder: add one elastic crew event.
    pub fn elastic_event(mut self, after_completions: usize, delta: isize) -> FaultPlan {
        self.elastic.push(ElasticEvent { after_completions, delta });
        self
    }

    /// The bounded linear backoff answering this plan's failures: the
    /// shared [`crate::util::retry`] implementation with `base` in
    /// milliseconds (attempt `k` sleeps `k × backoff_ms`, up to
    /// [`FaultPlan::max_retries`] attempts).
    pub fn backoff(&self) -> LinearBackoff {
        LinearBackoff::new(self.backoff_ms as f64, self.max_retries)
    }

    /// Whether the plan disturbs anything at all. A no-op plan must
    /// leave the executor bit-identical to a plain malleable run
    /// (tested).
    pub fn is_noop(&self) -> bool {
        self.elastic.is_empty() && self.inject.iter().all(|&(_, f)| f == 0)
    }

    /// Materialize per-task pending-failure counts for an `n_tasks`
    /// run. Out-of-range rules are dropped.
    pub fn injected_failures(&self, n_tasks: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_tasks];
        for &(t, f) in &self.inject {
            if t < n_tasks {
                counts[t] += f;
            }
        }
        counts
    }

    /// Elastic events sorted by completion threshold (stable: events
    /// sharing a threshold apply in insertion order).
    pub fn sorted_elastic(&self) -> Vec<ElasticEvent> {
        let mut ev = self.elastic.clone();
        ev.sort_by_key(|e| e.after_completions);
        ev
    }

    /// Parse a CLI injection spec: comma-separated `task:ID:F` (the
    /// first `F` executions of task `ID` fail) and `every:K:F` (every
    /// K-th task — ids `0, K, 2K, …` — fails `F` times).
    pub fn parse_inject(&mut self, spec: &str, n_tasks: usize) -> Result<()> {
        for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let item = item.trim();
            let toks: Vec<&str> = item.split(':').collect();
            let num = |what: &str, v: &str| -> Result<usize> {
                v.parse()
                    .map_err(|_| anyhow!("fault plan: bad {what} {v:?} in {item:?}"))
            };
            match toks.as_slice() {
                ["task", id, f] => {
                    let id = num("task id", id)?;
                    if id >= n_tasks {
                        bail!("fault plan: task {id} out of range (tree has {n_tasks} tasks)");
                    }
                    self.inject.push((id, num("failure count", f)?));
                }
                ["every", k, f] => {
                    let k = num("period", k)?;
                    if k == 0 {
                        bail!("fault plan: every:0 is invalid");
                    }
                    let f = num("failure count", f)?;
                    let mut t = 0;
                    while t < n_tasks {
                        self.inject.push((t, f));
                        t += k;
                    }
                }
                _ => bail!("fault plan: bad inject item {item:?} (want task:ID:F or every:K:F)"),
            }
        }
        Ok(())
    }

    /// Parse a CLI elasticity spec: comma-separated `±N@C` items — the
    /// crew target moves by `±N` workers after `C` completions, e.g.
    /// `-2@5,+2@40`.
    pub fn parse_elastic(&mut self, spec: &str) -> Result<()> {
        for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let item = item.trim();
            let Some((d, at)) = item.split_once('@') else {
                bail!("elastic plan: bad item {item:?} (want ±N@COMPLETIONS)");
            };
            let delta: isize = d
                .parse()
                .map_err(|_| anyhow!("elastic plan: bad delta {d:?} in {item:?}"))?;
            if delta == 0 {
                bail!("elastic plan: zero delta in {item:?}");
            }
            let after_completions: usize = at
                .parse()
                .map_err(|_| anyhow!("elastic plan: bad threshold {at:?} in {item:?}"))?;
            self.elastic.push(ElasticEvent { after_completions, delta });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_a_noop_with_a_retry_budget() {
        let p = FaultPlan::new();
        assert!(p.is_noop());
        assert_eq!(p.max_retries, 3);
        assert_eq!(p.backoff_ms, 1);
        assert_eq!(p.injected_failures(5), vec![0; 5]);
    }

    #[test]
    fn backoff_is_the_shared_linear_schedule() {
        let mut p = FaultPlan::new();
        p.max_retries = 2;
        p.backoff_ms = 4;
        let b = p.backoff();
        assert_eq!(b, LinearBackoff::new(4.0, 2));
        assert_eq!(b.delay(1), Some(4.0));
        assert_eq!(b.delay(2), Some(8.0));
        assert_eq!(b.delay(3), None, "the third failure exhausts the budget");
    }

    #[test]
    fn parse_inject_expands_task_and_every_rules() {
        let mut p = FaultPlan::new();
        p.parse_inject("task:3:2, every:4:1", 10).unwrap();
        let counts = p.injected_failures(10);
        assert_eq!(counts, vec![1, 0, 0, 2, 1, 0, 0, 0, 1, 0]);
        assert!(!p.is_noop());
    }

    #[test]
    fn parse_inject_rejects_malformed_specs() {
        for bad in [
            "task:3",          // missing count
            "task:3:2:1",      // extra field
            "task:99:1",       // out of range
            "every:0:1",       // zero period
            "melt:1:1",        // unknown rule
            "task:x:1",        // non-numeric id
            "task:1:y",        // non-numeric count
        ] {
            let mut p = FaultPlan::new();
            assert!(p.parse_inject(bad, 10).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_elastic_reads_signed_deltas_and_sorts() {
        let mut p = FaultPlan::new();
        p.parse_elastic("+2@9,-1@4").unwrap();
        let ev = p.sorted_elastic();
        assert_eq!(
            ev,
            vec![
                ElasticEvent { after_completions: 4, delta: -1 },
                ElasticEvent { after_completions: 9, delta: 2 },
            ]
        );
        for bad in ["2", "-1@x", "z@3", "0@4"] {
            let mut p = FaultPlan::new();
            assert!(p.parse_elastic(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn builder_entries_accumulate_per_task() {
        let p = FaultPlan::new().inject_task(2, 1).inject_task(2, 3);
        assert_eq!(p.injected_failures(4)[2], 4);
        // out-of-range rules are dropped at materialization
        assert_eq!(p.injected_failures(2), vec![0, 0]);
    }
}
