//! Fractional-to-integer share realization.
//!
//! The paper assumes non-integer processor shares, realized at runtime
//! by time-sharing (§1: "one processor will dedicate 60% of its
//! processing time to A and 40% to B"). Per scheduling slice we hand
//! each running task an integer core count by largest-remainder
//! rounding, which preserves `Σ shares` exactly and each share within
//! ±1 core — the discretization whose cost the ablation bench
//! measures.

/// Round fractional `shares` to integers summing to
/// `min(total, round(Σ shares))`, largest remainder first. Shares are
/// first rescaled when they over-subscribe `total` (schedulers emit
/// `Σ shares <= p`, but be safe for callers that do not).
pub fn integer_shares(raw: &[f64], total: usize) -> Vec<usize> {
    let raw_sum: f64 = raw.iter().sum();
    let scaled: Vec<f64>;
    let shares: &[f64] = if raw_sum > total as f64 {
        scaled = raw.iter().map(|&s| s * total as f64 / raw_sum).collect();
        &scaled
    } else {
        raw
    };
    let sum: f64 = shares.iter().sum();
    let budget = total.min(sum.round() as usize);
    let mut base: Vec<usize> = shares.iter().map(|&s| s.floor() as usize).collect();
    let used: usize = base.iter().sum();
    let mut rema: Vec<(f64, usize)> = shares
        .iter()
        .enumerate()
        .map(|(i, &s)| (s - s.floor(), i))
        .collect();
    // total_cmp: a NaN share (degenerate schedule input) must not
    // panic — the executor's TeamPlan calls this under its queue
    // mutex, where a panic would poison the whole crew. NaN
    // remainders sort last and the `frac > 0.0` guard skips them.
    rema.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut left = budget.saturating_sub(used);
    for (frac, i) in rema {
        if left == 0 {
            break;
        }
        if frac > 0.0 {
            base[i] += 1;
            left -= 1;
        }
    }
    base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};

    #[test]
    fn exact_integers_pass_through() {
        assert_eq!(integer_shares(&[2.0, 3.0, 1.0], 6), vec![2, 3, 1]);
    }

    #[test]
    fn fractions_round_by_largest_remainder() {
        // 2.6 + 3.4 = 6: one extra core goes to the .6 task
        assert_eq!(integer_shares(&[2.6, 3.4], 6), vec![3, 3]);
        // 1.5 + 1.5 + 1.0 = 4: both halves tie; one of them gets it
        let s = integer_shares(&[1.5, 1.5, 1.0], 4);
        assert_eq!(s.iter().sum::<usize>(), 4);
        assert!(s == vec![2, 1, 1] || s == vec![1, 2, 1]);
    }

    #[test]
    fn never_exceeds_total() {
        let s = integer_shares(&[0.9, 0.9, 0.9], 2);
        assert!(s.iter().sum::<usize>() <= 2);
    }

    #[test]
    fn nan_shares_do_not_panic() {
        // degenerate schedules can surface NaN ratios; rounding must
        // stay total (NaN sorts last, gets nothing) instead of
        // panicking inside the executor's queue lock
        let s = integer_shares(&[f64::NAN, 2.5, 1.5], 4);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], 0, "NaN share must round to zero: {s:?}");
        assert!(s.iter().sum::<usize>() <= 4);
    }

    #[test]
    fn preserves_sum_within_one_randomized() {
        check(
            Config { cases: 100, seed: 88 },
            "largest remainder invariants",
            |rng| {
                let n = rng.range(1, 12);
                let shares: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 8.0)).collect();
                let total = rng.range(1, 40);
                (shares, total)
            },
            |(shares, total)| {
                let ints = integer_shares(shares, *total);
                let sum_f: f64 = shares.iter().sum();
                let sum_i: usize = ints.iter().sum();
                if sum_i > *total {
                    return Err(format!("sum {sum_i} exceeds total {total}"));
                }
                if sum_i as f64 > sum_f + 1.0 {
                    return Err("over-allocated".into());
                }
                // per-item bound against the (possibly rescaled) shares
                let scale = if sum_f > *total as f64 { *total as f64 / sum_f } else { 1.0 };
                for (&s, &i) in shares.iter().zip(&ints) {
                    let s = s * scale;
                    if (i as f64) < s.floor() || (i as f64) > s.ceil() {
                        return Err(format!("share {s} rounded to {i}"));
                    }
                }
                Ok(())
            },
        );
    }
}
