//! The malleable work-crew executor.
//!
//! Takes a *schedule* (from any strategy in [`crate::sched`]) and
//! actually runs the numeric multifrontal factorization it describes:
//!
//! * **virtual time** follows the malleable model — the schedule's
//!   spans, fractional shares realized as integer cores per time slice
//!   by [`integer_shares`] (largest-remainder rounding, the mechanism
//!   the paper attributes to runtime-system time sharing);
//! * **wall time** is the real execution of each front through a
//!   [`FrontBackend`]. The PJRT backend is a single accelerator
//!   command queue (`Rc` client), so `execute_serial` streams fronts
//!   in schedule order; `execute_parallel` adds thread-crew tree
//!   parallelism for `Send + Sync` backends (the pure-Rust one); and
//!   `execute_malleable` realizes the paper's malleable-task model in
//!   wall time too — a [`TeamPlan`] turns fractional schedule shares
//!   into integer worker teams per front (re-rounded at every
//!   completion event), and team-capable backends factor a front's
//!   tiles cooperatively through the
//!   [`crate::frontal::FrontTeamJob`] cursor.
//!
//! All paths produce bit-identical factors to
//! [`crate::frontal::factorize`]; tests enforce it.
//!
//! `execute_malleable_faulty` is the **self-healing** variant
//! (DESIGN.md §13): a [`FaultPlan`] injects deterministic transient
//! failures and elastic crew leave/join events; failed fronts are
//! requeued with bounded backoff (children contributions survive via
//! arena-accounted copies), and the live crew re-rounds team shares at
//! every completion — factors stay bit-identical throughout.
//!
//! [`FrontBackend`]: crate::frontal::FrontBackend

mod fault;
mod report;
mod shares;
pub mod team;
mod worker;

pub use fault::{ElasticEvent, FaultPlan};
pub use report::ExecReport;
pub use shares::integer_shares;
pub use team::{occupancy_by_width, OccupancyRow, TeamPlan};
pub use worker::{
    execute_malleable, execute_malleable_capped, execute_malleable_capped_traced,
    execute_malleable_faulty, execute_malleable_faulty_traced, execute_malleable_traced,
    execute_parallel, execute_parallel_traced, execute_serial, execute_serial_traced,
};
