//! The malleable work-crew executor.
//!
//! Takes a *schedule* (from any strategy in [`crate::sched`]) and
//! actually runs the numeric multifrontal factorization it describes:
//!
//! * **virtual time** follows the malleable model — the schedule's
//!   spans, fractional shares realized as integer cores per time slice
//!   by [`integer_shares`] (largest-remainder rounding, the mechanism
//!   the paper attributes to runtime-system time sharing);
//! * **wall time** is the real execution of each front through a
//!   [`FrontBackend`]. The PJRT backend is a single accelerator
//!   command queue (`Rc` client), so `execute_serial` streams fronts
//!   in schedule order; `execute_parallel` adds true thread-crew tree
//!   parallelism for `Send + Sync` backends (the pure-Rust one).
//!
//! Both paths produce bit-identical factors to
//! [`crate::frontal::factorize`]; tests enforce it.

mod report;
mod shares;
mod worker;

pub use report::ExecReport;
pub use shares::integer_shares;
pub use worker::{execute_parallel, execute_serial};
