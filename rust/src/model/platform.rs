//! Execution platforms (paper §6).
//!
//! The first half of the paper schedules on a single shared-memory
//! multicore ([`Platform::Shared`]); §6 moves to *distributed*
//! platforms of several multicore nodes where a malleable task may not
//! span nodes — the `p^α` model applies within a node only. The
//! scheduling layers thread a `Platform` value from the CLI / benches
//! down to the mapping layer ([`crate::dist::mapping`]) and the
//! cross-node simulator ([`crate::sim::des::simulate_distributed`]):
//!
//! * [`Platform::Shared`] — one node of `p` cores: the whole-tree
//!   Prasanna–Musicus path of §5, kept as the 1-node special case of
//!   the sub-forest machinery;
//! * [`Platform::Homogeneous`] — `nodes` identical nodes of `p` cores
//!   each (Theorem 7 territory: NP-complete already at 2 nodes;
//!   Algorithm 11 approximates);
//! * [`Platform::Heterogeneous`] — one node per entry of `speeds`
//!   (core counts may differ; Algorithm 12's λ-scheme covers the
//!   two-node independent-task core).

use anyhow::{bail, Result};

/// A distributed platform of multicore nodes. Tasks may not span
/// nodes; within node `k` a task on share `s ≤ cores(k)` speeds up as
/// `s^α`.
#[derive(Debug, Clone, PartialEq)]
pub enum Platform {
    /// A single shared-memory node of `p` cores.
    Shared { p: f64 },
    /// `nodes` identical nodes of `p` cores each.
    Homogeneous { nodes: usize, p: f64 },
    /// One node per entry; `speeds[k]` is the core count of node `k`.
    Heterogeneous { speeds: Vec<f64> },
}

impl Platform {
    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        match self {
            Platform::Shared { .. } => 1,
            Platform::Homogeneous { nodes, .. } => *nodes,
            Platform::Heterogeneous { speeds } => speeds.len(),
        }
    }

    /// Core count of node `k` (panics when `k` is out of range).
    pub fn node_cores(&self, k: usize) -> f64 {
        match self {
            Platform::Shared { p } => {
                assert!(k == 0, "shared platform has one node, asked for {k}");
                *p
            }
            Platform::Homogeneous { nodes, p } => {
                assert!(k < *nodes, "node {k} out of range ({nodes} nodes)");
                *p
            }
            Platform::Heterogeneous { speeds } => speeds[k],
        }
    }

    /// Total cores pooled over all nodes (`Σ_k cores(k)`).
    pub fn total_cores(&self) -> f64 {
        match self {
            Platform::Shared { p } => *p,
            Platform::Homogeneous { nodes, p } => *nodes as f64 * p,
            Platform::Heterogeneous { speeds } => speeds.iter().sum(),
        }
    }

    /// Index of a node with the most cores (ties broken toward the
    /// lowest index) — where single-node fallbacks and root chains run.
    pub fn fastest_node(&self) -> usize {
        match self {
            Platform::Shared { .. } | Platform::Homogeneous { .. } => 0,
            Platform::Heterogeneous { speeds } => {
                let mut best = 0usize;
                for (k, &s) in speeds.iter().enumerate() {
                    if s > speeds[best] {
                        best = k;
                    }
                }
                best
            }
        }
    }

    /// Structural sanity: at least one node, every core count positive
    /// and finite.
    pub fn validate(&self) -> Result<()> {
        if self.num_nodes() == 0 {
            bail!("platform has no nodes");
        }
        for k in 0..self.num_nodes() {
            let c = self.node_cores(k);
            if !c.is_finite() || c <= 0.0 {
                bail!("node {k} has invalid core count {c}");
            }
        }
        Ok(())
    }

    /// Pooled lower bound on any distributed makespan: no schedule on
    /// this platform beats the shared-memory optimum on `Σ_k cores(k)`
    /// processors, i.e. `L_G / (Σ_k cores(k))^α` (the `L_G/(Np)^α`
    /// bound of §6 in the homogeneous case).
    pub fn pooled_lower_bound(&self, equiv_len: f64, alpha: f64) -> f64 {
        equiv_len / self.total_cores().powf(alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    #[test]
    fn shapes_and_totals() {
        let s = Platform::Shared { p: 8.0 };
        assert_eq!(s.num_nodes(), 1);
        assert_eq!(s.node_cores(0), 8.0);
        assert_eq!(s.total_cores(), 8.0);

        let h = Platform::Homogeneous { nodes: 4, p: 8.0 };
        assert_eq!(h.num_nodes(), 4);
        assert_eq!(h.node_cores(3), 8.0);
        assert_eq!(h.total_cores(), 32.0);
        assert_eq!(h.fastest_node(), 0);

        let g = Platform::Heterogeneous { speeds: vec![4.0, 12.0, 8.0] };
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.node_cores(1), 12.0);
        assert_eq!(g.total_cores(), 24.0);
        assert_eq!(g.fastest_node(), 1);
    }

    #[test]
    fn validate_rejects_bad_platforms() {
        assert!(Platform::Heterogeneous { speeds: vec![] }.validate().is_err());
        assert!(Platform::Heterogeneous { speeds: vec![4.0, 0.0] }.validate().is_err());
        assert!(Platform::Homogeneous { nodes: 0, p: 4.0 }.validate().is_err());
        assert!(Platform::Shared { p: f64::NAN }.validate().is_err());
        assert!(Platform::Homogeneous { nodes: 2, p: 8.0 }.validate().is_ok());
    }

    #[test]
    fn pooled_bound_matches_closed_form() {
        let h = Platform::Homogeneous { nodes: 4, p: 8.0 };
        // L_G / (N p)^α
        assert!(approx_eq(
            h.pooled_lower_bound(100.0, 0.9),
            100.0 / 32f64.powf(0.9),
            1e-12
        ));
    }
}
