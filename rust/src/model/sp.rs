//! Series-parallel graphs (paper §4) as an arena.
//!
//! A tree is turned into a *pseudo-tree* SP graph (paper Figure 7): each
//! tree node `u` becomes `Series(Parallel(children...), Leaf(u))`. The
//! `Agreg` transformation of §7 then rewrites this SP structure, which
//! is why the schedulers operate on [`SpGraph`] rather than only on
//! trees. Compositions are n-ary (a normalized form of the paper's
//! binary compositions) so that sibling sets are single `Parallel`
//! nodes.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::tree::TaskTree;

/// Index of a node in the [`SpGraph`] arena.
pub type SpNodeId = u32;

/// SP-graph node.
#[derive(Debug, Clone, PartialEq)]
pub enum SpNode {
    /// An actual malleable task. `task` tracks the originating tree
    /// task id when the graph came from a [`TaskTree`].
    Leaf { len: f64, task: Option<u32> },
    /// Sequential composition, executed left to right.
    Series(Vec<SpNodeId>),
    /// Parallel composition (the branches of paper §4).
    Parallel(Vec<SpNodeId>),
}

/// Arena-allocated series-parallel graph.
///
/// The reachable topological order is computed once and cached
/// ([`SpGraph::topo`]): the scheduler hot paths (`PmSolution::solve`,
/// `task_spans`, `min_task_share`, the baselines, `Agreg`) all traverse
/// the graph repeatedly, and materializing a fresh `Vec` per call
/// dominated large-tree solves (§Perf in EXPERIMENTS.md). Mutating
/// `nodes`/`root` directly after a traversal requires
/// [`SpGraph::invalidate_topo`]; the in-crate mutators do this
/// automatically.
#[derive(Debug, Clone)]
pub struct SpGraph {
    pub nodes: Vec<SpNode>,
    pub root: SpNodeId,
    /// Cached root-first reachable order (`OnceLock` so shared
    /// references across scheduler threads can fill it lazily).
    topo: OnceLock<Box<[SpNodeId]>>,
}

impl SpGraph {
    /// Build from an arena and a root id.
    pub fn new(nodes: Vec<SpNode>, root: SpNodeId) -> Self {
        SpGraph { nodes, root, topo: OnceLock::new() }
    }

    /// Single-task graph.
    pub fn leaf(len: f64) -> Self {
        SpGraph::new(vec![SpNode::Leaf { len, task: None }], 0)
    }

    pub fn push(&mut self, node: SpNode) -> SpNodeId {
        self.topo.take(); // arena changed: drop the cached order
        self.nodes.push(node);
        (self.nodes.len() - 1) as SpNodeId
    }

    /// Drop the cached topological order after direct mutation of
    /// `nodes` / `root`.
    pub fn invalidate_topo(&mut self) {
        self.topo.take();
    }

    /// Series composition of two graphs (`G1 ; G2`).
    pub fn series(g1: SpGraph, g2: SpGraph) -> Self {
        Self::combine(g1, g2, true)
    }

    /// Parallel composition of two graphs (`G1 || G2`).
    pub fn parallel(g1: SpGraph, g2: SpGraph) -> Self {
        Self::combine(g1, g2, false)
    }

    fn combine(g1: SpGraph, mut g2: SpGraph, series: bool) -> Self {
        let mut nodes = g1.nodes;
        let off = nodes.len() as SpNodeId;
        for n in &mut g2.nodes {
            match n {
                SpNode::Series(c) | SpNode::Parallel(c) => {
                    for id in c {
                        *id += off;
                    }
                }
                SpNode::Leaf { .. } => {}
            }
        }
        nodes.extend(g2.nodes);
        let (r1, r2) = (g1.root, g2.root + off);
        let root = nodes.len() as SpNodeId;
        nodes.push(if series {
            SpNode::Series(vec![r1, r2])
        } else {
            SpNode::Parallel(vec![r1, r2])
        });
        SpGraph::new(nodes, root)
    }

    /// Pseudo-tree conversion of a task tree (paper Figure 7),
    /// iterative over a postorder.
    pub fn from_tree(tree: &TaskTree) -> Self {
        let n = tree.len();
        // sp node id of each completed tree subtree
        let mut sub: Vec<SpNodeId> = vec![0; n];
        let mut g = SpGraph::new(Vec::with_capacity(2 * n), 0);
        for &v in &tree.topo_up() {
            let node = &tree.nodes[v as usize];
            let leaf = g.push(SpNode::Leaf { len: node.len, task: Some(v) });
            let id = if node.children.is_empty() {
                leaf
            } else {
                let kids: Vec<SpNodeId> =
                    node.children.iter().map(|&c| sub[c as usize]).collect();
                let par = if kids.len() == 1 {
                    kids[0]
                } else {
                    g.push(SpNode::Parallel(kids))
                };
                g.push(SpNode::Series(vec![par, leaf]))
            };
            sub[v as usize] = id;
        }
        g.root = sub[tree.root as usize];
        g
    }

    /// Pseudo-tree conversion of a *sub-forest*: the full subtrees of
    /// `tree` rooted at each of `roots` (which must be disjoint),
    /// composed in parallel — disjoint subtrees share no precedence,
    /// so a node-local root set behaves exactly like independent trees
    /// (paper §6). With `roots == [tree.root]` the arena produced is
    /// bit-identical to [`SpGraph::from_tree`]: the whole-tree path is
    /// the single-root special case of this builder (property-tested
    /// in `dist_integration.rs`).
    pub fn from_forest(tree: &TaskTree, roots: &[u32]) -> Self {
        Self::build_forest(tree, roots, None)
    }

    /// Pseudo-tree conversion of the sub-forest *induced* by a
    /// membership mask: member tasks only, with tree edges kept when
    /// both endpoints are members. Local roots are the member tasks
    /// whose parent is absent or a non-member, taken in increasing
    /// task-id order (deterministic, and matching the natural sibling
    /// order of [`TaskTree::from_parents`] trees). Returns `None` when
    /// no task is a member. This is the node-local view of a
    /// distributed mapping: a node owning a root chain sees the chain
    /// with its offloaded children cut away.
    pub fn from_induced(tree: &TaskTree, member: &[bool]) -> Option<Self> {
        assert_eq!(member.len(), tree.len(), "membership mask size mismatch");
        let roots: Vec<u32> = (0..tree.len() as u32)
            .filter(|&v| {
                if !member[v as usize] {
                    return false;
                }
                match tree.nodes[v as usize].parent {
                    Some(p) => !member[p as usize],
                    None => true,
                }
            })
            .collect();
        if roots.is_empty() {
            return None;
        }
        Some(Self::build_forest(tree, &roots, Some(member)))
    }

    /// Shared core of [`SpGraph::from_forest`] / [`SpGraph::from_induced`]:
    /// iterative DFS from the given roots (children filtered by the
    /// optional mask), then the bottom-up arena construction of
    /// [`SpGraph::from_tree`] over that order.
    fn build_forest(tree: &TaskTree, roots: &[u32], member: Option<&[bool]>) -> Self {
        assert!(!roots.is_empty(), "forest needs at least one root");
        let n = tree.len();
        let keep = |t: u32| match member {
            Some(m) => m[t as usize],
            None => true,
        };
        // Root-first order; seeded so roots[0] is processed first, and
        // children are stacked exactly as in `TaskTree::topo_down` so
        // the single-root case reproduces `from_tree` bit for bit.
        let mut order: Vec<u32> = Vec::with_capacity(n);
        let mut stack: Vec<u32> = roots.iter().rev().copied().collect();
        while let Some(v) = stack.pop() {
            order.push(v);
            stack.extend(
                tree.nodes[v as usize]
                    .children
                    .iter()
                    .copied()
                    .filter(|&c| keep(c)),
            );
        }
        let mut sub: Vec<SpNodeId> = vec![0; n];
        let mut g = SpGraph::new(Vec::with_capacity(2 * order.len() + 1), 0);
        for &v in order.iter().rev() {
            let node = &tree.nodes[v as usize];
            let leaf = g.push(SpNode::Leaf { len: node.len, task: Some(v) });
            let kids: Vec<SpNodeId> = node
                .children
                .iter()
                .copied()
                .filter(|&c| keep(c))
                .map(|c| sub[c as usize])
                .collect();
            let id = if kids.is_empty() {
                leaf
            } else {
                let par = if kids.len() == 1 {
                    kids[0]
                } else {
                    g.push(SpNode::Parallel(kids))
                };
                g.push(SpNode::Series(vec![par, leaf]))
            };
            sub[v as usize] = id;
        }
        let rids: Vec<SpNodeId> = roots.iter().map(|&r| sub[r as usize]).collect();
        g.root = if rids.len() == 1 {
            rids[0]
        } else {
            g.push(SpNode::Parallel(rids))
        };
        g
    }

    /// Number of actual tasks (leaves).
    pub fn num_tasks(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, SpNode::Leaf { .. }))
            .count()
    }

    /// Total sequential work of all leaves reachable from the root.
    pub fn total_work(&self) -> f64 {
        let mut sum = 0.0;
        for &v in self.topo() {
            if let SpNode::Leaf { len, .. } = self.nodes[v as usize] {
                sum += len;
            }
        }
        sum
    }

    /// Cached root-first order over *reachable* nodes (parents before
    /// children). Computed on first use, O(1) afterwards; iterate it in
    /// reverse for a children-first order. This is the traversal every
    /// scheduler pass uses — solvers must not allocate per call.
    pub fn topo(&self) -> &[SpNodeId] {
        self.topo
            .get_or_init(|| {
                let mut order = Vec::with_capacity(self.nodes.len());
                let mut stack = vec![self.root];
                while let Some(v) = stack.pop() {
                    order.push(v);
                    match &self.nodes[v as usize] {
                        SpNode::Series(c) | SpNode::Parallel(c) => {
                            stack.extend(c.iter().copied())
                        }
                        SpNode::Leaf { .. } => {}
                    }
                }
                order.into_boxed_slice()
            })
            .as_ref()
    }

    /// Root-first order as an owned `Vec` (compat wrapper over
    /// [`SpGraph::topo`]; prefer `topo()` in hot paths).
    pub fn topo_down(&self) -> Vec<SpNodeId> {
        self.topo().to_vec()
    }

    /// Children-first order over reachable nodes.
    pub fn topo_up(&self) -> Vec<SpNodeId> {
        let mut order = self.topo_down();
        order.reverse();
        order
    }

    /// Structural sanity: every composition non-empty, every child id in
    /// range, reachable subgraph is acyclic (guaranteed by arena
    /// construction but re-checked after rewrites like `Agreg`).
    pub fn validate(&self) -> Result<()> {
        let n = self.nodes.len();
        if self.root as usize >= n {
            bail!("root out of range");
        }
        // acyclicity + range check via DFS with visitation states
        let mut state = vec![0u8; n]; // 0=unseen 1=open 2=done
        let mut stack: Vec<(SpNodeId, usize)> = vec![(self.root, 0)];
        state[self.root as usize] = 1;
        while let Some(&mut (v, ref mut i)) = stack.last_mut() {
            let kids: &[SpNodeId] = match &self.nodes[v as usize] {
                SpNode::Series(c) | SpNode::Parallel(c) => {
                    if c.is_empty() {
                        bail!("empty composition at node {v}");
                    }
                    c
                }
                SpNode::Leaf { len, .. } => {
                    if !len.is_finite() || *len < 0.0 {
                        bail!("bad leaf length at node {v}");
                    }
                    &[]
                }
            };
            if *i < kids.len() {
                let c = kids[*i];
                *i += 1;
                if c as usize >= n {
                    bail!("child {c} out of range at node {v}");
                }
                match state[c as usize] {
                    1 => bail!("cycle through node {c}"),
                    0 => {
                        state[c as usize] = 1;
                        stack.push((c, 0));
                    }
                    _ => {} // shared subgraphs are not SP; but Agreg never shares
                }
            } else {
                state[v as usize] = 2;
                stack.pop();
            }
        }
        Ok(())
    }

    /// Rebuild the arena keeping only reachable nodes and flattening
    /// nested same-kind compositions / singleton compositions.
    pub fn normalized(&self) -> SpGraph {
        let mut out = SpGraph::new(Vec::with_capacity(self.nodes.len()), 0);
        let mut map: Vec<Option<SpNodeId>> = vec![None; self.nodes.len()];
        for &v in self.topo().iter().rev() {
            if map[v as usize].is_some() {
                continue;
            }
            let id = match &self.nodes[v as usize] {
                SpNode::Leaf { len, task } => out.push(SpNode::Leaf { len: *len, task: *task }),
                SpNode::Series(c) => {
                    let flat = Self::flatten(&out, c, &map, true);
                    if flat.len() == 1 {
                        flat[0]
                    } else {
                        out.push(SpNode::Series(flat))
                    }
                }
                SpNode::Parallel(c) => {
                    let flat = Self::flatten(&out, c, &map, false);
                    if flat.len() == 1 {
                        flat[0]
                    } else {
                        out.push(SpNode::Parallel(flat))
                    }
                }
            };
            map[v as usize] = Some(id);
        }
        out.root = map[self.root as usize].unwrap();
        out
    }

    fn flatten(
        out: &SpGraph,
        kids: &[SpNodeId],
        map: &[Option<SpNodeId>],
        series: bool,
    ) -> Vec<SpNodeId> {
        let mut flat = Vec::with_capacity(kids.len());
        for &c in kids {
            let nc = map[c as usize].expect("child mapped before parent");
            match (&out.nodes[nc as usize], series) {
                (SpNode::Series(inner), true) => flat.extend(inner.iter().copied()),
                (SpNode::Parallel(inner), false) => flat.extend(inner.iter().copied()),
                _ => flat.push(nc),
            }
        }
        flat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tree() -> TaskTree {
        TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap()
    }

    #[test]
    fn from_tree_preserves_tasks_and_work() {
        let t = sample_tree();
        let g = SpGraph::from_tree(&t);
        g.validate().unwrap();
        assert_eq!(g.num_tasks(), 5);
        assert_eq!(g.total_work(), 15.0);
    }

    #[test]
    fn from_tree_structure_is_pseudo_tree() {
        // node 1 (with children 3,4) becomes Series(Parallel(3,4), leaf1)
        let t = sample_tree();
        let g = SpGraph::from_tree(&t);
        let SpNode::Series(root_kids) = &g.nodes[g.root as usize] else {
            panic!("root should be series");
        };
        assert_eq!(root_kids.len(), 2);
        let SpNode::Parallel(par) = &g.nodes[root_kids[0] as usize] else {
            panic!("first series element should be the children parallel");
        };
        assert_eq!(par.len(), 2);
    }

    #[test]
    fn single_child_skips_parallel_wrapper() {
        // chain 0 <- 1
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 2.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let SpNode::Series(kids) = &g.nodes[g.root as usize] else {
            panic!()
        };
        assert!(matches!(g.nodes[kids[0] as usize], SpNode::Leaf { .. }));
    }

    #[test]
    fn series_parallel_builders() {
        let g = SpGraph::series(SpGraph::leaf(1.0), SpGraph::leaf(2.0));
        g.validate().unwrap();
        assert_eq!(g.total_work(), 3.0);
        let g = SpGraph::parallel(g, SpGraph::leaf(4.0));
        g.validate().unwrap();
        assert_eq!(g.total_work(), 7.0);
        assert_eq!(g.num_tasks(), 3);
    }

    #[test]
    fn normalized_flattens_nested_series() {
        let g = SpGraph::series(
            SpGraph::series(SpGraph::leaf(1.0), SpGraph::leaf(2.0)),
            SpGraph::leaf(3.0),
        );
        let n = g.normalized();
        let SpNode::Series(kids) = &n.nodes[n.root as usize] else {
            panic!()
        };
        assert_eq!(kids.len(), 3);
        assert_eq!(n.total_work(), 6.0);
    }

    #[test]
    fn normalized_drops_unreachable() {
        let mut g = SpGraph::leaf(1.0);
        g.push(SpNode::Leaf { len: 99.0, task: None }); // orphan
        let n = g.normalized();
        assert_eq!(n.nodes.len(), 1);
        assert_eq!(n.total_work(), 1.0);
    }

    #[test]
    fn validate_rejects_empty_composition() {
        let g = SpGraph::new(vec![SpNode::Parallel(vec![])], 0);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_cycle() {
        let g = SpGraph::new(vec![SpNode::Series(vec![0])], 0);
        assert!(g.validate().is_err());
    }

    #[test]
    fn topo_cache_survives_reads_and_invalidates_on_push() {
        let t = sample_tree();
        let g = SpGraph::from_tree(&t);
        let first = g.topo().to_vec();
        // repeated reads return the cached slice with identical content
        assert_eq!(g.topo(), first.as_slice());
        assert_eq!(g.topo_down(), first);
        let mut rev = first.clone();
        rev.reverse();
        assert_eq!(g.topo_up(), rev);
        // mutation invalidates: an orphan push keeps reachable order,
        // attaching it via a fresh root must be observed
        let mut g = g;
        let orphan = g.push(SpNode::Leaf { len: 7.0, task: None });
        assert_eq!(g.topo().to_vec(), first, "orphan is unreachable");
        let old_root = g.root;
        let new_root = g.push(SpNode::Series(vec![old_root, orphan]));
        g.root = new_root;
        g.invalidate_topo();
        let now = g.topo();
        assert_eq!(now.len(), first.len() + 2);
        assert_eq!(now[0], new_root);
        assert_eq!(g.total_work(), 15.0 + 7.0);
    }

    #[test]
    fn from_forest_single_root_is_bit_identical_to_from_tree() {
        let t = sample_tree();
        let whole = SpGraph::from_tree(&t);
        let forest = SpGraph::from_forest(&t, &[t.root]);
        assert_eq!(forest.nodes, whole.nodes);
        assert_eq!(forest.root, whole.root);
    }

    #[test]
    fn from_forest_composes_disjoint_subtrees_in_parallel() {
        // roots 1 and 2 of the sample: subtree {1,3,4} plus leaf {2}
        let t = sample_tree();
        let g = SpGraph::from_forest(&t, &[1, 2]);
        g.validate().unwrap();
        assert_eq!(g.num_tasks(), 4);
        assert_eq!(g.total_work(), 2.0 + 3.0 + 4.0 + 5.0);
        let SpNode::Parallel(kids) = &g.nodes[g.root as usize] else {
            panic!("multi-root forest must be a parallel composition");
        };
        assert_eq!(kids.len(), 2);
    }

    #[test]
    fn from_induced_cuts_edges_to_non_members() {
        // keep the root chain {0} and subtree root 1, drop 3 and 4:
        // node 1 loses its children, 2 is absent -> forest {0 <- 1}
        let t = sample_tree();
        let mut member = vec![false; t.len()];
        member[0] = true;
        member[1] = true;
        let g = SpGraph::from_induced(&t, &member).unwrap();
        g.validate().unwrap();
        assert_eq!(g.num_tasks(), 2);
        assert_eq!(g.total_work(), 1.0 + 2.0);
        // structure: Series(leaf1, leaf0) — one local root (task 0)
        let SpNode::Series(kids) = &g.nodes[g.root as usize] else {
            panic!("chain must stay a series");
        };
        assert_eq!(kids.len(), 2);
    }

    #[test]
    fn from_induced_empty_mask_is_none() {
        let t = sample_tree();
        assert!(SpGraph::from_induced(&t, &vec![false; t.len()]).is_none());
        // full mask reproduces the whole tree
        let g = SpGraph::from_induced(&t, &vec![true; t.len()]).unwrap();
        assert_eq!(g.nodes, SpGraph::from_tree(&t).nodes);
        assert_eq!(g.root, SpGraph::from_tree(&t).root);
    }

    #[test]
    fn deep_tree_no_stack_overflow() {
        let n = 100_000;
        let parents: Vec<usize> = (0..n).map(|i: usize| i.saturating_sub(1)).collect();
        let t = TaskTree::from_parents(&parents, &vec![1.0; n]).unwrap();
        let g = SpGraph::from_tree(&t);
        g.validate().unwrap();
        assert_eq!(g.num_tasks(), n);
        let norm = g.normalized();
        assert_eq!(norm.num_tasks(), n);
    }
}
