//! In-trees of malleable tasks.

use anyhow::{bail, Result};

/// One malleable task in the tree.
#[derive(Debug, Clone)]
pub struct TreeNode {
    /// Sequential processing time `L_i` (flops, seconds at p=1 — any
    /// consistent unit).
    pub len: f64,
    /// Parent task (None for the root). Edges point child -> parent:
    /// a task can start only when all its children completed.
    pub parent: Option<u32>,
    /// Children, filled by [`TaskTree::from_parents`].
    pub children: Vec<u32>,
}

/// An in-tree of malleable tasks (paper §4).
///
/// Stored as an arena indexed by `u32` task ids; the root is unique.
#[derive(Debug, Clone)]
pub struct TaskTree {
    pub nodes: Vec<TreeNode>,
    pub root: u32,
}

impl TaskTree {
    /// Build from a parent array (`parents[i] == i` marks the root) and
    /// per-task sequential lengths.
    pub fn from_parents(parents: &[usize], lens: &[f64]) -> Result<Self> {
        if parents.len() != lens.len() || parents.is_empty() {
            bail!("parents/lens size mismatch or empty");
        }
        let n = parents.len();
        let mut nodes: Vec<TreeNode> = lens
            .iter()
            .map(|&len| TreeNode { len, parent: None, children: Vec::new() })
            .collect();
        let mut root = None;
        for (i, &p) in parents.iter().enumerate() {
            if p == i {
                if root.replace(i as u32).is_some() {
                    bail!("multiple roots");
                }
            } else {
                if p >= n {
                    bail!("parent {p} out of range");
                }
                nodes[i].parent = Some(p as u32);
                nodes[p].children.push(i as u32);
            }
        }
        let Some(root) = root else { bail!("no root") };
        let tree = TaskTree { nodes, root };
        tree.validate()?;
        Ok(tree)
    }

    /// Single task.
    pub fn singleton(len: f64) -> Self {
        TaskTree {
            nodes: vec![TreeNode { len, parent: None, children: Vec::new() }],
            root: 0,
        }
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Total sequential work `Σ L_i`.
    pub fn total_work(&self) -> f64 {
        self.nodes.iter().map(|n| n.len).sum()
    }

    /// Check connectivity and acyclicity (every node reaches the root).
    pub fn validate(&self) -> Result<()> {
        let n = self.len();
        let mut seen = vec![false; n];
        let order = self.topo_down();
        if order.len() != n {
            bail!("tree is disconnected: reached {} of {n}", order.len());
        }
        for &v in &order {
            if seen[v as usize] {
                bail!("cycle through node {v}");
            }
            seen[v as usize] = true;
        }
        Ok(())
    }

    /// Root-to-leaves order (every node appears after its parent).
    pub fn topo_down(&self) -> Vec<u32> {
        let mut order = Vec::with_capacity(self.len());
        let mut stack = vec![self.root];
        while let Some(v) = stack.pop() {
            order.push(v);
            stack.extend(self.nodes[v as usize].children.iter().copied());
        }
        order
    }

    /// Leaves-to-root (postorder-compatible: children before parents).
    pub fn topo_up(&self) -> Vec<u32> {
        let mut order = self.topo_down();
        order.reverse();
        order
    }

    /// Depth of each node (root = 0), iteratively.
    pub fn depths(&self) -> Vec<u32> {
        let mut d = vec![0u32; self.len()];
        for &v in &self.topo_down() {
            if let Some(p) = self.nodes[v as usize].parent {
                d[v as usize] = d[p as usize] + 1;
            }
        }
        d
    }

    /// Tree height (max depth).
    pub fn height(&self) -> u32 {
        self.depths().into_iter().max().unwrap_or(0)
    }

    /// Per-node subtree work `W(v) = Σ_{u in subtree(v)} L_u`.
    pub fn subtree_work(&self) -> Vec<f64> {
        let mut w: Vec<f64> = self.nodes.iter().map(|n| n.len).collect();
        for &v in &self.topo_up() {
            if let Some(p) = self.nodes[v as usize].parent {
                w[p as usize] += w[v as usize];
            }
        }
        w
    }

    /// Critical path: max root-to-leaf sum of lengths.
    pub fn critical_path(&self) -> f64 {
        let mut cp = vec![0f64; self.len()];
        let mut best = 0f64;
        for &v in &self.topo_up() {
            let node = &self.nodes[v as usize];
            let child_max = node
                .children
                .iter()
                .map(|&c| cp[c as usize])
                .fold(0f64, f64::max);
            cp[v as usize] = node.len + child_max;
            best = best.max(cp[v as usize]);
        }
        best
    }

    /// Leaf count.
    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.children.is_empty()).count()
    }

    /// All task ids in the subtree rooted at `root` (root-first,
    /// iterative) — the unit the distributed mapping layer assigns to
    /// a node (tasks may not span nodes, so whole subtrees move).
    pub fn subtree_tasks(&self, root: u32) -> Vec<u32> {
        let mut out = Vec::new();
        let mut stack = vec![root];
        while let Some(v) = stack.pop() {
            out.push(v);
            stack.extend(self.nodes[v as usize].children.iter().copied());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's running example shape: root with two children, one of
    /// which has two leaf children.
    pub fn sample() -> TaskTree {
        // 0 = root; 1,2 children of 0; 3,4 children of 1
        TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap()
    }

    #[test]
    fn from_parents_builds_children() {
        let t = sample();
        assert_eq!(t.root, 0);
        assert_eq!(t.nodes[0].children, vec![1, 2]);
        assert_eq!(t.nodes[1].children, vec![3, 4]);
        assert!(t.nodes[3].children.is_empty());
    }

    #[test]
    fn rejects_multiple_roots() {
        assert!(TaskTree::from_parents(&[0, 1], &[1.0, 1.0]).is_err());
    }

    #[test]
    fn rejects_cycle() {
        // 1 -> 2 -> 1 cycle, 0 root
        assert!(TaskTree::from_parents(&[0, 2, 1], &[1.0, 1.0, 1.0]).is_err());
    }

    #[test]
    fn rejects_out_of_range_parent() {
        assert!(TaskTree::from_parents(&[0, 9], &[1.0, 1.0]).is_err());
    }

    #[test]
    fn topo_orders_respect_edges() {
        let t = sample();
        let down = t.topo_down();
        let pos: Vec<usize> = {
            let mut p = vec![0; t.len()];
            for (i, &v) in down.iter().enumerate() {
                p[v as usize] = i;
            }
            p
        };
        for (i, n) in t.nodes.iter().enumerate() {
            if let Some(par) = n.parent {
                assert!(pos[par as usize] < pos[i]);
            }
        }
    }

    #[test]
    fn work_and_depth() {
        let t = sample();
        assert_eq!(t.total_work(), 15.0);
        let w = t.subtree_work();
        assert_eq!(w[0], 15.0);
        assert_eq!(w[1], 11.0);
        assert_eq!(w[2], 3.0);
        let d = t.depths();
        assert_eq!(d, vec![0, 1, 1, 2, 2]);
        assert_eq!(t.height(), 2);
    }

    #[test]
    fn critical_path_value() {
        let t = sample();
        // root(1) + node1(2) + node4(5) = 8
        assert_eq!(t.critical_path(), 8.0);
    }

    #[test]
    fn deep_chain_no_stack_overflow() {
        // 100k-deep chain — must not recurse.
        let n = 100_000;
        let mut parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { i - 1 }).collect();
        parents[0] = 0;
        let lens = vec![1.0; n];
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        assert_eq!(t.height() as usize, n - 1);
        assert_eq!(t.critical_path(), n as f64);
    }

    #[test]
    fn subtree_tasks_covers_exactly_the_subtree() {
        let t = sample();
        let mut s = t.subtree_tasks(1);
        s.sort_unstable();
        assert_eq!(s, vec![1, 3, 4]);
        assert_eq!(t.subtree_tasks(2), vec![2]);
        let mut whole = t.subtree_tasks(t.root);
        whole.sort_unstable();
        assert_eq!(whole, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn singleton_tree() {
        let t = TaskTree::singleton(4.0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.total_work(), 4.0);
        assert_eq!(t.num_leaves(), 1);
    }
}
