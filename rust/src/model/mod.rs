//! Task-graph model (paper §4): malleable tasks, in-trees, and
//! series-parallel graphs.
//!
//! Trees come out of sparse symbolic analysis ([`crate::sparse`]) or the
//! workload generators; the schedulers in [`crate::sched`] consume
//! either a [`TaskTree`] directly or its pseudo-tree [`SpGraph`]
//! conversion (paper Figure 7). All traversals are iterative — the
//! paper's dataset has trees of depth 75 000, far beyond any default
//! thread stack.

mod disturbance;
mod platform;
mod sp;
mod tree;

pub mod dot;

pub use disturbance::{FaultEvent, FaultKind, FaultTrace};
pub use platform::Platform;
pub use sp::{SpGraph, SpNode, SpNodeId};
pub use tree::{TaskTree, TreeNode};
