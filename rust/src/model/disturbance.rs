//! Disturbance model: failure and elasticity events over a platform
//! (DESIGN.md §13).
//!
//! The malleable model (`p^α` speedup, shares re-solvable at any
//! event) extends naturally to platforms that change under the
//! schedule. A [`FaultTrace`] is a time-sorted list of disturbance
//! events against the node indices of a [`crate::model::Platform`]:
//!
//! * [`FaultKind::Crash`] — the node dies; every contribution block
//!   resident on it is lost and the affected subtrees must be
//!   re-mapped onto survivors ([`crate::sim::faults`]);
//! * [`FaultKind::Leave`] / [`FaultKind::Join`] — elastic capacity:
//!   cores leave or join a node mid-run;
//! * [`FaultKind::Slowdown`] — a transient multiplicative speed drop
//!   (e.g. co-tenancy interference) that clears after `duration`;
//! * [`FaultKind::LinkDegrade`] / [`FaultKind::LinkDown`] — the
//!   *network* misbehaves: the link between two nodes runs at
//!   `factor ×` its nominal bandwidth (or is severed outright) for
//!   `duration` seconds. Compute replay ([`crate::sim::faults`])
//!   ignores them — its network is free by assumption — while the
//!   priced network replay ([`crate::net`]) times out and retransmits
//!   the affected transfers.
//!
//! Traces are deterministic values — generated seeded by
//! [`crate::workload::generator::random_fault_trace`], serialized in
//! trace v3 ([`crate::workload::trace`]) — so every fault experiment
//! is reproducible.

use anyhow::{bail, Result};

/// One disturbance against a platform node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Node `node` dies permanently; resident data is lost.
    Crash { node: usize },
    /// `cores` processors leave `node` (capacity must stay positive).
    Leave { node: usize, cores: f64 },
    /// `cores` processors join `node`.
    Join { node: usize, cores: f64 },
    /// `node` runs at `factor ×` its nominal speed for `duration`
    /// seconds (factor < 1 is a slowdown; > 1 a transient boost).
    Slowdown { node: usize, factor: f64, duration: f64 },
    /// The link between nodes `a` and `b` (both directions) runs at
    /// `factor ×` its nominal bandwidth for `duration` seconds.
    LinkDegrade { a: usize, b: usize, factor: f64, duration: f64 },
    /// The link between nodes `a` and `b` is severed (zero bandwidth)
    /// for `duration` seconds, then restored — bounded, so a
    /// wait-it-out baseline always stays finite.
    LinkDown { a: usize, b: usize, duration: f64 },
}

impl FaultKind {
    /// The node this event targets (the first endpoint for link
    /// events).
    pub fn node(&self) -> usize {
        match *self {
            FaultKind::Crash { node }
            | FaultKind::Leave { node, .. }
            | FaultKind::Join { node, .. }
            | FaultKind::Slowdown { node, .. } => node,
            FaultKind::LinkDegrade { a, .. } | FaultKind::LinkDown { a, .. } => a,
        }
    }

    /// True for events against a link rather than a node.
    pub fn is_link(&self) -> bool {
        matches!(self, FaultKind::LinkDegrade { .. } | FaultKind::LinkDown { .. })
    }

    /// Short name used by the trace v3 format and CLI tables.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Leave { .. } => "leave",
            FaultKind::Join { .. } => "join",
            FaultKind::Slowdown { .. } => "slow",
            FaultKind::LinkDegrade { .. } => "linkslow",
            FaultKind::LinkDown { .. } => "linkdown",
        }
    }
}

/// A [`FaultKind`] at an absolute simulation time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub time: f64,
    pub kind: FaultKind,
}

/// A deterministic, time-sorted disturbance trace.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultTrace {
    /// Events sorted by time (stable: same-time events keep insertion
    /// order, which makes replay deterministic).
    pub events: Vec<FaultEvent>,
}

impl FaultTrace {
    /// The fault-free trace.
    pub fn empty() -> Self {
        FaultTrace { events: Vec::new() }
    }

    /// Build a trace, sorting events by time (stable on ties).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| a.time.total_cmp(&b.time));
        FaultTrace { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Number of crash events.
    pub fn crashes(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Crash { .. }))
            .count()
    }

    /// Number of link events ([`FaultKind::is_link`]).
    pub fn link_events(&self) -> usize {
        self.events.iter().filter(|e| e.kind.is_link()).count()
    }

    /// The sub-trace of link events only (times preserved).
    pub fn link_only(&self) -> FaultTrace {
        FaultTrace {
            events: self.events.iter().copied().filter(|e| e.kind.is_link()).collect(),
        }
    }

    /// Check the trace against a platform of `n_nodes` nodes: finite
    /// non-negative times, in-range node indices, positive magnitudes,
    /// and at least one node left uncrashed.
    pub fn validate(&self, n_nodes: usize) -> Result<()> {
        let mut crashed = vec![false; n_nodes];
        for (i, e) in self.events.iter().enumerate() {
            if !e.time.is_finite() || e.time < 0.0 {
                bail!("event {i}: bad time {}", e.time);
            }
            let node = e.kind.node();
            if node >= n_nodes {
                bail!("event {i}: node {node} out of range (platform has {n_nodes})");
            }
            match e.kind {
                FaultKind::Crash { node } => crashed[node] = true,
                FaultKind::Leave { cores, .. } | FaultKind::Join { cores, .. } => {
                    if !(cores > 0.0) || !cores.is_finite() {
                        bail!("event {i}: cores must be positive, got {cores}");
                    }
                }
                FaultKind::Slowdown { factor, duration, .. } => {
                    if !(factor > 0.0) || !factor.is_finite() {
                        bail!("event {i}: slowdown factor must be positive, got {factor}");
                    }
                    if !(duration > 0.0) || !duration.is_finite() {
                        bail!("event {i}: slowdown duration must be positive, got {duration}");
                    }
                }
                FaultKind::LinkDegrade { a, b, factor, duration } => {
                    if b >= n_nodes {
                        bail!("event {i}: node {b} out of range (platform has {n_nodes})");
                    }
                    if a == b {
                        bail!("event {i}: link endpoints must differ, got {a}-{b}");
                    }
                    if !(factor > 0.0) || !factor.is_finite() {
                        bail!("event {i}: link factor must be positive, got {factor}");
                    }
                    if !(duration > 0.0) || !duration.is_finite() {
                        bail!("event {i}: link duration must be positive, got {duration}");
                    }
                }
                FaultKind::LinkDown { a, b, duration } => {
                    if b >= n_nodes {
                        bail!("event {i}: node {b} out of range (platform has {n_nodes})");
                    }
                    if a == b {
                        bail!("event {i}: link endpoints must differ, got {a}-{b}");
                    }
                    if !(duration > 0.0) || !duration.is_finite() {
                        bail!("event {i}: link duration must be positive, got {duration}");
                    }
                }
            }
        }
        if n_nodes > 0 && crashed.iter().all(|&c| c) {
            bail!("trace crashes every node; at least one must survive");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_by_time() {
        let t = FaultTrace::new(vec![
            FaultEvent { time: 5.0, kind: FaultKind::Crash { node: 1 } },
            FaultEvent { time: 1.0, kind: FaultKind::Join { node: 0, cores: 2.0 } },
        ]);
        assert_eq!(t.events[0].time, 1.0);
        assert_eq!(t.events[1].time, 5.0);
        assert_eq!(t.crashes(), 1);
    }

    #[test]
    fn validate_rejects_bad_events() {
        let n = 2;
        let bad = [
            FaultEvent { time: -1.0, kind: FaultKind::Crash { node: 0 } },
            FaultEvent { time: f64::INFINITY, kind: FaultKind::Crash { node: 0 } },
            FaultEvent { time: 1.0, kind: FaultKind::Crash { node: 2 } },
            FaultEvent { time: 1.0, kind: FaultKind::Leave { node: 0, cores: 0.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::Slowdown { node: 0, factor: -0.5, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::Slowdown { node: 0, factor: 0.5, duration: 0.0 } },
        ];
        for e in bad {
            assert!(FaultTrace::new(vec![e]).validate(n).is_err(), "{e:?}");
        }
        assert!(FaultTrace::empty().validate(n).is_ok());
    }

    #[test]
    fn validate_checks_link_events() {
        let good = FaultTrace::new(vec![
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.25, duration: 2.0 } },
            FaultEvent { time: 2.0, kind: FaultKind::LinkDown { a: 1, b: 0, duration: 1.0 } },
        ]);
        assert!(good.validate(2).is_ok());
        assert_eq!(good.link_events(), 2);
        assert_eq!(good.link_only().len(), 2);
        assert!(good.events[0].kind.is_link());
        assert_eq!(good.events[0].kind.name(), "linkslow");
        assert_eq!(good.events[1].kind.name(), "linkdown");
        let bad = [
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 0, b: 2, factor: 0.5, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 2, b: 0, factor: 0.5, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 0, b: 0, factor: 0.5, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.0, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.5, duration: 0.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDown { a: 0, b: 0, duration: 1.0 } },
            FaultEvent { time: 1.0, kind: FaultKind::LinkDown { a: 0, b: 1, duration: f64::INFINITY } },
        ];
        for e in bad {
            assert!(FaultTrace::new(vec![e]).validate(2).is_err(), "{e:?}");
        }
        // a crash-everything trace is still rejected with link noise
        let t = FaultTrace::new(vec![
            FaultEvent { time: 1.0, kind: FaultKind::Crash { node: 0 } },
            FaultEvent { time: 1.5, kind: FaultKind::LinkDown { a: 0, b: 1, duration: 1.0 } },
            FaultEvent { time: 2.0, kind: FaultKind::Crash { node: 1 } },
        ]);
        assert!(t.validate(2).is_err());
        assert_eq!(t.link_only().len(), 1);
    }

    #[test]
    fn validate_rejects_total_crash() {
        let t = FaultTrace::new(vec![
            FaultEvent { time: 1.0, kind: FaultKind::Crash { node: 0 } },
            FaultEvent { time: 2.0, kind: FaultKind::Crash { node: 1 } },
        ]);
        assert!(t.validate(2).is_err());
        assert!(t.validate(3).is_ok());
    }
}
