//! Graphviz DOT export for trees and SP graphs (debugging / docs).

use super::{SpGraph, SpNode, TaskTree};

/// Render a [`TaskTree`] as DOT (edges child -> parent, as in the paper).
pub fn tree_to_dot(tree: &TaskTree) -> String {
    let mut s = String::from("digraph tree {\n  rankdir=BT;\n");
    for (i, n) in tree.nodes.iter().enumerate() {
        s.push_str(&format!("  t{i} [label=\"T{i}\\nL={:.3}\"];\n", n.len));
        if let Some(p) = n.parent {
            s.push_str(&format!("  t{i} -> t{p};\n"));
        }
    }
    s.push_str("}\n");
    s
}

/// Render an [`SpGraph`] as DOT (compositions as boxes).
pub fn sp_to_dot(g: &SpGraph) -> String {
    let mut s = String::from("digraph sp {\n");
    for &v in &g.topo_down() {
        match &g.nodes[v as usize] {
            SpNode::Leaf { len, task } => {
                let t = task.map(|t| format!("T{t}")).unwrap_or_else(|| "·".into());
                s.push_str(&format!("  n{v} [label=\"{t}\\nL={len:.3}\"];\n"));
            }
            SpNode::Series(c) => {
                s.push_str(&format!("  n{v} [shape=box,label=\";\"];\n"));
                for x in c {
                    s.push_str(&format!("  n{v} -> n{x};\n"));
                }
            }
            SpNode::Parallel(c) => {
                s.push_str(&format!("  n{v} [shape=box,label=\"||\"];\n"));
                for x in c {
                    s.push_str(&format!("  n{v} -> n{x};\n"));
                }
            }
        }
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_dot_mentions_all_nodes() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 2.0, 3.0]).unwrap();
        let dot = tree_to_dot(&t);
        for i in 0..3 {
            assert!(dot.contains(&format!("t{i} ")));
        }
        assert!(dot.contains("t1 -> t0;"));
    }

    #[test]
    fn sp_dot_renders_compositions() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 2.0, 3.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let dot = sp_to_dot(&g);
        assert!(dot.contains("\";\""));
        assert!(dot.contains("\"||\""));
    }
}
