//! Batch scheduling front-end: many independent trees, one thread pool.
//!
//! The multi-tenant scenario the ROADMAP targets — heavy traffic of
//! scheduling requests, each an independent assembly tree — is
//! embarrassingly parallel *across* trees, and the per-tree pipeline
//! (pseudo-tree conversion → incremental `Agreg` → PM solve) reuses
//! all solver state through a per-worker [`SchedWorkspace`] (the
//! remaining per-tree allocations are the graph materializations
//! themselves). [`schedule_batch`] claims trees off a shared atomic
//! counter, so results are deterministic per tree regardless of thread
//! count or claim order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::model::{SpGraph, TaskTree};

use super::workspace::SchedWorkspace;

/// Batch scheduling parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Speedup exponent α.
    pub alpha: f64,
    /// Processors per tree (each tenant schedules against its own
    /// platform, as in the paper's per-tree evaluation).
    pub p: f64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Whether to run the `Agreg` rewriting before the PM solve (the
    /// realistic ≥ 1-processor pipeline) or solve the raw pseudo-tree.
    pub agreg: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { alpha: crate::DEFAULT_ALPHA, p: 40.0, threads: 0, agreg: true }
    }
}

/// Per-tree output of a batch run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Index of the tree in the input slice.
    pub index: usize,
    /// Task count of the tree.
    pub tasks: usize,
    /// PM makespan (of the `Agreg`-rewritten graph when
    /// `BatchConfig::agreg` is set) on `p` processors.
    pub makespan: f64,
    /// Minimum task share of the solved graph (≥ 1 − ε after `Agreg`).
    pub min_share: f64,
    /// `Agreg` iterations (0 when `agreg` is off).
    pub agreg_iterations: usize,
    /// `Agreg` branches serialized (0 when `agreg` is off).
    pub agreg_moved: usize,
}

/// Resolve the worker count: `threads` if positive, else one per
/// available core.
pub fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Schedule one tree with a caller-provided workspace (the per-worker
/// inner loop of [`schedule_batch`], exposed for reuse and testing).
pub fn schedule_one(
    tree: &TaskTree,
    cfg: &BatchConfig,
    ws: &mut SchedWorkspace,
    index: usize,
) -> BatchResult {
    let g = SpGraph::from_tree(tree);
    let (graph, stats) = if cfg.agreg {
        let (ag, stats) = ws.agreg(&g, cfg.alpha, cfg.p);
        (ag, stats)
    } else {
        (g, Default::default())
    };
    let sol = ws.solve(&graph, cfg.alpha);
    BatchResult {
        index,
        tasks: tree.len(),
        makespan: sol.makespan_const(cfg.p),
        min_share: sol.min_task_share(&graph, cfg.p),
        agreg_iterations: stats.iterations,
        agreg_moved: stats.moved,
    }
}

/// Schedule every tree of `trees` concurrently; results are returned
/// in input order. Deterministic: per-tree outputs are independent of
/// the thread count.
pub fn schedule_batch(trees: &[TaskTree], cfg: &BatchConfig) -> Vec<BatchResult> {
    let workers = effective_threads(cfg.threads).min(trees.len().max(1));
    if workers <= 1 {
        let mut ws = SchedWorkspace::new();
        return trees
            .iter()
            .enumerate()
            .map(|(i, t)| schedule_one(t, cfg, &mut ws, i))
            .collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<BatchResult>> = Mutex::new(Vec::with_capacity(trees.len()));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // one workspace per worker: reused across every tree
                // this worker claims — the steady state allocates
                // nothing in the solver
                let mut ws = SchedWorkspace::new();
                let mut local: Vec<BatchResult> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= trees.len() {
                        break;
                    }
                    local.push(schedule_one(&trees[i], cfg, &mut ws, i));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|r| r.index);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{generator::random_tree, TreeClass};

    fn corpus(n_trees: usize, size: usize) -> Vec<TaskTree> {
        let mut rng = Rng::new(0xBA7C);
        let classes = [
            TreeClass::Uniform,
            TreeClass::Recent,
            TreeClass::Deep,
            TreeClass::Binary,
        ];
        (0..n_trees)
            .map(|i| random_tree(classes[i % classes.len()], size + i * 13, &mut rng))
            .collect()
    }

    #[test]
    fn batch_matches_sequential_and_is_thread_count_invariant() {
        let trees = corpus(12, 120);
        let base = BatchConfig { alpha: 0.9, p: 8.0, threads: 1, agreg: true };
        let seq = schedule_batch(&trees, &base);
        for threads in [2, 4, 7] {
            let cfg = BatchConfig { threads, ..base };
            let par = schedule_batch(&trees, &cfg);
            assert_eq!(par.len(), seq.len());
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.tasks, b.tasks);
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
                assert_eq!(a.agreg_iterations, b.agreg_iterations);
                assert_eq!(a.agreg_moved, b.agreg_moved);
            }
        }
    }

    #[test]
    fn batch_results_respect_agreg_postcondition() {
        let trees = corpus(8, 150);
        let cfg = BatchConfig { alpha: 0.85, p: 6.0, threads: 3, agreg: true };
        for r in schedule_batch(&trees, &cfg) {
            assert!(r.min_share >= 1.0 - 1e-6, "tree {}: {}", r.index, r.min_share);
            assert!(r.makespan.is_finite() && r.makespan > 0.0);
        }
    }

    #[test]
    fn batch_without_agreg_matches_direct_solve() {
        use crate::sched::pm::PmSolution;
        let trees = corpus(5, 80);
        let cfg = BatchConfig { alpha: 0.7, p: 16.0, threads: 2, agreg: false };
        let got = schedule_batch(&trees, &cfg);
        for (i, r) in got.iter().enumerate() {
            let g = SpGraph::from_tree(&trees[i]);
            let want = PmSolution::solve(&g, 0.7).makespan_const(16.0);
            assert_eq!(r.makespan.to_bits(), want.to_bits());
            assert_eq!(r.agreg_iterations, 0);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let out = schedule_batch(&[], &BatchConfig::default());
        assert!(out.is_empty());
    }
}
