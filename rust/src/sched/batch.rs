//! Batch scheduling front-end: many independent trees, one thread pool.
//!
//! The multi-tenant scenario the ROADMAP targets — heavy traffic of
//! scheduling requests, each an independent assembly tree — is
//! embarrassingly parallel *across* trees, and the per-tree pipeline
//! (pseudo-tree conversion → incremental `Agreg` → PM solve) reuses
//! all solver state through a per-worker [`SchedWorkspace`] (the
//! remaining per-tree allocations are the graph materializations
//! themselves). [`schedule_batch`] claims trees off a shared atomic
//! counter, so results are deterministic per tree regardless of thread
//! count or claim order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::model::{SpGraph, TaskTree};

use super::workspace::SchedWorkspace;

/// Batch scheduling parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Speedup exponent α.
    pub alpha: f64,
    /// Processors per tree (each tenant schedules against its own
    /// platform, as in the paper's per-tree evaluation).
    pub p: f64,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Whether to run the `Agreg` rewriting before the PM solve (the
    /// realistic ≥ 1-processor pipeline) or solve the raw pseudo-tree.
    pub agreg: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { alpha: crate::DEFAULT_ALPHA, p: 40.0, threads: 0, agreg: true }
    }
}

/// Per-tree output of a batch run.
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Index of the tree in the input slice.
    pub index: usize,
    /// Task count of the tree.
    pub tasks: usize,
    /// PM makespan (of the `Agreg`-rewritten graph when
    /// `BatchConfig::agreg` is set) on `p` processors.
    pub makespan: f64,
    /// Minimum task share of the solved graph (≥ 1 − ε after `Agreg`).
    pub min_share: f64,
    /// `Agreg` iterations (0 when `agreg` is off).
    pub agreg_iterations: usize,
    /// `Agreg` branches serialized (0 when `agreg` is off).
    pub agreg_moved: usize,
}

/// Resolve the worker count: `threads` if positive, else one per
/// available core.
pub fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Schedule one tree with a caller-provided workspace (the per-worker
/// inner loop of [`schedule_batch`], exposed for reuse and testing).
pub fn schedule_one(
    tree: &TaskTree,
    cfg: &BatchConfig,
    ws: &mut SchedWorkspace,
    index: usize,
) -> BatchResult {
    let g = SpGraph::from_tree(tree);
    let (graph, stats) = if cfg.agreg {
        let (ag, stats) = ws.agreg(&g, cfg.alpha, cfg.p);
        (ag, stats)
    } else {
        (g, Default::default())
    };
    let sol = ws.solve(&graph, cfg.alpha);
    BatchResult {
        index,
        tasks: tree.len(),
        makespan: sol.makespan_const(cfg.p),
        min_share: sol.min_task_share(&graph, cfg.p),
        agreg_iterations: stats.iterations,
        agreg_moved: stats.moved,
    }
}

/// Schedule every tree of `trees` concurrently; results are returned
/// in input order. Deterministic: per-tree outputs are independent of
/// the thread count.
pub fn schedule_batch(trees: &[TaskTree], cfg: &BatchConfig) -> Vec<BatchResult> {
    let workers = effective_threads(cfg.threads).min(trees.len().max(1));
    if workers <= 1 {
        let mut ws = SchedWorkspace::new();
        return trees
            .iter()
            .enumerate()
            .map(|(i, t)| schedule_one(t, cfg, &mut ws, i))
            .collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<BatchResult>> = Mutex::new(Vec::with_capacity(trees.len()));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // one workspace per worker: reused across every tree
                // this worker claims — the steady state allocates
                // nothing in the solver
                let mut ws = SchedWorkspace::new();
                let mut local: Vec<BatchResult> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= trees.len() {
                        break;
                    }
                    local.push(schedule_one(&trees[i], cfg, &mut ws, i));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|r| r.index);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::{generator::random_tree, TreeClass};

    fn corpus(n_trees: usize, size: usize) -> Vec<TaskTree> {
        let mut rng = Rng::new(0xBA7C);
        let classes = [
            TreeClass::Uniform,
            TreeClass::Recent,
            TreeClass::Deep,
            TreeClass::Binary,
        ];
        (0..n_trees)
            .map(|i| random_tree(classes[i % classes.len()], size + i * 13, &mut rng))
            .collect()
    }

    #[test]
    fn batch_matches_sequential_and_is_thread_count_invariant() {
        let trees = corpus(12, 120);
        let base = BatchConfig { alpha: 0.9, p: 8.0, threads: 1, agreg: true };
        let seq = schedule_batch(&trees, &base);
        for threads in [2, 4, 7] {
            let cfg = BatchConfig { threads, ..base };
            let par = schedule_batch(&trees, &cfg);
            assert_eq!(par.len(), seq.len());
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.tasks, b.tasks);
                assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
                assert_eq!(a.agreg_iterations, b.agreg_iterations);
                assert_eq!(a.agreg_moved, b.agreg_moved);
            }
        }
    }

    #[test]
    fn batch_results_respect_agreg_postcondition() {
        let trees = corpus(8, 150);
        let cfg = BatchConfig { alpha: 0.85, p: 6.0, threads: 3, agreg: true };
        for r in schedule_batch(&trees, &cfg) {
            assert!(r.min_share >= 1.0 - 1e-6, "tree {}: {}", r.index, r.min_share);
            assert!(r.makespan.is_finite() && r.makespan > 0.0);
        }
    }

    #[test]
    fn batch_without_agreg_matches_direct_solve() {
        use crate::sched::pm::PmSolution;
        let trees = corpus(5, 80);
        let cfg = BatchConfig { alpha: 0.7, p: 16.0, threads: 2, agreg: false };
        let got = schedule_batch(&trees, &cfg);
        for (i, r) in got.iter().enumerate() {
            let g = SpGraph::from_tree(&trees[i]);
            let want = PmSolution::solve(&g, 0.7).makespan_const(16.0);
            assert_eq!(r.makespan.to_bits(), want.to_bits());
            assert_eq!(r.agreg_iterations, 0);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let out = schedule_batch(&[], &BatchConfig::default());
        assert!(out.is_empty());
    }

    #[test]
    fn single_node_trees_reduce_to_the_closed_form() {
        // a lone task on p processors finishes in L/p^α and holds the
        // whole machine
        let trees: Vec<TaskTree> = [3.0, 1.0, 0.5].iter().map(|&l| TaskTree::singleton(l)).collect();
        let cfg = BatchConfig { alpha: 0.9, p: 8.0, threads: 2, agreg: true };
        for (i, r) in schedule_batch(&trees, &cfg).iter().enumerate() {
            let want = trees[i].nodes[0].len / 8f64.powf(0.9);
            assert_eq!(r.tasks, 1);
            assert!((r.makespan - want).abs() <= 1e-12 * want.max(1.0), "tree {i}");
            assert!((r.min_share - 8.0).abs() < 1e-9, "lone task takes all of p");
        }
    }

    #[test]
    fn zero_work_tasks_mixed_into_a_tree_do_not_break_the_pipeline() {
        // chains/branches of zero-length tasks exercise the agreg and
        // PM zero-denominator guards
        let mut trees = corpus(3, 60);
        for t in trees.iter_mut() {
            for (i, node) in t.nodes.iter_mut().enumerate() {
                if i % 3 == 0 {
                    node.len = 0.0;
                }
            }
        }
        let cfg = BatchConfig { alpha: 0.9, p: 8.0, threads: 2, agreg: true };
        let out = schedule_batch(&trees, &cfg);
        assert_eq!(out.len(), trees.len());
        for r in &out {
            assert!(r.makespan.is_finite() && r.makespan > 0.0, "tree {}", r.index);
        }
    }

    #[test]
    fn all_zero_work_trees_schedule_to_zero_makespan() {
        // an entirely empty job (every task length 0): the solve must
        // terminate and report a zero makespan rather than NaN. The
        // raw pseudo-tree path covers the degenerate L_G = 0 solve.
        let mut t = corpus(1, 40).pop().unwrap();
        for node in t.nodes.iter_mut() {
            node.len = 0.0;
        }
        let trees = [t, TaskTree::singleton(0.0)];
        let cfg = BatchConfig { alpha: 0.9, p: 4.0, threads: 1, agreg: false };
        for r in schedule_batch(&trees, &cfg) {
            assert_eq!(r.makespan, 0.0, "tree {}", r.index);
            assert!(!r.makespan.is_nan());
        }
    }
}
