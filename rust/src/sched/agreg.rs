//! The `Agreg` transformation (paper §7, Figure 15).
//!
//! The `p^α` model is super-linear for `p < 1`, which is unrealistic.
//! The paper therefore rewrites each tree so that the PM schedule never
//! allocates less than one processor: whenever a parallel branch
//! (subtree of a node `u`) would receive a share `< 1`, the branch is
//! *moved out* of the parallel composition and executed in series right
//! before `u`, on `u`'s whole share. The routine is iterated until a
//! fixpoint (the rewritten branches get bigger shares, which may expose
//! new violations deeper down). The result is a series-parallel graph
//! (the input tree's pseudo-tree rewritten), which is why the whole
//! scheduling stack operates on [`SpGraph`].

use crate::model::{SpGraph, SpNode};

use super::pm::PmSolution;

/// Statistics from an [`agreg`] run.
#[derive(Debug, Clone, Copy, Default)]
pub struct AgregStats {
    /// Rewriting iterations until fixpoint.
    pub iterations: usize,
    /// Parallel branches serialized in total.
    pub moved: usize,
    /// Whether a fixpoint was reached within the iteration cap.
    pub converged: bool,
}

/// Share threshold: a branch allocated less than this many processors
/// is serialized. The paper uses exactly one processor.
const ONE_PROC: f64 = 1.0 - 1e-9;

/// Apply the §7 aggregation to `g` for exponent `alpha` on `p`
/// processors. Returns the rewritten graph and statistics.
///
/// Postcondition (checked by tests): the PM schedule of the result
/// allocates ≥ 1 processor to every task with positive length, provided
/// `p >= 1`.
pub fn agreg(g: &SpGraph, alpha: f64, p: f64) -> (SpGraph, AgregStats) {
    let mut cur = g.normalized();
    let mut stats = AgregStats::default();
    // Each iteration strictly serializes at least one branch, and a
    // graph with no parallel branches cannot violate; the number of
    // parallel branches is < #nodes, so #iterations is bounded. The cap
    // is a belt-and-braces guard.
    let cap = cur.nodes.len().max(64);
    for _ in 0..cap {
        stats.iterations += 1;
        let sol = PmSolution::solve(&cur, alpha);
        let mut moved_this_round = 0usize;
        // §Perf: clone the arena lazily — the common case (last
        // iteration / well-shaped tree) detects zero violations and
        // must not pay an O(n) copy.
        let mut nodes: Option<Vec<SpNode>> = None;
        for (vi, node) in cur.nodes.iter().enumerate() {
            let SpNode::Parallel(children) = node else {
                continue;
            };
            let (keep, movev): (Vec<u32>, Vec<u32>) = children
                .iter()
                .partition(|&&c| sol.ratio[c as usize] * p >= ONE_PROC);
            if movev.is_empty() {
                continue;
            }
            moved_this_round += movev.len();
            let nodes = nodes.get_or_insert_with(|| cur.nodes.clone());
            // Rewrite: Parallel(keep) followed in series by the moved
            // branches (each on the full contextual share).
            let mut seq: Vec<u32> = Vec::with_capacity(1 + movev.len());
            match keep.len() {
                0 => {}
                1 => seq.push(keep[0]),
                _ => {
                    nodes.push(SpNode::Parallel(keep));
                    seq.push((nodes.len() - 1) as u32);
                }
            }
            seq.extend(movev);
            nodes[vi] = SpNode::Series(seq);
        }
        if moved_this_round == 0 {
            stats.converged = true;
            break;
        }
        stats.moved += moved_this_round;
        cur = SpGraph { nodes: nodes.unwrap(), root: cur.root }.normalized();
    }
    (cur, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TaskTree;
    use crate::sched::pm::PmSolution;
    use crate::util::approx_le;

    /// After agreg, every positive-length task gets >= 1 processor.
    fn assert_min_share(g: &SpGraph, alpha: f64, p: f64) {
        let sol = PmSolution::solve(g, alpha);
        let min = sol.min_task_share(g, p);
        assert!(
            min >= 1.0 - 1e-6,
            "task with share {min} survived agreg (alpha={alpha}, p={p})"
        );
    }

    #[test]
    fn no_op_when_everything_fits() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[4.0, 4.0, 4.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 16.0);
        assert!(stats.converged);
        assert_eq!(stats.moved, 0);
        assert_eq!(out.num_tasks(), 3);
    }

    #[test]
    fn serializes_tiny_branch() {
        // p = 2, branches with very unequal lengths: the tiny one gets
        // a sub-processor share and must be serialized.
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 1e-6, 10.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let alpha = 0.5;
        let p = 2.0;
        let before = PmSolution::solve(&g, alpha);
        assert!(before.min_task_share(&g, p) < 1.0);
        let (out, stats) = agreg(&g, alpha, p);
        assert!(stats.converged);
        assert!(stats.moved >= 1);
        assert_min_share(&out, alpha, p);
        // no task lost
        assert_eq!(out.num_tasks(), 3);
    }

    #[test]
    fn fixpoint_on_wide_flat_tree() {
        // 64 equal leaves on p=4: each would get 1/16 processor; after
        // aggregation everything must be >= 1.
        let n = 65;
        let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { 0 }).collect();
        let t = TaskTree::from_parents(&parents, &vec![1.0; n]).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 4.0);
        assert!(stats.converged);
        assert_min_share(&out, 0.9, 4.0);
        assert_eq!(out.num_tasks(), n);
    }

    #[test]
    fn preserves_total_work() {
        let t = TaskTree::from_parents(
            &[0, 0, 0, 1, 1, 2, 2, 3, 3],
            &[1.0, 0.2, 3.0, 0.1, 5.0, 0.01, 2.0, 0.5, 0.3],
        )
        .unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, _) = agreg(&g, 0.7, 3.0);
        assert!((out.total_work() - g.total_work()).abs() < 1e-9);
        assert_eq!(out.num_tasks(), 9);
        out.validate().unwrap();
    }

    #[test]
    fn makespan_never_improves() {
        // Serializing branches cannot beat the unconstrained optimum.
        let t = TaskTree::from_parents(
            &[0, 0, 0, 1, 1, 2, 2],
            &[1.0, 0.3, 2.0, 0.05, 4.0, 0.2, 1.5],
        )
        .unwrap();
        let g = SpGraph::from_tree(&t);
        let alpha = 0.8;
        let p = 2.0;
        let before = PmSolution::solve(&g, alpha).makespan_const(p);
        let (out, _) = agreg(&g, alpha, p);
        let after = PmSolution::solve(&out, alpha).makespan_const(p);
        assert!(approx_le(before, after, 1e-9), "before={before} after={after}");
    }

    #[test]
    fn deep_tree_converges() {
        // 10k-node binaryish tree with log-spread lengths, small p
        let n = 10_000;
        let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { (i - 1) / 2 }).collect();
        let lens: Vec<f64> = (0..n)
            .map(|i| 10f64.powf((i % 5) as f64 - 2.0))
            .collect();
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 8.0);
        assert!(stats.converged, "iterations={}", stats.iterations);
        assert_min_share(&out, 0.9, 8.0);
        assert_eq!(out.num_tasks(), n);
    }
}
