//! The `Agreg` transformation (paper §7, Figure 15).
//!
//! The `p^α` model is super-linear for `p < 1`, which is unrealistic.
//! The paper therefore rewrites each tree so that the PM schedule never
//! allocates less than one processor: whenever a parallel branch
//! (subtree of a node `u`) would receive a share `< 1`, the branch is
//! *moved out* of the parallel composition and executed in series right
//! before `u`, on `u`'s whole share. The routine is iterated until a
//! fixpoint (the rewritten branches get bigger shares, which may expose
//! new violations deeper down). The result is a series-parallel graph
//! (the input tree's pseudo-tree rewritten), which is why the whole
//! scheduling stack operates on [`SpGraph`].
//!
//! ## Incremental engine (§Perf)
//!
//! The reference implementation ([`agreg_full_resolve`]) re-solves the
//! whole graph between rounds: O(n) per iteration, O(n·iterations)
//! total — iterations grow with tree depth, so 100k-task trees paid
//! tens of full solves. [`agreg`] keeps the *same round semantics*
//! (every violation test uses the allocation of the round-start
//! solution, so it reaches the identical fixpoint graph — up to
//! measure-zero ULP ties against the serialization threshold, since
//! later rounds accumulate aggregates with different float groupings)
//! but maintains
//! the equivalent lengths `L`, power-lengths `L^{1/α}` and a per-node
//! lower bound `m(v)` on the minimum relative ratio inside the subtree
//! incrementally:
//!
//! * a round is a descent from the root that only enters branches
//!   whose `ratio · p · m < 1` — regions with no possible violation
//!   are never visited;
//! * after serializing a branch, only the path to the root is updated
//!   (series sums and parallel power-sums by delta, `m` by min-in with
//!   a rescale when a parallel denominator grows), O(depth) per move.
//!
//! Total cost O(n + moved·depth + Σ visited) instead of
//! O(n·iterations); `sched_perf` tracks the speedup (≥ 3× on the
//! 100k-task stress case is the EXPERIMENTS.md §Perf bar).

use crate::model::{SpGraph, SpNode};

use super::pm::PmSolution;

/// Statistics from an [`agreg`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AgregStats {
    /// Rewriting iterations until fixpoint.
    pub iterations: usize,
    /// Parallel branches serialized in total.
    pub moved: usize,
    /// Whether a fixpoint was reached within the iteration cap.
    pub converged: bool,
}

/// Share threshold: a branch allocated less than this many processors
/// is serialized. The paper uses exactly one processor.
const ONE_PROC: f64 = 1.0 - 1e-9;

/// Apply the §7 aggregation to `g` for exponent `alpha` on `p`
/// processors, with the incremental engine. Returns the rewritten
/// graph and statistics.
///
/// Postcondition (checked by tests): the PM schedule of the result
/// allocates ≥ 1 processor to every task with positive length, provided
/// `p >= 1`. The result is the same graph [`agreg_full_resolve`]
/// produces (property-tested), at a fraction of the cost.
pub fn agreg(g: &SpGraph, alpha: f64, p: f64) -> (SpGraph, AgregStats) {
    let mut scratch = AgregScratch::default();
    scratch.run(g, alpha, p)
}

/// Reference implementation: full `PmSolution` re-solve between
/// rounds. Kept as the oracle the incremental engine is tested
/// against, and as the baseline `sched_perf` measures speedups over.
pub fn agreg_full_resolve(g: &SpGraph, alpha: f64, p: f64) -> (SpGraph, AgregStats) {
    let mut cur = g.normalized();
    let mut stats = AgregStats::default();
    // Each iteration strictly serializes at least one branch, and a
    // graph with no parallel branches cannot violate; the number of
    // parallel branches is < #nodes, so #iterations is bounded. The cap
    // is a belt-and-braces guard.
    let cap = cur.nodes.len().max(64);
    for _ in 0..cap {
        stats.iterations += 1;
        let sol = PmSolution::solve(&cur, alpha);
        let mut moved_this_round = 0usize;
        // §Perf: clone the arena lazily — the common case (last
        // iteration / well-shaped tree) detects zero violations and
        // must not pay an O(n) copy.
        let mut nodes: Option<Vec<SpNode>> = None;
        for (vi, node) in cur.nodes.iter().enumerate() {
            let SpNode::Parallel(children) = node else {
                continue;
            };
            let (keep, movev): (Vec<u32>, Vec<u32>) = children
                .iter()
                .partition(|&&c| sol.ratio[c as usize] * p >= ONE_PROC);
            if movev.is_empty() {
                continue;
            }
            moved_this_round += movev.len();
            let nodes = nodes.get_or_insert_with(|| cur.nodes.clone());
            // Rewrite: Parallel(keep) followed in series by the moved
            // branches (each on the full contextual share).
            let mut seq: Vec<u32> = Vec::with_capacity(1 + movev.len());
            match keep.len() {
                0 => {}
                1 => seq.push(keep[0]),
                _ => {
                    nodes.push(SpNode::Parallel(keep));
                    seq.push((nodes.len() - 1) as u32);
                }
            }
            seq.extend(movev);
            nodes[vi] = SpNode::Series(seq);
        }
        if moved_this_round == 0 {
            stats.converged = true;
            break;
        }
        stats.moved += moved_this_round;
        cur = SpGraph::new(nodes.unwrap(), cur.root).normalized();
    }
    (cur, stats)
}

/// DFS frame of the guided violation descent.
#[derive(Debug, Clone, Copy)]
struct Frame {
    v: u32,
    /// Next child index to examine.
    i: u32,
    /// Contextual processor ratio of `v` in the round-start solution.
    r: f64,
}

/// One pending rewrite, collected postorder so deeper rewrites are
/// applied (and their aggregate updates propagated) before shallower
/// ones in the same round.
#[derive(Debug)]
struct Rewrite {
    v: u32,
    keep: Vec<u32>,
    mov: Vec<u32>,
}

/// Reusable state of the incremental `Agreg` engine (held by
/// [`super::SchedWorkspace`] so repeated aggregations are
/// allocation-free up to per-rewrite child lists).
#[derive(Debug, Default)]
pub(crate) struct AgregScratch {
    nodes: Vec<SpNode>,
    parent: Vec<u32>,
    /// Equivalent length `L(v)`.
    ltot: Vec<f64>,
    /// `L(v)^{1/α}`; for `Parallel` nodes this equals the ratio
    /// denominator `Σ_c pow(c)`.
    pow: Vec<f64>,
    /// Lower bound on `min_{leaf ℓ ∈ subtree(v)} ratio(ℓ)/ratio(v)`.
    /// Exact after the initial pass; kept conservative (never above
    /// the true minimum) across incremental updates, and refreshed for
    /// every subtree the descent visits.
    mrel: Vec<f64>,
    topo: Vec<u32>,
    frames: Vec<Frame>,
    rewrites: Vec<Rewrite>,
}

const NO_PARENT: u32 = u32::MAX;

impl AgregScratch {
    /// Run the incremental aggregation (see module docs).
    pub(crate) fn run(&mut self, g: &SpGraph, alpha: f64, p: f64) -> (SpGraph, AgregStats) {
        let inv = 1.0 / alpha;
        // The two `normalized()` calls (here and on exit) are the only
        // per-run O(n) allocations besides the arena copy; all solver
        // state below reuses the scratch buffers across runs.
        let cur = g.normalized();
        let root = cur.root;
        self.nodes.clear();
        self.nodes.extend(cur.nodes.iter().cloned());
        let n = self.nodes.len();
        self.parent.clear();
        self.parent.resize(n, NO_PARENT);
        self.ltot.clear();
        self.ltot.resize(n, 0.0);
        self.pow.clear();
        self.pow.resize(n, 0.0);
        self.mrel.clear();
        self.mrel.resize(n, 1.0);
        // Root-first order into the reusable buffer (a normalized graph
        // has every arena node reachable).
        self.topo.clear();
        self.topo.reserve(n);
        let mut stack: Vec<u32> = vec![root];
        while let Some(v) = stack.pop() {
            self.topo.push(v);
            if let SpNode::Series(c) | SpNode::Parallel(c) = &self.nodes[v as usize] {
                stack.extend(c.iter().copied());
                for &x in c {
                    self.parent[x as usize] = v;
                }
            }
        }
        // Bottom-up aggregates (identical arithmetic to the PM solve,
        // so round-1 decisions are bit-for-bit the full-resolve ones).
        for i in (0..self.topo.len()).rev() {
            let v = self.topo[i];
            self.recompute_node(v as usize, alpha, inv);
        }

        let mut stats = AgregStats::default();
        let cap = n.max(64);
        for _ in 0..cap {
            stats.iterations += 1;
            self.collect_violations(root, p);
            if self.rewrites.is_empty() {
                stats.converged = true;
                break;
            }
            // Take the list so `self` stays borrowable inside the loop.
            let rewrites = std::mem::take(&mut self.rewrites);
            for rw in &rewrites {
                stats.moved += rw.mov.len();
                self.apply_rewrite(rw, alpha, inv);
            }
            self.rewrites = rewrites;
            self.rewrites.clear();
        }
        let out = SpGraph::new(std::mem::take(&mut self.nodes), root).normalized();
        (out, stats)
    }

    /// Exact aggregates of one node from its children's stored values.
    fn recompute_node(&mut self, vi: usize, alpha: f64, inv: f64) {
        match &self.nodes[vi] {
            SpNode::Leaf { len, .. } => {
                self.ltot[vi] = *len;
                self.pow[vi] = len.powf(inv);
                self.mrel[vi] = 1.0;
            }
            SpNode::Series(c) => {
                let sum: f64 = c.iter().map(|&x| self.ltot[x as usize]).sum();
                let m = c
                    .iter()
                    .map(|&x| self.mrel[x as usize])
                    .fold(f64::INFINITY, f64::min);
                self.ltot[vi] = sum;
                self.pow[vi] = sum.powf(inv);
                self.mrel[vi] = m;
            }
            SpNode::Parallel(c) => {
                let denom: f64 = c.iter().map(|&x| self.pow[x as usize]).sum();
                let k = c.len() as f64;
                let m = c
                    .iter()
                    .map(|&x| {
                        let f = if denom > 0.0 {
                            self.pow[x as usize] / denom
                        } else {
                            1.0 / k
                        };
                        f * self.mrel[x as usize]
                    })
                    .fold(f64::INFINITY, f64::min);
                self.pow[vi] = denom;
                self.ltot[vi] = denom.powf(alpha);
                self.mrel[vi] = m;
            }
        }
    }

    /// Contextual ratio of child `c` of composite `vi` whose own ratio
    /// is `r` (mirrors the PM top-down pass exactly).
    fn child_ratio(&self, vi: usize, r: f64, c: u32) -> f64 {
        match &self.nodes[vi] {
            SpNode::Series(_) => r,
            SpNode::Parallel(ch) => {
                let denom = self.pow[vi];
                if denom > 0.0 {
                    r * self.pow[c as usize] / denom
                } else {
                    r / ch.len() as f64
                }
            }
            SpNode::Leaf { .. } => unreachable!("leaves have no children"),
        }
    }

    /// Guided descent from the root: visits only subtrees that may
    /// contain a violation (`ratio·p·mrel < 1`), refreshes `mrel` for
    /// everything visited, and records the round's rewrites postorder.
    /// All ratio tests use the frozen round-start aggregates — the
    /// updates happen afterwards in [`AgregScratch::apply_rewrite`] —
    /// so the round semantics equal the full re-solve reference.
    fn collect_violations(&mut self, root: u32, p: f64) {
        self.frames.clear();
        self.rewrites.clear();
        if matches!(self.nodes[root as usize], SpNode::Leaf { .. }) {
            return;
        }
        self.frames.push(Frame { v: root, i: 0, r: 1.0 });
        while let Some(&Frame { v, i, r }) = self.frames.last() {
            let vi = v as usize;
            let nchildren = match &self.nodes[vi] {
                SpNode::Series(c) | SpNode::Parallel(c) => c.len(),
                SpNode::Leaf { .. } => unreachable!(),
            };
            if (i as usize) < nchildren {
                self.frames.last_mut().unwrap().i += 1;
                let c = match &self.nodes[vi] {
                    SpNode::Series(ch) | SpNode::Parallel(ch) => ch[i as usize],
                    SpNode::Leaf { .. } => unreachable!(),
                };
                let ci = c as usize;
                if matches!(self.nodes[ci], SpNode::Leaf { .. }) {
                    continue; // leaf violations are handled by the parent's exit scan
                }
                let rc = self.child_ratio(vi, r, c);
                if rc * p * self.mrel[ci] < ONE_PROC {
                    self.frames.push(Frame { v: c, i: 0, r: rc });
                }
            } else {
                // exit: refresh mrel from (partly refreshed) children
                // and, for parallel nodes, partition by the snapshot
                // ratios
                if let SpNode::Parallel(ch) = &self.nodes[vi] {
                    let denom = self.pow[vi];
                    let k = ch.len() as f64;
                    let rc_of = |pw: f64| if denom > 0.0 { r * pw / denom } else { r / k };
                    // common case: nothing violates — detect without
                    // allocating the partition vectors
                    let any = ch
                        .iter()
                        .any(|&c| rc_of(self.pow[c as usize]) * p < ONE_PROC);
                    if any {
                        let (keep, mov): (Vec<u32>, Vec<u32>) = ch
                            .iter()
                            .partition(|&&c| rc_of(self.pow[c as usize]) * p >= ONE_PROC);
                        self.rewrites.push(Rewrite { v, keep, mov });
                    }
                }
                // exact local refresh tightens any stale lower bound
                self.refresh_mrel(vi);
                self.frames.pop();
            }
        }
    }

    /// Recompute `mrel[vi]` from children (exact w.r.t. stored child
    /// bounds; preserves the conservative invariant).
    fn refresh_mrel(&mut self, vi: usize) {
        let m = match &self.nodes[vi] {
            SpNode::Leaf { .. } => 1.0,
            SpNode::Series(c) => c
                .iter()
                .map(|&x| self.mrel[x as usize])
                .fold(f64::INFINITY, f64::min),
            SpNode::Parallel(c) => {
                let denom = self.pow[vi];
                let k = c.len() as f64;
                c.iter()
                    .map(|&x| {
                        let f = if denom > 0.0 {
                            self.pow[x as usize] / denom
                        } else {
                            1.0 / k
                        };
                        f * self.mrel[x as usize]
                    })
                    .fold(f64::INFINITY, f64::min)
            }
        };
        self.mrel[vi] = m;
    }

    /// Serialize the violating branches of one parallel node and update
    /// aggregates along the path to the root (O(children) local work +
    /// O(depth) path walk).
    fn apply_rewrite(&mut self, rw: &Rewrite, alpha: f64, inv: f64) {
        let vi = rw.v as usize;
        debug_assert!(matches!(self.nodes[vi], SpNode::Parallel(_)));
        let old_l = self.ltot[vi];
        let old_pow = self.pow[vi];

        let mut seq: Vec<u32> = Vec::with_capacity(1 + rw.mov.len());
        match rw.keep.len() {
            0 => {}
            1 => seq.push(rw.keep[0]),
            _ => {
                // new inner parallel over the kept branches
                let np = self.nodes.len() as u32;
                self.nodes.push(SpNode::Parallel(rw.keep.clone()));
                self.parent.push(rw.v);
                self.ltot.push(0.0);
                self.pow.push(0.0);
                self.mrel.push(1.0);
                for &c in &rw.keep {
                    self.parent[c as usize] = np;
                }
                self.recompute_node(np as usize, alpha, inv);
                seq.push(np);
            }
        }
        seq.extend(rw.mov.iter().copied());
        self.nodes[vi] = SpNode::Series(seq);
        // moved children keep `v` as parent; a single kept child does too
        self.recompute_node(vi, alpha, inv);

        // Walk the dirty path to the root with delta updates.
        let mut child_l_old = old_l;
        let mut child_l_new = self.ltot[vi];
        let mut child_pow_old = old_pow;
        let mut child_pow_new = self.pow[vi];
        let mut child_m = self.mrel[vi];
        let mut a = self.parent[vi];
        while a != NO_PARENT {
            let ai = a as usize;
            let a_l_old = self.ltot[ai];
            let a_pow_old = self.pow[ai];
            let a_m_contrib;
            match &self.nodes[ai] {
                SpNode::Series(_) => {
                    self.ltot[ai] = self.ltot[ai] - child_l_old + child_l_new;
                    self.pow[ai] = self.ltot[ai].powf(inv);
                    a_m_contrib = child_m;
                }
                SpNode::Parallel(ch) => {
                    let denom_old = self.pow[ai];
                    let denom_new = denom_old - child_pow_old + child_pow_new;
                    self.pow[ai] = denom_new;
                    self.ltot[ai] = denom_new.powf(alpha);
                    // other children's relative contributions scale by
                    // denom_old/denom_new when the denominator grows —
                    // rescale the stored bound so it stays conservative
                    if denom_new > denom_old && denom_new > 0.0 {
                        self.mrel[ai] *= denom_old / denom_new;
                    }
                    a_m_contrib = if denom_new > 0.0 {
                        child_pow_new / denom_new * child_m
                    } else {
                        child_m / ch.len() as f64
                    };
                }
                SpNode::Leaf { .. } => unreachable!("leaf cannot be a parent"),
            }
            self.mrel[ai] = self.mrel[ai].min(a_m_contrib);
            child_l_old = a_l_old;
            child_l_new = self.ltot[ai];
            child_pow_old = a_pow_old;
            child_pow_new = self.pow[ai];
            child_m = self.mrel[ai];
            a = self.parent[ai];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TaskTree;
    use crate::sched::pm::PmSolution;
    use crate::util::approx_le;

    /// After agreg, every positive-length task gets >= 1 processor.
    fn assert_min_share(g: &SpGraph, alpha: f64, p: f64) {
        let sol = PmSolution::solve(g, alpha);
        let min = sol.min_task_share(g, p);
        assert!(
            min >= 1.0 - 1e-6,
            "task with share {min} survived agreg (alpha={alpha}, p={p})"
        );
    }

    /// Incremental and full-resolve engines must agree exactly: same
    /// canonical arena (normalization is deterministic in structure),
    /// same statistics.
    fn assert_engines_agree(t: &TaskTree, alpha: f64, p: f64) {
        let g = SpGraph::from_tree(t);
        let (inc, si) = agreg(&g, alpha, p);
        let (full, sf) = agreg_full_resolve(&g, alpha, p);
        assert_eq!(si, sf, "stats diverge (alpha={alpha}, p={p})");
        let (inc, full) = (inc.normalized(), full.normalized());
        assert_eq!(inc.root, full.root, "roots diverge");
        assert_eq!(inc.nodes, full.nodes, "graphs diverge (alpha={alpha}, p={p})");
    }

    #[test]
    fn no_op_when_everything_fits() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[4.0, 4.0, 4.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 16.0);
        assert!(stats.converged);
        assert_eq!(stats.moved, 0);
        assert_eq!(out.num_tasks(), 3);
        assert_engines_agree(&t, 0.9, 16.0);
    }

    #[test]
    fn serializes_tiny_branch() {
        // p = 2, branches with very unequal lengths: the tiny one gets
        // a sub-processor share and must be serialized.
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 1e-6, 10.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let alpha = 0.5;
        let p = 2.0;
        let before = PmSolution::solve(&g, alpha);
        assert!(before.min_task_share(&g, p) < 1.0);
        let (out, stats) = agreg(&g, alpha, p);
        assert!(stats.converged);
        assert!(stats.moved >= 1);
        assert_min_share(&out, alpha, p);
        // no task lost
        assert_eq!(out.num_tasks(), 3);
        assert_engines_agree(&t, alpha, p);
    }

    #[test]
    fn fixpoint_on_wide_flat_tree() {
        // 64 equal leaves on p=4: each would get 1/16 processor; after
        // aggregation everything must be >= 1.
        let n = 65;
        let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { 0 }).collect();
        let t = TaskTree::from_parents(&parents, &vec![1.0; n]).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 4.0);
        assert!(stats.converged);
        assert_min_share(&out, 0.9, 4.0);
        assert_eq!(out.num_tasks(), n);
        assert_engines_agree(&t, 0.9, 4.0);
    }

    #[test]
    fn preserves_total_work() {
        let t = TaskTree::from_parents(
            &[0, 0, 0, 1, 1, 2, 2, 3, 3],
            &[1.0, 0.2, 3.0, 0.1, 5.0, 0.01, 2.0, 0.5, 0.3],
        )
        .unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, _) = agreg(&g, 0.7, 3.0);
        assert!((out.total_work() - g.total_work()).abs() < 1e-9);
        assert_eq!(out.num_tasks(), 9);
        out.validate().unwrap();
        assert_engines_agree(&t, 0.7, 3.0);
    }

    #[test]
    fn makespan_never_improves() {
        // Serializing branches cannot beat the unconstrained optimum.
        let t = TaskTree::from_parents(
            &[0, 0, 0, 1, 1, 2, 2],
            &[1.0, 0.3, 2.0, 0.05, 4.0, 0.2, 1.5],
        )
        .unwrap();
        let g = SpGraph::from_tree(&t);
        let alpha = 0.8;
        let p = 2.0;
        let before = PmSolution::solve(&g, alpha).makespan_const(p);
        let (out, _) = agreg(&g, alpha, p);
        let after = PmSolution::solve(&out, alpha).makespan_const(p);
        assert!(approx_le(before, after, 1e-9), "before={before} after={after}");
    }

    #[test]
    fn deep_tree_converges() {
        // 10k-node binaryish tree with log-spread lengths, small p
        let n = 10_000;
        let parents: Vec<usize> = (0..n).map(|i| if i == 0 { 0 } else { (i - 1) / 2 }).collect();
        let lens: Vec<f64> = (0..n)
            .map(|i| 10f64.powf((i % 5) as f64 - 2.0))
            .collect();
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let g = SpGraph::from_tree(&t);
        let (out, stats) = agreg(&g, 0.9, 8.0);
        assert!(stats.converged, "iterations={}", stats.iterations);
        assert_min_share(&out, 0.9, 8.0);
        assert_eq!(out.num_tasks(), n);
    }

    #[test]
    fn zero_length_tasks_get_serialized_consistently() {
        // zero-length leaves inside parallels always violate; both
        // engines must serialize them the same way and converge
        let t = TaskTree::from_parents(&[0, 0, 0, 0, 1, 1], &[1.0, 2.0, 0.0, 3.0, 0.0, 4.0])
            .unwrap();
        for p in [1.0, 2.0, 8.0] {
            assert_engines_agree(&t, 0.9, p);
        }
    }

    #[test]
    fn scratch_reuse_across_runs_is_clean() {
        let mut scratch = AgregScratch::default();
        let trees = [
            TaskTree::from_parents(&[0, 0, 0], &[1.0, 1e-6, 10.0]).unwrap(),
            TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap(),
            TaskTree::from_parents(&[0, 0], &[1.0, 2.0]).unwrap(),
        ];
        for t in &trees {
            for p in [1.5, 4.0] {
                let g = SpGraph::from_tree(t);
                let (a, sa) = scratch.run(&g, 0.8, p);
                let (b, sb) = agreg_full_resolve(&g, 0.8, p);
                assert_eq!(sa, sb);
                assert_eq!(a.normalized().nodes, b.normalized().nodes);
            }
        }
    }
}
