//! The Prasanna–Musicus optimal schedule (paper §5, Theorem 6).
//!
//! Any SP graph `G` is equivalent to a single task of length `L_G`
//! (Definition 1):
//!
//! ```text
//! L_{T_i}     = L_i
//! L_{G1; G2}  = L_{G1} + L_{G2}
//! L_{G1||G2}  = (L_{G1}^{1/α} + L_{G2}^{1/α})^α
//! ```
//!
//! and in the (unique) optimal schedule each branch of a parallel
//! composition receives a **constant ratio** of the processors,
//! proportional to `L^{1/α}` (Lemma 4). This module computes equivalent
//! lengths, per-task ratios, completion times and materialized
//! schedules, all iteratively (trees are up to 10⁶ nodes / 10⁵ deep).
//!
//! Everything is expressed in "speedup time" `θ(t) = ∫ p(x)^α dx`
//! (Lemma 5): a subgraph with ratio `r` and equivalent length `L`
//! occupies a θ-interval of measure `L / r^α`, regardless of the step
//! profile. Wall-clock times are recovered through `θ⁻¹`.

use crate::model::{SpGraph, SpNode, TaskTree};

use super::profile::Profile;
use super::schedule::{Schedule, TaskSpan};

/// Full PM solution over an SP graph, stored as SoA arrays indexed by
/// SP node id so a [`super::SchedWorkspace`] can reuse the buffers
/// across solves.
#[derive(Debug, Clone)]
pub struct PmSolution {
    /// Equivalent length per SP node (paper Definition 1).
    pub equiv_len: Vec<f64>,
    /// `L^{1/α}` per SP node (the power-length the parallel split
    /// ratios are proportional to; cached to avoid re-`powf`).
    pub equiv_pow: Vec<f64>,
    /// Constant processor ratio per SP node (root = 1).
    pub ratio: Vec<f64>,
    /// θ-interval `[theta_start, theta_end)` per SP node.
    pub theta_start: Vec<f64>,
    pub theta_end: Vec<f64>,
    /// Equivalent length of the whole graph (`L_G`).
    pub total_len: f64,
    alpha: f64,
}

/// Scatter per-SP-node leaf ratios back to task ids
/// (`out[task] = ratio[leaf node]`; non-leaf entries of `out` are left
/// untouched). The one copy of the task-id mapping shared by the DES
/// policy paths and [`super::SchedWorkspace::pm_task_ratios`].
pub(crate) fn scatter_leaf_ratios(g: &SpGraph, ratio: &[f64], out: &mut [f64]) {
    for &v in g.topo() {
        if let SpNode::Leaf { task: Some(t), .. } = g.nodes[v as usize] {
            out[t as usize] = ratio[v as usize];
        }
    }
}

/// Solve into `sol`'s existing buffers (clear + resize in place): the
/// allocation-free core both [`PmSolution::solve`] and
/// [`super::SchedWorkspace::solve`] drive. Traversals use the graph's
/// cached topo order — no per-call `Vec` materialization.
pub(crate) fn solve_into(g: &SpGraph, alpha: f64, sol: &mut PmSolution) {
    assert!(alpha > 0.0 && alpha <= 1.0, "alpha in (0, 1]");
    let n = g.nodes.len();
    let inv = 1.0 / alpha;
    sol.alpha = alpha;
    reset(&mut sol.equiv_len, n);
    reset(&mut sol.equiv_pow, n);
    reset(&mut sol.ratio, n);
    reset(&mut sol.theta_start, n);
    reset(&mut sol.theta_end, n);
    let topo = g.topo();

    // Bottom-up: equivalent lengths (children-first = reverse topo).
    for &v in topo.iter().rev() {
        let vi = v as usize;
        match &g.nodes[vi] {
            SpNode::Leaf { len, .. } => {
                sol.equiv_len[vi] = *len;
                sol.equiv_pow[vi] = len.powf(inv);
            }
            SpNode::Series(c) => {
                let sum: f64 = c.iter().map(|&x| sol.equiv_len[x as usize]).sum();
                sol.equiv_len[vi] = sum;
                sol.equiv_pow[vi] = sum.powf(inv);
            }
            SpNode::Parallel(c) => {
                let sum: f64 = c.iter().map(|&x| sol.equiv_pow[x as usize]).sum();
                sol.equiv_pow[vi] = sum;
                sol.equiv_len[vi] = sum.powf(alpha);
            }
        }
    }
    sol.total_len = sol.equiv_len[g.root as usize];

    // Top-down: ratios and θ-intervals.
    let ri = g.root as usize;
    sol.ratio[ri] = 1.0;
    sol.theta_start[ri] = 0.0;
    sol.theta_end[ri] = sol.total_len; // ratio 1 ⇒ θ-measure = L_G
    for &v in topo {
        let vi = v as usize;
        let (r, t0, t1) = (sol.ratio[vi], sol.theta_start[vi], sol.theta_end[vi]);
        match &g.nodes[vi] {
            SpNode::Leaf { .. } => {}
            SpNode::Series(c) => {
                // same ratio, consecutive θ-intervals, length-proportional
                let mut acc = t0;
                let scale = if sol.equiv_len[vi] > 0.0 {
                    (t1 - t0) / sol.equiv_len[vi]
                } else {
                    0.0
                };
                for &x in c {
                    let xi = x as usize;
                    sol.ratio[xi] = r;
                    sol.theta_start[xi] = acc;
                    acc += sol.equiv_len[xi] * scale;
                    sol.theta_end[xi] = acc;
                }
                // guard rounding: pin the last child to the parent end
                if let Some(&last) = c.last() {
                    sol.theta_end[last as usize] = t1;
                }
            }
            SpNode::Parallel(c) => {
                // same θ-interval, ratio ∝ L^{1/α} (Lemma 4); the
                // denominator is the parent's cached power-length
                let denom = sol.equiv_pow[vi];
                for &x in c {
                    let xi = x as usize;
                    sol.ratio[xi] = if denom > 0.0 {
                        r * sol.equiv_pow[xi] / denom
                    } else {
                        r / c.len() as f64
                    };
                    sol.theta_start[xi] = t0;
                    sol.theta_end[xi] = t1;
                }
            }
        }
    }
}

fn reset(buf: &mut Vec<f64>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// A PM schedule materialized against a concrete profile.
#[derive(Debug, Clone)]
pub struct PmSchedule {
    pub solution: PmSolution,
    pub schedule: Schedule,
}

impl PmSolution {
    /// An empty solution whose buffers a workspace can reuse across
    /// solves (`solve_into` resizes them in place).
    pub(crate) fn empty(alpha: f64) -> PmSolution {
        PmSolution {
            equiv_len: Vec::new(),
            equiv_pow: Vec::new(),
            ratio: Vec::new(),
            theta_start: Vec::new(),
            theta_end: Vec::new(),
            total_len: 0.0,
            alpha,
        }
    }

    /// The exponent this solution was solved for.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Solve the PM allocation for `g` with exponent `alpha`.
    ///
    /// Cost: two linear passes over the cached topo order; 2 `powf` per
    /// node (see §Perf notes in EXPERIMENTS.md for why lengths are
    /// carried in both `L` and `L^{1/α}` form). Allocates the five SoA
    /// arrays once; reuse a [`super::SchedWorkspace`] to amortize even
    /// that across repeated solves.
    pub fn solve(g: &SpGraph, alpha: f64) -> PmSolution {
        let mut sol = PmSolution::empty(alpha);
        solve_into(g, alpha, &mut sol);
        sol
    }

    /// Makespan under `profile` (Theorem 6: the graph behaves as one
    /// task of length `L_G`).
    pub fn makespan(&self, profile: &Profile) -> f64 {
        profile.theta_inv(self.alpha, self.total_len)
    }

    /// Makespan under a constant profile `p`: the closed form `L_G/p^α`.
    pub fn makespan_const(&self, p: f64) -> f64 {
        self.total_len / p.powf(self.alpha)
    }

    /// Per-*task* spans (tree task ids) under `profile`. Spans are in
    /// wall-clock time; each task keeps its constant ratio.
    pub fn task_spans(&self, g: &SpGraph, profile: &Profile) -> Vec<TaskSpan> {
        let mut spans = Vec::with_capacity(g.num_tasks());
        self.task_spans_into(g, profile, &mut spans);
        spans
    }

    /// [`PmSolution::task_spans`] into a caller-owned buffer (cleared
    /// first) — the workspace path; iterates the cached topo order, so
    /// repeated materializations are allocation-free once the buffer
    /// has grown to the task count.
    pub fn task_spans_into(&self, g: &SpGraph, profile: &Profile, spans: &mut Vec<TaskSpan>) {
        spans.clear();
        for &v in g.topo() {
            let vi = v as usize;
            if let SpNode::Leaf { task, .. } = g.nodes[vi] {
                spans.push(TaskSpan {
                    task: task.unwrap_or(vi as u32),
                    start: profile.theta_inv(self.alpha, self.theta_start[vi]),
                    finish: profile.theta_inv(self.alpha, self.theta_end[vi]),
                    ratio: self.ratio[vi],
                });
            }
        }
    }

    /// Minimum processor share any task receives under a constant
    /// profile `p` (the quantity `Agreg` pushes above one). Zero
    /// allocations: walks the cached topo order.
    pub fn min_task_share(&self, g: &SpGraph, p: f64) -> f64 {
        let mut min = f64::INFINITY;
        for &v in g.topo() {
            let vi = v as usize;
            if matches!(g.nodes[vi], SpNode::Leaf { len, .. } if len > 0.0) {
                min = min.min(self.ratio[vi] * p);
            }
        }
        min
    }
}

impl PmSchedule {
    /// Solve and materialize the PM schedule for a task tree.
    pub fn for_tree(tree: &TaskTree, alpha: f64, profile: &Profile) -> PmSchedule {
        let g = SpGraph::from_tree(tree);
        Self::for_graph(&g, alpha, profile)
    }

    /// Solve and materialize for an arbitrary SP graph.
    pub fn for_graph(g: &SpGraph, alpha: f64, profile: &Profile) -> PmSchedule {
        let solution = PmSolution::solve(g, alpha);
        let spans = solution.task_spans(g, profile);
        PmSchedule { solution, schedule: Schedule::new(spans) }
    }
}

/// Closed-form equivalent length of `n` independent tasks run in
/// parallel (used by the distributed algorithms of §6):
/// `(Σ L_i^{1/α})^α`.
pub fn parallel_equiv_len(lens: &[f64], alpha: f64) -> f64 {
    let inv = 1.0 / alpha;
    lens.iter().map(|l| l.powf(inv)).sum::<f64>().powf(alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::approx_eq;

    const A: f64 = 0.5;

    #[test]
    fn leaf_equiv_len_is_len() {
        let g = SpGraph::leaf(5.0);
        let s = PmSolution::solve(&g, A);
        assert_eq!(s.total_len, 5.0);
    }

    #[test]
    fn series_adds() {
        let g = SpGraph::series(SpGraph::leaf(2.0), SpGraph::leaf(3.0));
        let s = PmSolution::solve(&g, A);
        assert_eq!(s.total_len, 5.0);
    }

    #[test]
    fn parallel_combines_with_power_mean() {
        // α = 0.5: (L1² + L2²)^0.5 ; L1=1, L2=4 → √17
        let g = SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(4.0));
        let s = PmSolution::solve(&g, A);
        assert!(approx_eq(s.total_len, 17f64.sqrt(), 1e-12));
    }

    #[test]
    fn parallel_ratios_follow_lemma4() {
        // π1 = 1/(1 + (L2/L1)^{1/α}); L1=1, L2=4, α=0.5 → 1/(1+16)
        let a = 0.5;
        let g = SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(4.0));
        let s = PmSolution::solve(&g, a);
        // find the two leaves
        let mut ratios: Vec<(f64, f64)> = g
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                SpNode::Leaf { len, .. } => Some((*len, s.ratio[i])),
                _ => None,
            })
            .collect();
        ratios.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        assert!(approx_eq(ratios[0].1, 1.0 / 17.0, 1e-12));
        assert!(approx_eq(ratios[1].1, 16.0 / 17.0, 1e-12));
    }

    #[test]
    fn makespan_closed_form_constant_profile() {
        let g = SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(4.0));
        let s = PmSolution::solve(&g, A);
        let pr = Profile::constant(9.0);
        let want = 17f64.sqrt() / 3.0; // L_G / p^α
        assert!(approx_eq(s.makespan(&pr), want, 1e-12));
        assert!(approx_eq(s.makespan_const(9.0), want, 1e-12));
    }

    #[test]
    fn tree_schedule_is_valid_and_siblings_cofinish() {
        let tree =
            TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let pr = Profile::constant(10.0);
        let pm = PmSchedule::for_tree(&tree, 0.7, &pr);
        pm.schedule.validate(&tree, 0.7, &pr, 1e-9).unwrap();
        // siblings 3 and 4 finish together; siblings 1 and 2 (as
        // subtrees) finish together = start of root
        let span = |t: u32| {
            *pm.schedule
                .spans
                .iter()
                .find(|s| s.task == t)
                .unwrap()
        };
        assert!(approx_eq(span(3).finish, span(4).finish, 1e-9));
        assert!(approx_eq(span(1).finish, span(2).finish, 1e-9));
        assert!(approx_eq(span(0).start, span(1).finish, 1e-9));
        // makespan equals L_G / p^α
        assert!(approx_eq(
            pm.schedule.makespan,
            pm.solution.makespan(&pr),
            1e-9
        ));
        // optimal schedules saturate the platform (Lemma 2)
        assert!(approx_eq(pm.schedule.peak_utilization(), 1.0, 1e-9));
    }

    #[test]
    fn all_leaves_start_at_zero() {
        // pseudo-tree property: every leaf of the original tree starts at 0
        let tree =
            TaskTree::from_parents(&[0, 0, 0, 1, 1, 2], &[1.0; 6]).unwrap();
        let pr = Profile::constant(4.0);
        let pm = PmSchedule::for_tree(&tree, 0.9, &pr);
        for s in &pm.schedule.spans {
            let is_leaf = tree.nodes[s.task as usize].children.is_empty();
            if is_leaf {
                assert!(s.start.abs() < 1e-12, "leaf {} starts at {}", s.task, s.start);
            }
        }
    }

    #[test]
    fn step_profile_schedule_still_valid() {
        let tree =
            TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let pr = Profile::steps(&[(0.5, 2.0), (1.0, 6.0), (2.0, 3.0)]).unwrap();
        let a = 0.8;
        let pm = PmSchedule::for_tree(&tree, a, &pr);
        pm.schedule.validate(&tree, a, &pr, 1e-9).unwrap();
        // Theorem 6: makespan equals completion of the equivalent task
        assert!(approx_eq(
            pm.schedule.makespan,
            pr.completion(a, pm.solution.total_len),
            1e-9
        ));
    }

    #[test]
    fn alpha_one_reduces_to_proportional_work() {
        // α = 1: L_{1||2} = L1 + L2 (perfect parallelism)
        let g = SpGraph::parallel(SpGraph::leaf(2.0), SpGraph::leaf(3.0));
        let s = PmSolution::solve(&g, 1.0);
        assert!(approx_eq(s.total_len, 5.0, 1e-12));
    }

    #[test]
    fn equiv_length_is_associative_in_parallel() {
        // ((a || b) || c) == (a || (b || c)) by the power-sum form
        let abc1 = SpGraph::parallel(
            SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(2.0)),
            SpGraph::leaf(3.0),
        );
        let abc2 = SpGraph::parallel(
            SpGraph::leaf(1.0),
            SpGraph::parallel(SpGraph::leaf(2.0), SpGraph::leaf(3.0)),
        );
        let a = 0.77;
        assert!(approx_eq(
            PmSolution::solve(&abc1, a).total_len,
            PmSolution::solve(&abc2, a).total_len,
            1e-12
        ));
    }

    #[test]
    fn zero_length_tasks_are_harmless() {
        // roots of length 0 appear in Lemma 9 normalizations
        let tree = TaskTree::from_parents(&[0, 0, 0], &[0.0, 2.0, 2.0]).unwrap();
        let pr = Profile::constant(4.0);
        let pm = PmSchedule::for_tree(&tree, 0.5, &pr);
        assert!(pm.solution.total_len > 0.0);
        assert!(pm.schedule.makespan > 0.0);
    }

    #[test]
    fn parallel_equiv_len_matches_graph() {
        let lens = [1.0, 4.0, 9.0];
        let a = 0.5;
        let g = SpGraph::parallel(
            SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(4.0)),
            SpGraph::leaf(9.0),
        );
        assert!(approx_eq(
            parallel_equiv_len(&lens, a),
            PmSolution::solve(&g, a).total_len,
            1e-12
        ));
        // (1² + 4² + 9²)^0.5 = √98
        assert!(approx_eq(parallel_equiv_len(&lens, a), 98f64.sqrt(), 1e-12));
    }

    #[test]
    fn series_theta_end_rounding_guard_pins_last_child() {
        // A series nested under a parallel receives a sub-interval, so
        // its children's θ-ends are produced by `acc += len * scale`
        // with a non-trivial scale — real rounding territory (0.1 is
        // not representable). The guard must pin the last child's end
        // to the parent's end *exactly* (bitwise): a sibling that
        // starts at `theta_end[series]` must never observe a θ-gap.
        let mut chain = SpGraph::leaf(0.1);
        for _ in 0..20 {
            chain = SpGraph::series(chain, SpGraph::leaf(0.1));
        }
        let g = SpGraph::parallel(chain, SpGraph::leaf(1.0)).normalized();
        let s = PmSolution::solve(&g, 0.7);
        // locate the flattened series node
        let (si, kids) = g
            .nodes
            .iter()
            .enumerate()
            .find_map(|(i, n)| match n {
                SpNode::Series(c) => Some((i, c.clone())),
                _ => None,
            })
            .expect("series survives normalization");
        assert_eq!(kids.len(), 21);
        let last = *kids.last().unwrap() as usize;
        assert_eq!(
            s.theta_end[last].to_bits(),
            s.theta_end[si].to_bits(),
            "last child θ-end must be pinned to the parent θ-end"
        );
        // interior children chain consecutively (no gaps, no overlaps)
        for w in kids.windows(2) {
            assert_eq!(
                s.theta_end[w[0] as usize].to_bits(),
                s.theta_start[w[1] as usize].to_bits()
            );
        }
        // the pin only absorbs rounding noise, never real mass
        let naive = s.theta_start[si]
            + kids
                .iter()
                .map(|&k| s.equiv_len[k as usize])
                .sum::<f64>()
                * (s.theta_end[si] - s.theta_start[si])
                / s.equiv_len[si];
        assert!((naive - s.theta_end[si]).abs() <= 1e-9 * s.theta_end[si].abs());
    }

    #[test]
    fn solve_into_reuses_buffers_and_matches_fresh_solve() {
        let mut sol = PmSolution::empty(0.9);
        for (n, alpha) in [(50usize, 0.9), (200, 0.5), (10, 1.0), (120, 0.7)] {
            let parents: Vec<usize> =
                (0..n).map(|i| if i == 0 { 0 } else { (i - 1) / 3 }).collect();
            let lens: Vec<f64> = (0..n).map(|i| 0.5 + (i % 11) as f64).collect();
            let tree = TaskTree::from_parents(&parents, &lens).unwrap();
            let g = SpGraph::from_tree(&tree);
            super::solve_into(&g, alpha, &mut sol);
            let fresh = PmSolution::solve(&g, alpha);
            assert_eq!(sol.total_len.to_bits(), fresh.total_len.to_bits());
            assert_eq!(sol.ratio, fresh.ratio);
            assert_eq!(sol.theta_start, fresh.theta_start);
            assert_eq!(sol.theta_end, fresh.theta_end);
            assert_eq!(sol.equiv_len, fresh.equiv_len);
            assert_eq!(sol.equiv_pow, fresh.equiv_pow);
        }
    }

    #[test]
    fn huge_tree_linear_time_smoke() {
        // 200k-node random-ish tree solved without recursion/stack issues
        let n = 200_000usize;
        let parents: Vec<usize> = (0..n)
            .map(|i| if i == 0 { 0 } else { (i - 1) / 2 })
            .collect();
        let lens: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let tree = TaskTree::from_parents(&parents, &lens).unwrap();
        let g = SpGraph::from_tree(&tree);
        let s = PmSolution::solve(&g, 0.9);
        assert!(s.total_len.is_finite());
        assert!(s.total_len >= tree.critical_path());
        assert!(s.total_len <= tree.total_work() + 1e-6);
    }
}
