//! Step-function processor profiles `p(t)` (paper §4).
//!
//! The number of available processors may vary over time; the paper
//! restricts to step functions. The last step extends to infinity so
//! every workload completes.

use anyhow::{bail, Result};

/// One step: `p` processors for `dur` time units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Step {
    pub dur: f64,
    pub p: f64,
}

/// A step-function processor profile. The final step's processor count
/// persists forever (`dur` of the last step is a minimum).
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    steps: Vec<Step>,
}

impl Profile {
    /// Constant profile `p(t) = p`.
    pub fn constant(p: f64) -> Self {
        Profile { steps: vec![Step { dur: f64::INFINITY, p }] }
    }

    /// Build from `(duration, processors)` pairs; the last step is
    /// extended to infinity.
    pub fn steps(steps: &[(f64, f64)]) -> Result<Self> {
        if steps.is_empty() {
            bail!("profile needs at least one step");
        }
        for &(d, p) in steps {
            if !(d > 0.0) || !(p > 0.0) {
                bail!("profile steps need positive duration and processors");
            }
        }
        let mut v: Vec<Step> = steps.iter().map(|&(dur, p)| Step { dur, p }).collect();
        v.last_mut().unwrap().dur = f64::INFINITY;
        Ok(Profile { steps: v })
    }

    /// `p(t)`.
    pub fn at(&self, t: f64) -> f64 {
        let mut acc = 0.0;
        for s in &self.steps {
            acc += s.dur;
            if t < acc {
                return s.p;
            }
        }
        self.steps.last().unwrap().p
    }

    /// Is this a constant profile?
    pub fn is_constant(&self) -> bool {
        self.steps.iter().all(|s| s.p == self.steps[0].p)
    }

    /// Max processors over all steps.
    pub fn max_p(&self) -> f64 {
        self.steps.iter().map(|s| s.p).fold(0.0, f64::max)
    }

    /// Min processors over all steps — the constant platform the
    /// `Agreg` ≥ 1-processor guarantee must be proved against when a
    /// step profile varies over time (every instant then has at least
    /// this many processors).
    pub fn min_p(&self) -> f64 {
        self.steps.iter().map(|s| s.p).fold(f64::INFINITY, f64::min)
    }

    /// Time points where `p(t)` changes, strictly increasing.
    pub fn breakpoints(&self) -> Vec<f64> {
        let mut out = Vec::new();
        let mut acc = 0.0;
        for s in &self.steps[..self.steps.len() - 1] {
            acc += s.dur;
            out.push(acc);
        }
        out
    }

    /// θ(t) = ∫₀ᵗ p(x)^α dx — the "speedup time" accumulated by `t`.
    /// A task running with constant ratio `r` performs work
    /// `r^α · (θ(t1) − θ(t0))` over `[t0, t1]` (paper §5, Lemma 5).
    pub fn theta(&self, alpha: f64, t: f64) -> f64 {
        let mut acc = 0.0; // time consumed
        let mut th = 0.0;
        for s in &self.steps {
            let rate = s.p.powf(alpha);
            if t <= acc + s.dur {
                return th + (t - acc) * rate;
            }
            th += s.dur * rate;
            acc += s.dur;
        }
        // unreachable: last dur is infinite
        th
    }

    /// Inverse of [`Profile::theta`]: the wall-clock time at which the
    /// accumulated speedup-time reaches `theta`.
    pub fn theta_inv(&self, alpha: f64, theta: f64) -> f64 {
        let mut acc = 0.0;
        let mut th = 0.0;
        for s in &self.steps {
            let rate = s.p.powf(alpha);
            let step_theta = s.dur * rate;
            if theta <= th + step_theta {
                return acc + (theta - th) / rate;
            }
            th += step_theta;
            acc += s.dur;
        }
        f64::INFINITY
    }

    /// Makespan of a single equivalent task of length `len` starting at
    /// `t = 0` and using the full profile (PM Theorem 6 corollary).
    pub fn completion(&self, alpha: f64, len: f64) -> f64 {
        self.theta_inv(alpha, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_profile_theta_is_linear() {
        let pr = Profile::constant(4.0);
        let a = 0.5;
        assert!((pr.theta(a, 3.0) - 3.0 * 2.0).abs() < 1e-12);
        assert!((pr.theta_inv(a, 6.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn completion_matches_closed_form() {
        // L / p^α
        let pr = Profile::constant(9.0);
        let a = 0.5;
        assert!((pr.completion(a, 12.0) - 12.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn step_profile_integrates_piecewise() {
        // 2 procs for 1s then 8 procs; α = 1/3 → rates 2^(1/3), 2
        let pr = Profile::steps(&[(1.0, 2.0), (1.0, 8.0)]).unwrap();
        let a = 1.0 / 3.0;
        let r1 = 2f64.powf(a);
        assert!((pr.theta(a, 1.0) - r1).abs() < 1e-12);
        assert!((pr.theta(a, 2.0) - (r1 + 2.0)).abs() < 1e-12);
        // inversion round-trips
        for &t in &[0.3, 1.0, 1.7, 5.0] {
            let th = pr.theta(a, t);
            assert!((pr.theta_inv(a, th) - t).abs() < 1e-9);
        }
    }

    #[test]
    fn at_returns_step_values() {
        let pr = Profile::steps(&[(2.0, 3.0), (1.0, 5.0)]).unwrap();
        assert_eq!(pr.at(0.5), 3.0);
        assert_eq!(pr.at(1.99), 3.0);
        assert_eq!(pr.at(2.5), 5.0);
        assert_eq!(pr.at(100.0), 5.0); // last step persists
        assert_eq!(pr.max_p(), 5.0);
        assert_eq!(pr.min_p(), 3.0);
        assert_eq!(pr.breakpoints(), vec![2.0]);
        assert_eq!(Profile::constant(4.0).min_p(), 4.0);
    }

    #[test]
    fn rejects_bad_steps() {
        assert!(Profile::steps(&[]).is_err());
        assert!(Profile::steps(&[(0.0, 2.0)]).is_err());
        assert!(Profile::steps(&[(1.0, -1.0)]).is_err());
    }

    #[test]
    fn constant_detection() {
        assert!(Profile::constant(4.0).is_constant());
        assert!(!Profile::steps(&[(1.0, 2.0), (1.0, 3.0)]).unwrap().is_constant());
    }
}
