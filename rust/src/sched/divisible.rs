//! "Divisible" baseline (paper §7): assume perfect linear speedup,
//! which makes any parallelism pointless — process the tasks
//! sequentially in a topological order, each on the whole platform.
//!
//! Under the true `p^α` model this costs `Σ L_i / p^α` on a constant
//! profile (order-independent), which is what the paper charges it.

use crate::model::{SpGraph, TaskTree};

use super::profile::Profile;
use super::schedule::{Schedule, TaskSpan};

/// Makespan of the Divisible strategy on a tree under `profile`.
pub fn divisible_makespan(total_work: f64, alpha: f64, profile: &Profile) -> f64 {
    profile.theta_inv(alpha, total_work)
}

/// Divisible makespan for a tree under constant `p`.
pub fn divisible_makespan_tree(tree: &TaskTree, alpha: f64, p: f64) -> f64 {
    tree.total_work() / p.powf(alpha)
}

/// Divisible makespan for an SP graph under constant `p`.
pub fn divisible_makespan_sp(g: &SpGraph, alpha: f64, p: f64) -> f64 {
    g.total_work() / p.powf(alpha)
}

/// Materialized Divisible schedule: tasks one after another in
/// leaves-to-root order, full platform each.
pub fn divisible_schedule(tree: &TaskTree, alpha: f64, profile: &Profile) -> Schedule {
    let mut spans = Vec::with_capacity(tree.len());
    let mut theta = 0.0;
    for &v in &tree.topo_up() {
        let len = tree.nodes[v as usize].len;
        let t0 = profile.theta_inv(alpha, theta);
        theta += len;
        let t1 = profile.theta_inv(alpha, theta);
        spans.push(TaskSpan { task: v, start: t0, finish: t1, ratio: 1.0 });
    }
    Schedule::new(spans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::pm::PmSolution;
    use crate::util::{approx_eq, approx_le};

    fn tree() -> TaskTree {
        TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap()
    }

    #[test]
    fn closed_form_constant_profile() {
        let t = tree();
        let pr = Profile::constant(4.0);
        let ms = divisible_makespan(t.total_work(), 0.5, &pr);
        assert!(approx_eq(ms, 15.0 / 2.0, 1e-12));
        assert!(approx_eq(ms, divisible_makespan_tree(&t, 0.5, 4.0), 1e-12));
    }

    #[test]
    fn schedule_is_valid_and_matches_makespan() {
        let t = tree();
        let a = 0.8;
        let pr = Profile::constant(5.0);
        let s = divisible_schedule(&t, a, &pr);
        s.validate(&t, a, &pr, 1e-9).unwrap();
        assert!(approx_eq(s.makespan, divisible_makespan(t.total_work(), a, &pr), 1e-9));
    }

    #[test]
    fn equals_pm_at_alpha_one() {
        // α = 1: tree parallelism buys nothing over sequential full-p
        let t = tree();
        let g = SpGraph::from_tree(&t);
        let p = 6.0;
        assert!(approx_eq(
            divisible_makespan_tree(&t, 1.0, p),
            PmSolution::solve(&g, 1.0).makespan_const(p),
            1e-12
        ));
    }

    #[test]
    fn never_beats_pm() {
        let t = tree();
        let g = SpGraph::from_tree(&t);
        for &a in &[0.5, 0.7, 0.9] {
            let p = 13.0;
            assert!(approx_le(
                PmSolution::solve(&g, a).makespan_const(p),
                divisible_makespan_tree(&t, a, p),
                1e-9
            ));
        }
    }

    #[test]
    fn step_profile_integration() {
        // total work 15, α=1, profile: 2 procs 3s then 6 procs
        let t = tree();
        let pr = Profile::steps(&[(3.0, 2.0), (1.0, 6.0)]).unwrap();
        let ms = divisible_makespan(t.total_work(), 1.0, &pr);
        // work 6 in first 3s, remaining 9 at rate 6 → 1.5s more
        assert!(approx_eq(ms, 4.5, 1e-12));
    }
}
