//! Materialized schedules and validity checking (paper §4).
//!
//! A schedule is a set of piecewise-constant share functions
//! `p_i(t)`, stored as a sorted list of events and, per interval, the
//! allocation `(task, share)` of every running task. Validity is the
//! paper's three conditions: resource constraint, completion of all
//! tasks, and precedence.

use anyhow::Result;

use crate::model::TaskTree;

use super::profile::Profile;

/// Execution span of one task under a schedule with *constant ratio*
/// semantics (the PM schedule form): the task runs on `share(t) =
/// ratio * p(t)` between `start` and `finish`.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpan {
    pub task: u32,
    pub start: f64,
    pub finish: f64,
    /// Constant fraction of the whole platform (`0 < ratio <= 1`).
    pub ratio: f64,
}

/// A materialized schedule: interval events plus per-interval
/// allocations, produced from [`TaskSpan`]s.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-task spans, sorted by start time.
    pub spans: Vec<TaskSpan>,
    /// Total makespan.
    pub makespan: f64,
}

/// Violations detected by [`Schedule::validate`].
///
/// `Display`/`Error` are hand-implemented (the offline crate set has no
/// `thiserror`); messages match the original derive attributes.
#[derive(Debug)]
pub enum ScheduleError {
    Resource { task: u32, t: f64, total: f64 },
    Work { task: u32, done: f64, len: f64 },
    Precedence { task: u32, start: f64, child: u32, finish: f64 },
    Missing { task: u32 },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Resource { task, t, total } => write!(
                f,
                "task {task}: resource constraint violated at t={t}: total ratio {total}"
            ),
            ScheduleError::Work { task, done, len } => {
                write!(f, "task {task}: work {done} != length {len}")
            }
            ScheduleError::Precedence { task, start, child, finish } => write!(
                f,
                "task {task} starts at {start} before child {child} finishes at {finish}"
            ),
            ScheduleError::Missing { task } => write!(f, "task {task} missing from schedule"),
        }
    }
}

impl std::error::Error for ScheduleError {}

impl Schedule {
    pub fn new(mut spans: Vec<TaskSpan>) -> Self {
        spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        let makespan = spans.iter().map(|s| s.finish).fold(0.0, f64::max);
        Schedule { spans, makespan }
    }

    /// Work performed by a span under `profile`:
    /// `ratio^α (θ(finish) − θ(start))`.
    pub fn span_work(span: &TaskSpan, alpha: f64, profile: &Profile) -> f64 {
        span.ratio.powf(alpha)
            * (profile.theta(alpha, span.finish) - profile.theta(alpha, span.start))
    }

    /// Validate the paper's three conditions against `tree` under
    /// `profile` with relative tolerance `tol`.
    pub fn validate(
        &self,
        tree: &TaskTree,
        alpha: f64,
        profile: &Profile,
        tol: f64,
    ) -> Result<(), ScheduleError> {
        let n = tree.len();
        let mut by_task: Vec<Option<&TaskSpan>> = vec![None; n];
        for s in &self.spans {
            by_task[s.task as usize] = Some(s);
        }
        for t in 0..n {
            if by_task[t].is_none() {
                return Err(ScheduleError::Missing { task: t as u32 });
            }
        }

        // 1. Resource constraint: at every span boundary, the sum of
        // ratios of active spans must be <= 1 (+tol). Checking at
        // boundaries suffices for piecewise-constant allocations.
        let mut events: Vec<f64> = self
            .spans
            .iter()
            .flat_map(|s| [s.start, s.finish])
            .collect();
        events.sort_by(|a, b| a.partial_cmp(b).unwrap());
        events.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        for w in events.windows(2) {
            let mid = 0.5 * (w[0] + w[1]);
            let total: f64 = self
                .spans
                .iter()
                .filter(|s| s.start <= mid && mid < s.finish)
                .map(|s| s.ratio)
                .sum();
            if total > 1.0 + tol {
                let offender = self
                    .spans
                    .iter()
                    .find(|s| s.start <= mid && mid < s.finish)
                    .map(|s| s.task)
                    .unwrap_or(0);
                return Err(ScheduleError::Resource { task: offender, t: mid, total });
            }
        }

        // 2. Completion: each task's work equals its length.
        for (t, node) in tree.nodes.iter().enumerate() {
            let span = by_task[t].unwrap();
            let done = Self::span_work(span, alpha, profile);
            let scale = node.len.abs().max(1e-12);
            if (done - node.len).abs() > tol * scale {
                return Err(ScheduleError::Work { task: t as u32, done, len: node.len });
            }
        }

        // 3. Precedence: parents start no earlier than children finish.
        for (t, node) in tree.nodes.iter().enumerate() {
            let span = by_task[t].unwrap();
            for &c in &node.children {
                let cs = by_task[c as usize].unwrap();
                if span.start < cs.finish - tol * cs.finish.abs().max(1e-12) {
                    return Err(ScheduleError::Precedence {
                        task: t as u32,
                        start: span.start,
                        child: c,
                        finish: cs.finish,
                    });
                }
            }
        }
        Ok(())
    }

    /// Peak total ratio across the schedule (diagnostics; 1.0 means the
    /// platform is saturated, as Lemma 2 requires for optimality).
    pub fn peak_utilization(&self) -> f64 {
        let mut events: Vec<f64> = self
            .spans
            .iter()
            .flat_map(|s| [s.start, s.finish])
            .collect();
        events.sort_by(|a, b| a.partial_cmp(b).unwrap());
        events.dedup();
        let mut peak = 0.0f64;
        for w in events.windows(2) {
            let mid = 0.5 * (w[0] + w[1]);
            let total: f64 = self
                .spans
                .iter()
                .filter(|s| s.start <= mid && mid < s.finish)
                .map(|s| s.ratio)
                .sum();
            peak = peak.max(total);
        }
        peak
    }

    /// Per-task constant ratio vector for an `n`-task tree: `r[task]`
    /// is the fraction of the platform the schedule grants that task
    /// (0 for tasks without a span). This is what the malleable
    /// executor turns into integer worker-team sizes
    /// (`exec::TeamPlan`).
    pub fn task_ratios(&self, n: usize) -> Vec<f64> {
        let mut r = vec![0.0; n];
        for s in &self.spans {
            if (s.task as usize) < n {
                r[s.task as usize] = s.ratio;
            }
        }
        r
    }

    /// Minimum share (ratio × p) ever allocated to a task, under a
    /// constant profile — what `Agreg` must push above 1.
    pub fn min_share(&self, p: f64) -> f64 {
        self.spans
            .iter()
            .map(|s| s.ratio * p)
            .fold(f64::INFINITY, f64::min)
    }

    /// Peak of `Σ weight(task)` over concurrently *active* tasks —
    /// with `weight = front_order²` this is the peak dense working set
    /// of a multifrontal run under this schedule (the memory axis the
    /// paper's companion report [23] studies; scheduling for time and
    /// for memory pull in opposite directions, which the ablation
    /// benches quantify).
    pub fn peak_weighted_active(&self, weight: impl Fn(u32) -> f64) -> f64 {
        // sweep events: +w at start, -w at finish
        let mut events: Vec<(f64, f64)> = Vec::with_capacity(2 * self.spans.len());
        for s in &self.spans {
            let w = weight(s.task);
            events.push((s.start, w));
            events.push((s.finish, -w));
        }
        events.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                // process releases before acquisitions at equal times
                .then(a.1.partial_cmp(&b.1).unwrap())
        });
        let mut cur = 0.0f64;
        let mut peak = 0.0f64;
        for (_, dw) in events {
            cur += dw;
            peak = peak.max(cur);
        }
        peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain2() -> TaskTree {
        // 1 -> 0 (leaf 1, then root 0)
        TaskTree::from_parents(&[0, 0], &[2.0, 3.0]).unwrap()
    }

    #[test]
    fn valid_sequential_schedule_passes() {
        let t = chain2();
        let alpha = 0.5;
        let p = 4.0;
        let pr = Profile::constant(p);
        // leaf (task 1, len 3) runs [0, 1.5), root [1.5, 2.5) at ratio 1
        let s = Schedule::new(vec![
            TaskSpan { task: 1, start: 0.0, finish: 3.0 / 2.0, ratio: 1.0 },
            TaskSpan { task: 0, start: 1.5, finish: 2.5, ratio: 1.0 },
        ]);
        s.validate(&t, alpha, &pr, 1e-9).unwrap();
        assert!((s.makespan - 2.5).abs() < 1e-12);
        assert!((s.peak_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn detects_resource_violation() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 1.0, 1.0]).unwrap();
        let pr = Profile::constant(1.0);
        let s = Schedule::new(vec![
            TaskSpan { task: 1, start: 0.0, finish: 1.0, ratio: 0.8 },
            TaskSpan { task: 2, start: 0.0, finish: 1.0, ratio: 0.8 },
            TaskSpan { task: 0, start: 1.0, finish: 2.0, ratio: 1.0 },
        ]);
        assert!(matches!(
            s.validate(&t, 1.0, &pr, 1e-9),
            Err(ScheduleError::Resource { .. })
        ));
    }

    #[test]
    fn detects_wrong_work() {
        let t = chain2();
        let pr = Profile::constant(4.0);
        let s = Schedule::new(vec![
            TaskSpan { task: 1, start: 0.0, finish: 1.0, ratio: 1.0 }, // too short
            TaskSpan { task: 0, start: 1.5, finish: 2.5, ratio: 1.0 },
        ]);
        assert!(matches!(
            s.validate(&t, 0.5, &pr, 1e-9),
            Err(ScheduleError::Work { task: 1, .. })
        ));
    }

    #[test]
    fn detects_precedence_violation() {
        // Construct spans that satisfy the resource and work conditions
        // (both at ratio 0.5, α = 0.5, p = 4 ⇒ speedup √2) but start the
        // parent before the child finishes.
        let t = chain2();
        let pr = Profile::constant(4.0);
        let r2 = 2f64.sqrt();
        let s = Schedule::new(vec![
            TaskSpan { task: 1, start: 0.0, finish: 3.0 / r2, ratio: 0.5 },
            TaskSpan { task: 0, start: 1.0, finish: 1.0 + 2.0 / r2, ratio: 0.5 },
        ]);
        assert!(matches!(
            s.validate(&t, 0.5, &pr, 1e-9),
            Err(ScheduleError::Precedence { .. })
        ));
    }

    #[test]
    fn detects_missing_task() {
        let t = chain2();
        let pr = Profile::constant(4.0);
        let s = Schedule::new(vec![TaskSpan { task: 0, start: 0.0, finish: 1.5, ratio: 1.0 }]);
        assert!(matches!(
            s.validate(&t, 0.5, &pr, 1e-9),
            Err(ScheduleError::Missing { task: 1 })
        ));
    }

    #[test]
    fn peak_weighted_active_tracks_concurrency() {
        // tasks 1,2 run concurrently [0,1); task 0 alone [1,2)
        let s = Schedule::new(vec![
            TaskSpan { task: 1, start: 0.0, finish: 1.0, ratio: 0.5 },
            TaskSpan { task: 2, start: 0.0, finish: 1.0, ratio: 0.5 },
            TaskSpan { task: 0, start: 1.0, finish: 2.0, ratio: 1.0 },
        ]);
        // unit weights: peak concurrency = 2
        assert_eq!(s.peak_weighted_active(|_| 1.0), 2.0);
        // weighted: task 0 heavy but alone
        let w = |t: u32| if t == 0 { 3.0 } else { 1.0 };
        assert_eq!(s.peak_weighted_active(w), 3.0);
        // back-to-back spans at t=1 do not double-count
        let w0 = |t: u32| if t == 0 { 1.5 } else { 1.0 };
        assert_eq!(s.peak_weighted_active(w0), 2.0);
    }

    #[test]
    fn span_work_under_step_profile() {
        // ratio 0.5, α=1: work = 0.5 * ∫p over the span
        let pr = Profile::steps(&[(1.0, 2.0), (1.0, 4.0)]).unwrap();
        let span = TaskSpan { task: 0, start: 0.5, finish: 1.5, ratio: 0.5 };
        let w = Schedule::span_work(&span, 1.0, &pr);
        assert!((w - 0.5 * (0.5 * 2.0 + 0.5 * 4.0)).abs() < 1e-12);
    }
}
