//! Schedulers for trees/SP-graphs of malleable `p^α` tasks.
//!
//! * [`pm`] — the Prasanna–Musicus optimal schedule (paper §5,
//!   Theorem 6): equivalent lengths, constant ratios, event-form
//!   schedule materialization under step processor profiles;
//! * [`proportional`] — Pothen–Sun proportional mapping (the α-unaware
//!   baseline of §7);
//! * [`divisible`] — the perfect-speedup baseline of §7 (sequentialize
//!   the tree, give every task all processors);
//! * [`agreg`] — the §7 `Agreg` rewriting that guarantees every task at
//!   least one processor under PM (incremental engine; the full
//!   re-solve reference survives as `agreg_full_resolve`);
//! * [`workspace`] — reusable solver buffers so repeated solves are
//!   allocation-free (the hot-path contract of EXPERIMENTS.md §Perf);
//! * [`batch`] — thread-pool scheduling of many independent trees (the
//!   multi-tenant front-end);
//! * [`profile`] — step-function processor profiles `p(t)`;
//! * [`schedule`] — materialized schedules + validity checking (the
//!   three conditions of §4).

pub mod agreg;
pub mod batch;
pub mod divisible;
pub mod pm;
pub mod profile;
pub mod proportional;
pub mod schedule;
pub mod workspace;

pub use agreg::{agreg, agreg_full_resolve, AgregStats};
pub use batch::{schedule_batch, BatchConfig, BatchResult};
pub use divisible::divisible_makespan;
pub use pm::{PmSchedule, PmSolution};
pub use profile::Profile;
pub use proportional::{proportional_makespan, proportional_shares};
pub use schedule::{Schedule, ScheduleError, TaskSpan};
pub use workspace::SchedWorkspace;

/// One tree's relative distances (%) of the baselines to PM — the
/// quantity plotted in Figures 13–14: `(Divisible%, Proportional%)`,
/// evaluated on the `Agreg`-rewritten graph as §7 prescribes.
pub fn relative_distances(tree: &crate::model::TaskTree, alpha: f64, p: f64) -> (f64, f64) {
    relative_distances_graph(&crate::model::SpGraph::from_tree(tree), alpha, p)
}

/// [`relative_distances`] over a prebuilt pseudo-tree graph — hoist the
/// tree→SP conversion out of α sweeps (§Perf: ~15% of the Figure-13
/// sweep was redundant conversions).
pub fn relative_distances_graph(g: &crate::model::SpGraph, alpha: f64, p: f64) -> (f64, f64) {
    let (ag, _) = agreg(g, alpha, p);
    let pm = pm::PmSolution::solve(&ag, alpha).makespan_const(p);
    let prop = proportional_makespan(&ag, alpha, p);
    let div = divisible::divisible_makespan_sp(&ag, alpha, p);
    (100.0 * (div - pm) / pm, 100.0 * (prop - pm) / pm)
}

/// Realistic speedup used when evaluating α-unaware strategies (§7):
/// `p^α` for `p >= 1`, linear `p` below one processor (a sub-processor
/// share cannot be super-linear).
pub fn realistic_speedup(share: f64, alpha: f64) -> f64 {
    if share >= 1.0 {
        share.powf(alpha)
    } else {
        share
    }
}

/// Pure model speedup `p^α`.
pub fn model_speedup(share: f64, alpha: f64) -> f64 {
    share.powf(alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realistic_speedup_kinks_at_one() {
        assert_eq!(realistic_speedup(0.5, 0.9), 0.5);
        assert!((realistic_speedup(4.0, 0.5) - 2.0).abs() < 1e-12);
        assert_eq!(realistic_speedup(1.0, 0.3), 1.0);
    }

    #[test]
    fn model_speedup_is_powf() {
        assert!((model_speedup(8.0, 1.0 / 3.0) - 2.0).abs() < 1e-12);
    }
}
