//! Reusable scheduler workspace: allocation-free repeated solves.
//!
//! The scheduler core is called in tight loops everywhere above it —
//! `Agreg` fixpoints, α sweeps, DES cross-checks, the batch front-end,
//! the benches — and a fresh [`PmSolution`] allocates five O(n) arrays
//! per call. [`SchedWorkspace`] owns those arrays (plus the span buffer
//! and the incremental `Agreg` scratch) and resizes them in place, so
//! repeated *solves* and span materializations are allocation-free in
//! the steady state (§Perf in EXPERIMENTS.md). `agreg` reuses its
//! scratch arrays the same way, but producing the rewritten graph
//! itself still costs the input copy and two `normalized()` passes —
//! graph materialization, not solver state.

use crate::model::{SpGraph, TaskTree};

use super::agreg::{AgregScratch, AgregStats};
use super::pm::{self, PmSolution};
use super::profile::Profile;
use super::schedule::TaskSpan;

/// Reusable buffers for the PM solver, span materialization and the
/// incremental `Agreg` engine. Create once per worker thread; every
/// method reuses the grown capacity of previous calls.
#[derive(Debug)]
pub struct SchedWorkspace {
    sol: PmSolution,
    spans: Vec<TaskSpan>,
    agreg: AgregScratch,
    ratios: Vec<f64>,
    /// Pseudo-tree of the most recent sub-forest solve (the node-local
    /// root-set path of the distributed layer). Rebuilding it is graph
    /// materialization, not solver state — the five SoA solver arrays
    /// above stay reused, same contract as [`SchedWorkspace::agreg`].
    forest: SpGraph,
}

impl Default for SchedWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedWorkspace {
    pub fn new() -> Self {
        SchedWorkspace {
            sol: PmSolution::empty(crate::DEFAULT_ALPHA),
            spans: Vec::new(),
            agreg: AgregScratch::default(),
            ratios: Vec::new(),
            forest: SpGraph::leaf(0.0),
        }
    }

    /// Solve the PM allocation for `g` and scatter the leaf ratios back
    /// to task ids (`n_tasks` entries) through the reused per-task
    /// buffer — the DES's PM policy path, allocation-free on reuse.
    /// Values are bit-identical to mapping [`PmSolution::solve`]'s leaf
    /// ratios by hand.
    pub fn pm_task_ratios(&mut self, g: &SpGraph, alpha: f64, n_tasks: usize) -> &[f64] {
        pm::solve_into(g, alpha, &mut self.sol);
        self.ratios.clear();
        self.ratios.resize(n_tasks, 0.0);
        pm::scatter_leaf_ratios(g, &self.sol.ratio, &mut self.ratios);
        &self.ratios
    }

    /// Solve the PM allocation for `g` into the reused buffers. The
    /// returned reference is valid until the next workspace call;
    /// results are bit-identical to [`PmSolution::solve`].
    pub fn solve(&mut self, g: &SpGraph, alpha: f64) -> &PmSolution {
        pm::solve_into(g, alpha, &mut self.sol);
        &self.sol
    }

    /// The solution of the most recent [`SchedWorkspace::solve`].
    pub fn solution(&self) -> &PmSolution {
        &self.sol
    }

    /// Makespan of `g` under a constant profile `p` (solve + closed
    /// form, no allocations on reuse).
    pub fn pm_makespan_const(&mut self, g: &SpGraph, alpha: f64, p: f64) -> f64 {
        self.solve(g, alpha).makespan_const(p)
    }

    /// Solve and materialize per-task spans under `profile` into the
    /// reused span buffer.
    pub fn task_spans(&mut self, g: &SpGraph, alpha: f64, profile: &Profile) -> &[TaskSpan] {
        pm::solve_into(g, alpha, &mut self.sol);
        self.sol.task_spans_into(g, profile, &mut self.spans);
        &self.spans
    }

    /// Incremental `Agreg` (same fixpoint as
    /// [`super::agreg_full_resolve`]) reusing this workspace's scratch
    /// arrays across calls.
    pub fn agreg(&mut self, g: &SpGraph, alpha: f64, p: f64) -> (SpGraph, AgregStats) {
        self.agreg.run(g, alpha, p)
    }

    // --- sub-forest path (distributed platforms, paper §6) ---
    //
    // A node of a distributed platform owns a *root set* of disjoint
    // subtrees rather than the whole tree; these entry points build the
    // forest pseudo-tree (`SpGraph::from_forest`) and run the same
    // allocation-free solver core over it. The classic whole-tree path
    // is exactly `roots == [tree.root]` (bit-identical, see the
    // conservativity property test in `dist_integration.rs`).

    /// Solve the PM allocation over the sub-forest rooted at `roots`
    /// (disjoint subtrees of `tree`, composed in parallel). The graph
    /// is kept in the workspace ([`SchedWorkspace::forest_graph`]); the
    /// solver arrays are reused as in [`SchedWorkspace::solve`].
    pub fn solve_forest(&mut self, tree: &TaskTree, roots: &[u32], alpha: f64) -> &PmSolution {
        self.forest = SpGraph::from_forest(tree, roots);
        pm::solve_into(&self.forest, alpha, &mut self.sol);
        &self.sol
    }

    /// Solve the PM allocation over the sub-forest *induced* by a
    /// membership mask (edges kept when both endpoints are members) —
    /// the node-local view of a distributed mapping. Returns `None`
    /// when no task is a member.
    pub fn solve_induced(
        &mut self,
        tree: &TaskTree,
        member: &[bool],
        alpha: f64,
    ) -> Option<&PmSolution> {
        let g = SpGraph::from_induced(tree, member)?;
        self.forest = g;
        pm::solve_into(&self.forest, alpha, &mut self.sol);
        Some(&self.sol)
    }

    /// The forest pseudo-tree built by the most recent
    /// [`SchedWorkspace::solve_forest`] / [`SchedWorkspace::solve_induced`].
    pub fn forest_graph(&self) -> &SpGraph {
        &self.forest
    }

    /// Makespan of the sub-forest under a constant profile `p` — the
    /// per-node completion time the mapping layer balances.
    pub fn forest_makespan_const(
        &mut self,
        tree: &TaskTree,
        roots: &[u32],
        alpha: f64,
        p: f64,
    ) -> f64 {
        self.solve_forest(tree, roots, alpha).makespan_const(p)
    }

    /// Solve the sub-forest and scatter the leaf ratios back to global
    /// task ids (`n_tasks` entries; tasks outside the forest stay 0) —
    /// the per-node allocation vector the distributed DES replays.
    pub fn forest_task_ratios(
        &mut self,
        tree: &TaskTree,
        roots: &[u32],
        alpha: f64,
        n_tasks: usize,
    ) -> &[f64] {
        self.solve_forest(tree, roots, alpha);
        self.scatter_forest_ratios(n_tasks)
    }

    /// [`SchedWorkspace::forest_task_ratios`] over the *induced*
    /// sub-forest of a membership mask (the distributed DES's per-node
    /// allocation setup). `None` when no task is a member.
    pub fn induced_task_ratios(
        &mut self,
        tree: &TaskTree,
        member: &[bool],
        alpha: f64,
        n_tasks: usize,
    ) -> Option<&[f64]> {
        self.solve_induced(tree, member, alpha)?;
        Some(self.scatter_forest_ratios(n_tasks))
    }

    /// Scatter the current forest solution's leaf ratios to global task
    /// ids through the reused per-task buffer.
    fn scatter_forest_ratios(&mut self, n_tasks: usize) -> &[f64] {
        self.ratios.clear();
        self.ratios.resize(n_tasks, 0.0);
        pm::scatter_leaf_ratios(&self.forest, &self.sol.ratio, &mut self.ratios);
        &self.ratios
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TaskTree;
    use crate::sched::{agreg, Profile};
    use crate::util::approx_eq;

    fn tree(seed: usize) -> TaskTree {
        let n = 40 + seed * 17;
        let parents: Vec<usize> =
            (0..n).map(|i| if i == 0 { 0 } else { (i - 1) / (2 + seed % 3) }).collect();
        let lens: Vec<f64> = (0..n).map(|i| 0.25 + ((i * 7 + seed) % 13) as f64).collect();
        TaskTree::from_parents(&parents, &lens).unwrap()
    }

    #[test]
    fn workspace_solve_matches_one_shot_across_reuse() {
        let mut ws = SchedWorkspace::new();
        for seed in 0..6 {
            let g = SpGraph::from_tree(&tree(seed));
            let alpha = 0.5 + 0.1 * (seed % 5) as f64;
            let got = ws.solve(&g, alpha);
            let want = PmSolution::solve(&g, alpha);
            assert_eq!(got.total_len.to_bits(), want.total_len.to_bits());
            assert_eq!(got.ratio, want.ratio);
            assert_eq!(got.theta_end, want.theta_end);
        }
    }

    #[test]
    fn workspace_spans_match_solution_spans() {
        let mut ws = SchedWorkspace::new();
        let profile = Profile::constant(12.0);
        for seed in 0..4 {
            let g = SpGraph::from_tree(&tree(seed));
            let spans = ws.task_spans(&g, 0.85, &profile).to_vec();
            let want = PmSolution::solve(&g, 0.85).task_spans(&g, &profile);
            assert_eq!(spans.len(), want.len());
            for (a, b) in spans.iter().zip(&want) {
                assert_eq!(a.task, b.task);
                assert_eq!(a.start.to_bits(), b.start.to_bits());
                assert_eq!(a.finish.to_bits(), b.finish.to_bits());
                assert_eq!(a.ratio.to_bits(), b.ratio.to_bits());
            }
        }
    }

    #[test]
    fn workspace_agreg_matches_free_function() {
        let mut ws = SchedWorkspace::new();
        for seed in 0..4 {
            let g = SpGraph::from_tree(&tree(seed));
            let (a, sa) = ws.agreg(&g, 0.9, 4.0);
            let (b, sb) = agreg(&g, 0.9, 4.0);
            assert_eq!(sa, sb);
            assert_eq!(a.normalized().nodes, b.normalized().nodes);
            // and the aggregated graph satisfies the postcondition
            let min = ws.solve(&a, 0.9).min_task_share(&a, 4.0);
            assert!(min >= 1.0 - 1e-6, "min share {min}");
        }
    }

    #[test]
    fn pm_task_ratios_match_one_shot_mapping() {
        let mut ws = SchedWorkspace::new();
        // reuse across trees of different sizes: stale entries must not leak
        for seed in [3usize, 0, 2, 1] {
            let t = tree(seed);
            let g = SpGraph::from_tree(&t);
            let got = ws.pm_task_ratios(&g, 0.8, t.len()).to_vec();
            let sol = PmSolution::solve(&g, 0.8);
            let mut want = vec![0f64; t.len()];
            for &v in g.topo() {
                if let crate::model::SpNode::Leaf { task: Some(tk), .. } = g.nodes[v as usize] {
                    want[tk as usize] = sol.ratio[v as usize];
                }
            }
            assert_eq!(got, want);
        }
    }

    #[test]
    fn solve_forest_single_root_matches_whole_tree_path() {
        let mut ws = SchedWorkspace::new();
        for seed in 0..4 {
            let t = tree(seed);
            let alpha = 0.6 + 0.1 * (seed % 4) as f64;
            let got = ws.solve_forest(&t, &[t.root], alpha);
            let want = PmSolution::solve(&SpGraph::from_tree(&t), alpha);
            assert_eq!(got.total_len.to_bits(), want.total_len.to_bits());
            assert_eq!(got.ratio, want.ratio);
            assert_eq!(got.theta_end, want.theta_end);
            assert_eq!(ws.forest_graph().nodes, SpGraph::from_tree(&t).nodes);
        }
    }

    #[test]
    fn solve_forest_parallel_composes_subtree_lengths() {
        // forest of the root's children == parallel composition of the
        // per-subtree equivalent lengths
        let t = tree(1);
        let roots: Vec<u32> = t.nodes[t.root as usize].children.clone();
        assert!(roots.len() >= 2, "test tree must branch at the root");
        let alpha = 0.8;
        let mut ws = SchedWorkspace::new();
        let total = ws.solve_forest(&t, &roots, alpha).total_len;
        let inv = 1.0 / alpha;
        let want: f64 = roots
            .iter()
            .map(|&r| {
                PmSolution::solve(&SpGraph::from_forest(&t, &[r]), alpha)
                    .total_len
                    .powf(inv)
            })
            .sum::<f64>()
            .powf(alpha);
        assert!(approx_eq(total, want, 1e-12));
    }

    #[test]
    fn forest_task_ratios_scatter_only_forest_tasks() {
        let t = tree(2);
        let roots: Vec<u32> = t.nodes[t.root as usize].children.clone();
        let mut ws = SchedWorkspace::new();
        let ratios = ws.forest_task_ratios(&t, &roots, 0.9, t.len()).to_vec();
        // the root is not part of the forest: its ratio must stay 0,
        // and the forest roots' ratios must sum to 1
        assert_eq!(ratios[t.root as usize], 0.0);
        let sum: f64 = roots.iter().map(|&r| ratios[r as usize]).sum();
        // forest roots are the *last* tasks of their subtrees, each at
        // its subtree's branch ratio; ratios are positive and <= 1
        assert!(sum > 0.0 && sum <= 1.0 + 1e-12);
        for &r in &roots {
            assert!(ratios[r as usize] > 0.0);
        }
    }

    #[test]
    fn solve_induced_matches_forest_on_whole_subtrees() {
        let t = tree(3);
        let roots: Vec<u32> = t.nodes[t.root as usize].children.clone();
        let mut member = vec![true; t.len()];
        member[t.root as usize] = false;
        let mut ws = SchedWorkspace::new();
        let via_induced = ws.solve_induced(&t, &member, 0.85).unwrap().total_len;
        let mut ws2 = SchedWorkspace::new();
        let via_forest = ws2.solve_forest(&t, &roots, 0.85).total_len;
        assert_eq!(via_induced.to_bits(), via_forest.to_bits());
        // nobody home -> None
        assert!(ws.solve_induced(&t, &vec![false; t.len()], 0.85).is_none());
    }

    #[test]
    fn pm_makespan_const_matches() {
        let mut ws = SchedWorkspace::new();
        let g = SpGraph::from_tree(&tree(2));
        let want = PmSolution::solve(&g, 0.9).makespan_const(10.0);
        assert!(approx_eq(ws.pm_makespan_const(&g, 0.9, 10.0), want, 1e-15));
    }
}
