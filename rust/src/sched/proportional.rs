//! Pothen–Sun "proportional mapping" baseline (paper §7, [11]).
//!
//! Processor shares are split among parallel branches proportionally to
//! the branch's **total work** `Σ L_i` — i.e. the allocation an
//! α-unaware runtime would pick (it is exactly the PM allocation for
//! α = 1). Shares are constant per subtree; processors assigned to a
//! finished branch idle until the whole sibling set completes.
//!
//! Following the paper, the evaluation uses the *realistic* speedup:
//! `p^α` for `p >= 1` and linear `p` below one processor (Proportional
//! may allocate sub-processor shares; giving it super-linear speedup
//! there would be unfair in the other direction).

use crate::model::{SpGraph, SpNode};
#[cfg(test)]
use crate::model::TaskTree;

use super::realistic_speedup;
use super::schedule::{Schedule, TaskSpan};

/// Per-SP-node constant shares under proportional mapping with `p`
/// processors.
pub fn proportional_shares(g: &SpGraph, p: f64) -> Vec<f64> {
    let n = g.nodes.len();
    // bottom-up total work
    let mut work = vec![0f64; n];
    for &v in g.topo().iter().rev() {
        let vi = v as usize;
        work[vi] = match &g.nodes[vi] {
            SpNode::Leaf { len, .. } => *len,
            SpNode::Series(c) | SpNode::Parallel(c) => {
                c.iter().map(|&x| work[x as usize]).sum()
            }
        };
    }
    // top-down shares
    let mut share = vec![0f64; n];
    share[g.root as usize] = p;
    for &v in g.topo() {
        let vi = v as usize;
        match &g.nodes[vi] {
            SpNode::Leaf { .. } => {}
            SpNode::Series(c) => {
                for &x in c {
                    share[x as usize] = share[vi];
                }
            }
            SpNode::Parallel(c) => {
                let total: f64 = c.iter().map(|&x| work[x as usize]).sum();
                for &x in c {
                    let xi = x as usize;
                    share[xi] = if total > 0.0 {
                        share[vi] * work[xi] / total
                    } else {
                        share[vi] / c.len() as f64
                    };
                }
            }
        }
    }
    share
}

/// Makespan of proportional mapping on `g` with constant `p` processors
/// and exponent `alpha`, under the realistic speedup.
pub fn proportional_makespan(g: &SpGraph, alpha: f64, p: f64) -> f64 {
    let share = proportional_shares(g, p);
    let n = g.nodes.len();
    let mut dur = vec![0f64; n];
    for &v in g.topo().iter().rev() {
        let vi = v as usize;
        dur[vi] = match &g.nodes[vi] {
            SpNode::Leaf { len, .. } => {
                if *len == 0.0 {
                    0.0
                } else {
                    len / realistic_speedup(share[vi], alpha)
                }
            }
            SpNode::Series(c) => c.iter().map(|&x| dur[x as usize]).sum(),
            SpNode::Parallel(c) => c
                .iter()
                .map(|&x| dur[x as usize])
                .fold(0.0, f64::max),
        };
    }
    dur[g.root as usize]
}

/// Materialized proportional schedule (for the executor / inspection).
/// Spans carry `ratio = share / p`.
pub fn proportional_schedule(g: &SpGraph, alpha: f64, p: f64) -> Schedule {
    let share = proportional_shares(g, p);
    let n = g.nodes.len();
    let mut dur = vec![0f64; n];
    for &v in g.topo().iter().rev() {
        let vi = v as usize;
        dur[vi] = match &g.nodes[vi] {
            SpNode::Leaf { len, .. } => {
                if *len == 0.0 {
                    0.0
                } else {
                    len / realistic_speedup(share[vi], alpha)
                }
            }
            SpNode::Series(c) => c.iter().map(|&x| dur[x as usize]).sum(),
            SpNode::Parallel(c) => c
                .iter()
                .map(|&x| dur[x as usize])
                .fold(0.0, f64::max),
        };
    }
    let mut start = vec![0f64; n];
    for &v in g.topo() {
        let vi = v as usize;
        match &g.nodes[vi] {
            SpNode::Leaf { .. } => {}
            SpNode::Series(c) => {
                let mut acc = start[vi];
                for &x in c {
                    start[x as usize] = acc;
                    acc += dur[x as usize];
                }
            }
            SpNode::Parallel(c) => {
                for &x in c {
                    start[x as usize] = start[vi];
                }
            }
        }
    }
    let mut spans = Vec::with_capacity(g.num_tasks());
    for &v in g.topo() {
        let vi = v as usize;
        if let SpNode::Leaf { task, .. } = g.nodes[vi] {
            spans.push(TaskSpan {
                task: task.unwrap_or(v),
                start: start[vi],
                finish: start[vi] + dur[vi],
                ratio: share[vi] / p,
            });
        }
    }
    Schedule::new(spans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::pm::PmSolution;
    use crate::util::{approx_eq, approx_le};

    fn tree() -> TaskTree {
        TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap()
    }

    #[test]
    fn shares_split_by_work() {
        let g = SpGraph::parallel(SpGraph::leaf(1.0), SpGraph::leaf(3.0));
        let s = proportional_shares(&g, 8.0);
        let mut leaf_shares: Vec<(f64, f64)> = g
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                SpNode::Leaf { len, .. } => Some((*len, s[i])),
                _ => None,
            })
            .collect();
        leaf_shares.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert!(approx_eq(leaf_shares[0].1, 2.0, 1e-12));
        assert!(approx_eq(leaf_shares[1].1, 6.0, 1e-12));
    }

    #[test]
    fn matches_pm_at_alpha_one() {
        let g = SpGraph::from_tree(&tree());
        let p = 7.0;
        let ms_prop = proportional_makespan(&g, 1.0, p);
        let ms_pm = PmSolution::solve(&g, 1.0).makespan_const(p);
        assert!(approx_eq(ms_prop, ms_pm, 1e-9));
    }

    #[test]
    fn never_beats_pm_for_alpha_below_one() {
        let g = SpGraph::from_tree(&tree());
        for &a in &[0.5, 0.7, 0.9, 0.99] {
            // use p large enough that all shares stay >= 1 so the
            // realistic evaluation does not penalize Proportional
            let p = 40.0;
            let ms_prop = proportional_makespan(&g, a, p);
            let ms_pm = PmSolution::solve(&g, a).makespan_const(p);
            assert!(
                approx_le(ms_pm, ms_prop, 1e-9),
                "alpha={a}: pm={ms_pm} prop={ms_prop}"
            );
        }
    }

    #[test]
    fn sequential_chain_is_alpha_independent_of_mapping() {
        // chain: both strategies give everything the full p
        let t = TaskTree::from_parents(&[0, 0], &[2.0, 3.0]).unwrap();
        let g = SpGraph::from_tree(&t);
        let a = 0.8;
        let p = 4.0;
        let ms = proportional_makespan(&g, a, p);
        assert!(approx_eq(ms, 5.0 / p.powf(a), 1e-12));
    }

    #[test]
    fn schedule_spans_respect_structure() {
        let t = tree();
        let g = SpGraph::from_tree(&t);
        let s = proportional_schedule(&g, 0.9, 10.0);
        let span = |id: u32| *s.spans.iter().find(|x| x.task == id).unwrap();
        // leaves 3,4 start at 0; root starts after everything
        assert_eq!(span(3).start, 0.0);
        assert_eq!(span(4).start, 0.0);
        assert!(span(0).start >= span(1).finish - 1e-12);
        assert!(span(0).start >= span(2).finish - 1e-12);
        assert!(approx_eq(s.makespan, proportional_makespan(&g, 0.9, 10.0), 1e-12));
    }

    #[test]
    fn sub_processor_share_is_linear_penalized() {
        // two very unequal branches on p=2: small branch gets < 1 proc
        let g = SpGraph::parallel(SpGraph::leaf(0.1), SpGraph::leaf(10.0));
        let p = 2.0;
        let a = 0.5;
        let shares = proportional_shares(&g, p);
        let small_share = shares
            .iter()
            .zip(&g.nodes)
            .filter_map(|(s, n)| match n {
                SpNode::Leaf { len, .. } if *len < 1.0 => Some(*s),
                _ => None,
            })
            .next()
            .unwrap();
        assert!(small_share < 1.0);
        // duration of the small task uses linear speedup
        let ms = proportional_makespan(&g, a, p);
        let big_dur = 10.0 / (p * 10.0 / 10.1).powf(a);
        assert!(ms >= big_dur - 1e-12);
    }
}
