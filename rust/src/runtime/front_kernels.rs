//! Typed front-factorization entry points over the raw runtime.
//!
//! Real fronts have arbitrary `(n, k)`; the artifact menu is fixed. This
//! module embeds a front into the smallest fitting variant with
//! *identity padding* — extra rows/columns that carry `1` on the
//! diagonal and `0` elsewhere. For Cholesky this is exact:
//! `chol(diag(A, I)) = diag(chol(A), I)` and the Schur complement of a
//! decoupled identity block is untouched. The padding property is
//! verified bit-for-bit in `python/tests/test_model.py` and re-checked
//! here against the pure-Rust fallback in `frontal::dense`.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::client::Runtime;

/// Dense results of a partial factorization of an `n x n` front with
/// `k` eliminated columns. Row-major buffers.
#[derive(Debug, Clone)]
pub struct PartialResult {
    /// `k x k` lower Cholesky factor of the pivot block.
    pub l11: Vec<f32>,
    /// `(n-k) x k` panel factor.
    pub l21: Vec<f32>,
    /// `(n-k) x (n-k)` Schur complement.
    pub schur: Vec<f32>,
    pub n: usize,
    pub k: usize,
}

/// High-level front factorization API used by the multifrontal driver
/// and the malleable executor.
pub struct FrontKernels {
    rt: Arc<Runtime>,
}

impl FrontKernels {
    pub fn new(rt: Arc<Runtime>) -> Self {
        FrontKernels { rt }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Largest front order the artifact menu supports.
    pub fn max_front(&self) -> usize {
        self.rt.manifest.max_front()
    }

    /// Partial factorization (`0 < k < n`) via the padded PJRT kernel.
    pub fn partial_factor(&self, front: &[f32], n: usize, k: usize) -> Result<PartialResult> {
        anyhow::ensure!(k > 0 && k < n, "partial_factor needs 0 < k < n, got ({n}, {k})");
        anyhow::ensure!(front.len() == n * n, "front buffer mismatch");
        let spec = self
            .rt
            .manifest
            .pick_partial(n, k)
            .with_context(|| format!("no partial variant fits front (n={n}, k={k})"))?
            .clone();
        let (pn, pk) = (spec.n, spec.k);
        let m = n - k; // real trailing size
        // Embed: [0,k) real pivot, [k,pk) identity, [pk,pk+m) real trailing,
        // [pk+m,pn) identity.
        let mut padded = vec![0f32; pn * pn];
        for i in 0..pn {
            padded[i * pn + i] = 1.0;
        }
        let map = |i: usize| if i < k { i } else { pk + (i - k) };
        for i in 0..n {
            let pi = map(i);
            for j in 0..n {
                padded[pi * pn + map(j)] = front[i * n + j];
            }
        }
        let kernel = self.rt.kernel(&spec)?;
        let out = kernel.run_f32(&padded)?;
        anyhow::ensure!(out.len() == 3, "partial variant returned {} outputs", out.len());
        // Extract the real sub-blocks.
        let (pl11, pl21, ps) = (&out[0], &out[1], &out[2]);
        let pm = pn - pk;
        let mut l11 = vec![0f32; k * k];
        for i in 0..k {
            l11[i * k..(i + 1) * k].copy_from_slice(&pl11[i * pk..i * pk + k]);
        }
        let mut l21 = vec![0f32; m * k];
        for i in 0..m {
            l21[i * k..(i + 1) * k].copy_from_slice(&pl21[i * pk..i * pk + k]);
        }
        let mut schur = vec![0f32; m * m];
        for i in 0..m {
            schur[i * m..(i + 1) * m].copy_from_slice(&ps[i * pm..i * pm + m]);
        }
        Ok(PartialResult { l11, l21, schur, n, k })
    }

    /// Full factorization (`k == n`): returns the `n x n` lower factor.
    pub fn full_factor(&self, front: &[f32], n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(front.len() == n * n, "front buffer mismatch");
        let spec = self
            .rt
            .manifest
            .pick_full(n)
            .with_context(|| format!("no full variant fits front (n={n})"))?
            .clone();
        let pn = spec.n;
        let mut padded = vec![0f32; pn * pn];
        for i in 0..pn {
            padded[i * pn + i] = 1.0;
        }
        for i in 0..n {
            padded[i * pn..i * pn + n].copy_from_slice(&front[i * n..(i + 1) * n]);
        }
        let kernel = self.rt.kernel(&spec)?;
        let out = kernel.run_f32(&padded)?;
        anyhow::ensure!(out.len() == 1, "full variant returned {} outputs", out.len());
        let pl = &out[0];
        let mut l = vec![0f32; n * n];
        for i in 0..n {
            l[i * n..(i + 1) * n].copy_from_slice(&pl[i * pn..i * pn + n]);
        }
        Ok(l)
    }
}
