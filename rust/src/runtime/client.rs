//! PJRT client wrapper and per-variant executable cache.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifact::{ArtifactSpec, Manifest};

/// A compiled PJRT executable for one artifact variant.
pub struct CompiledKernel {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledKernel {
    /// Execute on a single `f32[n, n]` input; returns the flattened
    /// output tuple as row-major `Vec<f32>` buffers.
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let n = self.spec.n;
        anyhow::ensure!(
            input.len() == n * n,
            "variant {} expects {}x{} input, got {} elements",
            self.spec.name,
            n,
            n,
            input.len()
        );
        let lit = xla::Literal::vec1(input).reshape(&[n as i64, n as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Owns the PJRT client and the executable cache (compile-once per
/// variant, thread-safe interior mutability so the executor's worker
/// crew can share one `Runtime`).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn cpu(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Human-readable platform string (for logs / `--version`).
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Get (compiling on first use) the executable for `spec`.
    pub fn kernel(&self, spec: &ArtifactSpec) -> Result<std::sync::Arc<CompiledKernel>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(k) = cache.get(&spec.name) {
                return Ok(k.clone());
            }
        }
        // Compile outside the lock: compilation is seconds, execution is
        // micro/milliseconds; do not serialize unrelated variants.
        let proto = xla::HloModuleProto::from_text_file(&spec.path).with_context(|| {
            format!("parsing HLO text {}", spec.path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling variant {}", spec.name))?;
        let kernel = std::sync::Arc::new(CompiledKernel {
            spec: spec.clone(),
            exe,
        });
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(spec.name.clone()).or_insert(kernel).clone())
    }

    /// Eagerly compile every variant in the manifest (warm-up).
    pub fn warm_up(&self) -> Result<usize> {
        let specs = self.manifest.specs.clone();
        for spec in &specs {
            self.kernel(spec)?;
        }
        Ok(specs.len())
    }
}
