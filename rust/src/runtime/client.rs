//! PJRT client wrapper and per-variant executable cache.
//!
//! The real implementation needs the external `xla` crate, which the
//! offline build cannot fetch; it is therefore gated behind the `pjrt`
//! cargo feature (enabling it additionally requires adding `xla` to
//! `[dependencies]`). Without the feature this module compiles an
//! API-compatible stub whose constructor reports the missing backend —
//! all PJRT-path tests and commands skip or fail gracefully at runtime,
//! and the rest of the crate (the scheduler stack, the pure-Rust
//! numeric backend) is unaffected.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::Result;

use super::artifact::{ArtifactSpec, Manifest};

/// A compiled PJRT executable for one artifact variant.
pub struct CompiledKernel {
    pub spec: ArtifactSpec,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledKernel {
    /// Execute on a single `f32[n, n]` input; returns the flattened
    /// output tuple as row-major `Vec<f32>` buffers.
    #[cfg(feature = "pjrt")]
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let n = self.spec.n;
        anyhow::ensure!(
            input.len() == n * n,
            "variant {} expects {}x{} input, got {} elements",
            self.spec.name,
            n,
            n,
            input.len()
        );
        let lit = xla::Literal::vec1(input).reshape(&[n as i64, n as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Stub: unreachable in practice (the stub [`Runtime`] cannot be
    /// constructed), kept so callers compile identically.
    #[cfg(not(feature = "pjrt"))]
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<Vec<f32>>> {
        let n = self.spec.n;
        anyhow::ensure!(
            input.len() == n * n,
            "variant {} expects {}x{} input, got {} elements",
            self.spec.name,
            n,
            n,
            input.len()
        );
        anyhow::bail!("malltree was built without the `pjrt` feature")
    }
}

/// Owns the PJRT client and the executable cache (compile-once per
/// variant, thread-safe interior mutability so the executor's worker
/// crew can share one `Runtime`).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    #[cfg(feature = "pjrt")]
    pub fn cpu(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Stub constructor: always errors (the `xla` crate is absent).
    #[cfg(not(feature = "pjrt"))]
    pub fn cpu(dir: &Path) -> Result<Self> {
        // Validate the manifest anyway so configuration errors surface
        // even in stub builds.
        let manifest = Manifest::load(dir)?;
        let _ = &manifest;
        anyhow::bail!(
            "malltree was built without the `pjrt` feature; rebuild with \
             `--features pjrt` (requires the `xla` crate) to use the PJRT backend"
        )
    }

    /// Human-readable platform string (for logs / `--version`).
    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Stub platform string.
    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "pjrt-disabled".to_string()
    }

    /// Get (compiling on first use) the executable for `spec`.
    #[cfg(feature = "pjrt")]
    pub fn kernel(&self, spec: &ArtifactSpec) -> Result<std::sync::Arc<CompiledKernel>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(k) = cache.get(&spec.name) {
                return Ok(k.clone());
            }
        }
        // Compile outside the lock: compilation is seconds, execution is
        // micro/milliseconds; do not serialize unrelated variants.
        let proto = xla::HloModuleProto::from_text_file(&spec.path).with_context(|| {
            format!("parsing HLO text {}", spec.path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling variant {}", spec.name))?;
        let kernel = std::sync::Arc::new(CompiledKernel {
            spec: spec.clone(),
            exe,
        });
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(spec.name.clone()).or_insert(kernel).clone())
    }

    /// Stub: no compiler available.
    #[cfg(not(feature = "pjrt"))]
    pub fn kernel(&self, spec: &ArtifactSpec) -> Result<std::sync::Arc<CompiledKernel>> {
        let _ = self.cache.lock().unwrap();
        anyhow::bail!(
            "cannot compile variant {}: malltree was built without the `pjrt` feature",
            spec.name
        )
    }

    /// Eagerly compile every variant in the manifest (warm-up).
    pub fn warm_up(&self) -> Result<usize> {
        let specs = self.manifest.specs.clone();
        for spec in &specs {
            self.kernel(spec)?;
        }
        Ok(specs.len())
    }
}
