//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. The build-time
//! Python layer (`python/compile/aot.py`) lowers the L2 JAX model (which
//! calls the L1 Pallas kernels) to **HLO text**; here we parse that text
//! with [`xla::HloModuleProto::from_text_file`], compile one executable
//! per variant on the PJRT CPU client, and cache it for the lifetime of
//! the process. Python is never on the request path.
//!
//! Interchange is text rather than serialized protos because jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see DESIGN.md §3).

mod artifact;
mod client;
mod front_kernels;

pub use artifact::{ArtifactKind, ArtifactSpec, Manifest};
pub use client::{CompiledKernel, Runtime};
pub use front_kernels::{FrontKernels, PartialResult};
