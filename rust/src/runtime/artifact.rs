//! Artifact manifest parsing.
//!
//! `artifacts/manifest.txt` is written by `python/compile/aot.py` in a
//! dependency-free line format: `name key=value key=value ...`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// What a compiled variant computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Partial factorization: eliminate `k < n` leading columns,
    /// outputs `(L11, L21, S)`.
    Partial,
    /// Full factorization (`k == n`), single output `L`.
    Full,
}

/// One AOT-compiled variant of the frontal factorization model.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Variant name, e.g. `partial_n64_k32`.
    pub name: String,
    pub kind: ArtifactKind,
    /// Front order (the HLO input is `f32[n, n]`).
    pub n: usize,
    /// Eliminated columns (`k == n` for `Full`).
    pub k: usize,
    /// Pallas tile edge the kernel was built with.
    pub tile: usize,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
    /// Path to the `.hlo.txt` file.
    pub path: PathBuf,
}

/// Parsed `manifest.txt`: the menu of compiled variants.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub specs: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; artifact paths are resolved relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut specs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let name = it
                .next()
                .with_context(|| format!("manifest line {}: empty", lineno + 1))?
                .to_string();
            let mut kv = BTreeMap::new();
            for tok in it {
                let (key, val) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {lineno}: bad token {tok}"))?;
                kv.insert(key.to_string(), val.to_string());
            }
            let get = |key: &str| -> Result<usize> {
                kv.get(key)
                    .with_context(|| format!("manifest {name}: missing {key}"))?
                    .parse::<usize>()
                    .with_context(|| format!("manifest {name}: bad {key}"))
            };
            let kind = match kv.get("kind").map(|s| s.as_str()) {
                Some("partial") => ArtifactKind::Partial,
                Some("full") => ArtifactKind::Full,
                other => bail!("manifest {name}: bad kind {other:?}"),
            };
            let (n, k, tile, outputs) = (get("n")?, get("k")?, get("tile")?, get("outputs")?);
            specs.push(ArtifactSpec {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                kind,
                n,
                k,
                tile,
                outputs,
            });
        }
        if specs.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { specs })
    }

    /// Smallest `Partial` variant with `n >= front_n` and `k >= front_k`
    /// (identity padding makes oversizing exact; see DESIGN.md S12).
    pub fn pick_partial(&self, front_n: usize, front_k: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| {
                s.kind == ArtifactKind::Partial
                    && s.k >= front_k
                    // real trailing part must fit beside the padded pivot
                    && s.n - s.k >= front_n - front_k
            })
            .min_by_key(|s| s.n)
    }

    /// Smallest `Full` variant with `n >= front_n`.
    pub fn pick_full(&self, front_n: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.kind == ArtifactKind::Full && s.n >= front_n)
            .min_by_key(|s| s.n)
    }

    /// Largest front order any variant accepts.
    pub fn max_front(&self) -> usize {
        self.specs.iter().map(|s| s.n).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
partial_n32_k16 kind=partial n=32 k=16 tile=32 outputs=3
partial_n64_k32 kind=partial n=64 k=32 tile=32 outputs=3
full_n32 kind=full n=32 k=32 tile=32 outputs=1
full_n64 kind=full n=64 k=64 tile=32 outputs=1
";

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_all_lines() {
        let m = manifest();
        assert_eq!(m.specs.len(), 4);
        assert_eq!(m.specs[0].name, "partial_n32_k16");
        assert_eq!(m.specs[0].kind, ArtifactKind::Partial);
        assert_eq!(m.specs[0].n, 32);
        assert_eq!(m.specs[0].k, 16);
        assert_eq!(m.specs[3].kind, ArtifactKind::Full);
    }

    #[test]
    fn paths_resolved_against_dir() {
        let m = manifest();
        assert_eq!(
            m.specs[0].path,
            Path::new("/tmp/a/partial_n32_k16.hlo.txt")
        );
    }

    #[test]
    fn pick_partial_prefers_smallest_fit() {
        let m = manifest();
        assert_eq!(m.pick_partial(20, 10).unwrap().name, "partial_n32_k16");
        assert_eq!(m.pick_partial(40, 20).unwrap().name, "partial_n64_k32");
        // k fits in 16 but trailing 30 does not fit in 32-16
        assert_eq!(m.pick_partial(40, 10).unwrap().name, "partial_n64_k32");
        assert!(m.pick_partial(200, 10).is_none());
    }

    #[test]
    fn pick_full_prefers_smallest_fit() {
        let m = manifest();
        assert_eq!(m.pick_full(17).unwrap().name, "full_n32");
        assert_eq!(m.pick_full(33).unwrap().name, "full_n64");
        assert!(m.pick_full(65).is_none());
    }

    #[test]
    fn rejects_bad_kind() {
        assert!(Manifest::parse("x kind=weird n=1 k=1 tile=1 outputs=1", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Manifest::parse("# nothing\n", Path::new(".")).is_err());
    }

    #[test]
    fn max_front() {
        assert_eq!(manifest().max_front(), 64);
    }
}
